"""GoRouting (§4.4, Alg. 2): gain-oriented, capability-aware global router.

The router keeps lightweight per-instance state (event-driven prefill queue
``Q_pre`` + decode counter ``n_d``, periodically refreshed free blocks
``b_f``) with timestamp staleness compensation, and dispatches each request
to maximize *incremental gain* while reserving capacity on lightly loaded
instances for future long / high-priority requests (the anti-over-balancing
dual-threshold rule of Fig. 10).

Baselines: Min-Load and Round-Robin.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional

from .estimator import BatchLatencyEstimator
from .prefix import usable_prefix
from .request import Request


# replica-originated events a frontend can learn about late (window
# boundaries / heartbeats) — see InstanceState.apply_event
EV_PREFILL_DONE, EV_FINISHED = 0, 1


@dataclass
class QueuedStub:
    """Router-side view of one in-flight prefill request."""
    rid: int
    arrival: float
    priority: int
    weight: float
    prompt_len: int
    ttft_deadline: float         # absolute
    exec: float                  # estimated remaining prefill time


@dataclass
class InstanceState:
    """Router-side state for one engine instance (§4.4 monitoring)."""
    iid: int
    pre_queue: dict[int, QueuedStub] = field(default_factory=dict)
    n_d: int = 0                  # ongoing decode requests
    b_f: int = 0                  # free KV blocks (periodic report)
    total_blocks: int = 1
    prefill_len_total: int = 0    # L_pre for Eq. (11)
    ts: float = 0.0               # timestamp of last queue mutation
    speed: float = 1.0            # EWMA throughput factor (straggler aware)
    alive: bool = True
    role: str = "coloc"           # "coloc" | "prefill" | "decode"
    # decode-capacity blocks promised to in-flight prefill legs (disagg):
    # counted against b_f when picking a decode target so concurrent
    # admissions cannot oversubscribe a replica's block budget
    reserved_blocks: int = 0

    @property
    def effective_free(self) -> int:
        """Reported free blocks net of outstanding reservations."""
        return self.b_f - self.reserved_blocks

    def reserve(self, n: int) -> None:
        self.reserved_blocks += n

    def unreserve(self, n: int) -> None:
        self.reserved_blocks = max(0, self.reserved_blocks - n)

    # --- event-driven updates -----------------------------------------
    def on_dispatch(self, stub: QueuedStub, now: float) -> None:
        if not self.pre_queue:
            self.ts = now
        self.pre_queue[stub.rid] = stub
        self.prefill_len_total += stub.prompt_len

    def on_prefill_done(self, rid: int, now: float) -> None:
        stub = self.pre_queue.pop(rid, None)
        if stub is not None:
            self.prefill_len_total -= stub.prompt_len
            self.n_d += 1
        self.ts = now

    def on_prefill_exported(self, rid: int, now: float) -> None:
        """Prefill-role variant of ``on_prefill_done``: the request leaves
        this replica at handoff, so the decode counter stays untouched
        (the decode replica's ``n_d`` is bumped at adoption instead)."""
        stub = self.pre_queue.pop(rid, None)
        if stub is not None:
            self.prefill_len_total -= stub.prompt_len
        self.ts = now

    def on_finished(self, rid: int) -> None:
        stub = self.pre_queue.pop(rid, None)
        if stub is not None:
            # finished without ever reporting prefill-done here (e.g. a
            # failover-resumed request whose first token predates this
            # instance): clear the stub; n_d was never incremented.
            self.prefill_len_total -= stub.prompt_len
            return
        self.n_d = max(0, self.n_d - 1)

    def apply_event(self, kind: int, rid: int, t: float) -> None:
        """Apply one replica-originated event delivered late — the
        stale-view update path.  The live frontend and the sharded
        replay both learn about replica progress in delayed batches
        (heartbeats / window-boundary ack columns), not at the instant
        it happens; ``t`` is the ORIGINAL event time, so the ``ts``
        staleness compensation in ``queue_exec_total`` keeps measuring
        real elapsed progress, not transport lag."""
        if kind == EV_PREFILL_DONE:
            self.on_prefill_done(rid, t)
        elif kind == EV_FINISHED:
            self.on_finished(rid)
        else:                                           # pragma: no cover
            raise ValueError(f"unknown replica event kind {kind}")

    def queue_exec_total(self, now: float) -> float:
        """Σ exec over Q_pre with staleness compensation: subtract elapsed
        time since the last mutation (prefill progress the events missed)."""
        tot = sum(s.exec for s in self.pre_queue.values())
        if self.pre_queue:
            tot = max(0.0, tot - max(0.0, now - self.ts))
        return tot / max(self.speed, 1e-6)


def decode_need_blocks(req: Request, block_size: int) -> int:
    """Device blocks a decode replica must hold to adopt this request's
    KV at handoff — sized from the handoff extent ``needed_context`` ==
    prompt_len + max(0, generated-1) (exact for fresh admissions AND
    failover re-admissions; never reads the output-length oracle)."""
    ctx = req.prompt_len + max(0, req.generated - 1)
    return -(-ctx // block_size)


def pick_decode_target(decode_pool: list[InstanceState], req: Request,
                       block_size: int) -> Optional[int]:
    """Alg. 2 line 19, reservation-aware: prefer the decode replica with
    the most free blocks NET of outstanding reservations, among those
    that can actually hold the handoff KV; fall back to max effective
    free when none fits (admission control rejects upstream)."""
    d_live = [d for d in decode_pool if d.alive]
    if not d_live:
        return None
    need = decode_need_blocks(req, block_size)
    fits = [d for d in d_live if d.effective_free >= need]
    return max(fits or d_live, key=lambda d: d.effective_free).iid


@dataclass
class RouterConfig:
    alpha: float = 0.7            # candidate-set slack  C={Δ_p >= α·Δ_max}
    mu: float = 0.25              # light-load threshold (× TTFT_SLO)
    lam: float = 0.8              # heavy-load threshold (× TTFT_SLO)
    pd_mode: str = "coloc"        # "coloc" | "disagg"
    tpot_guard: float = 0.8       # coloc: exclude instance if t̂_d nears TPOT
    hedge_high_priority: bool = False   # straggler mitigation (beyond-paper)
    # weight on prefill work saved by a prefix-cache hit when comparing
    # instance load.  > 1 because a hit's savings recur: the prefix stays
    # warm for future repeats and shared blocks spare pool pressure, so
    # strict completion-time greedy (== 1) under-values affinity.
    affinity_bonus: float = 2.0


class GoRouting:
    name = "gorouting"

    def __init__(self, est: BatchLatencyEstimator, cfg: RouterConfig,
                 sort_key: Optional[Callable] = None):
        self.est = est
        self.cfg = cfg
        # mirror of the local scheduler's queue ordering; default: EDF-ish
        self.sort_key = sort_key or (lambda s, now: s.ttft_deadline)

    # ------------------------------------------------------------------
    def _decode_overhead(self, inst: InstanceState, block_size: int) -> float:
        """t̂_d(n_d), Eq. (10)–(11): estimated decode time riding along each
        co-located batch, from the block-occupancy estimate of decode KV."""
        if self.cfg.pd_mode != "coloc" or inst.n_d == 0:
            return 0.0
        used = inst.total_blocks - inst.b_f
        l_kv_d = max(0, used - inst.prefill_len_total // block_size) * block_size
        return self.est.a_d * l_kv_d + self.est.b_d * inst.n_d

    def _exec_schedule(self, inst: InstanceState, now: float,
                       extra: Optional[QueuedStub], block_size: int,
                       ) -> tuple[float, dict[int, float]]:
        """EstimateExec for every queued request on ``inst`` (+``extra``).

        Returns (total drain time, {rid: completion offset}).  Uses the
        conservative φ-style scaling with t_budget = min TPOT_SLO (App. A)
        plus the coloc decode term per batch round.
        """
        stubs = list(inst.pre_queue.values())
        if extra is not None:
            stubs = stubs + [extra]
        stubs.sort(key=lambda s: self.sort_key(s, now))
        t_c = self.est.t_c
        dec = self._decode_overhead(inst, block_size)
        # φ-scaling: each unit of prefill work inflates by budget/(budget-t_c)
        # — approximated by adding (t_c + decode term) per round where a
        # round carries ~t_budget of prefill work.
        acc = 0.0
        stale = max(0.0, now - inst.ts) if inst.pre_queue else 0.0
        out: dict[int, float] = {}
        for s in stubs:
            acc += s.exec / max(inst.speed, 1e-6) + t_c + dec
            out[s.rid] = acc
        total = max(0.0, acc - stale)
        for k in out:
            out[k] = max(0.0, out[k] - stale)
        return total, out

    def _gain(self, inst: InstanceState, now: float,
              extra: Optional[QueuedStub], block_size: int) -> float:
        """EstimateGain (App. A): Σ w_r(1)·1[exec ≤ remaining TTFT budget]."""
        _, completion = self._exec_schedule(inst, now, extra, block_size)
        stubs = {s.rid: s for s in inst.pre_queue.values()}
        if extra is not None:
            stubs[extra.rid] = extra
        g = 0.0
        for rid, done in completion.items():
            s = stubs[rid]
            if now + done <= s.ttft_deadline:
                g += s.weight
        return g

    # ------------------------------------------------------------------
    def select(self, req: Request, prefill_pool: list[InstanceState],
               decode_pool: Optional[list[InstanceState]], now: float,
               block_size: int = 16, exec_est: Optional[float] = None,
               affinity: Optional[dict[int, int]] = None,
               ) -> tuple[Optional[int], Optional[int]]:
        """Alg. 2: returns (prefill_instance, decode_instance) ids.

        ``affinity``: optional {iid: cached prefix tokens} from the prefix
        registry/caches — an instance already holding the request's prefix
        prefills only the uncached suffix, so its per-instance exec
        estimate (and hence its incremental gain) improves, and ties in
        the reservation rule break toward the prefix holder.
        """
        live = [p for p in prefill_pool if p.alive]
        if not live:
            return None, None
        if exec_est is None:
            exec_est = self.est.prefill_time(req.prompt_len)

        def exec_for(iid: int) -> float:
            cached = (affinity or {}).get(iid, 0)
            if cached <= 0:
                return exec_est
            cached = usable_prefix(cached, req.prompt_len, block_size)
            return self.est.prefill_time_cached(req.prompt_len, cached)

        def stub_for(iid: int) -> QueuedStub:
            return QueuedStub(req.rid, now, req.priority, req.weight,
                              req.prompt_len, req.arrival + req.slo.ttft,
                              exec_for(iid))

        # prefill work saved by landing on each instance's cached prefix,
        # weighted by the recurrence bonus (see RouterConfig.affinity_bonus)
        save = {p.iid: self.cfg.affinity_bonus
                * max(0.0, exec_est - exec_for(p.iid)) for p in live}

        # lines 2-6: incremental gain per instance
        deltas: dict[int, float] = {}
        for p in live:
            pre = self._gain(p, now, None, block_size)
            post = self._gain(p, now, stub_for(p.iid), block_size)
            deltas[p.iid] = post - pre
        d_max = max(deltas.values())

        # coloc decode-latency guard: drop instances whose decode term would
        # blow the TPOT SLO once the queued prefills also enter decode.
        def tpot_ok(p: InstanceState) -> bool:
            if self.cfg.pd_mode != "coloc":
                return True
            t_d = self.est.a_d * 0 + self.est.b_d * (p.n_d + len(p.pre_queue))
            return t_d + self._decode_overhead(p, block_size) \
                <= self.cfg.tpot_guard * req.slo.tpot

        # line 7: candidate set
        cand = [p for p in live
                if deltas[p.iid] >= self.cfg.alpha * d_max and tpot_ok(p)]
        if not cand:
            cand = live

        exec_wo = {p.iid: self._exec_schedule(p, now, None, block_size)[0]
                   for p in cand}
        exec_w = {p.iid: self._exec_schedule(p, now, stub_for(p.iid),
                                             block_size)[0]
                  for p in cand}

        if d_max > 0:
            ttft = req.slo.ttft
            light = [p for p in cand if exec_wo[p.iid] < self.cfg.mu * ttft]
            heavy = [p for p in cand if exec_w[p.iid] > self.cfg.lam * ttft]
            heavy_ids = {p.iid for p in heavy}
            non_heavy = [p for p in cand if p.iid not in heavy_ids]
            # prefix-affinity, reservation-aware: compare light instances on
            # load NET of the prefill work a cached prefix saves, so a
            # slightly busier prefix holder still wins; elsewhere affinity
            # only breaks ties (the anti-over-balancing rule keeps priority).
            if light:                                  # most idle light one
                pick = min(light,
                           key=lambda p: (exec_wo[p.iid] - save[p.iid],
                                          exec_wo[p.iid]))
            elif non_heavy:                            # HEAVIEST non-heavy:
                pick = max(non_heavy,                  # reserve light capacity
                           key=lambda p: (exec_wo[p.iid], save[p.iid]))
            else:                                      # all heavy: balance
                pick = min(cand,
                           key=lambda p: (exec_wo[p.iid] - save[p.iid],
                                          exec_wo[p.iid]))
        else:
            # line 18 fallback: no instance can meet the SLO — min load
            pick = min(live, key=lambda p: self._exec_schedule(
                p, now, None, block_size)[0] - save.get(p.iid, 0.0))

        d_pick = None
        if decode_pool is not None:
            d_pick = pick_decode_target(decode_pool, req, block_size)
        return pick.iid, d_pick


# --------------------------------------------------------------------------
# global-scheduler baselines
# --------------------------------------------------------------------------

class MinLoad:
    """Dispatch to the instance with the smallest estimated queue drain."""
    name = "min_load"

    def __init__(self, est: BatchLatencyEstimator):
        self.est = est

    def select(self, req, prefill_pool, decode_pool, now,
               block_size=16, exec_est=None, affinity=None):
        live = [p for p in prefill_pool if p.alive]
        if not live:
            return None, None
        pick = min(live, key=lambda p: p.queue_exec_total(now))
        d_pick = None
        if decode_pool is not None:
            d_pick = pick_decode_target(decode_pool, req, block_size)
        return pick.iid, d_pick


class RoundRobin:
    name = "round_robin"

    def __init__(self, est=None):
        self._it = itertools.count()

    def select(self, req, prefill_pool, decode_pool, now,
               block_size=16, exec_est=None, affinity=None):
        live = [p for p in prefill_pool if p.alive]
        if not live:
            return None, None
        pick = live[next(self._it) % len(live)]
        d_pick = None
        if decode_pool is not None:
            d_live = [d for d in decode_pool if d.alive]
            need = decode_need_blocks(req, block_size)
            fits = [d for d in d_live
                    if d.effective_free >= need] or d_live
            if fits:
                d_pick = fits[next(self._it) % len(fits)].iid
        return pick.iid, d_pick


ROUTERS = {"gorouting": GoRouting, "min_load": MinLoad,
           "round_robin": RoundRobin}
