"""Gain functions from §2: Weighted SLO, TA-SLO and the paper's TDG (Eq. 1-3).

All gain functions share the signature ``gain(req, w_p, w_d) -> float`` so
benchmarks can swap them (Table 1 / Appendix E comparison).  ``w_p`` weights
the first token (responsiveness), ``w_d`` the decode tokens (fluency); both
are scaled by the request's priority weight ``req.weight``.
"""
from __future__ import annotations

import numpy as np

from .request import Request


def token_weight(req: Request, i: int, w_p: float, w_d: float) -> float:
    """w_r(i) of Eq. (3)."""
    return (w_p if i == 1 else w_d) * req.weight


def tdg_gain(req: Request, w_p: float = 1.0, w_d: float = 1.0) -> float:
    """Token-level Deadline-aware Gain, Eq. (3).

    Each emitted token i earns w_r(i) iff it was delivered strictly before
    its FIXED deadline ``arrival + TTFT_SLO + (i-1)*TPOT_SLO``.  Fixed,
    independent deadlines give the two monotonicity properties of §2:
    early completion never hurts, late completion forfeits only that token
    (plus squeezing successors' slack) — no discard/postpone trick pays.
    """
    ts = req.out_times
    if len(ts) >= 32:
        # vectorized, bitwise identical to the loop: same per-token deadline
        # expression shape, late tokens enter the sequential accumulation
        # as +0.0 (exact for the non-negative weights)
        m = len(ts)
        dl = req.arrival + req.slo.ttft + np.arange(m) * req.slo.tpot
        terms = np.where(np.asarray(ts) < dl, w_d * req.weight, 0.0)
        if ts[0] < dl[0]:
            terms[0] = w_p * req.weight
        return float(np.add.accumulate(terms)[-1])
    g = 0.0
    for i, t in enumerate(req.out_times, start=1):
        if t < req.slo.token_deadline(req.arrival, i):
            g += token_weight(req, i, w_p, w_d)
    return g


def ideal_gain(req: Request, w_p: float = 1.0, w_d: float = 1.0) -> float:
    """Upper bound: every token of the request delivered on time."""
    if req.output_len <= 0:
        return 0.0
    return (w_p + (req.output_len - 1) * w_d) * req.weight


def tdg_ratio(reqs, w_p: float = 1.0, w_d: float = 1.0) -> float:
    """System gain metric TDG_Ratio = sum f_TDG / Ideal_Gain (§5.1)."""
    got = sum(tdg_gain(r, w_p, w_d) for r in reqs)
    ideal = sum(ideal_gain(r, w_p, w_d) for r in reqs)
    return got / ideal if ideal > 0 else 0.0


# --- strawman baselines (kept for the Table-1/Appendix-E comparison) -----

def weighted_slo_gain(req: Request, w_p: float = 1.0, w_d: float = 1.0) -> float:
    """Strawman 1, Eq. (1): all-or-nothing request-level attainment.

    Vulnerable to the discard-or-postpone trick: once TTFT is missed the
    request is worthless to the metric.
    """
    del w_p, w_d
    return req.weight if req.met_slo() else 0.0


def ta_slo_gain(req: Request, w_p: float = 1.0, w_d: float = 1.0) -> float:
    """Refined proposal 2, Eq. (2): TBT-based token accumulation.

    Vulnerable to the postponed-decoding trick: delaying an already-late
    token can rescue the NEXT token's TBT (negative monotonicity of TBT).
    """
    g = 0.0
    if req.out_times:
        if req.out_times[0] - req.arrival < req.slo.ttft:
            g += w_p * req.weight
        tbt_slo = req.slo.tpot
        for prev, cur in zip(req.out_times, req.out_times[1:]):
            if cur - prev < tbt_slo:
                g += w_d * req.weight
    return g


GAIN_FUNCTIONS = {
    "tdg": tdg_gain,
    "weighted_slo": weighted_slo_gain,
    "ta_slo": ta_slo_gain,
}
