"""Prefix-identity primitives shared by the router, the simulator and the
real engine (pure Python — no JAX).

Three pieces:

* ``chunk_hashes`` — the rolling per-block hash chain that identifies a
  prompt prefix at block granularity.  Hash ``k`` commits to the first
  ``(k+1) * block_size`` tokens, so two prompts agree on hash ``k`` iff
  they share that whole prefix (modulo hash collisions, which only cost a
  misrouted request — the engine-side radix cache compares real tokens).
* ``PrefixRegistry`` — router-side memory of which replica has prefilled
  which prefix recently.  GoRouting's prefix-affinity term reads it to
  land repeated prefixes on the replica already holding their KV.
* ``SimPrefixCache`` — the simulator's cache model.  Sim requests carry no
  token content, so it matches on the generator-stamped
  ``(prefix_group, shared_prefix_len)`` identity instead of a radix walk;
  capacity / pinning / LRU+priority eviction mirror the real
  ``serving/prefix_cache.RadixPrefixCache`` so simulated hit rates and
  block pressure are faithful.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional

from .estimator import COLD_WIRE_RATIO
from .request import Request


def usable_prefix(cache_len: int, prompt_len: int, block_size: int) -> int:
    """Largest cached span a prompt can consume: block-aligned, and at
    least one prompt token must stay uncached — the pass that completes
    the prompt produces the first token's logits."""
    return (min(cache_len, prompt_len - 1) // block_size) * block_size


def chunk_hashes(tokens, block_size: int) -> list[int]:
    """Rolling hash chain over full blocks: out[k] identifies tokens
    ``[0, (k+1)*block_size)``."""
    out: list[int] = []
    h = 0
    for i in range(len(tokens) // block_size):
        h = hash((h, tuple(int(t) for t in
                           tokens[i * block_size:(i + 1) * block_size])))
        out.append(h)
    return out


class PrefixRegistry:
    """Per-instance LRU of recently dispatched prefix hash chains.

    ``observe`` is called at dispatch time (optimistic: the replica will
    hold the prefix once it prefills); ``lookup`` returns, per instance,
    the longest prefix (in tokens) the instance plausibly has cached.
    """

    def __init__(self, block_size: int = 16, max_entries: int = 8192):
        self.block_size = block_size
        self.max_entries = max_entries
        # iid -> (chain hash -> cached tokens), LRU-ordered
        self._seen: dict[int, OrderedDict[int, int]] = {}

    def observe(self, iid: int, tokens, chain: Optional[list] = None) -> None:
        d = self._seen.setdefault(iid, OrderedDict())
        bs = self.block_size
        if chain is None:
            chain = chunk_hashes(tokens, bs)
        for k, h in enumerate(chain):
            if d.get(h, 0) < (k + 1) * bs:
                d[h] = (k + 1) * bs
            d.move_to_end(h)
        while len(d) > self.max_entries:
            d.popitem(last=False)

    def lookup(self, tokens, chain: Optional[list] = None) -> dict[int, int]:
        """{iid: cached prefix tokens} for every instance with a hit.
        ``chain`` (a precomputed ``chunk_hashes(tokens, block_size)``) lets
        hot callers hash the prompt once for lookup + observe."""
        if not self._seen:
            return {}
        bs = self.block_size
        if chain is None:
            chain = chunk_hashes(tokens, bs)
        # the rolling chain is prefix-stable: truncating == re-hashing the
        # usable (block-aligned, >=1 token left uncached) slice
        hashes = chain[:usable_prefix(len(tokens), len(tokens), bs) // bs]
        out: dict[int, int] = {}
        for iid, d in self._seen.items():
            for k in range(len(hashes) - 1, -1, -1):
                if hashes[k] in d:
                    out[iid] = (k + 1) * bs
                    break
        return out

    def drop(self, iid: int) -> None:
        self._seen.pop(iid, None)


class _SimEntry:
    __slots__ = ("blocks", "last_used", "weight")

    def __init__(self, blocks: int, now: float, weight: float):
        self.blocks = blocks
        self.last_used = now
        self.weight = weight


class _SimSpilled:
    """A cache entry whose blocks were evicted to the host tier instead of
    destroyed (sim mirror of the real radix cache's spill-on-evict).
    ``cold`` marks entries demoted past the host budget into the int8
    cold tier — their restore crosses the wire at COLD_WIRE_RATIO."""
    __slots__ = ("blocks", "last_used", "weight", "cold")

    def __init__(self, blocks: int, last_used: float, weight: float):
        self.blocks = blocks
        self.last_used = last_used
        self.weight = weight
        self.cold = False


class SimPrefixCache:
    """Group-identity prefix cache for one simulated instance.

    Implements the :class:`~repro.core.blocks.PrefixCacheHandle` protocol
    (``reclaim`` / ``detach``) so the BlockManager can charge and reclaim
    cache blocks, plus the match/insert surface the sim engine drives.
    Eviction is LRU with a priority bonus: an entry whose users carry
    weight ``w`` survives as if it were used ``priority_bonus * (w - 1)``
    seconds more recently.
    """

    def __init__(self, block_size: int, max_blocks: int,
                 priority_bonus: float = 30.0, *, spill: bool = False,
                 host_budget_blocks: Optional[int] = None):
        self.block_size = block_size
        self.max_blocks = max_blocks
        self.priority_bonus = priority_bonus
        # KV tiering mirror (serving/kv_pool.KVTierStore): with ``spill``
        # on, reclaimed entries move to a host tier instead of being
        # destroyed; a ``host_budget_blocks`` cap demotes LRU spilled
        # entries to the int8 cold tier, whose restores occupy the H2D
        # lane for only COLD_WIRE_RATIO of the hot time.
        self.spill = spill
        self.host_budget_blocks = host_budget_blocks
        self.bm = None                       # set by the owning engine
        self.entries: dict[int, _SimEntry] = {}
        self.spilled: dict[int, _SimSpilled] = {}
        self._pins: dict[int, set[int]] = {}      # group -> rids
        self._rid_group: dict[int, int] = {}
        self.hits = 0
        self.hit_tokens = 0
        self.evicted_blocks = 0
        self.spilled_blocks = 0
        self.restored_blocks = 0

    # --- capacity ------------------------------------------------------
    @property
    def cached_blocks(self) -> int:
        return sum(e.blocks for e in self.entries.values())

    def _usable_blocks(self, req: Request) -> int:
        if req.prefix_group < 0 or req.shared_prefix_len <= 0:
            return 0
        return usable_prefix(req.shared_prefix_len, req.prompt_len,
                             self.block_size) // self.block_size

    # --- engine surface -------------------------------------------------
    def match(self, req: Request, now: float) -> int:
        """Cached tokens usable by ``req`` (0 if its group is cold)."""
        e = self.entries.get(req.prefix_group)
        if e is None and self.spilled.get(req.prefix_group) is not None:
            e = self._restore(req.prefix_group, now)
        if e is None:
            return 0
        n = min(e.blocks, self._usable_blocks(req))
        if n <= 0:
            return 0
        e.last_used = now
        e.weight = max(e.weight, req.weight)
        self.hits += 1
        self.hit_tokens += n * self.block_size
        return n * self.block_size

    def attach(self, rid: int, group: int) -> None:
        """Pin the group's entry while ``rid`` references its blocks."""
        self._pins.setdefault(group, set()).add(rid)
        self._rid_group[rid] = group

    def insert(self, req: Request, now: float) -> int:
        """Adopt the shared span of a just-prefilled request; returns the
        number of newly cache-charged blocks (0 if already cached)."""
        target = self._usable_blocks(req)
        if target <= 0:
            return 0
        e = self.entries.get(req.prefix_group)
        if e is None:
            # re-adoption: the inserting request just recomputed a spilled
            # prefix on device — the host-tier copy is superseded (the real
            # cache re-links the node to the request's table blocks)
            self.spilled.pop(req.prefix_group, None)
            e = self.entries[req.prefix_group] = _SimEntry(0, now, req.weight)
        adopted = max(0, target - e.blocks)
        e.blocks = max(e.blocks, target)
        e.last_used = now
        e.weight = max(e.weight, req.weight)
        self.attach(req.rid, req.prefix_group)
        return adopted

    def peek_tokens(self, req: Request) -> int:
        """Cached tokens usable by ``req`` without touching LRU state.
        Spilled groups count: a match would restore them from the host
        tier, which still beats recomputing the prefix."""
        e = self.entries.get(req.prefix_group) \
            or self.spilled.get(req.prefix_group)
        return 0 if e is None else \
            min(e.blocks, self._usable_blocks(req)) * self.block_size

    # --- PrefixCacheHandle protocol -------------------------------------
    def detach(self, rid: int) -> None:
        g = self._rid_group.pop(rid, None)
        if g is not None:
            pins = self._pins.get(g)
            if pins is not None:
                pins.discard(rid)

    def reclaim(self, need_blocks: int) -> int:
        freed = 0
        while freed < need_blocks:
            victims = [(g, e) for g, e in self.entries.items()
                       if not self._pins.get(g)]
            if not victims:
                break
            g, e = min(victims, key=lambda ge: ge[1].last_used
                       + self.priority_bonus * (ge[1].weight - 1.0))
            freed += e.blocks
            if self.spill:
                # spill-on-evict: the KV moves to the host tier (the real
                # engine's gather + D2H ride the background lane, so no
                # charge here); device blocks free either way.
                self.spilled[g] = _SimSpilled(e.blocks, e.last_used, e.weight)
                self.spilled_blocks += e.blocks
            del self.entries[g]
            self._pins.pop(g, None)
        if freed and self.bm is not None:
            self.bm.discharge_cache(freed)
        self.evicted_blocks += freed
        if self.spill:
            self._enforce_spill_budget()
        return freed

    # --- host-tier spill model (mirror of the real spill-on-evict) ------
    def _enforce_spill_budget(self) -> None:
        """Demote LRU hot spilled entries to the cold tier until the hot
        span fits ``host_budget_blocks`` (None = unbounded hot tier)."""
        if self.host_budget_blocks is None:
            return
        while True:
            hot = [(g, s) for g, s in self.spilled.items() if not s.cold]
            over = (sum(s.blocks for _, s in hot)
                    - self.host_budget_blocks)
            if over <= 0 or not hot:
                return
            _, victim = min(hot, key=lambda gs: gs[1].last_used)
            victim.cold = True

    def _restore(self, group: int, now: float) -> Optional[_SimEntry]:
        """Reload a spilled group's blocks onto the device: free blocks are
        claimed (reclaiming other cache entries if short), the H2D lane is
        charged tier-aware (cold int8 groups at COLD_WIRE_RATIO width),
        and the entry rejoins ``entries``.  Returns None — a plain miss —
        when device space cannot be made; the spilled copy is kept."""
        sp = self.spilled.get(group)
        if sp is None or self.bm is None:
            return None
        need = sp.blocks
        short = need - self.bm.free_blocks
        if short > 0:
            # reclaim only touches device-resident entries, never the
            # spilled dict, so the restoring group is safe from it
            self.reclaim(short)
        if need > self.bm.free_blocks:
            return None
        if sp.cold:
            self.bm.h2d.enqueue(now, need, COLD_WIRE_RATIO)
        else:
            self.bm.h2d.enqueue(now, need)
        self.bm.charge_cache(need)
        del self.spilled[group]
        e = self.entries[group] = _SimEntry(sp.blocks, now, sp.weight)
        self.restored_blocks += need
        return e

    def shrink_to_capacity(self) -> int:
        over = self.cached_blocks - self.max_blocks
        return self.reclaim(over) if over > 0 else 0
