"""Request model: priority, SLOs, lifecycle and token timeline.

This module is pure Python (no JAX) so the identical scheduling core drives
both the discrete-event cluster simulator (sim/) and the real JAX engine
(serving/).  Time is a float in seconds; priorities are small ints where
LOWER value = HIGHER priority (1 = most important), matching the paper's
``P = {1..P}`` with ``w_1 >= ... >= w_P``.
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Optional


class Phase(enum.Enum):
    WAITING = 0      # in queue, no prefill progress
    PREFILL = 1      # some (possibly chunked) prefill done, first token not out
    DECODE = 2       # first token emitted, generating
    FINISHED = 3     # all output tokens emitted


@dataclass(frozen=True)
class SLO:
    """Per-request latency targets (seconds)."""
    ttft: float
    tpot: float

    def token_deadline(self, arrival: float, i: int) -> float:
        """Absolute deadline of output token ``i`` (1-based), Eq. (3):

        deadline_{r,i} = TTFT_SLO + (i-1) * TPOT_SLO   (relative to arrival)
        """
        if i < 1:
            raise ValueError(f"token index must be >= 1, got {i}")
        return arrival + self.ttft + (i - 1) * self.tpot


_rid_counter = itertools.count()


@dataclass
class Request:
    """One inference request with an originating-client priority."""
    prompt_len: int
    output_len: int              # ground-truth output length (oracle only;
                                 # schedulers must not read it — see note)
    arrival: float
    slo: SLO
    priority: int = 2            # 1 = high
    weight: float = 1.0          # w_{p(r)} priority weight
    rid: int = field(default_factory=lambda: next(_rid_counter))
    client: int = 0              # originating client id (for VTC fairness)
    # prefix identity (workload-generator stamped): requests in the same
    # ``prefix_group`` share their first ``shared_prefix_len`` prompt
    # tokens.  The real engine matches on token CONTENT (radix cache) and
    # ignores these; the simulator and trace replay use them to model /
    # synthesize shared prefixes.  -1 = no shared prefix.
    prefix_group: int = -1
    shared_prefix_len: int = 0

    # --- mutable serving state -------------------------------------------
    prefilled: int = 0           # prompt tokens whose KV exists on device
    host_prefilled: int = 0      # prompt tokens whose KV was computed but
                                 # currently lives in HOST memory (evicted)
    out_times: list[float] = field(default_factory=list)  # emission stamps
    first_scheduled: Optional[float] = None
    finish_time: Optional[float] = None
    preemptions: int = 0
    starving: bool = False       # anti-starvation promotion flag
    instance: Optional[int] = None   # routing assignment

    # ------------------------------------------------------------------
    @property
    def phase(self) -> Phase:
        if self.finish_time is not None:
            return Phase.FINISHED
        if self.out_times:
            return Phase.DECODE
        if self.prefilled > 0 or self.host_prefilled > 0:
            return Phase.PREFILL
        return Phase.WAITING

    @property
    def generated(self) -> int:
        return len(self.out_times)

    @property
    def next_token_index(self) -> int:
        """1-based index of the next output token to be produced."""
        return self.generated + 1

    @property
    def context_len(self) -> int:
        """Tokens of KV context currently implied (prompt progress + output)."""
        return self.prefilled + self.host_prefilled + self.generated

    @property
    def remaining_prompt(self) -> int:
        return self.prompt_len - self.prefilled - self.host_prefilled

    def next_deadline(self) -> float:
        """Absolute deadline of the token this request will emit next."""
        return self.slo.token_deadline(self.arrival, self.next_token_index)

    def remain(self, now: float) -> float:
        """``r.remain`` of Alg. 1: time left until the next token's deadline."""
        return self.next_deadline() - now

    def emit_token(self, t: float) -> None:
        if self.phase == Phase.FINISHED:
            raise RuntimeError(f"request {self.rid} already finished")
        if self.out_times and t < self.out_times[-1]:
            raise ValueError("token timestamps must be non-decreasing")
        self.out_times.append(t)
        if len(self.out_times) >= self.output_len:
            self.finish_time = t

    # --- observed latency metrics -----------------------------------------
    @property
    def ttft(self) -> Optional[float]:
        return (self.out_times[0] - self.arrival) if self.out_times else None

    @property
    def tpot(self) -> Optional[float]:
        """Average time-per-output-token after the first token."""
        if len(self.out_times) < 2:
            return None
        span = self.out_times[-1] - self.out_times[0]
        return span / (len(self.out_times) - 1)

    def met_slo(self) -> bool:
        """Request-level SLO attainment: TTFT and TPOT both under target."""
        if self.ttft is None:
            return False
        ok_ttft = self.ttft < self.slo.ttft
        t = self.tpot
        ok_tpot = True if t is None else (t < self.slo.tpot)
        return ok_ttft and ok_tpot

    def __repr__(self) -> str:  # compact, used in logs
        return (f"Req({self.rid} p{self.priority} w{self.weight} "
                f"in={self.prompt_len} out={self.generated}/{self.output_len} "
                f"{self.phase.name})")
