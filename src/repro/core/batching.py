"""Shared batch-formation types used by SlideBatching, all baselines, the
cluster simulator and the real JAX engine.

A scheduling policy sees a ``SchedView`` (queue + block manager + latency
estimator + engine config) and returns a ``BatchPlan``: which requests run
this iteration, how many tokens each processes (chunked prefill), which
requests are evicted, and which KV blocks are reloaded.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Protocol

from .blocks import BlockManager
from .estimator import BatchLatencyEstimator
from .request import Phase, Request


@dataclass
class EngineConfig:
    # SlideBatching knobs (§4.2)
    eta: float = 0.05            # lower bound on the latency budget (s)
    gamma: float = 0.9           # aggressiveness coefficient
    tau: float = 30.0            # starvation threshold (s)
    beta: float = 1.5            # partial-copy effective-progress threshold
    # capacity knobs used by the token-budget baselines
    token_budget: int = 2048     # max_num_batched_tokens
    max_seqs: int = 256          # max_num_seqs
    chunk_size: int = 512        # sarathi chunk
    # gain weights
    w_p: float = 4.0             # first-token weight
    w_d: float = 1.0             # decode-token weight
    # deployment
    pd_mode: str = "coloc"       # "coloc" | "prefill" | "decode"
    # speculative decoding: max draft depth k (0 = off).  The per-request
    # depth in [0, spec_k] is a scheduler decision (core/spec.py policy +
    # estimator pricing); the engine/sim execute whatever depth the plan
    # carries on each BatchEntry.
    spec_k: int = 0
    # estimator constant overhead is carried by the estimator itself (t_c)


@dataclass
class SchedView:
    queue: list[Request]         # unfinished requests assigned to the engine
    bm: BlockManager
    est: BatchLatencyEstimator
    cfg: EngineConfig
    now: float = 0.0


@dataclass
class BatchEntry:
    req: Request
    n_tokens: int                # tokens computed this pass
    l_kv: int                    # context length already cached before pass
    is_prefill: bool             # chunked-prefill-style pass vs single decode
    depth: int = 0               # speculation depth this pass (decode only)

    def work_item(self):
        return (self.n_tokens, self.l_kv, self.is_prefill)


@dataclass
class BatchPlan:
    entries: list[BatchEntry] = field(default_factory=list)
    evictions: list[Request] = field(default_factory=list)
    est_time: float = 0.0        # estimator's view of batch latency
    t_budget: float = 0.0        # SlideBatching latency budget (0 = n/a)
    copy_blocks: int = 0         # H2D blocks consumed this round

    def work_items(self):
        return [e.work_item() for e in self.entries]


class Policy(Protocol):
    name: str
    def form_batch(self, view: SchedView) -> BatchPlan: ...


# --------------------------------------------------------------------------
# shared helpers
# --------------------------------------------------------------------------

def needed_context(req: Request) -> int:
    """KV tokens that must be resident BEFORE the next forward pass.

    * generated == 0 : the remaining prompt is still to be processed; the
      pass that brings residency to ``prompt_len`` emits the first token.
    * generated == g : decoding token g+1 processes token g (writing its KV)
      while attending to the ``prompt_len + g - 1`` previous positions.
    """
    return req.prompt_len + max(0, req.generated - 1)


def compute_remaining(req: Request, bm: BlockManager) -> tuple[int, int]:
    """(tokens still to COMPUTE, resident tokens assumed restorable).

    Host-resident tokens count as restorable (copied, not recomputed);
    anything dropped at eviction shows up as missing and must be recomputed.
    """
    s = bm.state(req)
    resident = s.dev_tokens + s.host_tokens
    todo = max(0, needed_context(req) - resident)
    return todo, resident


def exec_estimate(req: Request, view: SchedView) -> float:
    """``r.exec`` of Alg. 1: estimated core latency to produce the next
    output token (full remaining prefill/recompute + one decode step)."""
    todo, resident = compute_remaining(req, view.bm)
    t = 0.0
    if todo > 0:
        t += view.est.prefill_time(todo, resident)
    if req.generated > 0:
        t += view.est.decode_time(needed_context(req) + 1)
    return max(t, 1e-9)


def next_token_weight(req: Request, cfg: EngineConfig) -> float:
    """w_r(r.len): gain of the next token to be emitted."""
    return (cfg.w_p if req.generated == 0 else cfg.w_d) * req.weight


def max_chunk_for_budget(est: BatchLatencyEstimator, l_kv: int,
                         t_left: float, cap: int) -> tuple[int, float]:
    """GetMaxChunk: largest prefill chunk whose estimated time fits t_left.

    Solves a_p c^2 + (b_p*l_kv + c_p) c <= t_left for c, capped at ``cap``.
    Returns (chunk_tokens, est_time); (0, 0) if even one token won't fit.
    """
    if cap <= 0 or t_left <= 0:
        return 0, 0.0
    if math.isinf(t_left):
        return cap, est.prefill_time(cap, l_kv)
    a = est.a_p
    b = est.b_p * l_kv + est.c_p
    if a <= 0:
        c = cap if b <= 0 else min(cap, int(t_left / b))
    else:
        disc = b * b + 4.0 * a * t_left
        c = min(cap, int((math.sqrt(disc) - b) / (2.0 * a)))
    if c < 1:
        return 0, 0.0
    return c, est.prefill_time(c, l_kv)


def evict_for_space(view: SchedView, need_blocks: int,
                    protect: set[int]) -> list[Request]:
    """§4.3 eviction policy: free blocks by evicting requests near the TAIL
    of the (already sorted) queue, sparing ``protect`` (batch members) and
    requests whose wait is close to the starvation threshold.  Unpinned
    prefix-cache blocks are reclaimed first — they cost no recompute."""
    evicted: list[Request] = []
    if view.bm.free_blocks < need_blocks:
        view.bm.reclaim_cache(need_blocks - view.bm.free_blocks)
    if view.bm.free_blocks >= need_blocks:
        return evicted
    for r in reversed(view.queue):
        if view.bm.free_blocks >= need_blocks:
            break
        if r.rid in protect or r.phase == Phase.FINISHED:
            continue
        wait = view.now - r.arrival
        if r.starving or wait > 0.8 * view.cfg.tau:
            continue
        if view.bm.state(r).dev_tokens > 0:
            view.bm.evict(r, view.now)
            r.preemptions += 1
            evicted.append(r)
    return evicted


def grow_with_eviction(view: SchedView, req: Request, n_tokens: int,
                       protect: set[int],
                       evictions: list[Request]) -> bool:
    """Reserve device blocks for ``n_tokens`` of new KV, evicting if needed."""
    need = view.bm.blocks_needed_for_growth(req, n_tokens)
    if need > view.bm.free_blocks:
        evictions.extend(evict_for_space(view, need, protect))
    if need > view.bm.free_blocks:
        return False
    return view.bm.grow(req, n_tokens, view.now)
