"""SlideBatching (§4.2, Alg. 1): load-adaptive local batch scheduler.

Per iteration:
  1.  refresh per-request metrics  exec / remain / density;
  2.  latency budget  t_budget = max(min_r remain, eta);
  3.  urgency partition:  URGENT iff remain < gamma * phi(Q)   (the sliding
      boundary — the URGENT/NORMAL split moves with load);
  4.  order: URGENT by density desc (fractional-knapsack greedy), then
      NORMAL by remaining time asc (EDF); starving requests jump the line;
  5.  compute the H2D copy budget (adaptive copy-budget control, §4.3);
  6.  admit requests in order, chunking prefill to saturate t_budget,
      consuming copy budget for evicted requests, evicting tail requests
      when device blocks run short.

The load-judgment function phi:
  PD co-location (Eq. 8):  phi(Q)   = t_budget/(t_budget - t_c) * sum exec
  PD disaggregation:       phi_p(Q) = sum exec + |Q| * t_c
"""
from __future__ import annotations

from dataclasses import dataclass

from .batching import (BatchEntry, BatchPlan, SchedView, compute_remaining,
                       exec_estimate, grow_with_eviction,
                       max_chunk_for_budget, next_token_weight,
                       needed_context)
from .blocks import blocks_for
from .request import Phase, Request
from .spec import AcceptanceEWMA, policy_depth

URGENT, NORMAL = 0, 1


@dataclass
class _Metrics:
    exec: float
    remain: float
    density: float
    state: int = NORMAL


class SlideBatching:
    name = "slidebatching"

    def __init__(self, *, use_density: bool = True, use_deadline: bool = True,
                 latency_aware_budget: bool = True):
        # ablation switches (§5.4): "w/ only deadline" disables the density
        # ordering, "w/ only density" disables the deadline ordering,
        # "w/o latency-aware" replaces the time budget with a token budget.
        self.use_density = use_density
        self.use_deadline = use_deadline
        self.latency_aware_budget = latency_aware_budget
        # speculative-decoding feedback: acceptance-rate EWMA driving the
        # per-request depth policy (core/spec.py).  The engine/sim report
        # (proposed, accepted) back after every verify.
        self.spec_accept = AcceptanceEWMA()

    # ------------------------------------------------------------------
    def _phi(self, view: SchedView, metrics: dict[int, _Metrics],
             t_budget: float) -> float:
        total_exec = sum(m.exec for m in metrics.values())
        t_c = view.est.t_c
        if view.cfg.pd_mode == "prefill":
            return total_exec + len(metrics) * t_c          # phi_p
        denom = max(t_budget - t_c, 1e-9)
        return (t_budget / denom) * total_exec              # Eq. (8)

    def form_batch(self, view: SchedView) -> BatchPlan:
        cfg, now = view.cfg, view.now
        queue = [r for r in view.queue if r.phase != Phase.FINISHED]
        if not queue:
            return BatchPlan()

        # ---- lines 1-6: refresh metrics ---------------------------------
        # t_min considers only requests that can still make their next
        # deadline: an already-late request cannot be saved by shrinking
        # this batch (line 6's purpose is "no request misses its deadline
        # IN THE CURRENT BATCH"), it would only strangle throughput.
        metrics: dict[int, _Metrics] = {}
        t_min = float("inf")
        for r in queue:
            ex = exec_estimate(r, view)
            rem = r.remain(now)
            metrics[r.rid] = _Metrics(
                exec=ex, remain=rem,
                density=next_token_weight(r, cfg) / ex)
            if rem > 0:
                t_min = min(t_min, rem)

        # ---- line 7: latency budget --------------------------------------
        if self.latency_aware_budget:
            if t_min == float("inf"):
                # every queued request is already past its next deadline:
                # no deadline constrains this batch — use the top of the
                # budget's natural range [eta, max TPOT_SLO] (§4.2)
                t_min = max(r.slo.tpot for r in queue)
            t_budget = max(t_min, cfg.eta)
        else:
            t_budget = float("inf")   # ablation: capacity from token budget

        # ---- lines 8-12: adaptive urgency partition ----------------------
        phi = self._phi(view, metrics, t_budget if self.latency_aware_budget
                        else cfg.eta)
        for r in queue:
            m = metrics[r.rid]
            m.state = URGENT if m.remain < cfg.gamma * phi else NORMAL

        # ablations collapse the partition to a single strategy
        if not self.use_deadline:
            for m in metrics.values():
                m.state = URGENT
        if not self.use_density:
            for m in metrics.values():
                m.state = NORMAL

        # ---- line 13: ordering -------------------------------------------
        # starving requests (anti-starvation, wait > tau) jump to the head.
        for r in queue:
            if now - r.arrival > cfg.tau and r.generated == 0:
                r.starving = True

        def key(r: Request):
            m = metrics[r.rid]
            if r.starving:
                return (0, 0, -m.density, r.arrival)
            if m.state == URGENT:
                return (1, 0, -m.density, r.arrival)
            return (1, 1, m.remain, r.arrival)

        order = sorted(queue, key=key)
        # keep the view's queue in sorted order: the §4.3 eviction policy
        # and GoRouting's EstimateExec both read this ordering.
        view.queue[:] = order

        # ---- line 14: copy budget (§4.3 adaptive copy-budget control) ----
        copy_budget = self._copy_budget(view, order, metrics, t_budget)

        # ---- lines 15-23: admission ---------------------------------------
        plan = BatchPlan(t_budget=t_budget if self.latency_aware_budget else 0.0)
        t_batch = view.est.t_c
        protect: set[int] = set()
        token_cap = cfg.token_budget if not self.latency_aware_budget else None
        tokens_used = 0
        for r in order:
            if len(plan.entries) >= cfg.max_seqs:
                break
            if self.latency_aware_budget:
                if t_batch >= t_budget:
                    break
                t_left = t_budget - t_batch
            else:
                if tokens_used >= token_cap:
                    break
                t_left = float("inf")

            entry, t, used_copy = self._admit(view, r, t_left,
                                              token_cap, tokens_used,
                                              copy_budget, protect, plan)
            # reloads may have been applied even if admission then failed —
            # they consumed real H2D bandwidth either way.
            copy_budget -= used_copy
            plan.copy_blocks += used_copy
            if entry is None:
                continue
            plan.entries.append(entry)
            protect.add(r.rid)
            t_batch += t
            tokens_used += entry.n_tokens
        plan.est_time = view.est.batch_time(plan.work_items())
        return plan

    # ------------------------------------------------------------------
    def _copy_budget(self, view: SchedView, order: list[Request],
                     metrics: dict[int, _Metrics], t_budget: float) -> int:
        """GetCopyBudget: the §4.3 three-case decision over the likely batch."""
        bm, est = view.bm, view.est
        if not any(bm.state(r).host_tokens for r in order):
            return 0
        # prefix of the sorted queue that plausibly fits this round
        t_acc, prefix = est.t_c, []
        horizon = t_budget if t_budget != float("inf") else \
            est.prefill_time(view.cfg.token_budget)
        for r in order:
            prefix.append(r)
            t_acc += metrics[r.rid].exec
            if t_acc >= horizon:
                break
        t_fwd_min = min(t_acc, horizon)  # forward time if all host blocks restored
        b_missing, b_cold = 0, 0
        for r in prefix:
            s = bm.state(r)
            nb = blocks_for(s.host_tokens, bm.block_size)
            b_missing += nb
            if s.cold_tokens:
                b_cold += nb            # whole-group tiers: all-or-nothing
        # tier-aware transfer ceiling: cold int8 blocks cross the wire at
        # COLD_WIRE_RATIO width.  t_block_eff is passed ONLY when cold
        # blocks exist — (b*t)/b != t in fp, so the all-hot path must use
        # bm.t_block itself to stay bitwise-legacy.
        t_trans_max = est.reload_time(b_missing - b_cold, b_cold, bm.t_block)
        t_block_eff = t_trans_max / b_missing if b_cold else None
        return bm.copy_budget(t_fwd_min, t_trans_max,
                              horizon, b_missing, t_block_eff=t_block_eff)

    def _assign_depth(self, view: SchedView, r: Request, l_kv: int,
                      t0: float, t_left: float,
                      t_budget: float) -> tuple[int, float]:
        """Speculation depth for one decode admission.  Returns
        (depth, admission time incl. verify+draft overhead).

        Order of caps: the load/priority policy (core/spec.py), the
        remaining-output cap (never draft past output_len), the
        block-room cap (speculative KV slots must fit the blocks the
        plain grow-by-1 already reserves, so block accounting is
        untouched), the estimator's tokens/s pricing, and finally the
        budget collapse — depth steps toward 0 before the admission
        loop would shed this request from the batch.  The same method
        runs in the vectorized sim fast path, so depth decisions stay
        result-identical."""
        cfg, est = view.cfg, view.est
        k = cfg.spec_k
        if k <= 0 or r.output_len - r.generated <= 1:
            return 0, t0
        rate = self.spec_accept.rate
        load = 0.0
        if 0.0 < t_budget < float("inf"):
            load = 1.0 - t_left / t_budget
        d = int(policy_depth(load, r.priority, rate, k))
        d = min(d, r.output_len - r.generated - 1)
        bs = view.bm.block_size
        room = (bs - ((l_kv + 1) % bs)) % bs
        d = min(d, room)
        if d > 0:
            d = est.spec_depth(l_kv, d, rate)
        if d == 0 and room >= 1 and self.spec_accept.probe():
            # explore: policy/pricing declined but a depth-1 draft fits
            # the block — probe periodically so the acceptance estimate
            # can recover (zero-speculation is otherwise absorbing).
            d = 1
        while d > 0 and t0 + est.spec_overhead(l_kv, d) > t_left:
            d -= 1
        return d, (t0 + est.spec_overhead(l_kv, d)) if d else t0

    def _admit(self, view: SchedView, r: Request, t_left: float,
               token_cap, tokens_used: int, copy_budget: int,
               protect: set[int], plan: BatchPlan):
        """Lines 17-23 for one request. Returns (entry|None, time, copies)."""
        bm, est, cfg = view.bm, view.est, view.cfg
        s = bm.state(r)
        todo, _ = compute_remaining(r, bm)

        # --- reload coordination (SatisfyCopyCondition / ConsumeCopyBudget)
        used_copy = 0
        if s.host_tokens > 0:
            cap = token_cap - tokens_used if token_cap is not None else 1 << 30
            chunk_cap, _ = max_chunk_for_budget(est, s.dev_tokens, t_left,
                                                min(cap, max(todo, 1)))
            cplan = bm.plan_reload(r, copy_budget,
                                   max(chunk_cap, 1), max(todo, 1))
            if not cplan.admitted:
                return None, 0.0, 0     # line 19-20: skip this round
            if cplan.restore_blocks or cplan.drop_host_tokens:
                need = cplan.restore_blocks
                if need > bm.free_blocks:
                    from .batching import evict_for_space
                    plan.evictions.extend(
                        evict_for_space(view, need, protect | {r.rid}))
                if need > bm.free_blocks:
                    return None, 0.0, 0
                bm.apply_reload(r, cplan, view.now)
                used_copy = cplan.restore_blocks
            todo, _ = compute_remaining(r, bm)

        # --- decode step (context fully resident) --------------------------
        if todo == 0 and r.phase == Phase.DECODE:
            l_kv = needed_context(r)
            t0 = est.decode_time(l_kv)
            depth, t = self._assign_depth(view, r, l_kv, t0, t_left,
                                          plan.t_budget)
            if t > t_left and plan.entries:
                return None, 0.0, used_copy
            if not grow_with_eviction(view, r, 1, protect | {r.rid},
                                      plan.evictions):
                return None, 0.0, used_copy
            return BatchEntry(r, 1, l_kv, False, depth), t, used_copy

        # --- (chunked) prefill / recompute ---------------------------------
        if todo <= 0:
            return None, 0.0, used_copy
        cap = todo
        if token_cap is not None:
            cap = min(cap, token_cap - tokens_used)
        chunk, t = max_chunk_for_budget(est, s.dev_tokens, t_left, cap)
        if chunk == 0:
            # guarantee progress: an empty batch would stall the engine
            if not plan.entries:
                chunk = min(cap, max(1, view.cfg.chunk_size))
                t = est.prefill_time(chunk, s.dev_tokens)
            else:
                return None, 0.0, used_copy
        if not grow_with_eviction(view, r, chunk, protect | {r.rid},
                                  plan.evictions):
            return None, 0.0, used_copy
        return BatchEntry(r, chunk, s.dev_tokens - chunk, True), t, used_copy
