"""Batch latency estimator (§4.1, Eq. 4-7).

Per-request core latencies:
    prefill:  T~_p(r) = a_p * l_q^2 + b_p * l_q * l_kv + c_p * l_q      (5)
    decode:   T~_d(r) = a_d * l_kv + b_d                                 (6)
Batch latency:
    T(B) = sum_r T~(r) + t_c                                            (7)

The quadratic l_q^2 term captures intra-chunk attention, l_q*l_kv the
attention against cached context (chunked prefill / prefix caching
compatible), c_p*l_q the linear (MLP/projection) cost.  Decode is
memory-bound: a_d*l_kv is the KV read, b_d the per-sequence overhead.

Coefficients {a_p,b_p,c_p,a_d,b_d,t_c} are fit by least squares on profiled
batches (offline, §4.1).  Because the batch time is LINEAR in the summed
per-request features, we fit one joint regression on batch-level aggregated
features — exactly the estimator a production deployment trains from engine
step logs.  The paper reports MAPE ~= 4.5%; we report ours in
EXPERIMENTS.md (benchmarks/bench_estimator.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Sequence

import numpy as np

# A forward-pass work item: (l_q, l_kv, is_prefill).
#   l_q  : tokens processed this pass (chunk size for prefill, 1 for decode)
#   l_kv : KV context length already cached BEFORE this pass
WorkItem = tuple[int, int, bool]

# Wire-byte ratio of a cold (int8 + per-plane fp32 scales) KV block to a
# hot (fp32) one: the H2D copy of a cold-tier reload moves ~4x fewer
# bytes (see kernels/kv_quant.py); its on-device dequant is fused into
# the staging scatter and is bandwidth-trivial next to the PCIe copy.
COLD_WIRE_RATIO = 0.25

# Speculative decoding cost model (core/spec.py drives depth with it).
# An extra verify row rides the same packed launch as the base decode
# row, so it costs a fraction of a standalone decode pass; each draft
# proposal costs a small-model decode step priced relative to the
# target's.  Both are ratios of T~_d(l_kv) so the fitted coefficients
# keep working without a separate speculation profile.
VERIFY_ROW_RATIO = 0.35
DRAFT_COST_RATIO = 0.2


def _features(items: Iterable[WorkItem]) -> np.ndarray:
    """Aggregate batch features [sum l_q^2, sum l_q*l_kv, sum l_q, sum l_kv_d, n_d, 1]."""
    items = list(items)
    if len(items) >= 32:
        return _features_cols(*_as_cols(items))
    f = np.zeros(6, dtype=np.float64)
    for l_q, l_kv, is_prefill in items:
        if is_prefill:
            f[0] += float(l_q) * l_q
            f[1] += float(l_q) * l_kv
            f[2] += float(l_q)
        else:
            f[3] += float(l_kv) + l_q  # decode reads ctx incl. current token
            f[4] += 1.0
    f[5] = 1.0
    return f


def _as_cols(items: Sequence[WorkItem]):
    arr = np.asarray(items, dtype=np.float64)
    return arr[:, 0], arr[:, 1], arr[:, 2] != 0.0


def _features_cols(l_q: np.ndarray, l_kv: np.ndarray,
                   is_prefill: np.ndarray) -> np.ndarray:
    """Columnar `_features`, bitwise identical to the scalar loop: masked
    rows contribute +0.0 (exact for these non-negative terms) and each
    column is reduced with the sequential ``np.add.accumulate`` — the
    pairwise ``np.sum`` would NOT reproduce the loop's rounding."""
    f = np.zeros(6, dtype=np.float64)
    if l_q.size:
        pf = is_prefill.astype(np.float64)
        df = 1.0 - pf
        f[0] = np.add.accumulate(pf * (l_q * l_q))[-1]
        f[1] = np.add.accumulate(pf * (l_q * l_kv))[-1]
        f[2] = np.add.accumulate(pf * l_q)[-1]
        f[3] = np.add.accumulate(df * (l_kv + l_q))[-1]
        f[4] = np.add.accumulate(df)[-1]
    f[5] = 1.0
    return f


@dataclass
class BatchLatencyEstimator:
    a_p: float = 0.0
    b_p: float = 0.0
    c_p: float = 0.0
    a_d: float = 0.0
    b_d: float = 0.0
    t_c: float = 0.0

    # --- prediction -------------------------------------------------------
    def prefill_time(self, l_q: int, l_kv: int = 0) -> float:
        """T~_p(r), Eq. (5) — excludes the constant batch overhead t_c."""
        return self.a_p * l_q * l_q + self.b_p * l_q * l_kv + self.c_p * l_q

    def decode_time(self, l_kv: int) -> float:
        """T~_d(r), Eq. (6)."""
        return self.a_d * l_kv + self.b_d

    def prefill_time_cached(self, prompt_len: int,
                            cached_tokens: int = 0) -> float:
        """Prefill cost after a prefix-cache hit: only the uncached suffix
        is computed, attending over the cached context (Eq. 5 with
        l_q = prompt - cached, l_kv = cached — the same decomposition that
        makes the estimator chunked-prefill compatible)."""
        l_q = max(prompt_len - cached_tokens, 0)
        return self.prefill_time(l_q, min(cached_tokens, prompt_len))

    def request_time(self, l_q: int, l_kv: int, is_prefill: bool) -> float:
        if is_prefill:
            return self.prefill_time(l_q, l_kv)
        return self.decode_time(l_kv + l_q)

    def reload_time(self, hot_blocks: int, cold_blocks: int,
                    t_block: float) -> float:
        """Tier-aware H2D reload estimate: hot (fp32) blocks cost a full
        ``t_block`` each, cold (int8) blocks only ``COLD_WIRE_RATIO`` of
        it — the copy-budget control (core/blocks.py, SlideBatching)
        uses this so cold-tier restores are priced by what actually
        crosses the wire.  ``cold_blocks == 0`` reproduces the legacy
        ``blocks * t_block`` bitwise."""
        return (hot_blocks + COLD_WIRE_RATIO * cold_blocks) * t_block

    def spec_overhead(self, l_kv, depth):
        """Extra cost of a depth-``depth`` verify launch over a plain
        decode of the same request: ``depth`` packed verify rows plus
        ``depth`` draft-model steps, both priced as ratios of
        T~_d(l_kv).  0 at depth 0 (bitwise: speculation off adds
        nothing).  Elementwise — scalars or numpy columns."""
        return ((VERIFY_ROW_RATIO + DRAFT_COST_RATIO) * depth
                * (self.a_d * l_kv + self.b_d))

    def spec_depth(self, l_kv: int, d_cap: int, rate: float) -> int:
        """Depth in [0, d_cap] maximizing expected accepted-tokens/s:
        expected_tokens(d, rate) / (T~_d + spec_overhead(d))."""
        from .spec import price_depth
        return price_depth(self.decode_time(l_kv),
                           lambda d: self.spec_overhead(l_kv, d),
                           d_cap, rate)

    def batch_time(self, items: Iterable[WorkItem]) -> float:
        """T(B), Eq. (7)."""
        coef = np.array([self.a_p, self.b_p, self.c_p,
                         self.a_d, self.b_d, self.t_c])
        return float(_features(items) @ coef)

    def batch_time_cols(self, l_q: Sequence[int], l_kv: Sequence[int],
                        is_prefill: Sequence[bool]) -> float:
        """``batch_time`` over pre-split columns (vectorized schedulers);
        bitwise identical to the tuple-list form."""
        coef = np.array([self.a_p, self.b_p, self.c_p,
                         self.a_d, self.b_d, self.t_c])
        f = _features_cols(np.asarray(l_q, np.float64),
                           np.asarray(l_kv, np.float64),
                           np.asarray(is_prefill, bool))
        return float(f @ coef)

    # --- fitting ----------------------------------------------------------
    @classmethod
    def fit(cls, batches: Sequence[Sequence[WorkItem]],
            latencies: Sequence[float], ridge: float = 1e-9,
            ) -> "BatchLatencyEstimator":
        """Least-squares fit (ridge-regularized, coefficients clipped >= 0)."""
        X = np.stack([_features(b) for b in batches])
        y = np.asarray(latencies, dtype=np.float64)
        # Normal equations with tiny ridge for conditioning; features span
        # ~10 orders of magnitude so whiten columns first.
        scale = np.maximum(np.abs(X).max(axis=0), 1e-30)
        Xs = X / scale
        A = Xs.T @ Xs + ridge * np.eye(X.shape[1])
        w = np.linalg.solve(A, Xs.T @ y) / scale
        w = np.maximum(w, 0.0)  # physical latencies are non-negative
        return cls(*w.tolist())

    def mape(self, batches: Sequence[Sequence[WorkItem]],
             latencies: Sequence[float]) -> float:
        preds = np.array([self.batch_time(b) for b in batches])
        y = np.asarray(latencies, dtype=np.float64)
        mask = y > 0
        return float(np.mean(np.abs(preds[mask] - y[mask]) / y[mask]))

    def as_dict(self) -> dict:
        return {k: getattr(self, k)
                for k in ("a_p", "b_p", "c_p", "a_d", "b_d", "t_c")}
