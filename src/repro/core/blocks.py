"""Efficient block management (§4.3).

Pure accounting layer shared by the simulator and the real engine: tracks,
per request, how many KV blocks live on DEVICE vs HOST, drives the paper's
three mechanisms, and exposes the copy-budget decision procedure:

* **Eviction policy** — under memory pressure evict blocks of requests near
  the tail of the sorted queue (they will not run soon), sparing requests
  close to the starvation threshold.
* **Asynchronous offloading** — blocks are proactively mirrored device→host
  every ``n_off`` newly generated blocks (priority-aware: lower priority ⇒
  smaller threshold ⇒ more eagerly mirrored, because it is more likely to be
  preempted).  At eviction time, mirrored blocks are freed instantly; blocks
  not yet mirrored are *dropped* (pending transfer discarded) and their
  tokens must later be recomputed — exactly the paper's "directly evict all
  its device blocks and discard the pending transfer".
* **Pipelined reloading + adaptive copy-budget control** — ``copy_budget``
  implements the 3-case decision procedure (T_fwd_min vs t_budget vs
  T_trans_max, with the binary search of case 2(ii)), and
  ``plan_reload`` implements the per-request full/partial-copy admission
  rule with the β effective-progress threshold.

Token-resident layout per request is always a CONTIGUOUS PREFIX:
``[0, dev_tokens)`` on device, ``[dev_tokens, dev_tokens+host_tokens)`` on
host; anything beyond was dropped and must be recomputed (it is ordinary
chunked-prefill work — prompt and generated tokens are all known).

**Prefix-cache accounting.**  With a radix prefix cache attached (see
``serving/prefix_cache.py`` / the sim cache in ``core/prefix.py``), every
device block is charged exactly once: blocks uniquely owned by a request
count in ``used_blocks``; blocks referenced by the cache (shared by any
number of requests) count in ``cache_charge``.  A request tracks how many
of its table blocks are cache-charged in ``ReqBlocks.shared_blocks`` so
release/evict free only the uniquely-owned remainder.  Cache-held blocks
are reclaimed on demand (``cache.reclaim``) before any request is evicted
— shared blocks are pinned while in use, so §4.3 offload/evict only ever
frees uniquely-owned blocks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Protocol

from .estimator import COLD_WIRE_RATIO
from .request import Request


class PrefixCacheHandle(Protocol):
    """What the BlockManager needs to know about an attached prefix cache."""

    def reclaim(self, need_blocks: int) -> int:
        """Evict unpinned cache entries until ``need_blocks`` are freed (or
        nothing evictable remains); returns blocks actually freed."""
        ...

    def detach(self, rid: int) -> None:
        """Unpin every cache node ``rid`` was holding."""
        ...


def blocks_for(tokens: int, block_size: int) -> int:
    return (tokens + block_size - 1) // block_size


@dataclass
class ReqBlocks:
    """Per-request block residency (token granularity, prefix-contiguous)."""
    dev_tokens: int = 0     # contiguous prefix resident on device
    host_tokens: int = 0    # next contiguous span resident on host
    mirrored_blocks: int = 0  # device blocks already mirrored to host (async offload)
    pending_offload: int = 0  # blocks queued on the D2H lane, not yet complete
    restore_pending: int = 0  # blocks apply_reload promised device-resident
    # whose DATA still sits on host — the engine's H2D copy order.  (With
    # async mirroring the host dict alone can't signal this: mirrored
    # blocks of a live device-resident request also appear there.)
    shared_blocks: int = 0  # table blocks charged to the prefix cache, not
    # to used_blocks (cache-referenced; possibly shared with other requests)
    cold_tokens: int = 0    # host span demoted to the int8 cold tier; the
    # tier demotes WHOLE groups, so this is 0 or == host_tokens, and a
    # reload of a cold group crosses the wire at COLD_WIRE_RATIO width

    def computed_tokens(self) -> int:
        return self.dev_tokens + self.host_tokens


@dataclass
class TransferLane:
    """Models one copy direction (D2H or H2D) with finite bandwidth.

    ``busy_until`` advances as copies are enqueued; copies overlap compute
    (separate stream, App. B) but the lane itself is serial.
    """
    t_block: float                    # seconds per block
    busy_until: float = 0.0
    total_blocks: int = 0

    def enqueue(self, now: float, n_blocks: int,
                wire_scale: float = 1.0) -> float:
        """Schedule n blocks; returns completion time.  ``wire_scale``
        shrinks the occupancy of narrow-wire copies (cold-tier int8
        blocks at COLD_WIRE_RATIO); the default 1.0 is exact — x*1.0 is
        bitwise x — so legacy callers are unchanged."""
        start = max(now, self.busy_until)
        self.busy_until = start + n_blocks * self.t_block * wire_scale
        self.total_blocks += n_blocks
        return self.busy_until


@dataclass
class CopyPlan:
    """Per-request reload decision for the coming batch."""
    restore_blocks: int = 0     # host blocks copied back H2D this round
    drop_host_tokens: int = 0   # host tokens abandoned (will be recomputed)
    admitted: bool = True       # False ⇒ skip request this round (Alg.1 l.19)


class BlockManager:
    """Device block pool + host pool + the §4.3 mechanisms."""

    def __init__(self, num_device_blocks: int, block_size: int,
                 t_block: float, *, async_offload: bool = True,
                 adaptive_copy: bool = True, recompute_only: bool = False,
                 n_off_by_priority: Optional[dict[int, int]] = None,
                 beta: float = 1.5, t_block_alpha: float = 0.25,
                 host_budget_blocks: Optional[int] = None):
        self.num_device_blocks = num_device_blocks
        self.block_size = block_size
        self.t_block = t_block
        self.async_offload = async_offload
        self.adaptive_copy = adaptive_copy
        self.recompute_only = recompute_only  # "Recompute" ablation: drop on evict
        self.beta = beta
        # priority -> offload threshold (new blocks between proactive mirrors);
        # lower priority (larger int) gets a SMALLER threshold.
        self.n_off_by_priority = n_off_by_priority or {1: 8, 2: 4, 3: 2}
        self.d2h = TransferLane(t_block)
        self.h2d = TransferLane(t_block)
        self.table: dict[int, ReqBlocks] = {}
        self.used_blocks = 0
        # optional radix prefix cache (real or simulated); blocks it holds
        # are charged here so free_blocks stays truthful for admission.
        self.cache: Optional[PrefixCacheHandle] = None
        self.cache_charge = 0
        # --- real transfer lanes (§4.3 closed loop) -----------------------
        # With ``external_lanes`` an engine-owned background worker performs
        # the actual copies: proactive-offload directives are forwarded to
        # ``offload_sink(rid, start_block, n_blocks)`` and mirrored blocks
        # advance only on ``note_offload_complete`` (real completions), not
        # on the virtual lane clock.  ``observe_transfer`` feeds measured
        # copy throughput back into ``t_block`` so the adaptive copy budget
        # tracks the hardware instead of a configured constant.
        self.external_lanes = False
        self.offload_sink: Optional[callable] = None
        self.t_block_alpha = t_block_alpha
        # --- host-tier byte budget (simulator mirror of KVTierStore) -----
        # With a budget, evicted-to-host spans beyond it demote LRU whole
        # groups to the int8 cold tier (cold_tokens): reloads then cross
        # the wire at COLD_WIRE_RATIO width.  None = unbounded host tier
        # (legacy).  The real engine drives residency from the actual
        # KVTierStore instead and leaves this None.
        self.host_budget_blocks = host_budget_blocks
        self._host_touch: dict[int, int] = {}
        self._host_clock = 0

    def _touch_host(self, rid: int) -> None:
        self._host_clock += 1
        self._host_touch[rid] = self._host_clock

    def _enforce_host_budget(self) -> None:
        """Demote LRU hot host groups to cold until the hot span fits the
        budget (mirrors ``KVTierStore._enforce``; whole groups only)."""
        if self.host_budget_blocks is None:
            return
        while True:
            hot = [(rid, s) for rid, s in self.table.items()
                   if s.host_tokens and not s.cold_tokens]
            over = (sum(blocks_for(s.host_tokens, self.block_size)
                        for _, s in hot) - self.host_budget_blocks)
            if over <= 0 or not hot:
                return
            victim = min(hot, key=lambda e: self._host_touch.get(e[0], 0))
            victim[1].cold_tokens = victim[1].host_tokens

    # ------------------------------------------------------------------
    def state(self, req: Request) -> ReqBlocks:
        return self.table.setdefault(req.rid, ReqBlocks())

    @property
    def free_blocks(self) -> int:
        return self.num_device_blocks - self.used_blocks - self.cache_charge

    def dev_blocks(self, req: Request) -> int:
        return blocks_for(self.state(req).dev_tokens, self.block_size)

    def blocks_needed_for_growth(self, req: Request, new_tokens: int) -> int:
        s = self.state(req)
        return (blocks_for(s.dev_tokens + new_tokens, self.block_size)
                - blocks_for(s.dev_tokens, self.block_size))

    # --- prefix-cache hooks ----------------------------------------------
    def reclaim_cache(self, need_blocks: int) -> int:
        """Ask the attached cache to free unpinned blocks (LRU/priority)."""
        if self.cache is None or need_blocks <= 0:
            return 0
        return self.cache.reclaim(need_blocks)

    def charge_cache(self, n_blocks: int) -> None:
        self.cache_charge += n_blocks

    def discharge_cache(self, n_blocks: int) -> None:
        self.cache_charge -= n_blocks

    def attach_cached(self, req: Request, tokens: int) -> None:
        """Admission-time prefix-cache hit: the first ``tokens`` (block
        aligned) are already resident in cache-charged blocks — the request
        references them without owning them."""
        s = self.state(req)
        assert s.dev_tokens == 0 and s.host_tokens == 0, \
            "attach_cached requires a fresh request"
        s.dev_tokens = tokens
        s.shared_blocks = tokens // self.block_size

    def donate_to_cache(self, req: Request, n_blocks: int) -> None:
        """The cache adopted ``n_blocks`` of req's uniquely-owned blocks
        (prompt insertion): transfer their charge request -> cache."""
        s = self.state(req)
        self.used_blocks -= n_blocks
        self.cache_charge += n_blocks
        s.shared_blocks += n_blocks

    def note_fork(self, req: Request) -> None:
        """A copy-on-write fork replaced one of req's shared blocks with a
        private copy: the new block is request-owned."""
        s = self.state(req)
        s.shared_blocks -= 1
        self.used_blocks += 1

    # --- growth / release ------------------------------------------------
    def grow(self, req: Request, new_tokens: int, now: float) -> bool:
        """Account for new KV written on device; triggers async offload."""
        need = self.blocks_needed_for_growth(req, new_tokens)
        if need > self.free_blocks:
            self.reclaim_cache(need - self.free_blocks)
        if need > self.free_blocks:
            return False
        s = self.state(req)
        s.dev_tokens += new_tokens
        self.used_blocks += need
        if self.async_offload and not self.recompute_only:
            self._maybe_offload(req, now)
        return True

    def _maybe_offload(self, req: Request, now: float) -> None:
        """Proactive D2H mirroring every ``n_off`` new FULL blocks (§4.3)."""
        s = self.state(req)
        n_off = self.n_off_by_priority.get(
            req.priority, max(self.n_off_by_priority.values()))
        full = s.dev_tokens // self.block_size        # only full blocks mirror
        unmirrored = full - s.mirrored_blocks - s.pending_offload
        if unmirrored >= n_off:
            start = s.mirrored_blocks + s.pending_offload
            if self.external_lanes and self.offload_sink is not None:
                self.offload_sink(req.rid, start, unmirrored)
            else:
                self.d2h.enqueue(now, unmirrored)
            s.pending_offload += unmirrored

    def complete_offloads(self, now: float) -> None:
        """Advance the D2H lane: anything enqueued before ``now`` is durable.

        With ``external_lanes`` this is a no-op — real transfer completions
        arrive via ``note_offload_complete`` instead of a virtual clock."""
        if self.external_lanes:
            return
        for s in self.table.values():
            if s.pending_offload and self.d2h.busy_until <= now:
                s.mirrored_blocks += s.pending_offload
                s.pending_offload = 0

    def note_offload_complete(self, rid: int, n_blocks: int) -> None:
        """A real D2H transfer of ``n_blocks`` landed on host (engine
        transfer-worker completion callback)."""
        s = self.table.get(rid)
        if s is None:
            return
        take = min(n_blocks, s.pending_offload)
        s.pending_offload -= take
        s.mirrored_blocks = min(s.mirrored_blocks + take,
                                s.dev_tokens // self.block_size)

    def note_offload_failed(self, rid: int, n_blocks: int) -> None:
        """A real D2H transfer failed: release its pending-offload claim so
        proactive mirroring can retry (the blocks stay unmirrored)."""
        s = self.table.get(rid)
        if s is None:
            return
        s.pending_offload = max(0, s.pending_offload - n_blocks)

    def observe_transfer(self, n_blocks: int, seconds: float) -> None:
        """Close the §4.3 control loop: fold a measured copy into the
        per-block transfer-time estimate the copy budget is computed from."""
        if n_blocks <= 0 or seconds <= 0:
            return
        sample = seconds / n_blocks
        a = self.t_block_alpha
        self.t_block = (1.0 - a) * self.t_block + a * sample
        self.d2h.t_block = self.h2d.t_block = self.t_block

    def release(self, req: Request) -> None:
        """Request finished: free its uniquely-owned device + host
        residency; cache-charged (shared) blocks stay with the cache."""
        s = self.table.pop(req.rid, None)
        if s is not None:
            self.used_blocks -= (blocks_for(s.dev_tokens, self.block_size)
                                 - s.shared_blocks)
        if self.cache is not None:
            # unconditional: a request can hold cache pins with zero
            # shared_blocks (its insert found the path already present)
            self.cache.detach(req.rid)

    # --- eviction ----------------------------------------------------------
    def evict(self, req: Request, now: float) -> int:
        """Evict ALL device blocks of ``req`` (preemption). Returns freed count.

        Mirrored blocks transition to host residency instantly (they were
        proactively copied); unmirrored blocks are dropped — with
        ``recompute_only`` everything is dropped.  Without async offload the
        un-mirrored blocks must be copied synchronously (D2H lane stall).
        """
        s = self.state(req)
        nblocks = blocks_for(s.dev_tokens, self.block_size)
        if nblocks == 0 and s.dev_tokens == 0:
            return 0
        freed = nblocks - s.shared_blocks   # shared blocks stay in the cache
        self.complete_offloads(now)
        if self.recompute_only:
            saved_tokens = 0
        elif self.async_offload:
            saved_tokens = min(s.mirrored_blocks * self.block_size, s.dev_tokens)
            s.pending_offload = 0   # discard in-flight transfers
        else:
            # synchronous offload: copy everything now (stalls the engine;
            # callers account d2h.busy_until - now as eviction latency)
            self.d2h.enqueue(now, nblocks)
            saved_tokens = s.dev_tokens
        # Residency must stay a contiguous prefix to be usable.  If only a
        # prefix of the device span was mirrored, the gap between it and any
        # pre-existing host suffix makes that suffix unusable — drop it.
        if saved_tokens >= s.dev_tokens:
            s.host_tokens = s.dev_tokens + s.host_tokens   # no gap
        else:
            s.host_tokens = saved_tokens                    # gap: suffix dropped
        s.dev_tokens = 0
        s.mirrored_blocks = 0
        s.restore_pending = 0   # nothing device-resident left to materialize
        s.cold_tokens = 0       # fresh eviction lands hot; budget may demote
        self._touch_host(req.rid)
        self._enforce_host_budget()
        self.used_blocks -= freed
        s.shared_blocks = 0
        if self.cache is not None:
            self.cache.detach(req.rid)
        return freed

    # --- adaptive copy-budget control (§4.3) --------------------------------
    def copy_budget(self, t_fwd_min: float, t_trans_max: float,
                    t_budget: float, b_missing: int,
                    t_block_eff: Optional[float] = None) -> int:
        """B_copy by the paper's 3-case procedure.

        ``t_block_eff`` is the tier-aware mean per-block transfer time of
        the missing set (cold int8 blocks cross the wire at
        COLD_WIRE_RATIO width); callers pass it ONLY when cold blocks
        are present, so the all-hot path stays bitwise-legacy on
        ``self.t_block``."""
        if not self.adaptive_copy:
            return b_missing          # "w/o dynamic": always copy everything
        if self.t_block <= 0:
            return b_missing
        tb = self.t_block if t_block_eff is None else t_block_eff
        if t_fwd_min > t_budget:
            # batch time is pinned at the latency budget: hide copies under it
            return int(t_budget // tb)
        if t_fwd_min >= t_trans_max:
            return b_missing          # compute dominates: copy all, fully hidden
        # case 2(ii): binary-search largest B_copy whose transfer time still
        # fits under the (B_copy-dependent) estimated batch latency.  More
        # copies ⇒ less recompute ⇒ forward latency falls toward t_fwd_min,
        # while transfer time rises toward t_trans_max (both monotone).
        lo, hi = 0, b_missing
        while lo < hi:
            mid = (lo + hi + 1) // 2
            trans = mid * tb
            recompute = (b_missing - mid) * self.t_block  # conservative proxy:
            # recomputing a dropped block costs at least its copy time on TPU
            # (prefill of s_blk tokens vs 32GB/s PCIe copy) — refined by the
            # engine which passes estimator-based t_fwd_min.
            fwd = t_fwd_min + recompute
            if trans <= fwd:
                lo = mid
            else:
                hi = mid - 1
        return lo

    def plan_reload(self, req: Request, budget_blocks: int,
                    chunk_cap_tokens: int, remaining_tokens: int) -> CopyPlan:
        """Per-request full/partial copy rule ("Put it Together", §4.3).

        If the remaining copy budget covers all of the request's missing
        (host) blocks, restore them all.  Otherwise consider PARTIAL copy:
        restore ``budget_blocks`` and abandon the rest, whose tokens will be
        recomputed as ordinary chunked prefill.  Partial copy is admitted
        only when it yields enough effective progress this round — either
        ``l_comp`` reaches the round's computable-token cap, or
        ``l_comp / dropped_tokens > beta`` (β > 1); otherwise the request is
        skipped this round and waits for more budget.

        ``chunk_cap_tokens``: max tokens r may compute this round (from the
        residual latency budget).  ``remaining_tokens``: total compute left
        for r assuming the dropped span is recomputed (dropped + new work).
        """
        s = self.state(req)
        miss = blocks_for(s.host_tokens, self.block_size)
        if miss == 0:
            return CopyPlan()
        if budget_blocks >= miss:
            return CopyPlan(restore_blocks=miss)
        restore = max(0, budget_blocks)
        dropped_tokens = max(0, s.host_tokens - restore * self.block_size)
        l_comp = min(chunk_cap_tokens, dropped_tokens + remaining_tokens)
        reaches_cap = l_comp >= chunk_cap_tokens
        ratio = l_comp / max(dropped_tokens, 1)
        if reaches_cap or ratio > self.beta:
            return CopyPlan(restore_blocks=restore,
                            drop_host_tokens=dropped_tokens)
        return CopyPlan(admitted=False)

    def apply_reload(self, req: Request, plan: CopyPlan, now: float) -> float:
        """Execute a reload plan. Returns H2D completion time (pipelined —
        overlapped with forward compute; caller enforces the copy-budget
        guarantee that it fits under batch latency)."""
        if plan.restore_blocks == 0 and plan.drop_host_tokens == 0:
            return now
        s = self.state(req)
        restore_tokens = min(plan.restore_blocks * self.block_size,
                             s.host_tokens)
        need = (blocks_for(s.dev_tokens + restore_tokens, self.block_size)
                - blocks_for(s.dev_tokens, self.block_size))
        self.used_blocks += need
        s.dev_tokens += restore_tokens
        s.host_tokens -= restore_tokens
        s.restore_pending += need   # engine: copy these blocks H2D
        # cold groups ride the int8 wire: same block count, ~4x fewer
        # bytes, so the lane is occupied for COLD_WIRE_RATIO of the time.
        # The hot path keeps the exact legacy enqueue (wire_scale 1.0).
        if s.cold_tokens > 0:
            done = self.h2d.enqueue(now, plan.restore_blocks,
                                    COLD_WIRE_RATIO)
        else:
            done = self.h2d.enqueue(now, plan.restore_blocks)
        if plan.drop_host_tokens:
            s.host_tokens = max(0, s.host_tokens - plan.drop_host_tokens)
        if s.cold_tokens:
            # whole-group tiers: what remains on host stays cold
            s.cold_tokens = s.host_tokens
        self._touch_host(req.rid)
        return done
