"""Baseline batch schedulers (§5.1) implemented against the same SchedView /
BatchPlan interface as SlideBatching so every policy runs inside the
identical engine — mirroring the paper's "all schedulers implemented within
xLLM" methodology.

* vLLM-FCFS        — prefill-prioritized FCFS, whole-prompt admission,
                     recompute preemption (vLLM default).
* Sarathi-FCFS     — chunked prefill, decode-prioritized, FCFS among
                     waiting prefills, profiled token budget.
* Sarathi-Priority — Sarathi with waiting queue ordered by (priority, arrival).
* FairBatching     — enhanced EDF: decodes near deadline > prefills (EDF) >
                     remaining decodes.
* Weighted VTC     — CFS-style weighted virtual token counters per client.
* EDF / SJF / Priority-First — classic orderings (§3 motivation studies).

For 10⁵-request replays every policy has a columnar fast path: queues of
``_MIN_COLS``+ rows are partitioned and sorted through numpy columns
(``_scan`` / ``_ordered``) instead of per-request Python.  The fast path
follows the ``sim/vector.py`` equivalence contract — integer predicates,
scalar-shaped float expressions, stable ``np.lexsort`` — so it is bitwise
identical to the scalar loops (asserted in tests/test_scheduling.py).
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from .batching import (BatchEntry, BatchPlan, SchedView, compute_remaining,
                       exec_estimate, grow_with_eviction, needed_context)
from .request import Phase, Request

# same columnar threshold as estimator._features / sim.vector
_MIN_COLS = 32


# --------------------------------------------------------------------------
# shared mechanics
# --------------------------------------------------------------------------

def _scan(view: SchedView) -> tuple[list[Request], list[Request]]:
    """One queue scan -> (ready decodes, prefillable requests), both in
    queue order.  The columnar path's partition predicate is all-integer
    (``todo = max(needed - resident, 0)``) so it is trivially identical
    to the scalar loop."""
    queue, bm = view.queue, view.bm
    if len(queue) < _MIN_COLS:
        decs, pref = [], []
        for r in queue:
            if r.phase == Phase.FINISHED:
                continue
            todo, _ = compute_remaining(r, bm)
            if todo > 0:
                pref.append(r)
            elif r.phase == Phase.DECODE:
                decs.append(r)
        return decs, pref
    n = len(queue)
    resident = np.zeros(n, np.int64)
    needed = np.zeros(n, np.int64)
    is_dec = np.zeros(n, bool)
    live = np.zeros(n, bool)
    for i, r in enumerate(queue):
        ph = r.phase
        if ph == Phase.FINISHED:
            continue    # scalar loops never touch bm.state for these
        live[i] = True
        is_dec[i] = ph == Phase.DECODE
        s = bm.state(r)
        resident[i] = s.dev_tokens + s.host_tokens
        needed[i] = r.prompt_len + max(0, r.generated - 1)
    todo = np.maximum(needed - resident, 0)
    decs = [queue[i] for i in np.nonzero(is_dec & (todo == 0))[0]]
    pref = [queue[i] for i in np.nonzero(live & (todo > 0))[0]]
    return decs, pref


def _decodes(view: SchedView) -> list[Request]:
    return _scan(view)[0]


def _prefillable(view: SchedView) -> list[Request]:
    return _scan(view)[1]


def _ordered(reqs: list[Request], key_fn,
             cols_fn=None) -> list[Request]:
    """``sorted(reqs, key=key_fn)`` with a columnar fast path: for
    ``_MIN_COLS``+ rows ``cols_fn(reqs)`` supplies the key columns
    (most-significant first) and a stable ``np.lexsort`` reproduces the
    scalar sort exactly — same key values, same tie-breaking stability."""
    if cols_fn is not None and len(reqs) >= _MIN_COLS:
        cols = cols_fn(reqs)
        if cols is not None:
            idx = np.lexsort(tuple(reversed(cols)))
            return [reqs[i] for i in idx]
    return sorted(reqs, key=key_fn)


def _arrival_cols(reqs: list[Request]) -> tuple[np.ndarray, ...]:
    return (np.fromiter((r.arrival for r in reqs), np.float64, len(reqs)),)


def _priority_cols(reqs: list[Request]) -> np.ndarray:
    return np.fromiter((r.priority for r in reqs), np.int64, len(reqs))


def _remain_col(reqs: list[Request], now: float) -> np.ndarray:
    """Columnar ``r.remain(now)``: the expression keeps the scalar
    association ``((arrival + ttft) + gen*tpot) - now`` (see
    ``SLO.token_deadline``) so each element is bitwise the scalar value."""
    n = len(reqs)
    arrival = np.fromiter((r.arrival for r in reqs), np.float64, n)
    ttft = np.fromiter((r.slo.ttft for r in reqs), np.float64, n)
    tpot = np.fromiter((r.slo.tpot for r in reqs), np.float64, n)
    gen = np.fromiter((r.generated for r in reqs), np.int64, n)
    return arrival + ttft + gen * tpot - now


def _exec_cols(view: SchedView, reqs: list[Request]) -> tuple[np.ndarray]:
    """Columnar ``exec_estimate`` (same float expression shapes as the
    scalar ``prefill_time`` / ``decode_time`` calls)."""
    est, bm = view.est, view.bm
    n = len(reqs)
    resident = np.empty(n, np.int64)
    needed = np.empty(n, np.int64)
    gen = np.empty(n, np.int64)
    for i, r in enumerate(reqs):
        s = bm.state(r)
        resident[i] = s.dev_tokens + s.host_tokens
        needed[i] = r.prompt_len + max(0, r.generated - 1)
        gen[i] = r.generated
    todo = np.maximum(needed - resident, 0)
    pre_t = est.a_p * todo * todo + est.b_p * todo * resident \
        + est.c_p * todo
    dec_t = est.a_d * (needed + 1) + est.b_d
    t = np.where(todo > 0, pre_t, 0.0) + np.where(gen > 0, dec_t, 0.0)
    return (np.maximum(t, 1e-9),)


def _restore_all_host(view: SchedView, r: Request,
                      plan: BatchPlan, protect: set[int]) -> bool:
    """Baselines restore any host-resident KV in full before running (they
    have no adaptive copy budget; w/o-dynamic behaviour)."""
    s = view.bm.state(r)
    if s.host_tokens == 0:
        return True
    cplan = view.bm.plan_reload(r, 1 << 30, 1 << 30, 1 << 30)
    need = cplan.restore_blocks
    if need > view.bm.free_blocks:
        from .batching import evict_for_space
        plan.evictions.extend(evict_for_space(view, need, protect | {r.rid}))
    if need > view.bm.free_blocks:
        return False
    view.bm.apply_reload(r, cplan, view.now)
    plan.copy_blocks += need
    return True


def _admit_decode(view: SchedView, r: Request, plan: BatchPlan,
                  protect: set[int]) -> bool:
    if not _restore_all_host(view, r, plan, protect):
        return False
    if not grow_with_eviction(view, r, 1, protect | {r.rid}, plan.evictions):
        return False
    plan.entries.append(BatchEntry(r, 1, needed_context(r), False))
    protect.add(r.rid)
    return True


def _admit_prefill_chunk(view: SchedView, r: Request, max_tokens: int,
                         plan: BatchPlan, protect: set[int]) -> int:
    """Admit up to ``max_tokens`` of (re)compute for r; returns tokens taken."""
    if not _restore_all_host(view, r, plan, protect):
        return 0
    todo, _ = compute_remaining(r, view.bm)
    chunk = min(todo, max_tokens)
    if chunk <= 0:
        return 0
    l_kv = view.bm.state(r).dev_tokens
    if not grow_with_eviction(view, r, chunk, protect | {r.rid},
                              plan.evictions):
        return 0
    plan.entries.append(BatchEntry(r, chunk, l_kv, True))
    protect.add(r.rid)
    return chunk


def _finalize(view: SchedView, plan: BatchPlan) -> BatchPlan:
    plan.est_time = view.est.batch_time(plan.work_items())
    return plan


# --------------------------------------------------------------------------
# vLLM default: prefill-prioritized FCFS, whole prompts, no chunking
# --------------------------------------------------------------------------

class VllmFCFS:
    name = "vllm_fcfs"

    def form_batch(self, view: SchedView) -> BatchPlan:
        plan = BatchPlan()
        protect: set[int] = set()
        cfg = view.cfg
        decs, pref = _scan(view)
        waiting = _ordered(pref, lambda r: r.arrival, _arrival_cols)
        budget = cfg.token_budget
        # admit WHOLE prompts FCFS while they fit the token budget; a prompt
        # longer than the whole budget runs ALONE (vLLM requires
        # max_num_batched_tokens >= max_model_len — emulated by lifting the
        # cap for a single head-of-line sequence instead of stalling it)
        for r in waiting:
            todo, _ = compute_remaining(r, view.bm)
            if len(plan.entries) >= cfg.max_seqs:
                break
            if todo > budget:
                if not plan.entries:
                    _admit_prefill_chunk(view, r, todo, plan, protect)
                break
            taken = _admit_prefill_chunk(view, r, todo, plan, protect)
            if taken == 0:
                break
            budget -= taken
        if plan.entries:          # vLLM v0: prefill batches run alone
            return _finalize(view, plan)
        for r in _ordered(decs, lambda r: r.arrival, _arrival_cols):
            if len(plan.entries) >= cfg.max_seqs:
                break
            _admit_decode(view, r, plan, protect)
        return _finalize(view, plan)


# --------------------------------------------------------------------------
# Sarathi family: decode-prioritized + chunked prefill under token budget
# --------------------------------------------------------------------------

class _SarathiBase:
    def _waiting_order(self, view: SchedView) -> Callable[[Request], tuple]:
        raise NotImplementedError

    def _waiting_cols(self, view: SchedView,
                      reqs: list[Request]) -> Optional[tuple]:
        """Columnar key columns matching ``_waiting_order`` (most
        significant first); None = no fast path for this policy."""
        return None

    def form_batch(self, view: SchedView) -> BatchPlan:
        plan = BatchPlan()
        protect: set[int] = set()
        cfg = view.cfg
        budget = cfg.token_budget
        decs, pref = _scan(view)
        for r in _ordered(decs, lambda r: r.arrival, _arrival_cols):
            if len(plan.entries) >= cfg.max_seqs or budget <= 0:
                break
            if _admit_decode(view, r, plan, protect):
                budget -= 1
        key = self._waiting_order(view)
        for r in _ordered(pref, key,
                          lambda reqs: self._waiting_cols(view, reqs)):
            if budget <= 0 or len(plan.entries) >= cfg.max_seqs:
                break
            chunk = min(budget, cfg.chunk_size)
            budget -= _admit_prefill_chunk(view, r, chunk, plan, protect)
        return _finalize(view, plan)


class SarathiFCFS(_SarathiBase):
    name = "sarathi_fcfs"

    def _waiting_order(self, view):
        return lambda r: (r.arrival,)

    def _waiting_cols(self, view, reqs):
        return _arrival_cols(reqs)


class SarathiPriority(_SarathiBase):
    name = "sarathi_priority"

    def _waiting_order(self, view):
        return lambda r: (r.priority, r.arrival)   # priority 1 first, then FCFS

    def _waiting_cols(self, view, reqs):
        return (_priority_cols(reqs),) + _arrival_cols(reqs)


class EDF(_SarathiBase):
    name = "edf"

    def _waiting_order(self, view):
        now = view.now
        return lambda r: (r.remain(now),)

    def _waiting_cols(self, view, reqs):
        return (_remain_col(reqs, view.now),)


class SJF(_SarathiBase):
    name = "sjf"

    def _waiting_order(self, view):
        return lambda r: (exec_estimate(r, view),)

    def _waiting_cols(self, view, reqs):
        return _exec_cols(view, reqs)


class PriorityFirst(_SarathiBase):
    """Strict priority-first (§3.1 motivation): priority dominates everything,
    including the decode/prefill split — emulated by ordering waiting work by
    priority and letting high-priority prefills consume the whole budget."""
    name = "priority_first"

    def _waiting_order(self, view):
        return lambda r: (r.priority, r.remain(view.now))

    def _waiting_cols(self, view, reqs):
        return (_priority_cols(reqs), _remain_col(reqs, view.now))


# --------------------------------------------------------------------------
# FairBatching: decodes near deadline > EDF prefills > remaining decodes
# --------------------------------------------------------------------------

class FairBatching:
    name = "fair_batching"

    def __init__(self, urgency_factor: float = 2.0):
        self.urgency_factor = urgency_factor

    def form_batch(self, view: SchedView) -> BatchPlan:
        plan = BatchPlan()
        protect: set[int] = set()
        cfg, now = view.cfg, view.now
        budget = cfg.token_budget
        decodes, pref = _scan(view)
        if len(decodes) >= _MIN_COLS:
            # columnar urgency split: the threshold keeps the scalar
            # expression (python-float ``factor * tpot``) per element
            rem = _remain_col(decodes, now)
            thresh = np.fromiter(
                (self.urgency_factor * r.slo.tpot for r in decodes),
                np.float64, len(decodes))
            mask = rem < thresh
            urgent = [decodes[i] for i in np.nonzero(mask)[0]]
            rest = [decodes[i] for i in np.nonzero(~mask)[0]]
        else:
            urgent, rest = [], []
            for r in decodes:
                slack = r.remain(now)
                if slack < self.urgency_factor * r.slo.tpot:
                    urgent.append(r)
                else:
                    rest.append(r)
        remain_cols = lambda rs: (_remain_col(rs, now),)  # noqa: E731
        for r in _ordered(urgent, lambda r: r.remain(now), remain_cols):
            if budget <= 0 or len(plan.entries) >= cfg.max_seqs:
                break
            if _admit_decode(view, r, plan, protect):
                budget -= 1
        for r in _ordered(pref, lambda r: r.remain(now), remain_cols):
            if budget <= 0 or len(plan.entries) >= cfg.max_seqs:
                break
            chunk = min(budget, cfg.chunk_size)
            budget -= _admit_prefill_chunk(view, r, chunk, plan, protect)
        for r in _ordered(rest, lambda r: r.remain(now), remain_cols):
            if budget <= 0 or len(plan.entries) >= cfg.max_seqs:
                break
            if _admit_decode(view, r, plan, protect):
                budget -= 1
        return _finalize(view, plan)


# --------------------------------------------------------------------------
# Weighted VTC (OSDI'24 fairness) — CFS-like weighted virtual token counters
# --------------------------------------------------------------------------

class WeightedVTC:
    """Clients accrue virtual time = served_tokens / weight; each round the
    scheduler serves the client with the LOWEST counter first, so processed
    token ratios track priority weights.  No SLO awareness (the paper's
    point: fairness alone cannot guarantee latency)."""
    name = "weighted_vtc"

    def __init__(self):
        self.counters: dict[int, float] = {}

    def _vt(self, client: int) -> float:
        return self.counters.get(client, 0.0)

    def _charge(self, r: Request, tokens: int) -> None:
        self.counters[r.client] = self._vt(r.client) + tokens / max(r.weight, 1e-9)

    def form_batch(self, view: SchedView) -> BatchPlan:
        plan = BatchPlan()
        protect: set[int] = set()
        cfg = view.cfg
        budget = cfg.token_budget
        # lift counters of newly active clients to min active counter (VTC)
        active = {r.client for r in view.queue if r.phase != Phase.FINISHED}
        if active:
            base = min(self._vt(c) for c in active)
            for c in active:
                if c not in self.counters:
                    self.counters[c] = base
        # decodes keep running (stall-free), charged to their clients
        decs, pref = _scan(view)

        def vt_cols(reqs):
            return (np.fromiter((self._vt(r.client) for r in reqs),
                                np.float64, len(reqs)),)

        for r in _ordered(decs, lambda r: self._vt(r.client), vt_cols):
            if budget <= 0 or len(plan.entries) >= cfg.max_seqs:
                break
            if _admit_decode(view, r, plan, protect):
                self._charge(r, 1)
                budget -= 1
        for r in _ordered(pref,
                          lambda r: (self._vt(r.client), r.arrival),
                          lambda reqs: vt_cols(reqs) + _arrival_cols(reqs)):
            if budget <= 0 or len(plan.entries) >= cfg.max_seqs:
                break
            chunk = min(budget, cfg.chunk_size)
            taken = _admit_prefill_chunk(view, r, chunk, plan, protect)
            if taken:
                self._charge(r, taken)
                budget -= taken
        return _finalize(view, plan)


POLICIES: dict[str, Callable[[], object]] = {
    "vllm_fcfs": VllmFCFS,
    "sarathi_fcfs": SarathiFCFS,
    "sarathi_priority": SarathiPriority,
    "fair_batching": FairBatching,
    "weighted_vtc": WeightedVTC,
    "edf": EDF,
    "sjf": SJF,
    "priority_first": PriorityFirst,
}


def make_policy(name: str, **kw):
    if name == "slidebatching":
        from .slidebatching import SlideBatching
        return SlideBatching(**kw)
    return POLICIES[name](**kw)
