"""Baseline batch schedulers (§5.1) implemented against the same SchedView /
BatchPlan interface as SlideBatching so every policy runs inside the
identical engine — mirroring the paper's "all schedulers implemented within
xLLM" methodology.

* vLLM-FCFS        — prefill-prioritized FCFS, whole-prompt admission,
                     recompute preemption (vLLM default).
* Sarathi-FCFS     — chunked prefill, decode-prioritized, FCFS among
                     waiting prefills, profiled token budget.
* Sarathi-Priority — Sarathi with waiting queue ordered by (priority, arrival).
* FairBatching     — enhanced EDF: decodes near deadline > prefills (EDF) >
                     remaining decodes.
* Weighted VTC     — CFS-style weighted virtual token counters per client.
* EDF / SJF / Priority-First — classic orderings (§3 motivation studies).
"""
from __future__ import annotations

from typing import Callable

from .batching import (BatchEntry, BatchPlan, SchedView, compute_remaining,
                       exec_estimate, grow_with_eviction, needed_context)
from .request import Phase, Request


# --------------------------------------------------------------------------
# shared mechanics
# --------------------------------------------------------------------------

def _decodes(view: SchedView) -> list[Request]:
    out = []
    for r in view.queue:
        if r.phase == Phase.DECODE:
            todo, _ = compute_remaining(r, view.bm)
            if todo == 0:
                out.append(r)
    return out


def _prefillable(view: SchedView) -> list[Request]:
    out = []
    for r in view.queue:
        if r.phase == Phase.FINISHED:
            continue
        todo, _ = compute_remaining(r, view.bm)
        if todo > 0:
            out.append(r)
    return out


def _restore_all_host(view: SchedView, r: Request,
                      plan: BatchPlan, protect: set[int]) -> bool:
    """Baselines restore any host-resident KV in full before running (they
    have no adaptive copy budget; w/o-dynamic behaviour)."""
    s = view.bm.state(r)
    if s.host_tokens == 0:
        return True
    cplan = view.bm.plan_reload(r, 1 << 30, 1 << 30, 1 << 30)
    need = cplan.restore_blocks
    if need > view.bm.free_blocks:
        from .batching import evict_for_space
        plan.evictions.extend(evict_for_space(view, need, protect | {r.rid}))
    if need > view.bm.free_blocks:
        return False
    view.bm.apply_reload(r, cplan, view.now)
    plan.copy_blocks += need
    return True


def _admit_decode(view: SchedView, r: Request, plan: BatchPlan,
                  protect: set[int]) -> bool:
    if not _restore_all_host(view, r, plan, protect):
        return False
    if not grow_with_eviction(view, r, 1, protect | {r.rid}, plan.evictions):
        return False
    plan.entries.append(BatchEntry(r, 1, needed_context(r), False))
    protect.add(r.rid)
    return True


def _admit_prefill_chunk(view: SchedView, r: Request, max_tokens: int,
                         plan: BatchPlan, protect: set[int]) -> int:
    """Admit up to ``max_tokens`` of (re)compute for r; returns tokens taken."""
    if not _restore_all_host(view, r, plan, protect):
        return 0
    todo, _ = compute_remaining(r, view.bm)
    chunk = min(todo, max_tokens)
    if chunk <= 0:
        return 0
    l_kv = view.bm.state(r).dev_tokens
    if not grow_with_eviction(view, r, chunk, protect | {r.rid},
                              plan.evictions):
        return 0
    plan.entries.append(BatchEntry(r, chunk, l_kv, True))
    protect.add(r.rid)
    return chunk


def _finalize(view: SchedView, plan: BatchPlan) -> BatchPlan:
    plan.est_time = view.est.batch_time(plan.work_items())
    return plan


# --------------------------------------------------------------------------
# vLLM default: prefill-prioritized FCFS, whole prompts, no chunking
# --------------------------------------------------------------------------

class VllmFCFS:
    name = "vllm_fcfs"

    def form_batch(self, view: SchedView) -> BatchPlan:
        plan = BatchPlan()
        protect: set[int] = set()
        cfg = view.cfg
        waiting = sorted(_prefillable(view), key=lambda r: r.arrival)
        budget = cfg.token_budget
        # admit WHOLE prompts FCFS while they fit the token budget; a prompt
        # longer than the whole budget runs ALONE (vLLM requires
        # max_num_batched_tokens >= max_model_len — emulated by lifting the
        # cap for a single head-of-line sequence instead of stalling it)
        for r in waiting:
            todo, _ = compute_remaining(r, view.bm)
            if len(plan.entries) >= cfg.max_seqs:
                break
            if todo > budget:
                if not plan.entries:
                    _admit_prefill_chunk(view, r, todo, plan, protect)
                break
            taken = _admit_prefill_chunk(view, r, todo, plan, protect)
            if taken == 0:
                break
            budget -= taken
        if plan.entries:          # vLLM v0: prefill batches run alone
            return _finalize(view, plan)
        for r in sorted(_decodes(view), key=lambda r: r.arrival):
            if len(plan.entries) >= cfg.max_seqs:
                break
            _admit_decode(view, r, plan, protect)
        return _finalize(view, plan)


# --------------------------------------------------------------------------
# Sarathi family: decode-prioritized + chunked prefill under token budget
# --------------------------------------------------------------------------

class _SarathiBase:
    def _waiting_order(self, view: SchedView) -> Callable[[Request], tuple]:
        raise NotImplementedError

    def form_batch(self, view: SchedView) -> BatchPlan:
        plan = BatchPlan()
        protect: set[int] = set()
        cfg = view.cfg
        budget = cfg.token_budget
        for r in sorted(_decodes(view), key=lambda r: r.arrival):
            if len(plan.entries) >= cfg.max_seqs or budget <= 0:
                break
            if _admit_decode(view, r, plan, protect):
                budget -= 1
        key = self._waiting_order(view)
        for r in sorted(_prefillable(view), key=key):
            if budget <= 0 or len(plan.entries) >= cfg.max_seqs:
                break
            chunk = min(budget, cfg.chunk_size)
            budget -= _admit_prefill_chunk(view, r, chunk, plan, protect)
        return _finalize(view, plan)


class SarathiFCFS(_SarathiBase):
    name = "sarathi_fcfs"

    def _waiting_order(self, view):
        return lambda r: (r.arrival,)


class SarathiPriority(_SarathiBase):
    name = "sarathi_priority"

    def _waiting_order(self, view):
        return lambda r: (r.priority, r.arrival)   # priority 1 first, then FCFS


class EDF(_SarathiBase):
    name = "edf"

    def _waiting_order(self, view):
        now = view.now
        return lambda r: (r.remain(now),)


class SJF(_SarathiBase):
    name = "sjf"

    def _waiting_order(self, view):
        return lambda r: (exec_estimate(r, view),)


class PriorityFirst(_SarathiBase):
    """Strict priority-first (§3.1 motivation): priority dominates everything,
    including the decode/prefill split — emulated by ordering waiting work by
    priority and letting high-priority prefills consume the whole budget."""
    name = "priority_first"

    def _waiting_order(self, view):
        return lambda r: (r.priority, r.remain(view.now))


# --------------------------------------------------------------------------
# FairBatching: decodes near deadline > EDF prefills > remaining decodes
# --------------------------------------------------------------------------

class FairBatching:
    name = "fair_batching"

    def __init__(self, urgency_factor: float = 2.0):
        self.urgency_factor = urgency_factor

    def form_batch(self, view: SchedView) -> BatchPlan:
        plan = BatchPlan()
        protect: set[int] = set()
        cfg, now = view.cfg, view.now
        budget = cfg.token_budget
        decodes = _decodes(view)
        urgent, rest = [], []
        for r in decodes:
            slack = r.remain(now)
            if slack < self.urgency_factor * r.slo.tpot:
                urgent.append(r)
            else:
                rest.append(r)
        for r in sorted(urgent, key=lambda r: r.remain(now)):
            if budget <= 0 or len(plan.entries) >= cfg.max_seqs:
                break
            if _admit_decode(view, r, plan, protect):
                budget -= 1
        for r in sorted(_prefillable(view), key=lambda r: r.remain(now)):
            if budget <= 0 or len(plan.entries) >= cfg.max_seqs:
                break
            chunk = min(budget, cfg.chunk_size)
            budget -= _admit_prefill_chunk(view, r, chunk, plan, protect)
        for r in sorted(rest, key=lambda r: r.remain(now)):
            if budget <= 0 or len(plan.entries) >= cfg.max_seqs:
                break
            if _admit_decode(view, r, plan, protect):
                budget -= 1
        return _finalize(view, plan)


# --------------------------------------------------------------------------
# Weighted VTC (OSDI'24 fairness) — CFS-like weighted virtual token counters
# --------------------------------------------------------------------------

class WeightedVTC:
    """Clients accrue virtual time = served_tokens / weight; each round the
    scheduler serves the client with the LOWEST counter first, so processed
    token ratios track priority weights.  No SLO awareness (the paper's
    point: fairness alone cannot guarantee latency)."""
    name = "weighted_vtc"

    def __init__(self):
        self.counters: dict[int, float] = {}

    def _vt(self, client: int) -> float:
        return self.counters.get(client, 0.0)

    def _charge(self, r: Request, tokens: int) -> None:
        self.counters[r.client] = self._vt(r.client) + tokens / max(r.weight, 1e-9)

    def form_batch(self, view: SchedView) -> BatchPlan:
        plan = BatchPlan()
        protect: set[int] = set()
        cfg = view.cfg
        budget = cfg.token_budget
        # lift counters of newly active clients to min active counter (VTC)
        active = {r.client for r in view.queue if r.phase != Phase.FINISHED}
        if active:
            base = min(self._vt(c) for c in active)
            for c in active:
                if c not in self.counters:
                    self.counters[c] = base
        # decodes keep running (stall-free), charged to their clients
        for r in sorted(_decodes(view), key=lambda r: self._vt(r.client)):
            if budget <= 0 or len(plan.entries) >= cfg.max_seqs:
                break
            if _admit_decode(view, r, plan, protect):
                self._charge(r, 1)
                budget -= 1
        for r in sorted(_prefillable(view),
                        key=lambda r: (self._vt(r.client), r.arrival)):
            if budget <= 0 or len(plan.entries) >= cfg.max_seqs:
                break
            chunk = min(budget, cfg.chunk_size)
            taken = _admit_prefill_chunk(view, r, chunk, plan, protect)
            if taken:
                self._charge(r, taken)
                budget -= taken
        return _finalize(view, plan)


POLICIES: dict[str, Callable[[], object]] = {
    "vllm_fcfs": VllmFCFS,
    "sarathi_fcfs": SarathiFCFS,
    "sarathi_priority": SarathiPriority,
    "fair_batching": FairBatching,
    "weighted_vtc": WeightedVTC,
    "edf": EDF,
    "sjf": SJF,
    "priority_first": PriorityFirst,
}


def make_policy(name: str, **kw):
    if name == "slidebatching":
        from .slidebatching import SlideBatching
        return SlideBatching(**kw)
    return POLICIES[name](**kw)
