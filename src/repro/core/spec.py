"""Speculative-decoding depth policy and acceptance accounting (§gain).

ProServe frames scheduling as service-gain maximization; draft-model
FLOPs are discretionary spend.  This module holds the *pure* pieces the
scheduler, the live engine and the simulator all share, so the sim
mirror and the columnar fast path stay result-identical by
construction:

* ``useful_depth`` / ``load_depth`` / ``policy_depth`` — the depth
  controller.  Deterministic, numpy-vectorizable (scalars in, scalars
  out; arrays in, arrays out), and monotone non-increasing in load for
  fixed priority, so depth collapses toward 0 under load before
  SlideBatching sheds batch width.
* ``expected_tokens`` — expected emitted tokens per verify launch at a
  given depth and acceptance rate (1 + p + ... + p^d): the estimator
  prices expected accepted-tokens/s against verify cost with it.
* ``AcceptanceEWMA`` — the acceptance-rate feedback loop.
* ``SpecAccounting`` — proposed/accepted/rejected counters with the
  ``proposed == accepted + rejected`` invariant enforced at record time.
* ``sim_accept_draw`` — the simulator's deterministic pseudo-acceptance
  oracle (splitmix-style hash), shared by the reference EngineSim loop
  and VectorClusterSim so their streams are identical.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

# Marginal-gain floor: position d in the draft chain is only worth
# proposing while P(all d prior drafts accepted) = p^d stays above this.
MARGINAL_GAIN_MIN = 0.25
# Priorities <= this keep their full policy depth; each priority level
# below loses one position (draft FLOPs flow to high-priority requests).
PRIO_FULL_DEPTH = 1
# The simulator's ground-truth per-token draft acceptance probability.
# In the live engine this is a property of draft/target agreement; the
# sim models it as a workload constant that ``sim_accept_draw`` samples
# and ``AcceptanceEWMA`` *estimates*.  Drawing from the EWMA itself
# would close a degenerate feedback loop: E[accepted/depth] < rate for
# depth > 1, so the estimate spirals down until pricing zeroes depth.
SIM_TRUE_ACCEPT_RATE = 0.85


def useful_depth(rate, k_max: int):
    """Largest depth whose marginal expected gain clears the floor.

    ``rate`` may be a scalar or an ndarray; the result is clipped to
    [0, k_max].  rate >= 1 -> k_max, rate <= floor -> 0.
    """
    r = np.clip(rate, 0.0, 1.0)
    safe = np.maximum(r, 1e-12)
    with np.errstate(divide="ignore", invalid="ignore"):
        d = np.floor(np.log(MARGINAL_GAIN_MIN) / np.log(safe))
    d = np.where(r >= 1.0, k_max, np.where(r <= MARGINAL_GAIN_MIN, 0.0, d))
    return np.clip(d, 0, k_max).astype(np.int64)


def load_depth(load, k_max: int):
    """Depth budget from instantaneous load in [0, 1].

    ``k_max - floor(load * k_max)``: full depth while the batch budget
    is mostly free, stepping down to 0 as the budget fills.  Monotone
    non-increasing in ``load`` by construction.
    """
    lo = np.clip(load, 0.0, 1.0)
    return (k_max - np.floor(lo * k_max)).astype(np.int64)


def policy_depth(load, priority, rate, k_max: int):
    """The depth controller: min(rate-justified, load budget), then a
    per-priority-level penalty below ``PRIO_FULL_DEPTH``.  Always in
    [0, k_max]; monotone non-increasing in ``load`` for fixed priority
    and rate.  Scalar or columnar."""
    if k_max <= 0:
        z = np.zeros_like(np.asarray(load), dtype=np.int64)
        return z if np.ndim(load) else np.int64(0)
    d = np.minimum(useful_depth(rate, k_max), load_depth(load, k_max))
    penalty = np.maximum(np.asarray(priority) - PRIO_FULL_DEPTH, 0)
    d = np.maximum(d - penalty, 0)
    return d if np.ndim(d) else np.int64(d)


def expected_tokens(depth, rate):
    """Expected tokens emitted per verify at ``depth``: 1 + p + ... + p^d.

    Always >= 1 (the verify emits at least the greedy next token)."""
    r = np.clip(rate, 0.0, 1.0)
    d = np.asarray(depth, dtype=np.float64)
    geo = (1.0 - r ** (d + 1.0)) / np.maximum(1.0 - r, 1e-12)
    return np.where(r >= 1.0, d + 1.0, geo)


def price_depth(t0: float, overhead_of, d_cap: int, rate: float) -> int:
    """Pick the depth in [0, d_cap] maximizing expected tokens/s.

    ``t0`` is the plain decode cost, ``overhead_of(d)`` the extra verify
    + draft cost at depth d (0 at d=0).  Deterministic: first depth with
    a strictly greater rate wins ties, so depth 0 is the fixed point
    when speculation never pays."""
    best_d, best_v = 0, 1.0 / t0 if t0 > 0 else 0.0
    for d in range(1, int(d_cap) + 1):
        t = t0 + overhead_of(d)
        v = float(expected_tokens(d, rate)) / t if t > 0 else 0.0
        if v > best_v:
            best_d, best_v = d, v
    return best_d


class AcceptanceEWMA:
    """Exponentially-weighted acceptance rate, optimistic at start so
    speculation engages before the first measurement.

    ``probe()`` is the explore half of the loop.  The EWMA only
    observes outcomes while speculating, so a noisy dip below the
    estimator's pricing threshold would freeze the rate at
    zero-speculation forever (an absorbing state: no drafts, no
    observations, no recovery).  Every ``probe_every``-th
    declined-but-feasible opportunity forces a depth-1 draft to
    refresh the estimate."""

    def __init__(self, init: float = 0.8, alpha: float = 0.2,
                 probe_every: int = 16):
        self.rate = float(init)
        self.alpha = float(alpha)
        self.probe_every = int(probe_every)
        self._declined = 0

    def update(self, proposed: int, accepted: int) -> float:
        if proposed > 0:
            obs = accepted / proposed
            self.rate += self.alpha * (obs - self.rate)
        return self.rate

    def probe(self) -> bool:
        """Record one declined-but-feasible opportunity; True on every
        ``probe_every``-th, telling the scheduler to draft depth 1
        anyway.  Deterministic, so the sim's reference and vectorized
        paths stay result-identical."""
        self._declined += 1
        if self._declined >= self.probe_every:
            self._declined = 0
            return True
        return False


@dataclass
class SpecAccounting:
    """proposed == accepted + rejected, by construction, always."""
    proposed: int = 0
    accepted: int = 0
    rejected: int = 0
    depth_hist: dict = field(default_factory=dict)

    def record(self, depth: int, accepted: int) -> None:
        if not 0 <= accepted <= depth:
            raise ValueError(f"accepted {accepted} outside [0, {depth}]")
        self.proposed += depth
        self.accepted += accepted
        self.rejected += depth - accepted
        self.depth_hist[depth] = self.depth_hist.get(depth, 0) + 1

    def check(self) -> bool:
        return self.proposed == self.accepted + self.rejected


def _hash01(n: int) -> float:
    """Deterministic uniform draw in [0, 1) from an integer key."""
    x = (n * 2654435761) & 0xFFFFFFFF
    x ^= x >> 16
    x = (x * 2246822519) & 0xFFFFFFFF
    x ^= x >> 13
    return x / 4294967296.0

def sim_accept_draw(rid: int, step: int, depth: int, rate: float) -> int:
    """Simulator acceptance oracle: leading-accept count of ``depth``
    independent hash draws against ``rate``.  Pure function of its
    arguments, so the reference loop and the vectorized sim agree."""
    a = 0
    for j in range(depth):
        if _hash01(rid * 1_000_003 + step * 7919 + j) < rate:
            a += 1
        else:
            break
    return a
