"""ProServe scheduling core: TDG gain, latency estimator, SlideBatching,
block management, GoRouting, and all baseline policies."""
from .request import Request, SLO, Phase
from .tdg import tdg_gain, tdg_ratio, ideal_gain, weighted_slo_gain, ta_slo_gain
from .estimator import BatchLatencyEstimator
from .blocks import BlockManager, blocks_for
from .prefix import PrefixRegistry, SimPrefixCache, chunk_hashes
from .batching import BatchEntry, BatchPlan, EngineConfig, SchedView
from .slidebatching import SlideBatching
from .spec import (AcceptanceEWMA, SpecAccounting, expected_tokens,
                   policy_depth, price_depth, sim_accept_draw, useful_depth)
from .schedulers import make_policy, POLICIES
from .gorouting import (GoRouting, MinLoad, RoundRobin, RouterConfig,
                        InstanceState, QueuedStub, ROUTERS)

__all__ = [
    "Request", "SLO", "Phase", "tdg_gain", "tdg_ratio", "ideal_gain",
    "weighted_slo_gain", "ta_slo_gain", "BatchLatencyEstimator",
    "BlockManager", "blocks_for", "PrefixRegistry", "SimPrefixCache",
    "chunk_hashes", "BatchEntry", "BatchPlan", "EngineConfig",
    "SchedView", "SlideBatching", "AcceptanceEWMA", "SpecAccounting",
    "expected_tokens", "policy_depth", "price_depth", "sim_accept_draw",
    "useful_depth", "make_policy", "POLICIES", "GoRouting",
    "MinLoad", "RoundRobin", "RouterConfig", "InstanceState", "QueuedStub",
    "ROUTERS",
]
