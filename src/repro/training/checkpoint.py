"""Fault-tolerant sharded checkpointing (no external deps).

Layout:  <dir>/step_<k>/
            shard_<i>.npz      one file per host-local shard group
            manifest.json      pytree structure + shapes + dtypes + crc32s
         <dir>/LATEST          atomically-renamed pointer file

Properties needed at 1000-node scale and provided here:
  * **atomicity** — writes go to ``step_<k>.tmp`` then ``os.replace`` to the
    final name; the LATEST pointer is updated last, so a crash mid-save can
    never corrupt the restore path;
  * **integrity** — per-array crc32 stored in the manifest and verified on
    restore;
  * **async save** — serialization runs on a background thread off the
    training critical path (``save_async``), double-buffered;
  * **resharding restore** — arrays are saved unsharded-logical (gathered)
    but restored with any target sharding via ``jax.device_put``, so a
    restart may use a different mesh shape (elastic restart);
  * **retention** — keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(x) for x in leaves], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree) -> str:
        leaves, _ = _flatten(tree)
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "arrays": []}
        arrays = {}
        for i, a in enumerate(leaves):
            manifest["arrays"].append({
                "name": f"a{i}", "shape": list(a.shape),
                "dtype": str(a.dtype), "crc32": zlib.crc32(a.tobytes())})
            arrays[f"a{i}"] = a
        np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        latest_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(str(step))
        os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()
        return final

    def save_async(self, step: int, tree) -> None:
        """Copy to host (blocking only for device->host) then write off-thread."""
        host_tree = jax.tree.map(np.asarray, tree)
        self.wait()
        self._thread = threading.Thread(
            target=self.save, args=(step, host_tree), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip())

    def restore(self, template, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``template``; optionally place each
        leaf with the given shardings pytree (resharding restore)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "shard_0.npz"))
        _, treedef = jax.tree.flatten(template)
        leaves = []
        for meta in manifest["arrays"]:
            a = data[meta["name"]]
            if zlib.crc32(a.tobytes()) != meta["crc32"]:
                raise IOError(f"checksum mismatch in {meta['name']} "
                              f"(corrupt checkpoint {d})")
            leaves.append(a)
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, step

    def _gc(self) -> None:
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(self.dir)
                       if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
