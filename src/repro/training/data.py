"""Deterministic synthetic token pipeline.

Seeded, shardable, restart-safe: batch ``i`` is a pure function of
(seed, i), so resuming from a checkpoint at step k replays the exact
stream without any state files.  A lightweight mixture (zipf unigram +
repeated n-gram motifs) gives the loss curve some structure to descend.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


class TokenPipeline:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 enc_frames: int = 0, d_model: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        self.enc_frames, self.d_model = enc_frames, d_model

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        zipf = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        toks = (zipf % (self.vocab - 2)) + 1
        # inject repeated motifs so the model has learnable structure
        motif = (np.arange(8) * 7 + 11) % (self.vocab - 2) + 1
        pos = rng.integers(0, self.seq - 8, size=(self.batch,))
        for b in range(min(self.batch, 64)):
            toks[b, pos[b]:pos[b] + 8] = motif
        out = {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        if self.enc_frames:
            out["enc_inputs"] = rng.standard_normal(
                (self.batch, self.enc_frames, self.d_model)).astype(
                np.float32) * 0.02
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
