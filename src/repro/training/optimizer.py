"""AdamW optimizer (pure JAX pytree implementation) with optional
int8 gradient compression for the cross-pod all-reduce.

The optimizer state (fp32 master copy + first/second moments) inherits the
parameter sharding, so FSDP keeps it fully distributed (ZeRO-1/2 style).
``compress_grads`` quantizes gradients to int8 with a per-tensor scale
before the data-parallel all-reduce and dequantizes after — an 8×
reduction in cross-pod gradient traffic (DESIGN.md §5, distributed-
optimization trick; error feedback keeps the quantization bias bounded).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict
    master: dict          # fp32 master params
    err: Optional[dict]   # error-feedback residual (compression only)


def init_adamw(params: dict, *, compress: bool = False) -> AdamWState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        master=jax.tree.map(f32, params),
        err=jax.tree.map(zeros, params) if compress else None)


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(g: jax.Array, err: jax.Array,
                        ) -> tuple[jax.Array, jax.Array]:
    """int8 round-trip with error feedback: returns (ĝ, new_err)."""
    g_c = g + err
    q, s = quantize_int8(g_c)
    g_hat = dequantize_int8(q, s)
    return g_hat, g_c - g_hat


def adamw_update(grads: dict, state: AdamWState, params: dict, *,
                 lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 grad_clip: float = 1.0,
                 compress: bool = False) -> tuple[dict, AdamWState]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if compress and state.err is not None:
        pairs = jax.tree.map(compress_decompress, grads, state.err)
        grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda p: p[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_err = state.err

    # global-norm clip
    gn = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gn, 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / bc1, v / bc2
        p_new = p - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)
        return m, v, p_new

    out = jax.tree.map(upd, grads, state.mu, state.nu, state.master)
    mu = jax.tree.map(lambda t: t[0], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), master, params)
    return new_params, AdamWState(step, mu, nu, master, new_err)
