"""Training step: sharded cross-entropy + AdamW + grad-accum microbatching.

``make_train_step`` returns a pure function suitable for ``jax.jit`` with
``in_shardings``/``out_shardings`` from distributed/sharding.py:

    (params, opt_state, batch{tokens, labels}) -> (params, opt_state, metrics)

The loss never materializes a replicated (tokens, vocab) logits tensor:
logits stay sharded (tokens over pod×data, vocab over model) and the
log-sum-exp reduction lowers to small all-reduces over the model axis.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..models.model import ArchConfig, forward
from .optimizer import AdamWState, adamw_update


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits: (B, S, V) possibly vocab-sharded; labels: (B, S) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - gold


def make_loss_fn(cfg: ArchConfig, *, attn_impl: str = "dense",
                 shard_fn: Optional[Callable] = None, remat: bool = True):
    def loss_fn(params, tokens, labels, enc_inputs=None):
        logits, _ = forward(cfg, params, tokens, attn_impl=attn_impl,
                            shard_fn=shard_fn, remat=remat,
                            enc_inputs=enc_inputs)
        ce = softmax_cross_entropy(logits, labels)
        return ce.mean()
    return loss_fn


def make_train_step(cfg: ArchConfig, *, attn_impl: str = "dense",
                    shard_fn: Optional[Callable] = None, remat: bool = True,
                    lr: float = 3e-4, grad_clip: float = 1.0,
                    microbatches: int = 1, compress_grads: bool = False,
                    grad_constraint: Optional[Callable] = None):
    """Builds train_step.  ``microbatches`` > 1 splits the global batch on
    the leading axis and accumulates gradients with a lax.scan (grad-accum),
    trading step latency for activation memory.

    ``grad_constraint``: optional pytree-sharding callback applied to each
    microbatch's gradients — pinning grads to the parameter sharding makes
    XLA emit per-layer REDUCE-SCATTERs instead of full all-reduces (ZeRO
    gradient sharding)."""
    loss_fn = make_loss_fn(cfg, attn_impl=attn_impl, shard_fn=shard_fn,
                           remat=remat)
    _raw_grad = jax.value_and_grad(loss_fn)

    def grad_fn(params, tokens, labels, enc=None):
        loss, g = _raw_grad(params, tokens, labels, enc)
        if grad_constraint is not None:
            g = grad_constraint(g)
        return loss, g

    def train_step(params, opt_state: AdamWState, batch: dict):
        tokens, labels = batch["tokens"], batch["labels"]
        enc = batch.get("enc_inputs")

        if microbatches <= 1:
            loss, grads = grad_fn(params, tokens, labels, enc)
        else:
            mb_tok = tokens.reshape(microbatches, -1, tokens.shape[-1])
            mb_lab = labels.reshape(microbatches, -1, labels.shape[-1])
            mb_enc = (enc.reshape(microbatches, -1, *enc.shape[1:])
                      if enc is not None else None)

            def acc_body(carry, xs):
                loss_acc, g_acc = carry
                t, l = xs[0], xs[1]
                e = xs[2] if len(xs) > 2 else None
                loss, g = grad_fn(params, t, l, e)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (loss_acc + loss, g_acc), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            xs = (mb_tok, mb_lab) if mb_enc is None else (mb_tok, mb_lab,
                                                          mb_enc)
            (loss, grads), _ = jax.lax.scan(acc_body, (0.0, zero_g), xs)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        new_params, new_opt = adamw_update(
            grads, opt_state, params, lr=lr, grad_clip=grad_clip,
            compress=compress_grads)
        gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                          for g in jax.tree.leaves(grads)))
        return new_params, new_opt, {"loss": loss, "grad_norm": gn}

    return train_step
