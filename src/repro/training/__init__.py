from .optimizer import AdamWState, init_adamw, adamw_update
from .train import make_train_step, make_loss_fn, softmax_cross_entropy
from .data import TokenPipeline
from .checkpoint import CheckpointManager

__all__ = ["AdamWState", "init_adamw", "adamw_update", "make_train_step",
           "make_loss_fn", "softmax_cross_entropy", "TokenPipeline",
           "CheckpointManager"]
