"""Asynchronous service tier: admission control + GoRouting dispatch over a
fleet of threaded engine replicas, streaming tokens to asyncio consumers.

Architecture (the Ray-Serve LLMRouter/LLMServer split, adapted):

    client coroutine ──submit()──► ServiceFrontend (asyncio, ingress)
                                      │  admission control (per-priority)
                                      │  GoRouting select + RouterBook
                                      ▼
                             EngineDriver inbox (per replica, thread-safe)
                                      │  driver thread: continuous batching
                                      ▼
                             Engine.step() ──TokenEvent──► sink
                                      │   call_soon_threadsafe
                                      ▼
                             RequestStream (asyncio.Queue) ──► client

Every request is admitted against per-priority in-flight quotas (reject
fast, or await a slot with ``wait=True`` — backpressure), dispatched by the
router to one replica's inbox, and streamed back as :class:`TokenEvent`s.
The stream records *client-edge* receive times so TTFT/TPOT attainment is
measured where a user would measure it, not inside the engine.

Fault tolerance mirrors the synchronous ``ServiceController``: every
request is logged at admission; ``kill_instance`` re-dispatches orphans
with their already-streamed tokens as ``prior_outputs`` so generation
resumes exactly (the client stream never notices beyond added latency).
"""
from __future__ import annotations

import asyncio
import itertools
import threading
import time
from dataclasses import dataclass
from typing import AsyncIterator, Optional

import numpy as np

from ..core.estimator import BatchLatencyEstimator
from ..core.gorouting import pick_decode_target
from ..core.request import Request
from .dispatch import RouterBook
from .engine import (Engine, EngineDriver, HandoffAdopted, HandoffDropped,
                     HandoffEvent, HandoffPayload, StepEvent, TokenEvent)


class AdmissionError(RuntimeError):
    """Request rejected at the ingress (quota exhausted or no live replica)."""

    def __init__(self, msg: str, *, priority: Optional[int] = None,
                 inflight: Optional[int] = None,
                 limit: Optional[int] = None):
        super().__init__(msg)
        self.priority = priority
        self.inflight = inflight
        self.limit = limit


@dataclass
class FrontendConfig:
    max_inflight: int = 512            # global admission cap
    # per-priority in-flight quotas; priorities absent from the map share
    # the global cap only.  This is the backpressure isolation: a flood of
    # low-priority traffic cannot consume high-priority admission slots.
    priority_quota: Optional[dict] = None
    speed_ewma: float = 0.2            # straggler EWMA (RouterBook)
    driver_idle_wait: float = 2e-3     # driver park interval when idle


class RequestStream:
    """Async iterator over one request's :class:`TokenEvent`s.

    Records client-edge receive stamps: ``ttft``/``tpot`` here include
    queueing, dispatch, batching and the thread→loop hop — everything a
    real client would see.
    """

    def __init__(self, req: Request, loop: asyncio.AbstractEventLoop):
        self.request = req
        self._loop = loop
        self._q: asyncio.Queue = asyncio.Queue()
        self.submitted = time.monotonic()
        self.tokens: list[int] = []
        self.recv_times: list[float] = []
        self.done = False
        self._error: Optional[BaseException] = None

    # -- producer side (loop thread, via call_soon_threadsafe) ----------
    def _push(self, ev: TokenEvent) -> None:
        self._q.put_nowait(ev)

    def _close(self, error: Optional[BaseException] = None) -> None:
        self._error = error
        self._q.put_nowait(None)

    # -- consumer side ---------------------------------------------------
    def __aiter__(self) -> AsyncIterator[TokenEvent]:
        return self

    async def __anext__(self) -> TokenEvent:
        if self.done:
            raise StopAsyncIteration
        ev = await self._q.get()
        if ev is None:
            self.done = True
            if self._error is not None:
                raise self._error
            raise StopAsyncIteration
        self.tokens.append(ev.token)
        self.recv_times.append(time.monotonic())
        if ev.last:
            self.done = True
        return ev

    async def collect(self) -> list[int]:
        """Drain the stream; returns all tokens."""
        async for _ in self:
            pass
        return self.tokens

    # -- client-edge latency metrics -------------------------------------
    @property
    def ttft(self) -> Optional[float]:
        return (self.recv_times[0] - self.submitted
                if self.recv_times else None)

    @property
    def tpot(self) -> Optional[float]:
        if len(self.recv_times) < 2:
            return None
        span = self.recv_times[-1] - self.recv_times[0]
        return span / (len(self.recv_times) - 1)

    def met_slo(self) -> bool:
        slo = self.request.slo
        if self.ttft is None or self.ttft >= slo.ttft:
            return False
        t = self.tpot
        return True if t is None else t < slo.tpot

    @property
    def complete(self) -> bool:
        """All expected tokens received (not closed early / truncated)."""
        return len(self.tokens) >= self.request.output_len

    def as_request(self) -> Request:
        """Clone with client-edge timing, for ``sim.metrics.summarize``.
        Keeps the TRUE output_len: a stream truncated by an abort scores
        as unfinished, not as a short successful request."""
        r = Request(prompt_len=self.request.prompt_len,
                    output_len=max(1, self.request.output_len),
                    arrival=0.0, slo=self.request.slo,
                    priority=self.request.priority,
                    weight=self.request.weight,
                    client=self.request.client)
        for t in self.recv_times:
            r.emit_token(t - self.submitted)
        return r


class ServiceFrontend:
    """Async ingress over N threaded engine replicas (see module doc)."""

    def __init__(self, router, est: BatchLatencyEstimator,
                 cfg: FrontendConfig = FrontendConfig()):
        self.cfg = cfg
        self.book = RouterBook(router, est, speed_ewma=cfg.speed_ewma)
        self.drivers: dict[int, EngineDriver] = {}
        self._iid = itertools.count()
        self._epoch = time.monotonic()
        self._lock = threading.Lock()       # guards book + maps + counters
        self._streams: dict[int, RequestStream] = {}
        self._reqs: dict[int, Request] = {}
        self._rid_iid: dict[int, int] = {}
        self._inflight: dict[int, int] = {}
        self._total_inflight = 0
        self._slot_events: dict[int, asyncio.Event] = {}
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self.finished: list[Request] = []
        self.completed_streams: list[RequestStream] = []
        self.rejected = 0
        self._started = False

    # --- fleet management -----------------------------------------------
    def add_instance(self, engine: Engine) -> int:
        """Register a replica; spawns its driver thread if started."""
        iid = next(self._iid)
        engine.use_wall_clock(self._epoch)
        driver = EngineDriver(iid, engine, self._make_sink(iid),
                              idle_wait=self.cfg.driver_idle_wait)
        with self._lock:
            self.drivers[iid] = driver
            self.book.add_instance(iid, engine.bm.num_device_blocks,
                                   engine.bm.free_blocks,
                                   has_prefix_cache=engine.cache is not None,
                                   role=engine.role)
        if self._started:
            driver.start()
        return iid

    def kill_instance(self, iid: int) -> None:
        """Hard failure: stop the driver, re-dispatch orphans from the log
        with their already-emitted tokens (generation resumes exactly)."""
        driver = self.drivers.pop(iid, None)
        if driver is None:
            return
        with self._lock:
            self.book.drop_instance(iid)
        orphans = driver.kill()
        for req in orphans:
            self._redispatch(req)

    def _redispatch(self, req: Request) -> None:
        logged = self.book.request_log.get(req.rid)
        if logged is None:
            return
        # resume from the durable log, not the dead engine's memory: an
        # orphan still sitting in an inbox (double failover) has no
        # engine.outputs entry, but the log always has every streamed token.
        _, prompt, partial = logged
        partial = list(partial)
        with self._lock:
            iid = self.book.route(req, self._now(), prompt_tokens=prompt)
            if iid is None:
                stream = self._streams.pop(req.rid, None)
                self.book.forget(req.rid)
                self._release_slot(req)
                if stream is not None and self._loop is not None:
                    self._loop.call_soon_threadsafe(
                        stream._close,
                        AdmissionError("no live replica for failover",
                                       priority=req.priority))
                return
            self._rid_iid[req.rid] = iid
            driver = self.drivers[iid]
        driver.submit(req, prompt, prior_outputs=partial)

    @property
    def engines(self) -> dict[int, Engine]:
        return {iid: d.engine for iid, d in self.drivers.items()}

    # --- lifecycle -------------------------------------------------------
    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._started = True
        for d in self.drivers.values():
            d.start()

    async def stop(self) -> None:
        self._started = False
        for d in self.drivers.values():
            d.stop()
        # wake any consumer still waiting on a stream
        with self._lock:
            streams = list(self._streams.values())
            self._streams.clear()
        for s in streams:
            if not s.done:
                s._close()

    async def drain(self, timeout: float = 120.0) -> bool:
        """Wait until every admitted request has finished streaming."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                if self._total_inflight == 0:
                    return True
            await asyncio.sleep(2e-3)
        return False

    def _now(self) -> float:
        return time.monotonic() - self._epoch

    # --- admission control -----------------------------------------------
    def _quota(self, priority: int) -> int:
        if self.cfg.priority_quota and priority in self.cfg.priority_quota:
            return self.cfg.priority_quota[priority]
        return self.cfg.max_inflight

    def _admit(self, priority: int) -> bool:
        return (self._total_inflight < self.cfg.max_inflight
                and self._inflight.get(priority, 0) < self._quota(priority))

    def _release_slot(self, req: Request) -> None:
        """Caller holds the lock."""
        self._total_inflight -= 1
        self._inflight[req.priority] -= 1
        self._reqs.pop(req.rid, None)
        self._rid_iid.pop(req.rid, None)
        if self._loop is not None:
            ev = self._slot_events.get(req.priority)
            if ev is not None:
                self._loop.call_soon_threadsafe(ev.set)

    # --- ingress ----------------------------------------------------------
    async def submit(self, req: Request, prompt_tokens,
                     *, wait: bool = False,
                     stamp_arrival: bool = True) -> RequestStream:
        """Admit + dispatch one request; returns its token stream.

        ``wait=False``: reject immediately with :class:`AdmissionError`
        when the priority's quota (or the global cap) is exhausted.
        ``wait=True``: apply backpressure instead — suspend this coroutine
        until a slot of the same priority frees up.
        """
        if self._loop is None:
            raise RuntimeError("frontend not started — await start() first")
        p = req.priority
        while True:
            with self._lock:
                if self._admit(p):
                    self._total_inflight += 1
                    self._inflight[p] = self._inflight.get(p, 0) + 1
                    break
                if not wait:
                    self.rejected += 1
                    raise AdmissionError(
                        f"priority {p} at quota "
                        f"({self._inflight.get(p, 0)}/{self._quota(p)}, "
                        f"total {self._total_inflight}"
                        f"/{self.cfg.max_inflight})",
                        priority=p, inflight=self._inflight.get(p, 0),
                        limit=self._quota(p))
                ev = self._slot_events.setdefault(p, asyncio.Event())
                ev.clear()
            await ev.wait()

        now = self._now()
        if stamp_arrival:
            req.arrival = now
        stream = RequestStream(req, self._loop)
        prompt_arr = np.asarray(prompt_tokens, np.int32)
        with self._lock:
            self.book.log_request(req, prompt_arr)
            iid = self.book.route(req, now, prompt_tokens=prompt_arr)
            if iid is None:
                self.book.forget(req.rid)
                self._release_slot(req)
                self.rejected += 1
                raise AdmissionError("no live replica", priority=p)
            self._streams[req.rid] = stream
            self._reqs[req.rid] = req
            self._rid_iid[req.rid] = iid
            driver = self.drivers[iid]
        driver.submit(req, prompt_arr)
        return stream

    # --- event sink (driver threads) ---------------------------------------
    def _make_sink(self, iid: int):
        def sink(ev) -> None:
            if isinstance(ev, TokenEvent):
                self._on_token(iid, ev)
            elif isinstance(ev, StepEvent):
                self._on_step(ev)
            elif isinstance(ev, HandoffEvent):
                self._on_handoff(iid, ev.payload)
            elif isinstance(ev, HandoffAdopted):
                self._on_handoff_adopted(ev.iid, ev.payload)
            elif isinstance(ev, HandoffDropped):
                self._redispatch(ev.payload.req)
        return sink

    # --- disagg two-leg lifecycle (driver threads) ------------------------
    def _on_handoff(self, src_iid: int, payload: HandoffPayload) -> None:
        """A prefill replica exported a payload: forward it to the decode
        replica reserved at admission — or, if that replica died mid-
        handoff, to the best surviving decode replica; with none left,
        fail over to a re-prefill (which route() lands on a coloc
        replica via the durable log)."""
        rid = payload.req.rid
        with self._lock:
            self.book.on_handoff_sent(src_iid, rid, self._now())
            d_iid = self.book.decode_target(rid)
            driver = self.drivers.get(d_iid) if d_iid is not None else None
            if driver is None:
                d_pool = [st for st in self.book.states.values()
                          if st.role == "decode"]
                d_iid = pick_decode_target(d_pool, payload.req,
                                           self.book.block_size)
                driver = (self.drivers.get(d_iid)
                          if d_iid is not None else None)
            if driver is not None:
                self._rid_iid[rid] = d_iid
        if driver is not None:
            driver.submit_handoff(payload)
        else:
            self._redispatch(payload.req)

    def _on_handoff_adopted(self, iid: int, payload: HandoffPayload) -> None:
        with self._lock:
            self.book.on_handoff_delivered(
                payload.req.rid, iid, payload.n_blocks,
                payload.wire_bytes, self._now())

    def _on_token(self, iid: int, ev: TokenEvent) -> None:
        with self._lock:
            stream = self._streams.get(ev.rid)
            logged = self.book.request_log.get(ev.rid)
            if logged is not None:       # stream into the durable log
                logged[2].append(ev.token)
        if stream is not None and self._loop is not None:
            self._loop.call_soon_threadsafe(stream._push, ev)

    def _on_step(self, ev: StepEvent) -> None:
        now = self._now()
        with self._lock:
            self.book.observe_step(ev.iid, free_blocks=ev.free_blocks,
                                   est_time=ev.est_time, latency=ev.latency)
            for rid in ev.prefill_done:
                self.book.on_first_token(ev.iid, rid, now)
            for rid in ev.finished:
                req = self._reqs.get(rid)
                self.book.on_finished(ev.iid, rid)
                stream = self._streams.pop(rid, None)
                if stream is not None:
                    self.completed_streams.append(stream)
                if req is not None:
                    self.finished.append(req)
                    self._release_slot(req)

    # --- reporting ----------------------------------------------------------
    def client_edge_requests(self) -> list[Request]:
        """Completed streams as Requests stamped with client-edge times —
        feed straight into ``repro.sim.metrics.summarize``."""
        return [s.as_request() for s in self.completed_streams]
