"""Real serving runtime: paged KV pool, jitted model exec, continuous-
batching engine, GoRouting service controller with fault tolerance."""
from .kv_pool import PagedKVPool
from .engine import Engine, EngineStats
from .service import ServiceController, ServiceConfig

__all__ = ["PagedKVPool", "Engine", "EngineStats", "ServiceController",
           "ServiceConfig"]
