"""Real serving runtime: paged KV pool, jitted model exec, continuous-
batching engine, threaded engine drivers, the synchronous GoRouting service
controller, and the async streaming front-end."""
from .kv_pool import PagedKVPool
from .prefix_cache import RadixPrefixCache
from .spec import DraftRunner
from .transfer import TransferDone, TransferWorker
from .engine import (Engine, EngineDriver, EngineStats, HandoffAdopted,
                     HandoffDropped, HandoffEvent, HandoffPayload,
                     StepEvent, TokenEvent)
from .dispatch import RouterBook
from .service import ServiceController, ServiceConfig
from .frontend import (AdmissionError, FrontendConfig, RequestStream,
                       ServiceFrontend)

__all__ = ["PagedKVPool", "RadixPrefixCache", "DraftRunner", "TransferDone",
           "TransferWorker", "Engine", "EngineDriver",
           "EngineStats", "HandoffAdopted", "HandoffDropped",
           "HandoffEvent", "HandoffPayload", "StepEvent", "TokenEvent",
           "RouterBook",
           "ServiceController", "ServiceConfig", "AdmissionError",
           "FrontendConfig", "RequestStream", "ServiceFrontend"]
