"""Radix-tree prefix cache over the paged KV store.

Matches incoming prompts against cached prefixes at BLOCK granularity
(token-aligned to ``block_size``): a hit lets the request point its block
table at the cached physical blocks (``PagedKVPool.share`` — reference
counted, copy-on-write on any later write into a shared block) and charges
only the uncached suffix to chunked prefill.

Structure: a compressed radix tree whose edges are runs of full token
blocks.  Each node stores the token content of its run (one tuple per
block) and the physical device blocks holding that run's KV.  Divergence
inside a node splits it at the block boundary (the standard radix split),
so every cached block is owned by exactly one node.

Lifecycle / accounting (composes with ``core.blocks.BlockManager``):

* ``match``   — admission: walk the tree, return the longest cached prefix
  usable by the prompt (at least one prompt token is always left uncached
  so the completing pass yields first-token logits), pin the path.
* ``insert``  — first-token time: adopt the request's uniquely-owned full
  prompt blocks into the tree (cache takes a pool reference; the caller
  transfers the block charge with ``BlockManager.donate_to_cache``).
* ``reclaim`` — LRU + priority-weighted eviction of UNPINNED leaves only;
  a shared block is pinned while any live request references it, so §4.3
  offload/evict never touches a block with more than one referent.

**Tiered spill (``spill=True``).**  Instead of destroying an evicted
node's KV, reclaim SPILLS it into the pool's ``KVTierStore`` under a
fresh negative pseudo-rid: the node stays in the tree with
``blocks == []`` and ``host_rid`` set, its device blocks are freed, and
its data rides the host tier's LRU (demoting to the int8 cold tier under
byte pressure).  A later ``match`` walking onto a spilled node RESTORES
it — preferring a buffer the transfer worker pre-staged through the
double-buffered H2D lane, else one synchronous batched scatter — and a
later ``insert`` whose prompt covers the node RE-ADOPTS the inserting
request's freshly prefilled device blocks directly (no copy at all),
dropping the host copy.  Spilled subtrees count zero device blocks, so
``max_blocks`` keeps bounding HBM while the tier bounds host bytes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from ..core.blocks import BlockManager
from .kv_pool import PagedKVPool


@dataclass(eq=False)     # identity semantics: nodes live in pin sets
class _Node:
    key: list            # token content, one tuple[int, ...] per block
    blocks: list         # physical block ids, len == len(key)
    children: dict = field(default_factory=dict)  # first-block tuple -> _Node
    parent: Optional["_Node"] = None
    pins: set = field(default_factory=set)        # rids using these blocks
    last_used: float = 0.0
    weight: float = 1.0  # max priority weight of requests that used it
    host_rid: Optional[int] = None  # tier pseudo-rid when spilled (blocks=[])


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    hit_tokens: int = 0
    inserted_blocks: int = 0
    evicted_blocks: int = 0
    cow_forks: int = 0
    spilled_blocks: int = 0    # blocks parked in the host tier on eviction
    restored_blocks: int = 0   # spilled blocks reloaded to device on match
    readopted_blocks: int = 0  # spilled blocks re-adopted from an insert
    staged_restores: int = 0   # restores served from pre-staged H2D buffers


class RadixPrefixCache:
    """One engine replica's prefix cache (not thread-safe by itself: the
    engine touches it only from its driver thread, like the pool)."""

    def __init__(self, pool: PagedKVPool, bm: BlockManager,
                 max_blocks: Optional[int] = None,
                 priority_bonus: float = 30.0, spill: bool = False):
        self.pool = pool
        self.bm = bm
        self.block_size = pool.block_size
        self.max_blocks = (pool.num_blocks // 2 if max_blocks is None
                           else max_blocks)
        self.priority_bonus = priority_bonus
        self.spill = spill                   # evictions park in the tier
        self.worker = None                   # optional TransferWorker (H2D
        #                                      staging for spill restores)
        self.root = _Node(key=[], blocks=[])
        self._locks: dict[int, set] = {}     # rid -> pinned nodes
        self._spilled: dict[int, _Node] = {}  # host pseudo-rid -> node
        self.stats = CacheStats()
        bm.cache = self

    # ------------------------------------------------------------------
    def _chunks(self, tokens, n_blocks: int) -> list[tuple]:
        bs = self.block_size
        return [tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])
                for i in range(n_blocks)]

    def _split(self, node: _Node, at: int) -> _Node:
        """Split ``node`` after its first ``at`` blocks; returns the upper
        half (which keeps the parent edge)."""
        lower = _Node(key=node.key[at:], blocks=node.blocks[at:],
                      children=node.children, parent=node,
                      pins=set(node.pins), last_used=node.last_used,
                      weight=node.weight)
        for c in lower.children.values():
            c.parent = lower
        node.key = node.key[:at]
        node.blocks = node.blocks[:at]
        node.children = {lower.key[0]: lower}
        if node.host_rid is not None:
            # splitting a SPILLED node: partition its tier group so both
            # halves stay independently reloadable.  Any buffer the worker
            # already staged for the old pseudo-rid remains valid for the
            # upper half (adopt takes the first ``at`` blocks).
            lower_host = self.pool.new_cache_rid()
            self.pool.tier.split_group(node.host_rid, at, lower_host)
            lower.host_rid = lower_host
            self._spilled[lower_host] = lower
        # pinning rids now hold both halves
        for rid in node.pins:
            self._locks[rid].add(lower)
        return node

    def _walk(self, chunks: list[tuple], on_spilled=None
              ) -> tuple[int, list[int], list[_Node]]:
        """Longest existing path matching ``chunks``, splitting the last
        node if the match ends inside it, so the match always ends at a
        node boundary.  Returns (blocks matched, physical blocks, path).

        Walking onto a SPILLED node calls ``on_spilled(node, i, path)``,
        which must bring the node's blocks back on device (restore or
        re-adopt) and return True — returning False (or no callback)
        stops the walk before the spilled node."""
        node, i, blocks, path = self.root, 0, [], []
        while i < len(chunks):
            child = node.children.get(chunks[i])
            if child is None:
                break
            j = 0
            while (j < len(child.key) and i + j < len(chunks)
                   and child.key[j] == chunks[i + j]):
                j += 1
            if j == 0:
                break
            if j < len(child.key):
                child = self._split(child, j)
            if child.host_rid is not None:
                if on_spilled is None or not on_spilled(child, i, path):
                    break
            blocks += child.blocks
            path.append(child)
            i += j
            node = child
        return i, blocks, path

    # --- engine surface -------------------------------------------------
    def match(self, tokens: np.ndarray, now: float, rid: int,
              weight: float = 1.0) -> tuple[int, list[int]]:
        """Longest cached prefix usable by ``tokens``; pins the path for
        ``rid``.  Returns (cached tokens, physical blocks to share).
        Spilled nodes on the path are restored from the host tier."""
        usable = (len(tokens) - 1) // self.block_size
        chunks = self._chunks(tokens, usable)

        def restore(child, i, path):
            return self._restore_node(child, path)

        n, blocks, path = self._walk(chunks, on_spilled=restore)
        if n == 0:
            self.stats.misses += 1
            return 0, []
        self._pin(rid, path, now, weight)
        self.stats.hits += 1
        self.stats.hit_tokens += n * self.block_size
        return n * self.block_size, blocks

    def insert(self, tokens: np.ndarray, table: list[int], rid: int,
               now: float, weight: float = 1.0) -> int:
        """Adopt the full-block prefix of a just-prefilled prompt into the
        tree.  Blocks already covered by existing nodes are left alone
        (the tree keeps its copies); the divergent suffix is adopted from
        ``table`` with a new pool reference.  A SPILLED node covered by
        the prompt is RE-ADOPTED from the request's freshly prefilled
        device blocks (no copy — the host tier's copy is dropped).
        Returns adopted block count (the caller transfers their charge
        via ``donate_to_cache``)."""
        nb = len(tokens) // self.block_size
        chunks = self._chunks(tokens, nb)
        adopted = 0

        def readopt(child, i, path):
            nonlocal adopted
            n = len(child.key)
            child.blocks = [table[i + k] for k in range(n)]
            for b in child.blocks:
                self.pool.incref(b)
            self._forget_spill(child)
            adopted += n
            self.stats.readopted_blocks += n
            return True

        i, _, path = self._walk(chunks, on_spilled=readopt)
        if i < nb:
            parent = path[-1] if path else self.root
            new = _Node(key=chunks[i:], blocks=list(table[i:nb]),
                        parent=parent, last_used=now, weight=weight)
            parent.children[new.key[0]] = new
            for b in new.blocks:
                self.pool.incref(b)
            adopted += nb - i
            path.append(new)
            self.stats.inserted_blocks += nb - i
        self._pin(rid, path, now, weight)
        return adopted

    def _pin(self, rid: int, path: list[_Node], now: float,
             weight: float) -> None:
        held = self._locks.setdefault(rid, set())
        for nd in path:
            nd.pins.add(rid)
            nd.last_used = now
            nd.weight = max(nd.weight, weight)
            held.add(nd)

    # --- PrefixCacheHandle protocol -------------------------------------
    def detach(self, rid: int) -> None:
        for nd in self._locks.pop(rid, ()):
            nd.pins.discard(rid)

    def reclaim(self, need_blocks: int,
                protect: Optional[set] = None) -> int:
        """Evict unpinned device-holding nodes (LRU, priority-weighted)
        until ``need_blocks`` freed or nothing evictable remains.  With
        ``spill`` the victim's KV is parked in the host tier (node stays
        in-tree, restorable); otherwise it is destroyed.  ``protect`` is
        a set of node ids that must not be touched (the match path of an
        in-progress restore)."""
        freed = 0
        skip: set[int] = set(protect or ())
        while freed < need_blocks:
            victim = self._evictable_leaf(skip)
            if victim is None:
                break
            n = len(victim.blocks)
            if self.spill:
                host_rid = self.pool.new_cache_rid()
                # gather (device copy) BEFORE the decrefs free the blocks
                self.pool.spill_cache_blocks(host_rid, victim.blocks)
                victim.host_rid = host_rid
                self._spilled[host_rid] = victim
                self.stats.spilled_blocks += n
            for b in victim.blocks:
                self.pool.decref(b)
            if self.spill:
                victim.blocks = []
            else:
                victim.parent.children.pop(victim.key[0], None)
            freed += n
        if freed:
            self.bm.discharge_cache(freed)
            self.stats.evicted_blocks += freed
        return freed

    def _evictable_leaf(self, skip: set) -> Optional[_Node]:
        """Cheapest unpinned node holding device blocks with NO device
        blocks below it (spilled descendants don't shield an ancestor) —
        never one whose blocks are still referenced by an in-flight block
        table (refcount > 1): eviction must not free a block with more
        than one reference.  Without spill every node holds device
        blocks, so this reduces to the classic leaf-only rule."""
        best, best_score = None, None

        def scan(nd: _Node) -> bool:
            # returns True iff nd's subtree holds any device blocks
            nonlocal best, best_score
            below = False
            for c in nd.children.values():
                below |= scan(c)
            if (nd.blocks and not below and not nd.pins
                    and id(nd) not in skip):
                if any(self.pool.refcount[b] > 1 for b in nd.blocks):
                    skip.add(id(nd))
                else:
                    score = (nd.last_used
                             + self.priority_bonus * (nd.weight - 1.0))
                    if best is None or score < best_score:
                        best, best_score = nd, score
            return below or bool(nd.blocks)

        for c in self.root.children.values():
            scan(c)
        return best

    # --- tier spill/restore ----------------------------------------------
    def _restore_node(self, node: _Node, path: list[_Node]) -> bool:
        """Bring a spilled node's KV back on device: adopt a buffer the
        transfer worker pre-staged through the H2D lane if one is ready,
        else one synchronous batched reload (evicting colder nodes for
        room if needed).  Returns True on success."""
        host_rid = node.host_rid
        n = len(node.key)
        if self.pool.tier.n_blocks(host_rid) < n:
            # tier lost the payload (invalidated group): prune the stub
            self._drop_spilled_subtree(node)
            return False
        phys: list[int] = []
        if self.worker is not None:
            st = self.worker.take_staged(host_rid, 0)
            if st is not None:
                phys = self.pool.adopt_staged_group(host_rid, st[1], n)
                if phys:
                    self.stats.staged_restores += 1
        if not phys:
            short = n - len(self.pool.free)
            if short > 0:
                self.reclaim(short,
                             protect={id(nd) for nd in path} | {id(node)})
            phys = self.pool.restore_cache_group(host_rid, n)
        if not phys:
            return False        # no room right now; node stays spilled
        node.blocks = phys
        self._forget_spill(node)
        self.bm.charge_cache(n)
        self.stats.restored_blocks += n
        return True

    def _forget_spill(self, node: _Node) -> None:
        """Node's KV is (back) on device: drop its tier group and any
        in-flight/staged worker buffer for the stale pseudo-rid."""
        host_rid = node.host_rid
        node.host_rid = None
        self._spilled.pop(host_rid, None)
        self.pool.tier.drop(host_rid)
        if self.worker is not None:
            self.worker.invalidate(host_rid)

    def _drop_spilled_subtree(self, node: _Node) -> None:
        """Prune a subtree whose spilled payload is gone for good."""
        node.parent.children.pop(node.key[0], None)
        stack = [node]
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            if nd.host_rid is not None:
                self._forget_spill(nd)
            if nd.blocks:       # defensive: spilled subtrees hold none
                for b in nd.blocks:
                    self.pool.decref(b)
                self.bm.discharge_cache(len(nd.blocks))
                self.stats.evicted_blocks += len(nd.blocks)

    def has_spilled(self, host_rid: int) -> bool:
        """Does the tree still hold the node for this spill pseudo-rid?
        (The engine's transfer-drain guard uses this to keep staged
        buffers for live spill groups.)"""
        return host_rid in self._spilled

    def spill_candidates(self, limit: int = 2) -> list[tuple]:
        """Most-recently-touched spilled groups as ``(host_rid,
        payloads)`` prefetch hints for the background H2D staging lane."""
        rids = sorted(self._spilled,
                      key=lambda r: self.pool.tier._touch.get(r, 0),
                      reverse=True)
        out = []
        for host_rid in rids[:limit]:
            nd = self._spilled[host_rid]
            payloads = self.pool.tier.payloads(host_rid,
                                               range(len(nd.key)))
            if payloads is not None:
                out.append((host_rid, payloads))
        return out

    def shrink_to_capacity(self) -> int:
        over = self.cached_blocks - self.max_blocks
        return self.reclaim(over) if over > 0 else 0

    # --- introspection ---------------------------------------------------
    @property
    def cached_blocks(self) -> int:
        total, stack = 0, list(self.root.children.values())
        while stack:
            nd = stack.pop()
            total += len(nd.blocks)
            stack.extend(nd.children.values())
        return total

    def hit_rate(self) -> float:
        n = self.stats.hits + self.stats.misses
        return self.stats.hits / n if n else 0.0
