"""Radix-tree prefix cache over the paged KV store.

Matches incoming prompts against cached prefixes at BLOCK granularity
(token-aligned to ``block_size``): a hit lets the request point its block
table at the cached physical blocks (``PagedKVPool.share`` — reference
counted, copy-on-write on any later write into a shared block) and charges
only the uncached suffix to chunked prefill.

Structure: a compressed radix tree whose edges are runs of full token
blocks.  Each node stores the token content of its run (one tuple per
block) and the physical device blocks holding that run's KV.  Divergence
inside a node splits it at the block boundary (the standard radix split),
so every cached block is owned by exactly one node.

Lifecycle / accounting (composes with ``core.blocks.BlockManager``):

* ``match``   — admission: walk the tree, return the longest cached prefix
  usable by the prompt (at least one prompt token is always left uncached
  so the completing pass yields first-token logits), pin the path.
* ``insert``  — first-token time: adopt the request's uniquely-owned full
  prompt blocks into the tree (cache takes a pool reference; the caller
  transfers the block charge with ``BlockManager.donate_to_cache``).
* ``reclaim`` — LRU + priority-weighted eviction of UNPINNED leaves only;
  a shared block is pinned while any live request references it, so §4.3
  offload/evict never touches a block with more than one referent.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from ..core.blocks import BlockManager
from .kv_pool import PagedKVPool


@dataclass(eq=False)     # identity semantics: nodes live in pin sets
class _Node:
    key: list            # token content, one tuple[int, ...] per block
    blocks: list         # physical block ids, len == len(key)
    children: dict = field(default_factory=dict)  # first-block tuple -> _Node
    parent: Optional["_Node"] = None
    pins: set = field(default_factory=set)        # rids using these blocks
    last_used: float = 0.0
    weight: float = 1.0  # max priority weight of requests that used it


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    hit_tokens: int = 0
    inserted_blocks: int = 0
    evicted_blocks: int = 0
    cow_forks: int = 0


class RadixPrefixCache:
    """One engine replica's prefix cache (not thread-safe by itself: the
    engine touches it only from its driver thread, like the pool)."""

    def __init__(self, pool: PagedKVPool, bm: BlockManager,
                 max_blocks: Optional[int] = None,
                 priority_bonus: float = 30.0):
        self.pool = pool
        self.bm = bm
        self.block_size = pool.block_size
        self.max_blocks = (pool.num_blocks // 2 if max_blocks is None
                           else max_blocks)
        self.priority_bonus = priority_bonus
        self.root = _Node(key=[], blocks=[])
        self._locks: dict[int, set] = {}     # rid -> pinned nodes
        self.stats = CacheStats()
        bm.cache = self

    # ------------------------------------------------------------------
    def _chunks(self, tokens, n_blocks: int) -> list[tuple]:
        bs = self.block_size
        return [tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])
                for i in range(n_blocks)]

    def _split(self, node: _Node, at: int) -> _Node:
        """Split ``node`` after its first ``at`` blocks; returns the upper
        half (which keeps the parent edge)."""
        lower = _Node(key=node.key[at:], blocks=node.blocks[at:],
                      children=node.children, parent=node,
                      pins=set(node.pins), last_used=node.last_used,
                      weight=node.weight)
        for c in lower.children.values():
            c.parent = lower
        node.key = node.key[:at]
        node.blocks = node.blocks[:at]
        node.children = {lower.key[0]: lower}
        # pinning rids now hold both halves
        for rid in node.pins:
            self._locks[rid].add(lower)
        return node

    def _walk(self, chunks: list[tuple]
              ) -> tuple[int, list[int], list[_Node]]:
        """Longest existing path matching ``chunks``, splitting the last
        node if the match ends inside it, so the match always ends at a
        node boundary.  Returns (blocks matched, physical blocks, path)."""
        node, i, blocks, path = self.root, 0, [], []
        while i < len(chunks):
            child = node.children.get(chunks[i])
            if child is None:
                break
            j = 0
            while (j < len(child.key) and i + j < len(chunks)
                   and child.key[j] == chunks[i + j]):
                j += 1
            if j == 0:
                break
            if j < len(child.key):
                child = self._split(child, j)
            blocks += child.blocks
            path.append(child)
            i += j
            node = child
        return i, blocks, path

    # --- engine surface -------------------------------------------------
    def match(self, tokens: np.ndarray, now: float, rid: int,
              weight: float = 1.0) -> tuple[int, list[int]]:
        """Longest cached prefix usable by ``tokens``; pins the path for
        ``rid``.  Returns (cached tokens, physical blocks to share)."""
        usable = (len(tokens) - 1) // self.block_size
        chunks = self._chunks(tokens, usable)
        n, blocks, path = self._walk(chunks)
        if n == 0:
            self.stats.misses += 1
            return 0, []
        self._pin(rid, path, now, weight)
        self.stats.hits += 1
        self.stats.hit_tokens += n * self.block_size
        return n * self.block_size, blocks

    def insert(self, tokens: np.ndarray, table: list[int], rid: int,
               now: float, weight: float = 1.0) -> int:
        """Adopt the full-block prefix of a just-prefilled prompt into the
        tree.  Blocks already covered by existing nodes are left alone
        (the tree keeps its copies); the divergent suffix is adopted from
        ``table`` with a new pool reference.  Returns adopted block count
        (the caller transfers their charge via ``donate_to_cache``)."""
        nb = len(tokens) // self.block_size
        chunks = self._chunks(tokens, nb)
        i, _, path = self._walk(chunks)
        adopted = 0
        if i < nb:
            parent = path[-1] if path else self.root
            new = _Node(key=chunks[i:], blocks=list(table[i:nb]),
                        parent=parent, last_used=now, weight=weight)
            parent.children[new.key[0]] = new
            for b in new.blocks:
                self.pool.incref(b)
            adopted = nb - i
            path.append(new)
            self.stats.inserted_blocks += adopted
        self._pin(rid, path, now, weight)
        return adopted

    def _pin(self, rid: int, path: list[_Node], now: float,
             weight: float) -> None:
        held = self._locks.setdefault(rid, set())
        for nd in path:
            nd.pins.add(rid)
            nd.last_used = now
            nd.weight = max(nd.weight, weight)
            held.add(nd)

    # --- PrefixCacheHandle protocol -------------------------------------
    def detach(self, rid: int) -> None:
        for nd in self._locks.pop(rid, ()):
            nd.pins.discard(rid)

    def reclaim(self, need_blocks: int) -> int:
        """Evict unpinned leaves (LRU, priority-weighted) until
        ``need_blocks`` freed or nothing evictable remains."""
        freed = 0
        skip: set[int] = set()
        while freed < need_blocks:
            victim = self._evictable_leaf(skip)
            if victim is None:
                break
            freed += len(victim.blocks)
            for b in victim.blocks:
                self.pool.decref(b)
            victim.parent.children.pop(victim.key[0], None)
        if freed:
            self.bm.discharge_cache(freed)
            self.stats.evicted_blocks += freed
        return freed

    def _evictable_leaf(self, skip: set) -> Optional[_Node]:
        """Cheapest unpinned leaf — never one whose blocks are still
        referenced by an in-flight block table (refcount > 1): eviction
        must not free a block with more than one reference."""
        best, best_score = None, None
        stack = list(self.root.children.values())
        while stack:
            nd = stack.pop()
            if nd.children:
                stack.extend(nd.children.values())
                continue
            if nd.pins or id(nd) in skip:
                continue
            if any(self.pool.refcount[b] > 1 for b in nd.blocks):
                skip.add(id(nd))
                continue
            score = nd.last_used + self.priority_bonus * (nd.weight - 1.0)
            if best is None or score < best_score:
                best, best_score = nd, score
        return best

    def shrink_to_capacity(self) -> int:
        over = self.cached_blocks - self.max_blocks
        return self.reclaim(over) if over > 0 else 0

    # --- introspection ---------------------------------------------------
    @property
    def cached_blocks(self) -> int:
        total, stack = 0, list(self.root.children.values())
        while stack:
            nd = stack.pop()
            total += len(nd.blocks)
            stack.extend(nd.children.values())
        return total

    def hit_rate(self) -> float:
        n = self.stats.hits + self.stats.misses
        return self.stats.hits / n if n else 0.0
