"""Synchronous service layer: GoRouting dispatch over N real engines.

This is now a thin deterministic wrapper over the same :class:`RouterBook`
bookkeeping that powers the async ``ServiceFrontend`` — one caller thread
drives every engine with ``step_all()``.  Use it for tests and offline
experiments where determinism matters; use ``ServiceFrontend`` to serve
live concurrent traffic.

Fault-tolerance semantics are shared (DESIGN.md §5): every request is
appended to a durable request log at admission; orphaned requests of a
dead instance are re-dispatched from the log (KV lost — recomputed);
instances can be added at runtime (elastic scale-up); an EWMA speed factor
per instance feeds GoRouting's EstimateExec so stragglers organically
receive less work.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..core.estimator import BatchLatencyEstimator
from ..core.gorouting import pick_decode_target
from ..core.request import Request
from .dispatch import RouterBook
from .engine import Engine, HandoffPayload


@dataclass
class ServiceConfig:
    heartbeat_timeout: float = 5.0
    speed_ewma: float = 0.2


class ServiceController:
    def __init__(self, router, est: BatchLatencyEstimator,
                 cfg: ServiceConfig = ServiceConfig()):
        self.cfg = cfg
        self.book = RouterBook(router, est, speed_ewma=cfg.speed_ewma)
        self.engines: dict[int, Engine] = {}
        self.finished: list[Request] = []
        self._iid = itertools.count()
        self.now = 0.0

    # thin delegation — the book owns router-side state
    @property
    def router(self):
        return self.book.router

    @property
    def est(self) -> BatchLatencyEstimator:
        return self.book.est

    @property
    def states(self):
        return self.book.states

    @property
    def request_log(self):
        return self.book.request_log

    # --- elasticity -------------------------------------------------------
    def add_instance(self, engine: Engine) -> int:
        iid = next(self._iid)
        self.engines[iid] = engine
        self.book.add_instance(iid, engine.bm.num_device_blocks,
                               engine.bm.free_blocks,
                               has_prefix_cache=engine.cache is not None,
                               role=engine.role)
        return iid

    def remove_instance(self, iid: int, *, drain: bool = True) -> None:
        """Graceful scale-down: stop dispatching; optionally re-dispatch."""
        eng = self.engines.pop(iid, None)
        self.book.drop_instance(iid)
        if eng is None:
            return
        orphans = eng.kill()
        if drain:
            for r in orphans:
                self._redispatch(r)

    def kill_instance(self, iid: int) -> None:
        """Hard failure: engine dies, requests recovered from the log."""
        eng = self.engines.pop(iid, None)
        self.book.drop_instance(iid)
        if eng is None:
            return
        for r in eng.kill():
            self._redispatch(r)

    def _redispatch(self, req: Request) -> None:
        partial = self.book.logged_partial(req.rid)
        if partial is None:
            return
        self.submit(req, self.book.request_log[req.rid][1],
                    _relog=False, _prior=partial)

    # --- dispatch ----------------------------------------------------------
    def submit(self, req: Request, prompt_tokens: np.ndarray,
               *, _relog: bool = True, _prior: Optional[list] = None
               ) -> Optional[int]:
        if _relog:
            self.book.log_request(req, prompt_tokens)
        iid = self.book.route(req, self.now, prompt_tokens=prompt_tokens)
        if iid is None:
            return None
        self.engines[iid].add_request(req, prompt_tokens,
                                      prior_outputs=_prior)
        return iid

    # --- disagg handoff delivery (synchronous) -----------------------------
    def _deliver_handoff(self, src_iid: int, payload: HandoffPayload) -> None:
        """Route one exported payload to its reserved decode replica (or
        the best surviving one); with no decode capacity left, fail the
        request over to a re-prefill from the durable log."""
        rid = payload.req.rid
        self.book.on_handoff_sent(src_iid, rid, self.now)
        partial = self.book.logged_partial(rid)
        if partial is not None:      # the prefill leg's tokens are durable
            partial[:] = list(payload.outputs)
        d_iid = self.book.decode_target(rid)
        eng = self.engines.get(d_iid) if d_iid is not None else None
        if eng is None:
            d_pool = [st for st in self.book.states.values()
                      if st.role == "decode"]
            d_iid = pick_decode_target(d_pool, payload.req,
                                       self.book.block_size)
            eng = self.engines.get(d_iid) if d_iid is not None else None
        if eng is not None and eng.import_handoff(payload):
            self.book.on_handoff_delivered(rid, d_iid, payload.n_blocks,
                                           payload.wire_bytes, self.now)
        else:
            self._redispatch(payload.req)

    # --- serving loop -------------------------------------------------------
    def step_all(self) -> int:
        """One scheduling round across instances; returns tokens emitted."""
        total = 0
        for iid, eng in list(self.engines.items()):
            res = eng.step()
            # pick up completed handoff exports even on idle steps (the
            # async D2H lane can land them while the queue is empty)
            for payload in eng.take_handoffs():
                payload.src_iid = iid
                self._deliver_handoff(iid, payload)
            if res is None:
                self.book.heartbeat(iid, eng.bm.free_blocks)
                continue
            self.now = max(self.now, eng.now)
            self.book.observe_step(iid, free_blocks=eng.bm.free_blocks,
                                   est_time=res["plan"].est_time,
                                   latency=res["latency"])
            for r in res["emitted"]:
                if r.generated == 1:
                    self.book.on_first_token(iid, r.rid, self.now)
                outs = eng.outputs.get(r.rid)
                if outs is None:     # exported at handoff this very step:
                    # the payload (possibly still in the D2H lane) holds
                    # the emitted token — it must reach the durable log
                    # NOW, or a crash before delivery would lose it
                    outs = eng.handoff_outputs(r.rid)
                if outs is None:
                    continue
                partial = self.book.logged_partial(r.rid)
                if partial is not None:  # stream into the durable log
                    partial[:] = outs
            for r in res["finished"]:
                self.book.on_finished(iid, r.rid)
                self.finished.append(r)
            total += len(res["emitted"])
        return total

    def serve_until_drained(self, max_rounds: int = 5000) -> None:
        for _ in range(max_rounds):
            pending = any(e.has_work() for e in self.engines.values())
            if not pending:
                break
            self.step_all()
