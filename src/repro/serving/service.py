"""Service layer: GoRouting dispatch over N real engines + fault tolerance.

Production shape (DESIGN.md §5): every request is appended to a durable
request log at admission; heartbeats mark instances dead after
``heartbeat_timeout``; orphaned requests of a dead instance are re-dispatched
from the log (KV lost — recomputed); instances can be added at runtime
(elastic scale-up) and are immediately eligible for dispatch; an EWMA speed
factor per instance feeds GoRouting's EstimateExec so stragglers
organically receive less work (straggler mitigation).
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from ..core.estimator import BatchLatencyEstimator
from ..core.gorouting import GoRouting, InstanceState, QueuedStub
from ..core.request import Phase, Request
from .engine import Engine


@dataclass
class ServiceConfig:
    heartbeat_timeout: float = 5.0
    speed_ewma: float = 0.2


class ServiceController:
    def __init__(self, router, est: BatchLatencyEstimator,
                 cfg: ServiceConfig = ServiceConfig()):
        self.router = router
        self.est = est
        self.cfg = cfg
        self.engines: dict[int, Engine] = {}
        self.states: dict[int, InstanceState] = {}
        # durable request log: prompt + tokens streamed so far — failover
        # resumes generation exactly where the dead instance stopped.
        self.request_log: dict[int, tuple[Request, np.ndarray, list]] = {}
        self.finished: list[Request] = []
        self._iid = itertools.count()
        self.now = 0.0

    # --- elasticity -------------------------------------------------------
    def add_instance(self, engine: Engine) -> int:
        iid = next(self._iid)
        self.engines[iid] = engine
        self.states[iid] = InstanceState(
            iid=iid, b_f=engine.bm.free_blocks,
            total_blocks=engine.bm.num_device_blocks)
        return iid

    def remove_instance(self, iid: int, *, drain: bool = True) -> None:
        """Graceful scale-down: stop dispatching; optionally re-dispatch."""
        eng = self.engines.pop(iid, None)
        st = self.states.pop(iid, None)
        if eng is None:
            return
        orphans = eng.kill()
        if drain:
            for r in orphans:
                self._redispatch(r)

    def kill_instance(self, iid: int) -> None:
        """Hard failure: engine dies, requests recovered from the log."""
        eng = self.engines.get(iid)
        if eng is None:
            return
        self.states[iid].alive = False
        orphans = eng.kill()
        del self.engines[iid]
        del self.states[iid]
        for r in orphans:
            self._redispatch(r)

    def _redispatch(self, req: Request) -> None:
        logged = self.request_log.get(req.rid)
        if logged is None:
            return
        _, prompt, partial = logged
        self.submit(req, prompt, _relog=False, _prior=partial)

    # --- dispatch ----------------------------------------------------------
    def submit(self, req: Request, prompt_tokens: np.ndarray,
               *, _relog: bool = True, _prior: Optional[list] = None
               ) -> Optional[int]:
        if _relog:
            self.request_log[req.rid] = (req, np.asarray(prompt_tokens), [])
        pools = list(self.states.values())
        exec_est = self.est.prefill_time(req.prompt_len)
        iid, _ = self.router.select(req, pools, None, self.now,
                                    exec_est=exec_est)
        if iid is None:
            return None
        self.states[iid].on_dispatch(
            QueuedStub(req.rid, self.now, req.priority, req.weight,
                       req.prompt_len, req.arrival + req.slo.ttft,
                       exec_est), self.now)
        self.engines[iid].add_request(req, prompt_tokens,
                                      prior_outputs=_prior)
        return iid

    # --- serving loop -------------------------------------------------------
    def step_all(self) -> int:
        """One scheduling round across instances; returns tokens emitted."""
        total = 0
        for iid, eng in list(self.engines.items()):
            res = eng.step()
            st = self.states[iid]
            st.b_f = eng.bm.free_blocks
            if res is None:
                continue
            self.now = max(self.now, eng.now)
            # straggler EWMA: observed vs estimated batch latency
            est_t = max(res["plan"].est_time, 1e-9)
            obs = max(res["latency"], 1e-9)
            ratio = est_t / obs
            st.speed = ((1 - self.cfg.speed_ewma) * st.speed
                        + self.cfg.speed_ewma * min(max(ratio, 0.05), 2.0))
            for r in res["emitted"]:
                if r.generated == 1:
                    st.on_prefill_done(r.rid, self.now)
                logged = self.request_log.get(r.rid)
                if logged is not None:       # stream into the durable log
                    logged[2][:] = eng.outputs[r.rid]
            for r in res["finished"]:
                st.on_finished(r.rid)
                self.finished.append(r)
                self.request_log.pop(r.rid, None)
            total += len(res["emitted"])
        return total

    def serve_until_drained(self, max_rounds: int = 5000) -> None:
        for _ in range(max_rounds):
            pending = any(e.has_work() for e in self.engines.values())
            if not pending:
                break
            self.step_all()
