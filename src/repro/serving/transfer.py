"""Background host↔device KV transfer lanes (the §4.3 mechanisms, real).

The BlockManager models two serial copy lanes (D2H offload, H2D reload)
whose occupancy drives the adaptive copy budget.  This module is the
matching *mechanism*: a single worker thread that performs the actual
copies off the engine's critical path, so ``Engine.step()`` only enqueues
transfers and drains completions.

* **D2H offload ring** — the engine snapshots the blocks to mirror with
  one device-side gather (`PagedKVPool.gather_blocks`; functional jax
  arrays make the snapshot race-free — later pool writes build new
  arrays) and hands the worker the gathered array.  The worker performs
  the blocking ``jax.device_get`` and reports a completion carrying the
  host block contents, the block count and the measured copy time.

* **H2D reload staging (double-buffered)** — the engine hints which
  evicted requests are likely to reload next round; the worker stages
  their host blocks into a ready device array (``jnp.asarray``) so the
  reload lands before the batch that needs it.  At most ``max_staged``
  requests are staged at a time (classic double buffering).

Both lanes speak the TIERED wire format: a D2H job whose snapshot was
quantized on device carries an ``(int8 vals, fp32 scales)`` pair and
lands as per-block tuples (the pool routes them into the cold tier);
an H2D job whose host payloads are such tuples uploads the int8 data
(~4x fewer wire bytes) and dequantizes ON DEVICE (Pallas kernel) so the
staged buffer the engine consumes is always fp32.

Every job carries the request's transfer *epoch*; the engine bumps the
epoch on eviction/release so completions for a superseded residency
generation are discarded instead of corrupting the accounting.

The engine drains completions at step start and feeds them back into
``BlockManager.note_offload_complete`` / ``observe_transfer`` — the
accounting lanes then track real transfers instead of a virtual clock.
"""
from __future__ import annotations

import logging
import queue
import threading
import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import kv_block_dequantize

logger = logging.getLogger(__name__)


@dataclass(frozen=True)
class TransferDone:
    """One completed background copy, as drained by the engine."""
    kind: str                    # "d2h" (offload) | "h2d" (reload staging)
    rid: int
    epoch: int
    n_blocks: int
    seconds: float               # measured wall time of the copy
    blocks: Optional[dict] = None   # d2h only: {logical index -> ndarray}
    ok: bool = True              # False: the copy raised; nothing landed
    quantized: bool = False      # int8 wire: excluded from the t_block
    # EWMA (the copy budget already scales cold copies by COLD_WIRE_RATIO)


class TransferWorker:
    """One background thread owning both copy lanes of one engine."""

    def __init__(self, max_staged: int = 2):
        self.max_staged = max_staged
        self._jobs: "queue.Queue[Optional[tuple]]" = queue.Queue()
        self._lock = threading.Lock()
        self._done: list[TransferDone] = []
        # rid -> (epoch, n_blocks, (n, L, 2, bs, Hkv, hd) device array)
        self._staged: dict[int, tuple[int, int, object]] = {}
        # rids with a staging job enqueued but not yet landed: reserves the
        # slot so the engine's per-step hints don't enqueue duplicates
        self._inflight: set[int] = set()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._warned = False

    # -- engine thread ----------------------------------------------------
    def _ensure_started(self) -> None:
        if self._stop.is_set():
            return
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._run, name="kv-transfer", daemon=True)
            self._thread.start()

    def offload(self, rid: int, epoch: int, logical: list[int],
                gathered) -> None:
        """Enqueue a D2H mirror: ``gathered`` is the (n, L, 2, bs, Hkv, hd)
        device-side snapshot of the blocks (already dispatched)."""
        self._ensure_started()
        self._jobs.put(("d2h", rid, epoch, logical, gathered))

    def prefetch(self, rid: int, epoch: int,
                 host_blocks: list[np.ndarray]) -> bool:
        """Enqueue H2D staging of ``host_blocks``; False if the staging
        ring is full or this rid is already staged/in flight."""
        with self._lock:
            if (rid in self._staged or rid in self._inflight
                    or len(self._staged) + len(self._inflight)
                    >= self.max_staged):
                return False
            self._inflight.add(rid)
        self._ensure_started()
        self._jobs.put(("h2d", rid, epoch, list(host_blocks)))
        return True

    def take_staged(self, rid: int, epoch: int):
        """Consume a staged reload buffer: (n_blocks, device array) or
        None if absent / stale-epoch."""
        with self._lock:
            got = self._staged.pop(rid, None)
        if got is None or got[0] != epoch:
            return None
        return got[1], got[2]

    def invalidate(self, rid: int) -> None:
        with self._lock:
            self._staged.pop(rid, None)

    def discard_stale(self, rid: int, current_epoch: int) -> None:
        """Drop a staged buffer whose epoch is no longer current — a
        staging job that completed AFTER ``invalidate`` would otherwise
        occupy one of the ``max_staged`` slots forever."""
        with self._lock:
            got = self._staged.get(rid)
            if got is not None and got[0] != current_epoch:
                del self._staged[rid]

    def drain(self) -> list[TransferDone]:
        with self._lock:
            out, self._done = self._done, []
        return out

    def flush(self, timeout: float = 30.0) -> bool:
        """Block until every enqueued job has executed (tests/benches).
        Uses the queue's unfinished-task count, so a job popped but still
        mid-execution keeps flush waiting."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self._jobs.unfinished_tasks == 0:
                return True
            time.sleep(1e-3)
        return False

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        self._jobs.put(None)
        if self._thread is not None and self._thread.is_alive():
            self._thread.join(timeout)

    # -- worker thread ------------------------------------------------------
    def _run(self) -> None:
        while not self._stop.is_set():
            job = self._jobs.get()
            if job is None:
                self._jobs.task_done()
                break
            try:
                self._execute(job)
            except Exception:
                # never kill the lane — the engine's synchronous fallback
                # stays correct — but never swallow silently either: report
                # a failed completion so pending-offload accounting drains
                # and the engine can count it.
                if not self._warned:
                    self._warned = True
                    logger.warning("background KV transfer failed; engine "
                                   "falls back to synchronous copies "
                                   "(further failures only counted)",
                                   exc_info=True)
                kind, rid, epoch = job[0], job[1], job[2]
                n = len(job[3])
                done = TransferDone(kind, rid, epoch, n, 0.0, ok=False)
                with self._lock:
                    self._inflight.discard(rid)
                    self._done.append(done)
            finally:
                self._jobs.task_done()

    def _execute(self, job: tuple) -> None:
        kind, rid, epoch = job[0], job[1], job[2]
        t0 = time.monotonic()
        if kind == "d2h":
            logical, gathered = job[3], job[4]
            if isinstance(gathered, tuple):
                # quantized-on-device snapshot: the wire carries int8 vals
                # + per-plane scales (~4x fewer bytes than fp32)
                vals, scales = jax.device_get(gathered)
                vals, scales = np.asarray(vals), np.asarray(scales)
                dt = time.monotonic() - t0
                blocks = {bi: (vals[i], scales[i])
                          for i, bi in enumerate(logical)}
                quant = True
            else:
                data = np.asarray(jax.device_get(gathered))
                dt = time.monotonic() - t0
                blocks = {bi: data[i] for i, bi in enumerate(logical)}
                quant = False
            done = TransferDone("d2h", rid, epoch, len(logical), dt,
                                blocks=blocks, quantized=quant)
            with self._lock:
                self._done.append(done)
        else:
            host_blocks = job[3]
            quant = any(isinstance(b, tuple) for b in host_blocks)
            if all(isinstance(b, tuple) for b in host_blocks):
                # cold-tier group: upload int8 + scales, dequantize on
                # device so the staged buffer is fp32 like any other
                vals = jnp.asarray(np.stack([b[0] for b in host_blocks]))
                scales = jnp.asarray(np.stack([b[1] for b in host_blocks]))
                arr = kv_block_dequantize(vals, scales)
            else:
                # whole-group tiering never mixes; thaw stray tuples
                # defensively so a mixed hint still stages correctly
                arr = jnp.asarray(np.stack(
                    [np.asarray(kv_block_dequantize(
                        jnp.asarray(b[0])[None], jnp.asarray(b[1])[None]))[0]
                     if isinstance(b, tuple) else b for b in host_blocks]))
            arr.block_until_ready()
            dt = time.monotonic() - t0
            done = TransferDone("h2d", rid, epoch, len(host_blocks), dt,
                                quantized=quant)
            with self._lock:
                self._inflight.discard(rid)
                self._staged[rid] = (epoch, len(host_blocks), arr)
                self._done.append(done)
