"""Real serving engine: continuous batching over an actual JAX model.

One ``Engine`` = one model replica.  Each iteration:

  1. the configured policy (SlideBatching or a baseline — the SAME code
     that drives the simulator) forms a batch against the shared
     BlockManager accounting;
  2. reload/eviction directives are applied to the PagedKVPool (host
     mirrors, drops, restores);
  3. decode entries run as one ``decode_batch`` call; prefill chunks run
     per request (``prefill_chunk``), greedy-sampling the first token when
     a prompt completes;
  4. measured wall-clock batch latencies feed the §4.1 estimator, which is
     refit online every ``refit_every`` batches (the offline-profiling
     bootstrap happens in ``calibrate``).

The engine clock can be virtual (``clock=manual``) for deterministic tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.batching import BatchPlan, EngineConfig, SchedView, compute_remaining
from ..core.blocks import BlockManager, blocks_for
from ..core.estimator import BatchLatencyEstimator
from ..core.request import Phase, Request
from ..models.model import ArchConfig, init_params
from . import model_exec
from .kv_pool import PagedKVPool


@dataclass
class EngineStats:
    iterations: int = 0
    tokens_out: int = 0
    prefill_tokens: int = 0
    evictions: int = 0
    reload_blocks: int = 0
    batch_latencies: list = field(default_factory=list)


class Engine:
    def __init__(self, cfg: ArchConfig, params, eng_cfg: EngineConfig,
                 policy, *, num_blocks: int = 512, block_size: int = 16,
                 t_block: float = 5e-4, max_ctx: int = 1024,
                 est: Optional[BatchLatencyEstimator] = None,
                 bm_kwargs: Optional[dict] = None, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.eng_cfg = eng_cfg
        self.policy = policy
        self.max_ctx = max_ctx
        self.pool = PagedKVPool(cfg, num_blocks, block_size)
        self.bm = BlockManager(num_blocks - 1, block_size, t_block,
                               **(bm_kwargs or {}))
        self.est = est or BatchLatencyEstimator(
            a_p=1e-8, b_p=1e-8, c_p=1e-5, a_d=1e-8, b_d=1e-4, t_c=1e-3)
        self.queue: list[Request] = []
        self.now = 0.0
        self.stats = EngineStats()
        self._profile: list[tuple[list, float]] = []
        self.refit_every = 50
        self.alive = True
        self.outputs: dict[int, list[int]] = {}

    # ------------------------------------------------------------------
    def add_request(self, req: Request, prompt_tokens: np.ndarray,
                    prior_outputs: Optional[list[int]] = None) -> None:
        """``prior_outputs``: tokens already streamed to the client before a
        failover — the engine resumes mid-generation by recomputing their
        KV (they are ordinary known tokens) and continuing exactly."""
        req.instance = id(self) & 0xffff
        self.queue.append(req)
        self.outputs[req.rid] = list(prior_outputs or [])
        req._prompt = np.asarray(prompt_tokens, np.int32)  # type: ignore

    def has_work(self) -> bool:
        return any(r.phase != Phase.FINISHED for r in self.queue)

    # ------------------------------------------------------------------
    def _sync_pool_with_bm(self, plan: BatchPlan) -> None:
        """Apply the §4.3 directives the policy issued on the accounting
        layer (BlockManager) to the actual data (PagedKVPool)."""
        for r in plan.evictions:
            s = self.bm.state(r)
            # mirror what survives to host, then drop device blocks
            keep_blocks = blocks_for(s.host_tokens, self.bm.block_size)
            if keep_blocks:
                self.pool.offload_blocks(
                    r.rid, list(range(keep_blocks)))
            self.pool.drop_device_blocks(r.rid)
            self.stats.evictions += 1

    def step(self) -> Optional[dict]:
        if not self.alive:
            return None
        self.bm.complete_offloads(self.now)
        view = SchedView(self.queue, self.bm, self.est, self.eng_cfg,
                         self.now)
        plan = self.policy.form_batch(view)
        if not plan.entries:
            return None
        t0 = time.monotonic()
        self._sync_pool_with_bm(plan)

        # reload data for requests whose plan restored host blocks
        for e in plan.entries:
            hb = self.pool.host_blocks(e.req.rid)
            dev_tok = self.bm.state(e.req).dev_tokens
            dev_blocks_needed = blocks_for(dev_tok, self.bm.block_size)
            have = len(self.pool.tables.get(e.req.rid, []))
            if have < dev_blocks_needed and hb:
                n = dev_blocks_needed - have
                self.pool.reload_blocks(e.req.rid, n)
                self.stats.reload_blocks += n

        decode_entries = [e for e in plan.entries if not e.is_prefill]
        prefill_entries = [e for e in plan.entries if e.is_prefill]
        emitted: list[Request] = []

        # --- prefill / recompute chunks (per request) ---------------------
        for e in prefill_entries:
            r = e.req
            c = model_exec.bucket(e.n_tokens)
            ctx = e.l_kv
            self.pool.ensure_capacity(r.rid, ctx + e.n_tokens)
            toks = np.zeros((1, c), np.int32)
            prompt: np.ndarray = r._prompt  # type: ignore
            seq = np.concatenate([prompt, np.asarray(
                self.outputs[r.rid], np.int32)])
            toks[0, :e.n_tokens] = seq[ctx:ctx + e.n_tokens]
            max_ctx = model_exec.bucket(ctx + c, buckets=(
                self.max_ctx,)) if ctx + c <= self.max_ctx else ctx + c
            maxp = max_ctx // self.pool.block_size
            table = self.pool.table_array([r.rid], maxp=maxp)
            logits, self.pool.kv = model_exec.prefill_chunk(
                self.cfg, self.params, self.pool.kv, jnp.asarray(toks),
                table, jnp.asarray([ctx], jnp.int32), max_ctx)
            self.stats.prefill_tokens += e.n_tokens
            done_ctx = ctx + e.n_tokens
            target = r.prompt_len + max(0, r.generated - 1)
            if done_ctx >= r.prompt_len and r.generated == 0:
                tok = int(jnp.argmax(logits[0, e.n_tokens - 1]))
                self._emit(r, tok, emitted)
            # recompute completion emits nothing (next decode pass does)

        # --- decode batch ---------------------------------------------------
        if decode_entries:
            rids = [e.req.rid for e in decode_entries]
            lens = np.array([e.l_kv for e in decode_entries], np.int32)
            for e in decode_entries:
                self.pool.ensure_capacity(e.req.rid, e.l_kv + 1)
            maxp = max(len(self.pool.tables[r]) for r in rids)
            table = self.pool.table_array(rids, maxp=maxp)
            last = np.array(
                [self._last_token(e.req) for e in decode_entries], np.int32)
            logits, self.pool.kv = model_exec.decode_batch(
                self.cfg, self.params, self.pool.kv, jnp.asarray(last),
                table, jnp.asarray(lens))
            nxt = np.asarray(jnp.argmax(logits, -1))
            for e, tok in zip(decode_entries, nxt):
                self._emit(e.req, int(tok), emitted)

        latency = time.monotonic() - t0
        self.now += latency
        self.stats.iterations += 1
        self.stats.batch_latencies.append(latency)
        self._profile.append((plan.work_items(), latency))
        if len(self._profile) >= self.refit_every:
            self._refit()

        finished = [r for r in self.queue if r.phase == Phase.FINISHED]
        for r in finished:
            self.bm.release(r)
            self.pool.release(r.rid)
        self.queue = [r for r in self.queue if r.phase != Phase.FINISHED]
        return {"emitted": emitted, "finished": finished,
                "latency": latency, "plan": plan}

    # ------------------------------------------------------------------
    def _last_token(self, r: Request) -> int:
        outs = self.outputs[r.rid]
        if outs:
            return outs[-1]
        return int(r._prompt[-1])  # type: ignore

    def _emit(self, r: Request, tok: int, emitted: list) -> None:
        self.outputs[r.rid].append(tok)
        r.emit_token(self.now)
        self.stats.tokens_out += 1
        emitted.append(r)

    def _refit(self) -> None:
        try:
            batches = [b for b, _ in self._profile]
            lats = [l for _, l in self._profile]
            self.est = BatchLatencyEstimator.fit(batches, lats)
        except Exception:
            pass
        self._profile = self._profile[-200:]

    def run_until_drained(self, max_iters: int = 10000) -> None:
        it = 0
        while self.has_work() and it < max_iters:
            if self.step() is None:
                # idle but queued work exists only if nothing schedulable
                break
            it += 1

    def kill(self) -> list[Request]:
        self.alive = False
        orphans = [r for r in self.queue if r.phase != Phase.FINISHED]
        for r in orphans:
            self.bm.release(r)
            self.pool.release(r.rid)
            r.instance = None
        self.queue.clear()
        return orphans
