"""Real serving engine: continuous batching over an actual JAX model.

One ``Engine`` = one model replica.  Each iteration:

  1. the configured policy (SlideBatching or a baseline — the SAME code
     that drives the simulator) forms a batch against the shared
     BlockManager accounting;
  2. reload/eviction directives are applied to the PagedKVPool (host
     mirrors, drops, restores);
  3. decode entries run as one ``decode_batch`` call; prefill chunks run
     per request (``prefill_chunk``), greedy-sampling the first token when
     a prompt completes;
  4. measured wall-clock batch latencies feed the §4.1 estimator, which is
     refit online every ``refit_every`` batches (the offline-profiling
     bootstrap happens in ``calibrate``).

The engine clock can be virtual (``clock=manual``) for deterministic tests.

Two driving modes:

* synchronous — a caller (tests, ``ServiceController``) invokes ``step()``
  directly and inspects the returned dict;
* threaded — an ``EngineDriver`` owns the engine on its own thread, pulls
  submissions from a per-instance inbox queue, and forwards per-token
  ``TokenEvent``s plus per-step ``StepEvent``s to a sink (the async
  ``ServiceFrontend``).  All engine state is touched only on the driver
  thread, so the engine itself needs no locks.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from ..core.batching import BatchPlan, EngineConfig, SchedView
from ..core.blocks import BlockManager, blocks_for
from ..core.estimator import BatchLatencyEstimator
from ..core.request import Phase, Request
from ..models.model import ArchConfig
from . import model_exec
from .kv_pool import PagedKVPool
from .prefix_cache import RadixPrefixCache


@dataclass(frozen=True)
class TokenEvent:
    """One token leaving an engine, stamped on the driver thread."""
    rid: int
    token: int
    index: int                   # 1-based output position
    t_wall: float                # time.monotonic() at emission
    first: bool
    last: bool


@dataclass(frozen=True)
class StepEvent:
    """Engine-side summary of one iteration, for router bookkeeping."""
    iid: int
    free_blocks: int
    latency: float
    est_time: float
    prefill_done: tuple = ()     # rids whose first token just came out
    finished: tuple = ()         # rids fully generated this step


@dataclass
class EngineStats:
    iterations: int = 0
    tokens_out: int = 0
    prefill_tokens: int = 0
    evictions: int = 0
    reload_blocks: int = 0
    cache_hit_tokens: int = 0      # prompt tokens served from the prefix cache
    cache_insert_blocks: int = 0   # blocks adopted into the prefix cache
    cow_forks: int = 0             # copy-on-write forks of shared blocks
    batch_latencies: list = field(default_factory=list)


class Engine:
    def __init__(self, cfg: ArchConfig, params, eng_cfg: EngineConfig,
                 policy, *, num_blocks: int = 512, block_size: int = 16,
                 t_block: float = 5e-4, max_ctx: int = 1024,
                 est: Optional[BatchLatencyEstimator] = None,
                 bm_kwargs: Optional[dict] = None, seed: int = 0,
                 prefix_cache: bool = True,
                 cache_blocks: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.eng_cfg = eng_cfg
        self.policy = policy
        self.max_ctx = max_ctx
        self.pool = PagedKVPool(cfg, num_blocks, block_size)
        self.bm = BlockManager(num_blocks - 1, block_size, t_block,
                               **(bm_kwargs or {}))
        # radix prefix cache: shares prompt KV across requests (refcounted
        # blocks, CoW); holds at most ``cache_blocks`` beyond live pins and
        # yields them back on demand (BlockManager.reclaim_cache).
        self.cache: Optional[RadixPrefixCache] = (
            RadixPrefixCache(self.pool, self.bm, max_blocks=cache_blocks)
            if prefix_cache else None)
        self.est = est or BatchLatencyEstimator(
            a_p=1e-8, b_p=1e-8, c_p=1e-5, a_d=1e-8, b_d=1e-4, t_c=1e-3)
        self.queue: list[Request] = []
        self.now = 0.0
        # when set (frontend mode), ``now`` tracks wall time relative to a
        # shared epoch so token stamps are monotonic ACROSS replicas —
        # required for cross-replica failover and client-edge metrics.
        self._wall_epoch: Optional[float] = None
        self.stats = EngineStats()
        self._profile: list[tuple[list, float]] = []
        self.refit_every = 50
        self.alive = True
        self.outputs: dict[int, list[int]] = {}
        # streaming hook: called as on_token(req, tok, first, last) from
        # whichever thread steps the engine, at the instant of emission —
        # this is what lets TTFT/TPOT be measured at the client edge.
        self.on_token: Optional[Callable[[Request, int, bool, bool],
                                         None]] = None

    # ------------------------------------------------------------------
    def add_request(self, req: Request, prompt_tokens: np.ndarray,
                    prior_outputs: Optional[list[int]] = None) -> None:
        """``prior_outputs``: tokens already streamed to the client before a
        failover — the engine resumes mid-generation by recomputing their
        KV (they are ordinary known tokens) and continuing exactly."""
        req.instance = id(self) & 0xffff
        self.queue.append(req)
        self.outputs[req.rid] = list(prior_outputs or [])
        prompt = np.asarray(prompt_tokens, np.int32)
        req._prompt = prompt  # type: ignore
        if self.cache is not None:
            hit, blocks = self.cache.match(prompt, self.now, req.rid,
                                           req.weight)
            req.prefilled = hit
            if hit:
                # point the table at the cached blocks; only the uncached
                # suffix remains as (chunked) prefill work
                self.pool.share(req.rid, blocks)
                self.bm.attach_cached(req, hit)
                self.stats.cache_hit_tokens += hit

    def has_work(self) -> bool:
        return any(r.phase != Phase.FINISHED for r in self.queue)

    # ------------------------------------------------------------------
    def _sync_pool_with_bm(self, plan: BatchPlan) -> None:
        """Apply the §4.3 directives the policy issued on the accounting
        layer (BlockManager) to the actual data (PagedKVPool)."""
        for r in plan.evictions:
            s = self.bm.state(r)
            # mirror what survives to host, then drop device blocks
            keep_blocks = blocks_for(s.host_tokens, self.bm.block_size)
            if keep_blocks:
                self.pool.offload_blocks(
                    r.rid, list(range(keep_blocks)))
            self.pool.drop_device_blocks(r.rid)
            self.stats.evictions += 1

    def use_wall_clock(self, epoch: float) -> None:
        """Drive ``now`` from ``time.monotonic() - epoch`` (shared across
        replicas) instead of the per-engine virtual latency accumulator."""
        self._wall_epoch = epoch
        self.now = max(self.now, time.monotonic() - epoch)

    def step(self) -> Optional[dict]:
        if not self.alive:
            return None
        if self._wall_epoch is not None:
            self.now = max(self.now, time.monotonic() - self._wall_epoch)
        self.bm.complete_offloads(self.now)
        view = SchedView(self.queue, self.bm, self.est, self.eng_cfg,
                         self.now)
        plan = self.policy.form_batch(view)
        if not plan.entries:
            return None
        t0 = time.monotonic()
        self._sync_pool_with_bm(plan)

        # reload data for requests whose plan restored host blocks
        for e in plan.entries:
            hb = self.pool.host_blocks(e.req.rid)
            dev_tok = self.bm.state(e.req).dev_tokens
            dev_blocks_needed = blocks_for(dev_tok, self.bm.block_size)
            have = len(self.pool.tables.get(e.req.rid, []))
            if have < dev_blocks_needed and hb:
                n = dev_blocks_needed - have
                self.pool.reload_blocks(e.req.rid, n)
                self.stats.reload_blocks += n

        decode_entries = [e for e in plan.entries if not e.is_prefill]
        prefill_entries = [e for e in plan.entries if e.is_prefill]
        emitted: list[Request] = []

        # --- prefill / recompute chunks (per request) ---------------------
        for e in prefill_entries:
            r = e.req
            c = model_exec.bucket(e.n_tokens)
            ctx = e.l_kv
            self.pool.ensure_capacity(r.rid, ctx + e.n_tokens)
            # CoW guard: the first block written this pass may be shared
            # (all later blocks are freshly allocated)
            if self.pool.ensure_writable(r.rid, ctx // self.pool.block_size):
                self.bm.note_fork(r)
                self.stats.cow_forks += 1
            toks = np.zeros((1, c), np.int32)
            prompt: np.ndarray = r._prompt  # type: ignore
            seq = np.concatenate([prompt, np.asarray(
                self.outputs[r.rid], np.int32)])
            toks[0, :e.n_tokens] = seq[ctx:ctx + e.n_tokens]
            max_ctx = model_exec.bucket(ctx + c, buckets=(
                self.max_ctx,)) if ctx + c <= self.max_ctx else ctx + c
            maxp = max_ctx // self.pool.block_size
            table = self.pool.table_array([r.rid], maxp=maxp)
            logits, self.pool.kv = model_exec.prefill_chunk(
                self.cfg, self.params, self.pool.kv, jnp.asarray(toks),
                table, jnp.asarray([ctx], jnp.int32), max_ctx)
            self.stats.prefill_tokens += e.n_tokens
            done_ctx = ctx + e.n_tokens
            target = r.prompt_len + max(0, r.generated - 1)
            if done_ctx >= r.prompt_len and r.generated == 0:
                tok = int(jnp.argmax(logits[0, e.n_tokens - 1]))
                self._emit(r, tok, emitted)
                if self.cache is not None:
                    # adopt the prompt's full blocks into the prefix cache
                    # (charge moves request -> cache; blocks now shared)
                    adopted = self.cache.insert(
                        prompt, self.pool.tables[r.rid], r.rid, self.now,
                        r.weight)
                    if adopted:
                        self.bm.donate_to_cache(r, adopted)
                        self.stats.cache_insert_blocks += adopted
                    self.cache.shrink_to_capacity()
            # recompute completion emits nothing (next decode pass does)

        # --- decode batch ---------------------------------------------------
        if decode_entries:
            rids = [e.req.rid for e in decode_entries]
            lens = np.array([e.l_kv for e in decode_entries], np.int32)
            for e in decode_entries:
                self.pool.ensure_capacity(e.req.rid, e.l_kv + 1)
                if self.pool.ensure_writable(e.req.rid,
                                             e.l_kv // self.pool.block_size):
                    self.bm.note_fork(e.req)
                    self.stats.cow_forks += 1
            maxp = max(len(self.pool.tables[r]) for r in rids)
            table = self.pool.table_array(rids, maxp=maxp)
            last = np.array(
                [self._last_token(e.req) for e in decode_entries], np.int32)
            logits, self.pool.kv = model_exec.decode_batch(
                self.cfg, self.params, self.pool.kv, jnp.asarray(last),
                table, jnp.asarray(lens))
            nxt = np.asarray(jnp.argmax(logits, -1))
            for e, tok in zip(decode_entries, nxt):
                self._emit(e.req, int(tok), emitted)

        latency = time.monotonic() - t0
        if self._wall_epoch is not None:
            self.now = max(self.now, time.monotonic() - self._wall_epoch)
        else:
            self.now += latency
        self.stats.iterations += 1
        self.stats.batch_latencies.append(latency)
        self._profile.append((plan.work_items(), latency))
        if len(self._profile) >= self.refit_every:
            self._refit()

        finished = [r for r in self.queue if r.phase == Phase.FINISHED]
        for r in finished:
            self.bm.release(r)
            self.pool.release(r.rid)
        self.queue = [r for r in self.queue if r.phase != Phase.FINISHED]
        return {"emitted": emitted, "finished": finished,
                "latency": latency, "plan": plan}

    # ------------------------------------------------------------------
    def _last_token(self, r: Request) -> int:
        outs = self.outputs[r.rid]
        if outs:
            return outs[-1]
        return int(r._prompt[-1])  # type: ignore

    def _emit(self, r: Request, tok: int, emitted: list) -> None:
        self.outputs[r.rid].append(tok)
        first = r.generated == 0
        r.emit_token(self.now)
        self.stats.tokens_out += 1
        emitted.append(r)
        if self.on_token is not None:
            self.on_token(r, tok, first, r.phase == Phase.FINISHED)

    def _refit(self) -> None:
        try:
            batches = [b for b, _ in self._profile]
            lats = [l for _, l in self._profile]
            self.est = BatchLatencyEstimator.fit(batches, lats)
        except Exception:
            pass
        self._profile = self._profile[-200:]

    def run_until_drained(self, max_iters: int = 10000) -> None:
        it = 0
        while self.has_work() and it < max_iters:
            if self.step() is None:
                # idle but queued work exists only if nothing schedulable
                break
            it += 1

    def kill(self) -> list[Request]:
        self.alive = False
        orphans = [r for r in self.queue if r.phase != Phase.FINISHED]
        for r in orphans:
            self.bm.release(r)
            self.pool.release(r.rid)
            r.instance = None
        self.queue.clear()
        return orphans


# --------------------------------------------------------------------------
# threaded driver loop
# --------------------------------------------------------------------------

class EngineDriver:
    """Runs one ``Engine``'s iteration loop on a dedicated thread.

    Submissions arrive on a per-instance inbox queue (fed by GoRouting
    dispatch in the ``ServiceFrontend``); each loop iteration drains the
    inbox into the engine queue, forms/executes one batch, and forwards
    token + step events to ``sink(event)``.  The sink is called on the
    driver thread and must be thread-safe (the frontend bridges into its
    asyncio loop with ``call_soon_threadsafe``).
    """

    def __init__(self, iid: int, engine: Engine,
                 sink: Callable[[object], None],
                 *, idle_wait: float = 2e-3, name: Optional[str] = None):
        self.iid = iid
        self.engine = engine
        self.sink = sink
        self.idle_wait = idle_wait
        self.inbox: "queue.Queue[tuple]" = queue.Queue()
        # rids added to THIS engine that have not yet emitted here —
        # drives StepEvent.prefill_done.  ``generated == 1`` would miss
        # failover-resumed requests whose first token predates this engine.
        self._awaiting_first: set[int] = set()
        self._first_done: list[int] = []
        self._stop = threading.Event()
        self._idle = threading.Event()
        self._idle.set()
        self._thread = threading.Thread(
            target=self._run, name=name or f"engine-driver-{iid}",
            daemon=True)
        engine.on_token = self._on_token

    # -- submission (any thread) ---------------------------------------
    def submit(self, req: Request, prompt_tokens,
               prior_outputs: Optional[list] = None) -> None:
        self.inbox.put((req, prompt_tokens, prior_outputs))
        self._idle.clear()

    def pending(self) -> int:
        return self.inbox.qsize()

    @property
    def idle(self) -> bool:
        """True when the inbox is drained and the engine has no work."""
        return self._idle.is_set()

    # -- lifecycle ------------------------------------------------------
    def start(self) -> None:
        self._thread.start()

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        if self._thread.ident is not None:   # never-started threads can't join
            self._thread.join(timeout)

    def join_idle(self, timeout: float = 60.0) -> bool:
        """Block until the driver has drained all submitted work."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self._idle.is_set() and self.inbox.empty():
                return True
            time.sleep(1e-3)
        return False

    def kill(self) -> list[Request]:
        """Hard-stop the thread and return orphaned requests (plus any
        submissions still sitting in the inbox, never started)."""
        self.stop(timeout=120.0)     # a mid-step JIT compile can be slow
        orphans = self.engine.kill()
        while True:
            try:
                req, _, _ = self.inbox.get_nowait()
            except queue.Empty:
                break
            orphans.append(req)
        return orphans

    # -- driver thread --------------------------------------------------
    def _on_token(self, req: Request, tok: int, first: bool,
                  last: bool) -> None:
        if req.rid in self._awaiting_first:
            self._awaiting_first.discard(req.rid)
            self._first_done.append(req.rid)
        self.sink(TokenEvent(req.rid, tok, req.generated,
                             time.monotonic(), first, last))

    def _run(self) -> None:
        eng = self.engine
        while not self._stop.is_set():
            drained = False
            while True:
                try:
                    req, prompt, prior = self.inbox.get_nowait()
                except queue.Empty:
                    break
                eng.add_request(req, prompt, prior_outputs=prior)
                self._awaiting_first.add(req.rid)
                drained = True
            res = eng.step() if eng.alive else None
            if res is None:
                if not drained and not eng.has_work():
                    self._idle.set()
                # park until new work or shutdown (also avoids a hot spin
                # when queued work is temporarily unschedulable)
                self._stop.wait(self.idle_wait)
                continue
            self._idle.clear()
            first_done, self._first_done = self._first_done, []
            self.sink(StepEvent(
                iid=self.iid, free_blocks=eng.bm.free_blocks,
                latency=res["latency"], est_time=res["plan"].est_time,
                prefill_done=tuple(first_done),
                finished=tuple(r.rid for r in res["finished"])))
            if not eng.has_work() and self.inbox.empty():
                self._idle.set()
