"""Real serving engine: continuous batching over an actual JAX model.

One ``Engine`` = one model replica.  Each iteration:

  1. the configured policy (SlideBatching or a baseline — the SAME code
     that drives the simulator) forms a batch against the shared
     BlockManager accounting;
  2. reload/eviction directives are applied to the PagedKVPool (host
     mirrors, drops, restores) — with ``overlap_transfers`` the copies run
     on a background worker (serving/transfer.py): offloads are enqueued
     as one-gather snapshots, reloads consume pre-staged buffers, and
     completions feed the BlockManager's accounting lanes + the measured
     ``t_block`` behind the §4.3 adaptive copy budget;
  3. decode entries run as one ``decode_batch`` call; prefill chunks run
     PACKED — every request's chunk concatenated into one flat-stream
     ``prefill_packed`` call (per-request ``prefill_chunk`` kept as a
     fallback) — greedy-sampling the first token when a prompt completes;
  4. measured wall-clock batch latencies feed the §4.1 estimator, which is
     refit online every ``refit_every`` batches (the offline-profiling
     bootstrap happens in ``calibrate``).

The engine clock can be virtual (``clock=manual``) for deterministic tests.

Two driving modes:

* synchronous — a caller (tests, ``ServiceController``) invokes ``step()``
  directly and inspects the returned dict;
* threaded — an ``EngineDriver`` owns the engine on its own thread, pulls
  submissions from a per-instance inbox queue, and forwards per-token
  ``TokenEvent``s plus per-step ``StepEvent``s to a sink (the async
  ``ServiceFrontend``).  All engine state is touched only on the driver
  thread, so the engine itself needs no locks.
"""
from __future__ import annotations

import dataclasses
import logging
import queue
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.batching import (BatchPlan, EngineConfig, SchedView,
                             compute_remaining, evict_for_space,
                             needed_context)
from ..core.blocks import BlockManager, blocks_for
from ..core.estimator import BatchLatencyEstimator
from ..core.request import Phase, Request
from ..kernels import kv_block_dequantize
from ..models.model import ArchConfig
from . import model_exec
from .kv_pool import PagedKVPool
from .prefix_cache import RadixPrefixCache
from .spec import DraftRunner
from .transfer import TransferWorker

logger = logging.getLogger(__name__)


@dataclass(frozen=True)
class TokenEvent:
    """One token leaving an engine, stamped on the driver thread."""
    rid: int
    token: int
    index: int                   # 1-based output position
    t_wall: float                # time.monotonic() at emission
    first: bool
    last: bool


@dataclass
class HandoffPayload:
    """One finished prefill leaving a prefill-role replica: the request,
    everything needed to resume it (prompt + tokens already streamed), and
    its KV as host-side block payloads — fp32 arrays, or ``(int8 vals,
    fp32 scales)`` pairs when the handoff wire is quantized (the same
    per-(layer, K/V)-plane scheme as the cold tier, dequantized ON DEVICE
    at adoption)."""
    req: Request
    prompt: np.ndarray
    outputs: list            # tokens already emitted (streamed by src)
    kv_tokens: int           # KV extent shipped == needed_context(req)
    payloads: list           # per-block: np.ndarray | (vals, scales)
    quantized: bool
    src_iid: int = -1        # stamped by the EngineDriver at emission

    @property
    def n_blocks(self) -> int:
        return len(self.payloads)

    @property
    def wire_bytes(self) -> int:
        return sum(b[0].nbytes + b[1].nbytes if isinstance(b, tuple)
                   else b.nbytes for b in self.payloads)


@dataclass(frozen=True)
class HandoffEvent:
    """A prefill replica finished a request's prefill leg: its KV payload
    is ready to be adopted by a decode replica."""
    iid: int                 # source (prefill) instance
    payload: HandoffPayload


@dataclass(frozen=True)
class HandoffAdopted:
    """A decode replica adopted a payload: the decode leg is live there."""
    iid: int                 # adopting (decode) instance
    payload: HandoffPayload


@dataclass(frozen=True)
class HandoffDropped:
    """A decode replica could not adopt a delivered payload (no device
    blocks even after policy eviction) — the router should fail the
    request over to a re-prefill."""
    iid: int                 # target (decode) instance that refused
    payload: HandoffPayload


@dataclass(frozen=True)
class StepEvent:
    """Engine-side summary of one iteration, for router bookkeeping."""
    iid: int
    free_blocks: int
    latency: float
    est_time: float
    prefill_done: tuple = ()     # rids whose first token just came out
    finished: tuple = ()         # rids fully generated this step
    # per-step transfer/overlap telemetry (§4.3 lanes made real)
    offload_blocks: int = 0      # D2H completions drained this step
    reload_blocks: int = 0       # H2D blocks restored for this batch
    transfer_wait: float = 0.0   # seconds the step stalled on sync copies


@dataclass
class EngineStats:
    iterations: int = 0
    tokens_out: int = 0
    prefill_tokens: int = 0
    evictions: int = 0
    reload_blocks: int = 0
    cache_hit_tokens: int = 0      # prompt tokens served from the prefix cache
    cache_insert_blocks: int = 0   # blocks adopted into the prefix cache
    cow_forks: int = 0             # copy-on-write forks of shared blocks
    packed_prefill_calls: int = 0  # batched multi-request prefill launches
    offload_blocks: int = 0        # async D2H blocks landed on host
    staged_hits: int = 0           # reloads served from pre-staged buffers
    staged_misses: int = 0         # reloads that fell back to a sync copy
    transfer_wait_s: float = 0.0   # total step time stalled on sync copies
    transfer_failures: int = 0     # background copies that raised (fell
    # back to the synchronous path; first one is logged by the worker)
    t_block_measured: float = 0.0  # EWMA per-block copy time (closed loop)
    refit_failures: int = 0        # online estimator refits that failed
    decode_launches: int = 0       # jitted decode calls (one per step with
    # decode work; fused or logits path)
    host_bytes: int = 0            # current hot host-tier bytes (<= budget)
    spill_blocks: int = 0          # cumulative prefix-cache blocks spilled
    # to the host tier instead of destroyed (tiered KV cache)
    cold_blocks: int = 0           # current int8 cold-tier blocks
    host_syncs: int = 0            # device->host fetches in the hot loop —
    # the perf gate asserts exactly one per model launch (no hidden syncs)
    # --- disaggregation (prefill/decode split) ---------------------------
    handoffs_out: int = 0          # prefill legs exported to a decode peer
    handoff_blocks_out: int = 0    # KV blocks shipped out
    handoff_bytes_out: int = 0     # wire bytes shipped out (int8 < fp32)
    handoffs_in: int = 0           # payloads adopted from a prefill peer
    handoff_blocks_in: int = 0     # KV blocks adopted
    handoff_bytes_in: int = 0      # wire bytes adopted
    # --- speculative decoding (draft propose + packed verify) ------------
    spec_proposed: int = 0         # draft tokens proposed for verification
    spec_accepted: int = 0         # proposals matching the target argmax
    spec_rejected: int = 0         # proposals refuted (== proposed - accepted)
    draft_launches: int = 0        # draft-model jit calls (prefill + rounds)
    spec_depth_hist: dict = field(default_factory=dict)  # depth -> entries
    # bounded: long-lived replicas must not grow without limit
    batch_latencies: deque = field(
        default_factory=lambda: deque(maxlen=512))


class Engine:
    def __init__(self, cfg: ArchConfig, params, eng_cfg: EngineConfig,
                 policy, *, num_blocks: int = 512, block_size: int = 16,
                 t_block: float = 5e-4, max_ctx: int = 1024,
                 est: Optional[BatchLatencyEstimator] = None,
                 bm_kwargs: Optional[dict] = None, seed: int = 0,
                 prefix_cache: bool = True,
                 cache_blocks: Optional[int] = None,
                 packed_prefill: bool = True,
                 overlap_transfers: bool = True,
                 fused_decode: bool = True,
                 host_tier_bytes: Optional[int] = None,
                 cold_quantize: bool = True,
                 role: str = "coloc",
                 handoff_quantize: bool = False,
                 spec_draft: Optional[tuple] = None,
                 spec_draft_blocks: Optional[int] = None):
        if role not in ("coloc", "prefill", "decode"):
            raise ValueError(f"unknown engine role: {role!r}")
        if eng_cfg.spec_k > 0 and spec_draft is None:
            raise ValueError("spec_k > 0 requires spec_draft=(cfg, params)")
        self.cfg = cfg
        self.params = params
        # a role-parameterized replica runs the same pipeline; the role
        # only (a) flips the policy's pd_mode (prefill replicas price
        # admission with the prefill-phase phi), (b) arms the handoff
        # export path (prefill) / import path (decode)
        self.role = role
        if role != "coloc" and eng_cfg.pd_mode != role:
            eng_cfg = dataclasses.replace(eng_cfg, pd_mode=role)
        self.eng_cfg = eng_cfg
        # int8 handoff wire: quantize the exported KV on device (the cold
        # tier's kernel pair) so the cross-replica copy is ~4x narrower;
        # lossy-but-deterministic (|x - deq| <= scale/2 per plane)
        self.handoff_quantize = handoff_quantize
        self.policy = policy
        self.max_ctx = max_ctx
        # host_tier_bytes bounds the hot host tier (LRU demotion into the
        # int8 cold tier, see kv_pool.KVTierStore); None = legacy
        # unbounded host mirror with bitwise-identical token streams
        self.pool = PagedKVPool(cfg, num_blocks, block_size,
                                host_tier_bytes=host_tier_bytes,
                                cold_quantize=cold_quantize)
        self.bm = BlockManager(num_blocks - 1, block_size, t_block,
                               **(bm_kwargs or {}))
        # radix prefix cache: shares prompt KV across requests (refcounted
        # blocks, CoW); holds at most ``cache_blocks`` beyond live pins and
        # yields them back on demand (BlockManager.reclaim_cache).  With a
        # bounded host tier, evictions SPILL into it instead of destroying
        # the KV (restorable on a later match).
        self.cache: Optional[RadixPrefixCache] = (
            RadixPrefixCache(self.pool, self.bm, max_blocks=cache_blocks,
                             spill=host_tier_bytes is not None)
            if prefix_cache else None)
        self.est = est or BatchLatencyEstimator(
            a_p=1e-8, b_p=1e-8, c_p=1e-5, a_d=1e-8, b_d=1e-4, t_c=1e-3)
        # --- overlapped execution (packed prefill + async transfer lanes)
        self.packed_prefill = packed_prefill
        # fused decode: argmax on device, batch/table padded to shape
        # buckets so the jit cache persists across steps (see
        # model_exec.decode_step); the logits path is kept as a safety
        # hatch and for the fused-vs-unfused perf/equivalence gate
        self.fused_decode = fused_decode
        self.overlap_transfers = overlap_transfers
        # speculative decoding: a draft replica proposes, the target packs
        # all (request, position) rows into ONE verify_step launch; greedy
        # acceptance keeps streams bitwise-identical to plain decode
        self.draft: Optional[DraftRunner] = None
        if spec_draft is not None and self.eng_cfg.spec_k > 0:
            dcfg, dparams = spec_draft
            self.draft = DraftRunner(
                dcfg, dparams, num_blocks=spec_draft_blocks or num_blocks,
                block_size=block_size, max_ctx=max_ctx)
        self.worker: Optional[TransferWorker] = (
            TransferWorker() if overlap_transfers else None)
        if self.cache is not None:
            # spill restores prefer buffers the worker pre-staged
            self.cache.worker = self.worker
        # per-rid transfer epoch: bumped on evict/release so background
        # completions for a superseded residency generation are discarded
        self._epoch: dict[int, int] = {}
        # proactive-offload directives recorded during form_batch (the K/V
        # they name is only fully written once the step's exec completes)
        self._offload_directives: list[tuple[int, int, int, int]] = []
        if self.worker is not None:
            self.bm.external_lanes = True
            self.bm.offload_sink = self._note_offload_directive
        # full token sequence (prompt + outputs) per request, appended
        # incrementally — avoids the per-chunk prompt+outputs rebuild
        self._seqs: dict[int, np.ndarray] = {}
        self._seq_fill: dict[int, int] = {}
        # prefill-role export state: payloads whose D2H copy is riding the
        # background lane (rid -> payload + retained device snapshot), and
        # completed payloads awaiting pickup by the driver/controller
        self._handoff_wait: dict[int, tuple[HandoffPayload, object, int]] = {}
        self._handoff_ready: list[HandoffPayload] = []
        self.queue: list[Request] = []
        self.now = 0.0
        # when set (frontend mode), ``now`` tracks wall time relative to a
        # shared epoch so token stamps are monotonic ACROSS replicas —
        # required for cross-replica failover and client-edge metrics.
        self._wall_epoch: Optional[float] = None
        self.stats = EngineStats()
        self._profile: list[tuple[list, float]] = []
        self.refit_every = 50
        self.alive = True
        self.outputs: dict[int, list[int]] = {}
        # streaming hook: called as on_token(req, tok, first, last) from
        # whichever thread steps the engine, at the instant of emission —
        # this is what lets TTFT/TPOT be measured at the client edge.
        self.on_token: Optional[Callable[[Request, int, bool, bool],
                                         None]] = None

    # ------------------------------------------------------------------
    def add_request(self, req: Request, prompt_tokens: np.ndarray,
                    prior_outputs: Optional[list[int]] = None) -> None:
        """``prior_outputs``: tokens already streamed to the client before a
        failover — the engine resumes mid-generation by recomputing their
        KV (they are ordinary known tokens) and continuing exactly."""
        req.instance = id(self) & 0xffff
        self.queue.append(req)
        self.outputs[req.rid] = list(prior_outputs or [])
        prompt = np.asarray(prompt_tokens, np.int32)
        req._prompt = prompt  # type: ignore
        # pre-size the full token sequence once; _emit appends in place
        prior = self.outputs[req.rid]
        seq = np.zeros(len(prompt) + max(req.output_len, len(prior)) + 1,
                       np.int32)
        seq[:len(prompt)] = prompt
        if prior:
            seq[len(prompt):len(prompt) + len(prior)] = prior
        self._seqs[req.rid] = seq
        self._seq_fill[req.rid] = len(prompt) + len(prior)
        if self.cache is not None:
            hit, blocks = self.cache.match(prompt, self.now, req.rid,
                                           req.weight)
            req.prefilled = hit
            if hit:
                # point the table at the cached blocks; only the uncached
                # suffix remains as (chunked) prefill work
                self.pool.share(req.rid, blocks)
                self.bm.attach_cached(req, hit)
                self.stats.cache_hit_tokens += hit

    def has_work(self) -> bool:
        return (any(r.phase != Phase.FINISHED for r in self.queue)
                or bool(self._handoff_wait) or bool(self._handoff_ready))

    # ------------------------------------------------------------------
    # §4.3 transfer lanes (background worker plumbing)
    # ------------------------------------------------------------------
    def _note_offload_directive(self, rid: int, start: int, n: int) -> None:
        """BlockManager offload_sink: a proactive D2H mirror was scheduled
        during form_batch.  The blocks' K/V is only guaranteed written once
        this step's exec completes, so just record the directive; the
        device snapshot happens in ``_dispatch_offloads``."""
        self._offload_directives.append(
            (rid, start, n, self._epoch.get(rid, 0)))

    def _dispatch_offloads(self) -> None:
        """Snapshot each recorded directive's blocks (one device gather)
        and hand them to the background D2H lane."""
        directives, self._offload_directives = self._offload_directives, []
        if self.worker is None:
            return
        for rid, start, n, epoch in directives:
            if epoch != self._epoch.get(rid, 0):
                continue            # evicted/released since the directive
            t = self.pool.tables.get(rid)
            if not t:
                continue
            logical = [bi for bi in range(start, start + n) if bi < len(t)]
            if not logical:
                continue
            if self.pool.tier.prefer_cold(len(logical)):
                # this mirror would land demote-bound in the cold tier:
                # quantize on device so the D2H wire is int8 (~4x less)
                gathered = self.pool.gather_blocks_quantized(rid, logical)
            else:
                gathered = self.pool.gather_blocks(rid, logical)
            self.worker.offload(rid, epoch, logical, gathered)

    def _drain_transfers(self) -> int:
        """Collect background-copy completions; feed the accounting lanes
        (real transfers replace the virtual clock) and the measured-
        throughput side of the adaptive copy budget."""
        if self.worker is None:
            return 0
        landed = 0
        for d in self.worker.drain():
            if d.kind == "d2h" and d.rid in self._handoff_wait:
                # handoff export riding the D2H lane: the local leg is
                # already released, so this must be intercepted BEFORE the
                # stale/dead guards.  Failure falls back to a synchronous
                # fetch of the retained device snapshot (functional, so
                # still intact regardless of later pool writes).
                payload, gathered, epoch = self._handoff_wait[d.rid]
                if d.epoch == epoch:
                    del self._handoff_wait[d.rid]
                    self._epoch.pop(d.rid, None)
                    if d.ok:
                        payload.payloads = [d.blocks[bi]
                                            for bi in sorted(d.blocks)]
                    else:
                        self.stats.transfer_failures += 1
                        payload.payloads = self._materialize_handoff(
                            gathered, payload.quantized)
                    self._finalize_handoff(payload)
                continue
            stale = d.epoch != self._epoch.get(d.rid, 0)
            dead = d.rid not in self.bm.table
            if d.kind == "h2d":
                # a staging buffer that can no longer be consumed would pin
                # one of the double-buffer slots forever: job finished after
                # invalidate() (stale), after the request was released
                # (dead), or after the reload it was staged for already ran
                # synchronously (nothing left on host to restore)
                if d.rid < 0:
                    # radix-cache spill pseudo-rid: never in bm.table, so
                    # the dead-guard must instead ask the cache whether the
                    # spilled group still exists (restore consumes the
                    # buffer; re-adoption/prune invalidates it)
                    if (self.cache is None
                            or not self.cache.has_spilled(d.rid)):
                        self.worker.invalidate(d.rid)
                    continue
                s = self.bm.table.get(d.rid)
                if dead or (s is not None and s.host_tokens == 0):
                    self.worker.invalidate(d.rid)
                elif stale:
                    self.worker.discard_stale(d.rid,
                                              self._epoch.get(d.rid, 0))
            if stale:
                continue
            if not d.ok:
                self.stats.transfer_failures += 1
                if d.kind == "d2h":
                    # release the pending claim; mirroring retries later
                    self.bm.note_offload_failed(d.rid, d.n_blocks)
                continue
            if d.kind == "d2h" and d.rid in self.bm.table:
                self.pool.host_store(d.rid, d.blocks)
                self.bm.note_offload_complete(d.rid, d.n_blocks)
                self.stats.offload_blocks += d.n_blocks
                landed += d.n_blocks
            if not d.quantized:
                # int8-wire copies are excluded: the copy budget scales
                # them by COLD_WIRE_RATIO on top of the fp32 t_block,
                # so folding their samples in would count the 4x twice
                self.bm.observe_transfer(d.n_blocks, d.seconds)
                self.stats.t_block_measured = self.bm.t_block
        return landed

    def _prefetch_reloads(self) -> None:
        """Hint the H2D staging lane: evicted requests near the head of the
        (policy-sorted) queue will likely reload next round — stage their
        host blocks now so the copy lands before the batch that needs it.
        Payloads go out in tier wire format: cold groups ship int8 and the
        worker dequantizes on device.  Leftover slots stage the most
        recently touched radix-cache spill groups."""
        if self.worker is None:
            return
        hinted = 0
        for r in self.queue:
            if hinted >= self.worker.max_staged:
                break
            s = self.bm.table.get(r.rid)
            if s is None or s.host_tokens <= 0 or s.dev_tokens > 0:
                continue
            nb = blocks_for(s.host_tokens, self.bm.block_size)
            payloads = self.pool.tier.payloads(r.rid, range(nb))
            if payloads is None:
                continue
            if self.worker.prefetch(r.rid, self._epoch.get(r.rid, 0),
                                    payloads):
                hinted += 1
        if self.cache is not None and hinted < self.worker.max_staged:
            for host_rid, payloads in self.cache.spill_candidates(
                    self.worker.max_staged - hinted):
                if self.worker.prefetch(host_rid, 0, payloads):
                    hinted += 1

    def _forget_transfers(self, rid: int) -> None:
        """Invalidate all in-flight transfer state for rid (evict/release)."""
        self._epoch[rid] = self._epoch.get(rid, 0) + 1
        if self.worker is not None:
            self.worker.invalidate(rid)

    def _sync_tier_state(self) -> None:
        """Mirror the tier store into the scheduling layer: mark each live
        request's host span cold when its tier group was demoted (the
        copy-budget control then prices its reload at the int8 wire), and
        refresh the tier gauges on EngineStats.  With an unbounded host
        tier nothing is ever cold and this is a no-op on the accounting."""
        tier = self.pool.tier
        if tier.budget_bytes is not None:
            for rid, s in self.bm.table.items():
                s.cold_tokens = (s.host_tokens if tier.is_cold(rid) else 0)
        self.stats.host_bytes = tier.host_bytes
        self.stats.cold_blocks = tier.cold_blocks
        if self.cache is not None:
            self.stats.spill_blocks = self.cache.stats.spilled_blocks

    def _evict_to_host(self, r: Request) -> None:
        """Apply one (already accounted) eviction to the data layer: the
        surviving span must be on host — with overlap the async mirror
        already landed (mirrored_blocks only counts real completions);
        otherwise copy the missing blocks now, in one batched device
        fetch — then drop the device references."""
        s = self.bm.state(r)
        keep_blocks = blocks_for(s.host_tokens, self.bm.block_size)
        if keep_blocks:
            h = self.pool.host.get(r.rid, {})
            missing = [bi for bi in range(keep_blocks) if bi not in h]
            self.pool.offload_blocks(r.rid, missing)
        self.pool.drop_device_blocks(r.rid)
        self._forget_transfers(r.rid)
        if self.draft is not None:
            self.draft.drop(r.rid)
        self.stats.evictions += 1

    def _sync_pool_with_bm(self, plan: BatchPlan) -> None:
        """Apply the §4.3 directives the policy issued on the accounting
        layer (BlockManager) to the actual data (PagedKVPool)."""
        for r in plan.evictions:
            self._evict_to_host(r)

    # ------------------------------------------------------------------
    # disaggregation: prefill -> decode KV handoff
    # ------------------------------------------------------------------
    def _materialize_handoff(self, gathered, quantized: bool) -> list:
        """Synchronous fetch of a handoff snapshot into per-block host
        payloads (the no-worker path, and the failure fallback)."""
        if quantized:
            vals, scales = jax.device_get(gathered)
            vals, scales = np.asarray(vals), np.asarray(scales)
            return [(vals[i], scales[i]) for i in range(vals.shape[0])]
        data = np.asarray(jax.device_get(gathered))
        return [data[i] for i in range(data.shape[0])]

    def _finalize_handoff(self, payload: HandoffPayload) -> None:
        self.stats.handoffs_out += 1
        self.stats.handoff_blocks_out += payload.n_blocks
        self.stats.handoff_bytes_out += payload.wire_bytes
        self._handoff_ready.append(payload)

    def _collect_handoffs(self) -> None:
        """Prefill role: any queued request whose prefill leg is complete
        (first token emitted — or a failover recompute caught up — and the
        KV fully device-resident) is exported.  Runs before form_batch so
        an export-ready request is never decoded locally, and again after
        the step so the common case (prefill finished this iteration)
        ships without an extra scheduling round."""
        ready = []
        for r in self.queue:
            if r.phase != Phase.DECODE:
                continue        # output_len == 1 finishes on this replica
            s = self.bm.table.get(r.rid)
            if s is None or s.dev_tokens < needed_context(r):
                continue
            ready.append(r)
        for r in ready:
            self._export_handoff(r)

    def _export_handoff(self, r: Request) -> None:
        rid = r.rid
        kv_tokens = needed_context(r)
        nb = blocks_for(kv_tokens, self.pool.block_size)
        logical = list(range(nb))
        payload = HandoffPayload(
            req=r, prompt=np.asarray(r._prompt, np.int32),  # type: ignore
            outputs=list(self.outputs.get(rid, [])),
            kv_tokens=kv_tokens, payloads=[],
            quantized=self.handoff_quantize)
        # ONE device gather (quantized on device when the wire is int8);
        # jax arrays are functional, so the snapshot is race-free and the
        # local blocks can be released immediately
        gathered = (self.pool.gather_blocks_quantized(rid, logical)
                    if self.handoff_quantize
                    else self.pool.gather_blocks(rid, logical))
        epoch = self._epoch.get(rid, 0) + 1
        self._epoch[rid] = epoch
        if self.worker is not None:
            self._handoff_wait[rid] = (payload, gathered, epoch)
            self.worker.offload(rid, epoch, logical, gathered)
        # release the local leg — the decode replica owns the request now
        self.bm.release(r)
        self.pool.release(rid)
        if self.worker is not None:
            self.worker.invalidate(rid)
        self.outputs.pop(rid, None)
        self._seqs.pop(rid, None)
        self._seq_fill.pop(rid, None)
        if self.draft is not None:
            self.draft.drop(rid)
        self.queue = [q for q in self.queue if q.rid != rid]
        r.instance = None
        if self.worker is None:
            self._epoch.pop(rid, None)
            payload.payloads = self._materialize_handoff(
                gathered, payload.quantized)
            self._finalize_handoff(payload)

    def take_handoffs(self) -> list[HandoffPayload]:
        """Completed handoff payloads since the last call (driver picks
        these up after each step and forwards them to the router)."""
        out, self._handoff_ready = self._handoff_ready, []
        return out

    def handoff_outputs(self, rid: int) -> Optional[list[int]]:
        """Streamed tokens of a request currently in handoff-export state.

        ``_export_handoff`` pops ``self.outputs[rid]`` the moment the KV
        snapshot is taken, so a caller mirroring outputs into a durable
        log after the step would otherwise miss the prefill leg's first
        token — and a failover resume from that log would drop it.  The
        payload keeps the authoritative copy until delivery."""
        ent = self._handoff_wait.get(rid)
        if ent is not None:
            return list(ent[0].outputs)
        for p in self._handoff_ready:
            if p.req.rid == rid:
                return list(p.outputs)
        return None

    def import_handoff(self, payload: HandoffPayload) -> bool:
        """Decode side: adopt a prefill peer's KV payload and continue the
        decode leg exactly where the source stopped.  All blocks land in
        ONE batched scatter; int8 wire payloads are dequantized ON DEVICE.
        Returns False if device blocks could not be made available (the
        caller should fail over to a re-prefill)."""
        req, rid = payload.req, payload.req.rid
        nb = len(payload.payloads)
        ok = self.bm.grow(req, payload.kv_tokens, self.now)
        if not ok:
            # the admission-time reservation should make this impossible;
            # evict per policy (mirrors EngineSim.import_request)
            view = SchedView(self.queue, self.bm, self.est, self.eng_cfg,
                             self.now)
            need = self.bm.blocks_needed_for_growth(req, payload.kv_tokens)
            for v in evict_for_space(view, need, {rid}):
                self._evict_to_host(v)
            ok = self.bm.grow(req, payload.kv_tokens, self.now)
        if not ok or not self.pool.alloc(rid, nb):
            self.bm.release(req)
            self.pool.release(rid)
            return False
        entries = payload.payloads
        if entries and all(isinstance(e, tuple) for e in entries):
            vals = jnp.asarray(np.stack([e[0] for e in entries]))
            scales = jnp.asarray(np.stack([e[1] for e in entries]))
            data = kv_block_dequantize(vals, scales)
        else:
            data = jnp.asarray(np.stack(entries))
        phys = jnp.asarray(self.pool.tables[rid], jnp.int32)
        self.pool.kv = self.pool.kv.at[:, :, phys].set(
            jnp.moveaxis(data, 0, 2))
        req.instance = id(self) & 0xffff
        self.queue.append(req)
        self.outputs[rid] = list(payload.outputs)
        prompt = np.asarray(payload.prompt, np.int32)
        req._prompt = prompt  # type: ignore
        prior = payload.outputs
        seq = np.zeros(len(prompt) + max(req.output_len, len(prior)) + 1,
                       np.int32)
        seq[:len(prompt)] = prompt
        if prior:
            seq[len(prompt):len(prompt) + len(prior)] = prior
        self._seqs[rid] = seq
        self._seq_fill[rid] = len(prompt) + len(prior)
        self.stats.handoffs_in += 1
        self.stats.handoff_blocks_in += nb
        self.stats.handoff_bytes_in += payload.wire_bytes
        return True

    def use_wall_clock(self, epoch: float) -> None:
        """Drive ``now`` from ``time.monotonic() - epoch`` (shared across
        replicas) instead of the per-engine virtual latency accumulator."""
        self._wall_epoch = epoch
        self.now = max(self.now, time.monotonic() - epoch)

    def step(self) -> Optional[dict]:
        if not self.alive:
            return None
        if self._wall_epoch is not None:
            self.now = max(self.now, time.monotonic() - self._wall_epoch)
        offload_landed = self._drain_transfers()
        self.bm.complete_offloads(self.now)
        self._sync_tier_state()
        if self.role == "prefill":
            # straggler exports (e.g. a full-prompt cache hit made the
            # request decode-ready without any prefill work this step) —
            # and keeps export-ready requests out of the local batch
            self._collect_handoffs()
        view = SchedView(self.queue, self.bm, self.est, self.eng_cfg,
                         self.now)
        plan = self.policy.form_batch(view)
        if not plan.entries:
            # evictions can outlive a failed admission round: keep the
            # pool consistent with the accounting before going idle, and
            # use the idle gap to stage likely reloads
            if plan.evictions:
                self._sync_pool_with_bm(plan)
            self._offload_directives.clear()
            self._prefetch_reloads()
            return None
        t0 = time.monotonic()
        self._sync_pool_with_bm(plan)

        # reload data for requests whose plan restored host blocks; prefer
        # the background lane's pre-staged buffers (the H2D copy already
        # landed), falling back to a synchronous batched copy
        step_reload, step_wait = 0, 0.0
        for e in plan.entries:
            s = self.bm.state(e.req)
            hb = self.pool.host_blocks(e.req.rid)
            dev_blocks_needed = blocks_for(s.dev_tokens, self.bm.block_size)
            have = len(self.pool.tables.get(e.req.rid, []))
            # only copy what apply_reload promised (restore_pending): with
            # async mirroring, host entries also exist for live
            # device-resident requests, so ``hb > 0`` alone would trigger
            # phantom reloads on every block-boundary growth
            if s.restore_pending > 0 and have < dev_blocks_needed and hb:
                n = min(s.restore_pending, dev_blocks_needed - have)
                s.restore_pending = 0
                staged = (self.worker.take_staged(
                    e.req.rid, self._epoch.get(e.req.rid, 0))
                    if self.worker is not None else None)
                if staged is not None and staged[0] > 0:
                    # ``n`` also counts blocks this step will write fresh
                    # (grown chunk/decode tokens); the staged buffer covers
                    # exactly the restorable host prefix — consume what it
                    # has, the rest is new capacity allocated at exec time
                    # (same semantics as reload_blocks, which stops at the
                    # first non-host block)
                    self.pool.reload_from_device(e.req.rid, staged[1],
                                                 min(n, staged[0]))
                    self.stats.staged_hits += 1
                else:
                    tr0 = time.monotonic()
                    self.pool.reload_blocks(e.req.rid, n)
                    step_wait += time.monotonic() - tr0
                    if self.worker is not None:
                        self.stats.staged_misses += 1
                self.stats.reload_blocks += n
                step_reload += n
        self.stats.transfer_wait_s += step_wait

        decode_entries = [e for e in plan.entries if not e.is_prefill]
        prefill_entries = [e for e in plan.entries if e.is_prefill]
        emitted: list[Request] = []

        if prefill_entries:
            if self.packed_prefill:
                self._run_prefill_packed(prefill_entries, emitted)
            else:
                self._run_prefill_fallback(prefill_entries, emitted)

        # --- decode batch ---------------------------------------------------
        if decode_entries:
            if self.draft is not None:
                self._run_decode_spec(decode_entries, emitted)
            else:
                self._run_decode(decode_entries, emitted)

        latency = time.monotonic() - t0
        if self._wall_epoch is not None:
            self.now = max(self.now, time.monotonic() - self._wall_epoch)
        else:
            self.now += latency
        self.stats.iterations += 1
        self.stats.batch_latencies.append(latency)
        self._profile.append((plan.work_items(), latency))
        if len(self._profile) >= self.refit_every:
            self._refit()

        finished = [r for r in self.queue if r.phase == Phase.FINISHED]
        for r in finished:
            self.bm.release(r)
            self.pool.release(r.rid)
            if self.draft is not None:
                self.draft.drop(r.rid)
            # drop all per-request transfer state — long-lived replicas
            # must not grow without bound.  A late completion for this rid
            # is caught by the dead-request guard in _drain_transfers (rid
            # no longer in bm.table), so no epoch bump is needed here.
            if self.worker is not None:
                self.worker.invalidate(r.rid)
            self._epoch.pop(r.rid, None)
            self._seqs.pop(r.rid, None)
            self._seq_fill.pop(r.rid, None)
        self.queue = [r for r in self.queue if r.phase != Phase.FINISHED]
        if self.role == "prefill":
            # export every request whose prefill leg just completed (the
            # gather runs before the proactive-mirror dispatch below, so
            # the exported KV ships exactly once)
            self._collect_handoffs()
        # all K/V written and finished requests released — snapshot +
        # enqueue the proactive D2H mirrors the policy scheduled (the
        # released requests' directives drop out via their empty tables,
        # sparing a full dead-request gather), then stage likely reloads
        self._dispatch_offloads()
        self._prefetch_reloads()
        return {"emitted": emitted, "finished": finished,
                "latency": latency, "plan": plan,
                "offload_blocks": offload_landed,
                "reload_blocks": step_reload,
                "transfer_wait": step_wait}

    # ------------------------------------------------------------------
    # decode execution
    # ------------------------------------------------------------------
    def _run_decode(self, decode_entries: list, emitted: list) -> None:
        """Plain decode: one token per request in one launch (fused argmax
        or the logits fallback)."""
        rids = [e.req.rid for e in decode_entries]
        nb = len(decode_entries)
        for e in decode_entries:
            self.pool.ensure_capacity(e.req.rid, e.l_kv + 1)
            if self.pool.ensure_writable(e.req.rid,
                                         e.l_kv // self.pool.block_size):
                self.bm.note_fork(e.req)
                self.stats.cow_forks += 1
        maxp = max(len(self.pool.tables[r]) for r in rids)
        if self.fused_decode:
            # pad batch/table to shape buckets (extra rows: token 0,
            # len 0, null-block table) and fetch only the (B,) argmax
            b_b = model_exec.seg_bucket(nb)
            maxp_b = model_exec.table_bucket(maxp)
            lens = np.zeros(b_b, np.int32)
            lens[:nb] = [e.l_kv for e in decode_entries]
            last = np.zeros(b_b, np.int32)
            last[:nb] = [self._last_token(e.req)
                         for e in decode_entries]
            table = self.pool.table_array(rids, maxp=maxp_b, rows=b_b)
            toks, self.pool.kv = model_exec.decode_step(
                self.cfg, self.params, self.pool.kv,
                jnp.asarray(last), table, jnp.asarray(lens))
            nxt = np.asarray(toks)[:nb]
        else:
            lens = np.array([e.l_kv for e in decode_entries], np.int32)
            table = self.pool.table_array(rids, maxp=maxp)
            last = np.array(
                [self._last_token(e.req) for e in decode_entries],
                np.int32)
            logits, self.pool.kv = model_exec.decode_batch(
                self.cfg, self.params, self.pool.kv, jnp.asarray(last),
                table, jnp.asarray(lens))
            nxt = np.asarray(jnp.argmax(logits, -1))
        self.stats.decode_launches += 1
        self.stats.host_syncs += 1
        for e, tok in zip(decode_entries, nxt):
            self._emit(e.req, int(tok), emitted)

    def _run_decode_spec(self, decode_entries: list, emitted: list) -> None:
        """Speculative decode: the draft proposes up to ``e.depth`` tokens
        per request, then ONE ``verify_step`` launch scores every
        (request, position) row packed together — depth-0 requests
        contribute their single plain-decode row.  Greedy acceptance takes
        the leading proposals that match the target argmax and emits one
        bonus token per match, so the stream is bitwise-identical to plain
        decode (the verify rows ARE plain decode rows; see
        kernels/spec_verify.py).  Depth was capped at admission to the
        current block's remainder, so all speculative writes land in
        blocks the +1-token growth already reserved."""
        for e in decode_entries:
            self.pool.ensure_capacity(e.req.rid, e.l_kv + 1 + e.depth)
            if self.pool.ensure_writable(e.req.rid,
                                         e.l_kv // self.pool.block_size):
                self.bm.note_fork(e.req)
                self.stats.cow_forks += 1
        launches0 = self.draft.launches
        syncs0 = self.draft.syncs
        items = [(e.req.rid, self._seq_view(e.req), e.depth)
                 for e in decode_entries if e.depth > 0]
        proposals = self.draft.propose(items) if items else {}
        self.stats.draft_launches += self.draft.launches - launches0
        self.stats.host_syncs += self.draft.syncs - syncs0
        for e in decode_entries:
            if e.depth > 0 and e.req.rid not in proposals:
                e.depth = 0      # draft pool exhausted: plain decode row

        # pack one verify row per (request, draft position); tables stay
        # compact — one row per REQUEST — addressed via row_seg.  The
        # segment bucket reserves one extra all-zero row so padding rows'
        # K/V write lands in the null block (decode_step convention).
        rids = [e.req.rid for e in decode_entries]
        n_seg = len(decode_entries)
        rows: list[tuple] = []   # (entry index, token)
        for i, e in enumerate(decode_entries):
            rows.append((i, self._last_token(e.req)))
            for t in proposals.get(e.req.rid, [])[:e.depth]:
                rows.append((i, t))
        n_rows = len(rows)
        r_b = model_exec.seg_bucket(n_rows)
        s_b = model_exec.seg_bucket(n_seg + 1)
        maxp = max(len(self.pool.tables[r]) for r in rids)
        maxp_b = model_exec.table_bucket(maxp)
        tokens = np.zeros(r_b, np.int32)
        lens = np.zeros(r_b, np.int32)
        row_seg = np.full(r_b, n_seg, np.int32)   # padding -> zero table row
        starts = np.zeros(n_seg, np.int32)
        prev = -1
        for ri, (i, tok) in enumerate(rows):
            if i != prev:
                starts[i] = ri
                prev = i
            tokens[ri] = tok
            lens[ri] = decode_entries[i].l_kv + (ri - starts[i])
            row_seg[ri] = i
        tables = self.pool.table_array(rids, maxp=maxp_b, rows=s_b)
        toks, self.pool.kv = model_exec.verify_step(
            self.cfg, self.params, self.pool.kv, jnp.asarray(tokens),
            tables, jnp.asarray(lens), jnp.asarray(row_seg))
        self.stats.decode_launches += 1
        self.stats.host_syncs += 1
        out = np.asarray(toks)

        for i, e in enumerate(decode_entries):
            d = e.depth
            g = out[starts[i]:starts[i] + d + 1]
            props = proposals.get(e.req.rid, [])[:d]
            a = 0
            while a < d and props[a] == g[a]:
                a += 1
            for t in g[:a + 1]:
                self._emit(e.req, int(t), emitted)
            # bonus tokens advance context inside blocks the +1 growth
            # already covers (depth <= block remainder at admission)
            self.bm.state(e.req).dev_tokens += a
            if d > 0:
                self.draft.observe(e.req.rid, d, a)
                accept = getattr(self.policy, "spec_accept", None)
                if accept is not None:
                    accept.update(d, a)
            self.stats.spec_proposed += d
            self.stats.spec_accepted += a
            self.stats.spec_rejected += d - a
            self.stats.spec_depth_hist[d] = \
                self.stats.spec_depth_hist.get(d, 0) + 1

    # ------------------------------------------------------------------
    # prefill execution
    # ------------------------------------------------------------------
    def _seq_view(self, r: Request) -> np.ndarray:
        """Full known token sequence (prompt + outputs so far), maintained
        incrementally — no per-chunk concatenation."""
        return self._seqs[r.rid][:self._seq_fill[r.rid]]

    def _prepare_prefill(self, e) -> None:
        """Block-table growth + CoW guard shared by both prefill paths."""
        r, ctx = e.req, e.l_kv
        self.pool.ensure_capacity(r.rid, ctx + e.n_tokens)
        # CoW guard: the first block written this pass may be shared
        # (all later blocks are freshly allocated)
        if self.pool.ensure_writable(r.rid, ctx // self.pool.block_size):
            self.bm.note_fork(r)
            self.stats.cow_forks += 1

    def _finish_prefill(self, e, tok: int, emitted: list) -> None:
        """Prompt-completion bookkeeping shared by both prefill paths."""
        r = e.req
        self._emit(r, tok, emitted)
        if self.cache is not None:
            # adopt the prompt's full blocks into the prefix cache
            # (charge moves request -> cache; blocks now shared)
            prompt: np.ndarray = r._prompt  # type: ignore
            adopted = self.cache.insert(
                prompt, self.pool.tables[r.rid], r.rid, self.now, r.weight)
            if adopted:
                self.bm.donate_to_cache(r, adopted)
                self.stats.cache_insert_blocks += adopted
            self.cache.shrink_to_capacity()

    def _run_prefill_packed(self, entries: list, emitted: list) -> None:
        """Packed multi-request prefill: every chunk this step concatenated
        into one flat token stream and executed in a single bucketed jit
        call — and each segment stages only the blocks it needs, instead
        of the engine-wide ``max_ctx`` span per chunk."""
        bs = self.pool.block_size
        for e in entries:
            self._prepare_prefill(e)
        n_seg = len(entries)
        sq = model_exec.chunk_bucket(max(e.n_tokens for e in entries))
        smax = model_exec.chunk_bucket(
            max(e.l_kv + e.n_tokens for e in entries))
        smax = -(-smax // bs) * bs
        maxp = smax // bs
        total = sum(e.n_tokens for e in entries)
        t_b = model_exec.flat_bucket(total)
        s_b = model_exec.seg_bucket(n_seg)

        tokens = np.zeros((1, t_b), np.int32)
        positions = np.zeros((1, t_b), np.int32)
        q_rows = np.full((t_b,), s_b, np.int32)   # padding -> extra row
        q_cols = np.zeros((t_b,), np.int32)
        sblocks = np.zeros((t_b,), np.int32)      # padding -> null block 0
        sslots = np.zeros((t_b,), np.int32)
        tables = np.zeros((s_b, maxp), np.int32)
        ctx_lens = np.zeros((s_b,), np.int32)
        last_idx = np.zeros((s_b,), np.int32)
        off = 0
        for i, e in enumerate(entries):
            r, ctx, n = e.req, e.l_kv, e.n_tokens
            seq = self._seq_view(r)
            tokens[0, off:off + n] = seq[ctx:ctx + n]
            pos = np.arange(ctx, ctx + n, dtype=np.int32)
            positions[0, off:off + n] = pos
            q_rows[off:off + n] = i
            q_cols[off:off + n] = np.arange(n, dtype=np.int32)
            t = np.asarray(self.pool.tables[r.rid], np.int32)
            sblocks[off:off + n] = t[pos // bs]
            sslots[off:off + n] = pos % bs
            k = min(len(t), maxp)
            tables[i, :k] = t[:k]
            ctx_lens[i] = ctx
            last_idx[i] = off + n - 1
            off += n

        logits, self.pool.kv = model_exec.prefill_packed(
            self.cfg, self.params, self.pool.kv,
            jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(q_rows), jnp.asarray(q_cols),
            jnp.asarray(sblocks), jnp.asarray(sslots),
            jnp.asarray(tables), jnp.asarray(ctx_lens),
            jnp.asarray(last_idx), smax, sq)
        self.stats.packed_prefill_calls += 1
        self.stats.host_syncs += 1
        nxt = np.asarray(jnp.argmax(logits, -1))
        for i, e in enumerate(entries):
            r = e.req
            self.stats.prefill_tokens += e.n_tokens
            if e.l_kv + e.n_tokens >= r.prompt_len and r.generated == 0:
                self._finish_prefill(e, int(nxt[i]), emitted)
            # recompute completion emits nothing (next decode pass does)

    def _run_prefill_fallback(self, entries: list, emitted: list) -> None:
        """Per-request chunked prefill (the pre-packed path, kept for
        equivalence testing and as a safety hatch)."""
        for e in entries:
            r = e.req
            c = model_exec.bucket(e.n_tokens)
            ctx = e.l_kv
            self._prepare_prefill(e)
            toks = np.zeros((1, c), np.int32)
            seq = self._seq_view(r)
            toks[0, :e.n_tokens] = seq[ctx:ctx + e.n_tokens]
            max_ctx = model_exec.bucket(ctx + c, buckets=(
                self.max_ctx,)) if ctx + c <= self.max_ctx else ctx + c
            maxp = max_ctx // self.pool.block_size
            table = self.pool.table_array([r.rid], maxp=maxp)
            logits, self.pool.kv = model_exec.prefill_chunk(
                self.cfg, self.params, self.pool.kv, jnp.asarray(toks),
                table, jnp.asarray([ctx], jnp.int32), max_ctx)
            self.stats.prefill_tokens += e.n_tokens
            if ctx + e.n_tokens >= r.prompt_len and r.generated == 0:
                self.stats.host_syncs += 1
                tok = int(jnp.argmax(logits[0, e.n_tokens - 1]))
                self._finish_prefill(e, tok, emitted)
            # recompute completion emits nothing (next decode pass does)

    # ------------------------------------------------------------------
    def _last_token(self, r: Request) -> int:
        outs = self.outputs[r.rid]
        if outs:
            return outs[-1]
        return int(r._prompt[-1])  # type: ignore

    def _emit(self, r: Request, tok: int, emitted: list) -> None:
        self.outputs[r.rid].append(tok)
        seq, fill = self._seqs.get(r.rid), self._seq_fill.get(r.rid, 0)
        if seq is not None:
            if fill >= len(seq):    # defensive: output ran past output_len
                seq = np.concatenate([seq, np.zeros(len(seq), np.int32)])
                self._seqs[r.rid] = seq
            seq[fill] = tok
            self._seq_fill[r.rid] = fill + 1
        first = r.generated == 0
        r.emit_token(self.now)
        self.stats.tokens_out += 1
        emitted.append(r)
        if self.on_token is not None:
            self.on_token(r, tok, first, r.phase == Phase.FINISHED)

    def _refit(self) -> None:
        try:
            batches = [b for b, _ in self._profile]
            lats = [l for _, l in self._profile]
            self.est = BatchLatencyEstimator.fit(batches, lats)
        except Exception:
            # keep serving on the previous fit, but never silently: count
            # every failure and log the first one per engine
            self.stats.refit_failures += 1
            if self.stats.refit_failures == 1:
                logger.warning(
                    "online estimator refit failed (keeping previous "
                    "coefficients); further failures are only counted",
                    exc_info=True)
        self._profile = self._profile[-200:]

    def flush_transfers(self, timeout: float = 30.0) -> bool:
        """Wait for the background lanes to drain, then fold the completed
        transfers into the accounting (tests / benchmarks)."""
        if self.worker is None:
            return True
        ok = self.worker.flush(timeout)
        self._drain_transfers()
        return ok

    def run_until_drained(self, max_iters: int = 10000) -> None:
        it = 0
        while self.has_work() and it < max_iters:
            if self.step() is None:
                # idle but queued work exists only if nothing schedulable
                break
            it += 1

    def kill(self) -> list[Request]:
        self.alive = False
        if self.worker is not None:
            self.worker.stop()
        orphans = [r for r in self.queue if r.phase != Phase.FINISHED]
        for r in orphans:
            self.bm.release(r)
            self.pool.release(r.rid)
            if self.draft is not None:
                self.draft.drop(r.rid)
            r.instance = None
        self.queue.clear()
        # handoff payloads in flight or awaiting pickup die with the
        # replica — their requests must re-prefill elsewhere
        for payload, _, _ in self._handoff_wait.values():
            payload.req.instance = None
            orphans.append(payload.req)
        self._handoff_wait.clear()
        for payload in self._handoff_ready:
            payload.req.instance = None
            orphans.append(payload.req)
        self._handoff_ready.clear()
        return orphans


# --------------------------------------------------------------------------
# threaded driver loop
# --------------------------------------------------------------------------

class EngineDriver:
    """Runs one ``Engine``'s iteration loop on a dedicated thread.

    Submissions arrive on a per-instance inbox queue (fed by GoRouting
    dispatch in the ``ServiceFrontend``); each loop iteration drains the
    inbox into the engine queue, forms/executes one batch, and forwards
    token + step events to ``sink(event)``.  The sink is called on the
    driver thread and must be thread-safe (the frontend bridges into its
    asyncio loop with ``call_soon_threadsafe``).
    """

    def __init__(self, iid: int, engine: Engine,
                 sink: Callable[[object], None],
                 *, idle_wait: float = 2e-3, name: Optional[str] = None):
        self.iid = iid
        self.engine = engine
        self.sink = sink
        self.idle_wait = idle_wait
        self.inbox: "queue.Queue[tuple]" = queue.Queue()
        # rids added to THIS engine that have not yet emitted here —
        # drives StepEvent.prefill_done.  ``generated == 1`` would miss
        # failover-resumed requests whose first token predates this engine.
        self._awaiting_first: set[int] = set()
        self._first_done: list[int] = []
        self._stop = threading.Event()
        self._idle = threading.Event()
        self._idle.set()
        self._thread = threading.Thread(
            target=self._run, name=name or f"engine-driver-{iid}",
            daemon=True)
        engine.on_token = self._on_token

    # -- submission (any thread) ---------------------------------------
    def submit(self, req: Request, prompt_tokens,
               prior_outputs: Optional[list] = None) -> None:
        self.inbox.put(("req", req, prompt_tokens, prior_outputs))
        self._idle.clear()

    def submit_handoff(self, payload: HandoffPayload) -> None:
        """Deliver a prefill peer's KV payload for adoption (decode leg)."""
        self.inbox.put(("handoff", payload))
        self._idle.clear()

    def pending(self) -> int:
        return self.inbox.qsize()

    @property
    def idle(self) -> bool:
        """True when the inbox is drained and the engine has no work."""
        return self._idle.is_set()

    # -- lifecycle ------------------------------------------------------
    def start(self) -> None:
        self._thread.start()

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        if self._thread.ident is not None:   # never-started threads can't join
            self._thread.join(timeout)

    def join_idle(self, timeout: float = 60.0) -> bool:
        """Block until the driver has drained all submitted work."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self._idle.is_set() and self.inbox.empty():
                return True
            time.sleep(1e-3)
        return False

    def kill(self) -> list[Request]:
        """Hard-stop the thread and return orphaned requests (plus any
        submissions still sitting in the inbox, never started)."""
        self.stop(timeout=120.0)     # a mid-step JIT compile can be slow
        orphans = self.engine.kill()
        while True:
            try:
                item = self.inbox.get_nowait()
            except queue.Empty:
                break
            orphans.append(item[1].req if item[0] == "handoff"
                           else item[1])
        return orphans

    # -- driver thread --------------------------------------------------
    def _on_token(self, req: Request, tok: int, first: bool,
                  last: bool) -> None:
        if req.rid in self._awaiting_first:
            self._awaiting_first.discard(req.rid)
            self._first_done.append(req.rid)
        self.sink(TokenEvent(req.rid, tok, req.generated,
                             time.monotonic(), first, last))

    def _run(self) -> None:
        eng = self.engine
        while not self._stop.is_set():
            drained = False
            while True:
                try:
                    item = self.inbox.get_nowait()
                except queue.Empty:
                    break
                if item[0] == "handoff":
                    payload = item[1]
                    if eng.import_handoff(payload):
                        self.sink(HandoffAdopted(self.iid, payload))
                    else:
                        self.sink(HandoffDropped(self.iid, payload))
                else:
                    _, req, prompt, prior = item
                    eng.add_request(req, prompt, prior_outputs=prior)
                    self._awaiting_first.add(req.rid)
                drained = True
            res = eng.step() if eng.alive else None
            for payload in eng.take_handoffs():
                payload.src_iid = self.iid
                self.sink(HandoffEvent(self.iid, payload))
            if res is None:
                if not drained and not eng.has_work():
                    self._idle.set()
                # park until new work or shutdown (also avoids a hot spin
                # when queued work is temporarily unschedulable)
                self._stop.wait(self.idle_wait)
                continue
            self._idle.clear()
            first_done, self._first_done = self._first_done, []
            self.sink(StepEvent(
                iid=self.iid, free_blocks=eng.bm.free_blocks,
                latency=res["latency"], est_time=res["plan"].est_time,
                prefill_done=tuple(first_done),
                finished=tuple(r.rid for r in res["finished"]),
                offload_blocks=res.get("offload_blocks", 0),
                reload_blocks=res.get("reload_blocks", 0),
                transfer_wait=res.get("transfer_wait", 0.0)))
            if not eng.has_work() and self.inbox.empty():
                self._idle.set()
