"""Paged KV pool: the real device-side block store + tiered host mirror.

Layout: one device array ``(L, 2, num_blocks, block_size, Hkv, hd)``
(k=0 / v=1), addressed through per-request block tables.  Off-device
residency is TIERED (``KVTierStore``): a capacity-bounded HOST tier of
fp32 numpy blocks (the §4.3 asynchronous-offload target) and an
unbounded int8-quantized COLD tier that host-tier evictions demote into
(per-plane scales; see ``kernels/kv_quant.py`` for the wire format and
error bound).  Tier entries are keyed per request — radix-cache spills
use negative pseudo-rids (``new_cache_rid``) so cache nodes and live
requests share one LRU clock.

Physical blocks are REFERENCE COUNTED so several block tables (and the
radix prefix cache, ``serving/prefix_cache.py``) can point at the same
device block: ``share`` appends existing blocks to another request's table,
``fork`` implements copy-on-write for writes into a shared block, and a
block returns to the free list only when its last reference drops.

The pool is DATA only; residency accounting/eviction policy lives in
core/blocks.BlockManager (shared with the simulator), keeping policy and
mechanism separate.
"""
from __future__ import annotations

from typing import Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import kv_block_dequantize, kv_block_quantize
from ..models.model import ArchConfig


class KVTierStore:
    """Two-tier off-device block store with one LRU clock across groups.

    * HOT (host DRAM, fp32): bounded by ``budget_bytes``; ``None`` means
      unbounded — the pre-tiering behaviour, bitwise-identical streams.
    * COLD ("disk", int8 + per-plane fp32 scales when ``cold_quantize``,
      else raw fp32 — the exact roundtrip mode): unbounded; host-tier
      evictions demote into it WHOLE GROUPS at a time (a group = all
      blocks of one rid / cache pseudo-rid), so any group lives entirely
      in one tier and per-request reload cost is unambiguous.

    Eviction is LRU by last touch (monotonic counter, deterministic):
    puts, reads and reloads touch the group.  Demotion quantizes all of
    a group's blocks in ONE ``kv_block_quantize`` call; promotion (a new
    hot put for a demoted rid) dequantizes in one call likewise.
    """

    def __init__(self, block_bytes: int, budget_bytes: Optional[int] = None,
                 cold_quantize: bool = True):
        self.block_bytes = block_bytes
        self.budget_bytes = budget_bytes
        self.cold_quantize = cold_quantize
        self.hot: dict[int, dict[int, np.ndarray]] = {}
        # bi -> (int8 vals (L,2,bs,Hkv,hd), fp32 scales (L,2)) | fp32 array
        self.cold: dict[int, dict[int, object]] = {}
        self._touch: dict[int, int] = {}
        self._clock = 0
        self.demoted_blocks = 0     # cumulative hot -> cold demotions
        self.cold_reload_blocks = 0  # cumulative cold blocks dequantized

    # --- byte/blocks accounting ------------------------------------------
    @property
    def hot_blocks(self) -> int:
        return sum(len(d) for d in self.hot.values())

    @property
    def cold_blocks(self) -> int:
        return sum(len(d) for d in self.cold.values())

    @property
    def host_bytes(self) -> int:
        return self.hot_blocks * self.block_bytes

    def touch(self, rid: int) -> None:
        self._clock += 1
        self._touch[rid] = self._clock

    def n_blocks(self, rid: int) -> int:
        return len(self.hot.get(rid, ())) + len(self.cold.get(rid, ()))

    def has_block(self, rid: int, bi: int) -> bool:
        return bi in self.hot.get(rid, ()) or bi in self.cold.get(rid, ())

    def block_ids(self, rid: int) -> Iterator[int]:
        yield from self.hot.get(rid, ())
        yield from self.cold.get(rid, ())

    def is_cold(self, rid: int) -> bool:
        return bool(self.cold.get(rid))

    def cold_block_count(self, rid: int) -> int:
        return len(self.cold.get(rid, ()))

    def prefer_cold(self, n_blocks: int) -> bool:
        """Should a fresh offload of ``n_blocks`` land directly in the
        cold tier (int8 D2H wire)?  Yes when the hot budget cannot take it
        without demoting — the put would be demote-bound anyway, so
        quantizing on device saves ~4x D2H traffic."""
        return (self.budget_bytes is not None and self.cold_quantize
                and self.host_bytes + n_blocks * self.block_bytes
                > self.budget_bytes)

    # --- tier movement ----------------------------------------------------
    def put(self, rid: int, blocks: dict) -> None:
        """Land fp32 blocks in the hot tier (D2H completion / sync
        offload), enforcing the byte budget by LRU whole-group demotion."""
        if not blocks:
            return
        if rid in self.cold:
            self._promote(rid)      # keep the whole group in one tier
        self.hot.setdefault(rid, {}).update(blocks)
        self.touch(rid)
        self._enforce(last=rid)

    def put_cold(self, rid: int, blocks: dict) -> None:
        """Land quantized ``(vals, scales)`` payloads straight in the cold
        tier (the int8 D2H wire of a demote-bound offload)."""
        if not blocks:
            return
        if rid in self.hot:
            self._demote(rid)       # group invariant: one tier per rid
        self.cold.setdefault(rid, {}).update(blocks)
        self.touch(rid)

    def get_block(self, rid: int, bi: int) -> Optional[np.ndarray]:
        """Fetch one block as fp32, dequantizing a cold entry on demand."""
        h = self.hot.get(rid)
        if h is not None and bi in h:
            self.touch(rid)
            return h[bi]
        c = self.cold.get(rid)
        if c is not None and bi in c:
            self.touch(rid)
            entry = c[bi]
            if isinstance(entry, tuple):
                self.cold_reload_blocks += 1
                return self._thaw_batch([entry])[0]
            return entry
        return None

    def payloads(self, rid: int, block_ids: Sequence[int]):
        """Raw wire payloads for the H2D lane: fp32 arrays for hot blocks,
        ``(int8 vals, scales)`` tuples for cold ones (uploaded as int8 and
        dequantized ON DEVICE by the transfer worker).  None if any block
        is absent."""
        out = []
        for bi in block_ids:
            h = self.hot.get(rid)
            if h is not None and bi in h:
                out.append(h[bi])
                continue
            c = self.cold.get(rid)
            if c is None or bi not in c:
                return None
            out.append(c[bi])
        if out:
            self.touch(rid)
        return out

    def drop(self, rid: int) -> None:
        self.hot.pop(rid, None)
        self.cold.pop(rid, None)
        self._touch.pop(rid, None)

    def split_group(self, rid: int, at: int, new_rid: int) -> None:
        """Radix-node split of a spilled group: blocks [at, n) move to
        ``new_rid`` re-keyed from 0 (mirroring ``_Node`` splits in the
        prefix cache, whose spilled halves must stay independently
        reloadable)."""
        moved = False
        for store in (self.hot, self.cold):
            g = store.get(rid)
            if not g:
                continue
            lower = {bi - at: v for bi, v in g.items() if bi >= at}
            if lower:
                store[rid] = {bi: v for bi, v in g.items() if bi < at}
                store.setdefault(new_rid, {}).update(lower)
                moved = True
        if moved:
            self._touch[new_rid] = self._touch.get(rid, 0)

    # --- internals --------------------------------------------------------
    def _thaw_batch(self, entries: list) -> np.ndarray:
        vals = jnp.asarray(np.stack([e[0] for e in entries]))
        scales = jnp.asarray(np.stack([e[1] for e in entries]))
        return np.asarray(kv_block_dequantize(vals, scales))

    def _promote(self, rid: int) -> None:
        entries = self.cold.pop(rid, {})
        if not entries:
            return
        keys = sorted(entries)
        quant = [k for k in keys if isinstance(entries[k], tuple)]
        h = self.hot.setdefault(rid, {})
        if quant:
            deq = self._thaw_batch([entries[k] for k in quant])
            self.cold_reload_blocks += len(quant)
            for i, k in enumerate(quant):
                h[k] = deq[i]
        for k in keys:
            if not isinstance(entries[k], tuple):
                h[k] = entries[k]

    def _demote(self, rid: int) -> None:
        entries = self.hot.pop(rid, {})
        if not entries:
            return
        keys = sorted(entries)
        c = self.cold.setdefault(rid, {})
        if self.cold_quantize:
            stacked = jnp.asarray(np.stack([entries[k] for k in keys]))
            vals, scales = kv_block_quantize(stacked)
            vals, scales = np.asarray(vals), np.asarray(scales)
            for i, k in enumerate(keys):
                c[k] = (vals[i], scales[i])
        else:
            for k in keys:
                c[k] = entries[k]
        self.demoted_blocks += len(keys)

    def _enforce(self, last: Optional[int] = None) -> None:
        if self.budget_bytes is None:
            return
        while self.host_bytes > self.budget_bytes and self.hot:
            others = [r for r in self.hot if r != last]
            victim = (min(others, key=lambda r: self._touch.get(r, 0))
                      if others else last)
            self._demote(victim)


class _RidBlocks:
    """Mapping view of one rid's tier entries as fp32 blocks (dict-like
    back-compat for the old ``pool.host[rid]`` dict; cold entries are
    dequantized on item access)."""

    def __init__(self, tier: KVTierStore, rid: int):
        self._tier = tier
        self._rid = rid

    def __contains__(self, bi) -> bool:
        return self._tier.has_block(self._rid, bi)

    def __iter__(self):
        return self._tier.block_ids(self._rid)

    def __len__(self) -> int:
        return self._tier.n_blocks(self._rid)

    def __getitem__(self, bi) -> np.ndarray:
        got = self._tier.get_block(self._rid, bi)
        if got is None:
            raise KeyError(bi)
        return got

    def get(self, bi, default=None):
        got = self._tier.get_block(self._rid, bi)
        return default if got is None else got

    def keys(self):
        return list(self._tier.block_ids(self._rid))


class _HostView:
    """Back-compat ``pool.host`` facade over the tier store."""

    def __init__(self, tier: KVTierStore):
        self._tier = tier

    def __contains__(self, rid) -> bool:
        return self._tier.n_blocks(rid) > 0

    def __getitem__(self, rid) -> _RidBlocks:
        if self._tier.n_blocks(rid) == 0:
            raise KeyError(rid)
        return _RidBlocks(self._tier, rid)

    def get(self, rid, default=None):
        if self._tier.n_blocks(rid) == 0:
            return default
        return _RidBlocks(self._tier, rid)


class PagedKVPool:
    def __init__(self, cfg: ArchConfig, num_blocks: int, block_size: int,
                 dtype=jnp.float32, host_tier_bytes: Optional[int] = None,
                 cold_quantize: bool = True):
        self.cfg = cfg
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.kv = jnp.zeros(
            (cfg.n_layers, 2, num_blocks, block_size, cfg.n_kv_heads,
             cfg.hd), dtype)
        self.free: list[int] = list(range(num_blocks - 1, 0, -1))
        # block 0 is reserved as the null page block tables pad with
        self.refcount: list[int] = [0] * num_blocks
        self.refcount[0] = 1                      # null page never freed
        self.tables: dict[int, list[int]] = {}
        # tiered host mirror, keyed rid -> {logical block index -> contents}
        # (host_tier_bytes=None keeps the legacy unbounded fp32 behaviour)
        block_bytes = int(cfg.n_layers * 2 * block_size * cfg.n_kv_heads
                          * cfg.hd * np.dtype(dtype).itemsize)
        self.tier = KVTierStore(block_bytes, host_tier_bytes, cold_quantize)
        self._cache_rid = -1        # next radix-cache spill pseudo-rid

    @property
    def host(self) -> _HostView:
        """Dict-like view of off-device residency (both tiers, as fp32)."""
        return _HostView(self.tier)

    def new_cache_rid(self) -> int:
        """Fresh negative pseudo-rid for a radix-cache spill group (never
        collides with real request ids, shares the tier's LRU clock)."""
        rid, self._cache_rid = self._cache_rid, self._cache_rid - 1
        return rid

    # --- allocation ------------------------------------------------------
    def alloc(self, rid: int, n: int) -> bool:
        if len(self.free) < n:
            return False
        t = self.tables.setdefault(rid, [])
        for _ in range(n):
            b = self.free.pop()
            self.refcount[b] = 1
            t.append(b)
        return True

    def ensure_capacity(self, rid: int, tokens: int) -> bool:
        """Grow rid's table to cover ``tokens`` positions."""
        need = -(-tokens // self.block_size) - len(self.tables.get(rid, []))
        return self.alloc(rid, need) if need > 0 else True

    def release(self, rid: int) -> None:
        for b in self.tables.pop(rid, []):
            self.decref(b)
        self.tier.drop(rid)

    def table_array(self, rids: list[int], maxp: Optional[int] = None,
                    rows: Optional[int] = None):
        """Padded block-table batch.  ``rows`` > len(rids) appends all-zero
        rows (the fused decode path pads the batch to a shape bucket;
        zero rows address the reserved null block 0)."""
        maxp = maxp or max(len(self.tables[r]) for r in rids)
        out = np.zeros((rows or len(rids), maxp), np.int32)
        for i, r in enumerate(rids):
            t = self.tables[r]
            out[i, :len(t)] = t
        return jnp.asarray(out)

    # --- sharing / copy-on-write -----------------------------------------
    def incref(self, block: int) -> None:
        self.refcount[block] += 1

    def decref(self, block: int) -> None:
        """Drop one reference; the block is freed when none remain."""
        self.refcount[block] -= 1
        if self.refcount[block] == 0:
            self.free.append(block)

    def share(self, rid: int, blocks: Sequence[int]) -> None:
        """Point rid's table at existing physical ``blocks`` (prefix-cache
        hit): each gains a reference instead of being allocated."""
        t = self.tables.setdefault(rid, [])
        for b in blocks:
            self.incref(b)
            t.append(b)

    def shared_with(self, rid: int) -> int:
        """Blocks in rid's table whose physical block has other referents."""
        return sum(1 for b in self.tables.get(rid, [])
                   if self.refcount[b] > 1)

    def fork(self, rid: int, logical: int) -> int:
        """Copy-on-write: give rid a private copy of logical block
        ``logical``.  Returns the new physical block id."""
        t = self.tables[rid]
        old = t[logical]
        if not self.free:
            raise RuntimeError("fork: no free block for copy-on-write")
        new = self.free.pop()
        self.refcount[new] = 1
        self.kv = self.kv.at[:, :, new].set(self.kv[:, :, old])
        t[logical] = new
        self.decref(old)
        return new

    def ensure_writable(self, rid: int, logical: int) -> bool:
        """CoW guard before writing into rid's ``logical`` block: fork the
        block iff it is physically shared.  Returns True if forked."""
        t = self.tables.get(rid, ())
        if logical >= len(t) or self.refcount[t[logical]] <= 1:
            return False
        self.fork(rid, logical)
        return True

    # --- host offload / reload (§4.3 mechanism) ---------------------------
    def gather_blocks(self, rid: int, block_indices: list[int]):
        """Device-side snapshot of rid's logical blocks, shaped
        (n, L, 2, bs, Hkv, hd).  Because jax arrays are functional the
        result is a race-free copy: later pool writes (or freeing the
        source blocks) cannot disturb it — this is what the background
        D2H lane consumes."""
        t = self.tables[rid]
        phys = jnp.asarray([t[bi] for bi in block_indices], jnp.int32)
        return jnp.moveaxis(self.kv[:, :, phys], 2, 0)

    def gather_blocks_quantized(self, rid: int, block_indices: list[int]):
        """Device-side snapshot of rid's logical blocks QUANTIZED on
        device (Pallas kernel fused after the gather): returns the
        ``(int8 vals, fp32 scales)`` device pair — the ~4x-cheaper D2H
        wire for offloads that will land demote-bound in the cold tier."""
        return kv_block_quantize(self.gather_blocks(rid, block_indices))

    def offload_blocks(self, rid: int, block_indices: list[int]) -> None:
        """Copy listed LOGICAL blocks of rid to host in ONE device fetch
        (synchronous fallback path of the D2H lane)."""
        if not block_indices:
            return
        data = np.asarray(jax.device_get(
            self.gather_blocks(rid, block_indices)))
        self.tier.put(rid, {bi: data[i]
                            for i, bi in enumerate(block_indices)})

    def host_store(self, rid: int, blocks: dict) -> None:
        """Land completed async D2H transfers in the host tiers: fp32
        arrays go hot, quantized ``(vals, scales)`` tuples (the int8 D2H
        wire) go straight cold."""
        quant = {bi: v for bi, v in blocks.items() if isinstance(v, tuple)}
        raw = {bi: v for bi, v in blocks.items()
               if not isinstance(v, tuple)}
        if raw:
            self.tier.put(rid, raw)
        if quant:
            self.tier.put_cold(rid, quant)

    def drop_device_blocks(self, rid: int) -> None:
        """Drop rid's device references (eviction); shared physical blocks
        survive under their remaining referents, host copies survive."""
        for b in self.tables.get(rid, []):
            self.decref(b)
        self.tables[rid] = []

    def reload_blocks(self, rid: int, n_blocks: int) -> int:
        """Restore the first n host blocks of rid to fresh device blocks.
        Returns tokens restored.  All restores land in ONE batched scatter
        (pipelined layer-wise on TPU; on CPU the copy is synchronous but
        accounted by the BlockManager lanes)."""
        restorable = []
        for bi in range(n_blocks):
            blk = self.tier.get_block(rid, bi)
            if blk is None or not self.alloc(rid, 1):
                break
            restorable.append((self.tables[rid][-1], blk))
        if not restorable:
            return 0
        dst = jnp.asarray([b for b, _ in restorable], jnp.int32)
        # host blocks are (L, 2, bs, Hkv, hd); stack -> (n, L, 2, ...) and
        # move the block axis behind (L, 2) to match self.kv's layout
        data = jnp.moveaxis(
            jnp.asarray(np.stack([blk for _, blk in restorable])), 0, 2)
        self.kv = self.kv.at[:, :, dst].set(data)
        return len(restorable) * self.block_size

    def reload_from_device(self, rid: int, staged, n_blocks: int) -> int:
        """Staged variant of ``reload_blocks``: ``staged`` is a
        (m, L, 2, bs, Hkv, hd) array the background H2D lane already
        landed on device; scatter its first ``n_blocks`` into freshly
        allocated blocks in one pass.  Returns tokens restored."""
        n = min(n_blocks, staged.shape[0])
        dst: list[int] = []
        for _ in range(n):
            if not self.alloc(rid, 1):
                break
            dst.append(self.tables[rid][-1])
        if not dst:
            return 0
        data = jnp.moveaxis(staged[:len(dst)], 0, 2)
        self.kv = self.kv.at[:, :, jnp.asarray(dst, jnp.int32)].set(data)
        return len(dst) * self.block_size

    def host_blocks(self, rid: int) -> int:
        return self.tier.n_blocks(rid)

    # --- radix-cache spill groups (physical blocks, no table) -------------
    def spill_cache_blocks(self, host_rid: int, phys: list[int]) -> None:
        """Spill cache-owned physical blocks to the tier under a pseudo-rid
        (keyed 0..n-1 in spill order).  One device gather; when the put
        would land demote-bound anyway, the gather is QUANTIZED on device
        (Pallas kernel) so the D2H wire is int8."""
        idx = jnp.asarray(phys, jnp.int32)
        g = jnp.moveaxis(self.kv[:, :, idx], 2, 0)
        if self.tier.prefer_cold(len(phys)):
            vals, scales = jax.device_get(kv_block_quantize(g))
            vals, scales = np.asarray(vals), np.asarray(scales)
            self.tier.put_cold(host_rid, {i: (vals[i], scales[i])
                                          for i in range(len(phys))})
        else:
            data = np.asarray(jax.device_get(g))
            self.tier.put(host_rid, {i: data[i]
                                     for i in range(len(phys))})

    def _alloc_free_blocks(self, n: int) -> list[int]:
        if len(self.free) < n:
            return []
        phys = []
        for _ in range(n):
            b = self.free.pop()
            self.refcount[b] = 1
            phys.append(b)
        return phys

    def restore_cache_group(self, host_rid: int, n: int) -> list[int]:
        """Reload a spilled cache group to fresh device blocks in ONE
        batched scatter; cold (int8) payloads travel the narrow wire and
        are dequantized ON DEVICE.  Returns the new physical block ids
        ([] if blocks are missing or the device pool is full)."""
        entries = self.tier.payloads(host_rid, list(range(n)))
        if entries is None:
            return []
        phys = self._alloc_free_blocks(n)
        if not phys:
            return []
        if all(isinstance(e, tuple) for e in entries):
            vals = jnp.asarray(np.stack([e[0] for e in entries]))
            scales = jnp.asarray(np.stack([e[1] for e in entries]))
            data = kv_block_dequantize(vals, scales)
            self.tier.cold_reload_blocks += n
        else:
            data = jnp.asarray(np.stack(
                [e if not isinstance(e, tuple) else
                 self.tier._thaw_batch([e])[0] for e in entries]))
        self.kv = self.kv.at[:, :, jnp.asarray(phys, jnp.int32)].set(
            jnp.moveaxis(data, 0, 2))
        self.tier.drop(host_rid)
        return phys

    def adopt_staged_group(self, host_rid: int, staged, n: int) -> list[int]:
        """Like ``restore_cache_group`` but the H2D copy already landed:
        ``staged`` is the (m, L, 2, bs, Hkv, hd) device buffer the transfer
        worker pre-staged for this group."""
        if staged.shape[0] < n:
            return []
        phys = self._alloc_free_blocks(n)
        if not phys:
            return []
        self.kv = self.kv.at[:, :, jnp.asarray(phys, jnp.int32)].set(
            jnp.moveaxis(staged[:n], 0, 2))
        self.tier.drop(host_rid)
        return phys
