"""Paged KV pool: the real device-side block store + host-side mirror.

Layout: one device array ``(L, 2, num_blocks, block_size, Hkv, hd)``
(k=0 / v=1), addressed through per-request block tables.  The host pool
holds offloaded/mirrored block contents as numpy arrays keyed per request
— the §4.3 asynchronous-offload target.

Physical blocks are REFERENCE COUNTED so several block tables (and the
radix prefix cache, ``serving/prefix_cache.py``) can point at the same
device block: ``share`` appends existing blocks to another request's table,
``fork`` implements copy-on-write for writes into a shared block, and a
block returns to the free list only when its last reference drops.

The pool is DATA only; residency accounting/eviction policy lives in
core/blocks.BlockManager (shared with the simulator), keeping policy and
mechanism separate.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import ArchConfig


class PagedKVPool:
    def __init__(self, cfg: ArchConfig, num_blocks: int, block_size: int,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.kv = jnp.zeros(
            (cfg.n_layers, 2, num_blocks, block_size, cfg.n_kv_heads,
             cfg.hd), dtype)
        self.free: list[int] = list(range(num_blocks - 1, 0, -1))
        # block 0 is reserved as the null page block tables pad with
        self.refcount: list[int] = [0] * num_blocks
        self.refcount[0] = 1                      # null page never freed
        self.tables: dict[int, list[int]] = {}
        # host mirror, keyed rid -> {logical block index -> contents}
        self.host: dict[int, dict[int, np.ndarray]] = {}

    # --- allocation ------------------------------------------------------
    def alloc(self, rid: int, n: int) -> bool:
        if len(self.free) < n:
            return False
        t = self.tables.setdefault(rid, [])
        for _ in range(n):
            b = self.free.pop()
            self.refcount[b] = 1
            t.append(b)
        return True

    def ensure_capacity(self, rid: int, tokens: int) -> bool:
        """Grow rid's table to cover ``tokens`` positions."""
        need = -(-tokens // self.block_size) - len(self.tables.get(rid, []))
        return self.alloc(rid, need) if need > 0 else True

    def release(self, rid: int) -> None:
        for b in self.tables.pop(rid, []):
            self.decref(b)
        self.host.pop(rid, None)

    def table_array(self, rids: list[int], maxp: Optional[int] = None,
                    rows: Optional[int] = None):
        """Padded block-table batch.  ``rows`` > len(rids) appends all-zero
        rows (the fused decode path pads the batch to a shape bucket;
        zero rows address the reserved null block 0)."""
        maxp = maxp or max(len(self.tables[r]) for r in rids)
        out = np.zeros((rows or len(rids), maxp), np.int32)
        for i, r in enumerate(rids):
            t = self.tables[r]
            out[i, :len(t)] = t
        return jnp.asarray(out)

    # --- sharing / copy-on-write -----------------------------------------
    def incref(self, block: int) -> None:
        self.refcount[block] += 1

    def decref(self, block: int) -> None:
        """Drop one reference; the block is freed when none remain."""
        self.refcount[block] -= 1
        if self.refcount[block] == 0:
            self.free.append(block)

    def share(self, rid: int, blocks: Sequence[int]) -> None:
        """Point rid's table at existing physical ``blocks`` (prefix-cache
        hit): each gains a reference instead of being allocated."""
        t = self.tables.setdefault(rid, [])
        for b in blocks:
            self.incref(b)
            t.append(b)

    def shared_with(self, rid: int) -> int:
        """Blocks in rid's table whose physical block has other referents."""
        return sum(1 for b in self.tables.get(rid, [])
                   if self.refcount[b] > 1)

    def fork(self, rid: int, logical: int) -> int:
        """Copy-on-write: give rid a private copy of logical block
        ``logical``.  Returns the new physical block id."""
        t = self.tables[rid]
        old = t[logical]
        if not self.free:
            raise RuntimeError("fork: no free block for copy-on-write")
        new = self.free.pop()
        self.refcount[new] = 1
        self.kv = self.kv.at[:, :, new].set(self.kv[:, :, old])
        t[logical] = new
        self.decref(old)
        return new

    def ensure_writable(self, rid: int, logical: int) -> bool:
        """CoW guard before writing into rid's ``logical`` block: fork the
        block iff it is physically shared.  Returns True if forked."""
        t = self.tables.get(rid, ())
        if logical >= len(t) or self.refcount[t[logical]] <= 1:
            return False
        self.fork(rid, logical)
        return True

    # --- host offload / reload (§4.3 mechanism) ---------------------------
    def gather_blocks(self, rid: int, block_indices: list[int]):
        """Device-side snapshot of rid's logical blocks, shaped
        (n, L, 2, bs, Hkv, hd).  Because jax arrays are functional the
        result is a race-free copy: later pool writes (or freeing the
        source blocks) cannot disturb it — this is what the background
        D2H lane consumes."""
        t = self.tables[rid]
        phys = jnp.asarray([t[bi] for bi in block_indices], jnp.int32)
        return jnp.moveaxis(self.kv[:, :, phys], 2, 0)

    def offload_blocks(self, rid: int, block_indices: list[int]) -> None:
        """Copy listed LOGICAL blocks of rid to host in ONE device fetch
        (synchronous fallback path of the D2H lane)."""
        if not block_indices:
            return
        data = np.asarray(jax.device_get(
            self.gather_blocks(rid, block_indices)))
        h = self.host.setdefault(rid, {})
        for i, bi in enumerate(block_indices):
            h[bi] = data[i]

    def host_store(self, rid: int, blocks: dict) -> None:
        """Land completed async D2H transfers in the host mirror."""
        self.host.setdefault(rid, {}).update(blocks)

    def drop_device_blocks(self, rid: int) -> None:
        """Drop rid's device references (eviction); shared physical blocks
        survive under their remaining referents, host copies survive."""
        for b in self.tables.get(rid, []):
            self.decref(b)
        self.tables[rid] = []

    def reload_blocks(self, rid: int, n_blocks: int) -> int:
        """Restore the first n host blocks of rid to fresh device blocks.
        Returns tokens restored.  All restores land in ONE batched scatter
        (pipelined layer-wise on TPU; on CPU the copy is synchronous but
        accounted by the BlockManager lanes)."""
        h = self.host.get(rid, {})
        restorable = []
        for bi in range(n_blocks):
            if bi not in h or not self.alloc(rid, 1):
                break
            restorable.append((self.tables[rid][-1], h[bi]))
        if not restorable:
            return 0
        dst = jnp.asarray([b for b, _ in restorable], jnp.int32)
        # host blocks are (L, 2, bs, Hkv, hd); stack -> (n, L, 2, ...) and
        # move the block axis behind (L, 2) to match self.kv's layout
        data = jnp.moveaxis(
            jnp.asarray(np.stack([blk for _, blk in restorable])), 0, 2)
        self.kv = self.kv.at[:, :, dst].set(data)
        return len(restorable) * self.block_size

    def reload_from_device(self, rid: int, staged, n_blocks: int) -> int:
        """Staged variant of ``reload_blocks``: ``staged`` is a
        (m, L, 2, bs, Hkv, hd) array the background H2D lane already
        landed on device; scatter its first ``n_blocks`` into freshly
        allocated blocks in one pass.  Returns tokens restored."""
        n = min(n_blocks, staged.shape[0])
        dst: list[int] = []
        for _ in range(n):
            if not self.alloc(rid, 1):
                break
            dst.append(self.tables[rid][-1])
        if not dst:
            return 0
        data = jnp.moveaxis(staged[:len(dst)], 0, 2)
        self.kv = self.kv.at[:, :, jnp.asarray(dst, jnp.int32)].set(data)
        return len(dst) * self.block_size

    def host_blocks(self, rid: int) -> int:
        return len(self.host.get(rid, ()))
