"""Paged KV pool: the real device-side block store + host-side mirror.

Layout: one device array ``(L, 2, num_blocks, block_size, Hkv, hd)``
(k=0 / v=1), addressed through per-request block tables.  The host pool
holds offloaded/mirrored block contents as numpy arrays keyed by
(rid, block_index) — the §4.3 asynchronous-offload target.

The pool is DATA only; residency accounting/eviction policy lives in
core/blocks.BlockManager (shared with the simulator), keeping policy and
mechanism separate.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import ArchConfig


class PagedKVPool:
    def __init__(self, cfg: ArchConfig, num_blocks: int, block_size: int,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.kv = jnp.zeros(
            (cfg.n_layers, 2, num_blocks, block_size, cfg.n_kv_heads,
             cfg.hd), dtype)
        self.free: list[int] = list(range(num_blocks - 1, 0, -1))
        # block 0 is reserved as the null page block tables pad with
        self.tables: dict[int, list[int]] = {}
        self.host: dict[tuple[int, int], np.ndarray] = {}

    # --- allocation ------------------------------------------------------
    def alloc(self, rid: int, n: int) -> bool:
        if len(self.free) < n:
            return False
        t = self.tables.setdefault(rid, [])
        for _ in range(n):
            t.append(self.free.pop())
        return True

    def ensure_capacity(self, rid: int, tokens: int) -> bool:
        """Grow rid's table to cover ``tokens`` positions."""
        need = -(-tokens // self.block_size) - len(self.tables.get(rid, []))
        return self.alloc(rid, need) if need > 0 else True

    def release(self, rid: int) -> None:
        for b in self.tables.pop(rid, []):
            self.free.append(b)
        self.host = {k: v for k, v in self.host.items() if k[0] != rid}

    def table_array(self, rids: list[int], maxp: Optional[int] = None):
        maxp = maxp or max(len(self.tables[r]) for r in rids)
        out = np.zeros((len(rids), maxp), np.int32)
        for i, r in enumerate(rids):
            t = self.tables[r]
            out[i, :len(t)] = t
        return jnp.asarray(out)

    # --- host offload / reload (§4.3 mechanism) ---------------------------
    def offload_blocks(self, rid: int, block_indices: list[int]) -> None:
        """Copy listed LOGICAL blocks of rid to host (async mirror)."""
        t = self.tables[rid]
        for bi in block_indices:
            blk = jax.device_get(self.kv[:, :, t[bi]])
            self.host[(rid, bi)] = np.asarray(blk)

    def drop_device_blocks(self, rid: int) -> None:
        """Free rid's device blocks (eviction); host copies survive."""
        for b in self.tables.get(rid, []):
            self.free.append(b)
        self.tables[rid] = []

    def reload_blocks(self, rid: int, n_blocks: int) -> int:
        """Restore the first n host blocks of rid to fresh device blocks.
        Returns tokens restored.  Pipelined layer-wise on TPU; on CPU the
        copies are synchronous but accounted by the BlockManager lanes."""
        restored = 0
        for bi in range(n_blocks):
            key = (rid, bi)
            if key not in self.host:
                break
            if not self.alloc(rid, 1):
                break
            b = self.tables[rid][-1]
            self.kv = self.kv.at[:, :, b].set(jnp.asarray(self.host[key]))
            restored += 1
        return restored * self.block_size

    def host_blocks(self, rid: int) -> int:
        return sum(1 for k in self.host if k[0] == rid)
