"""Jitted model execution against the paged KV pool.

Two entry points, both shape-bucketed to bound recompilation:

* ``decode_batch``  — one token for B requests: per layer, project QKV,
  scatter the new K/V into each request's current block slot, run
  paged flash-decode attention (the Pallas kernel in interpret mode on
  CPU, native on TPU — switchable to the jnp reference).
* ``prefill_chunk`` — a chunk of ``c`` tokens for ONE request (chunked
  prefill, Alg. 1): stages the request's context + writes new K/V, runs
  chunked-prefill flash attention.

Only dense/GQA families are supported by the real engine demo
(qwen1.5-0.5b smoke-scale is the example model); the simulator covers all
families at paper scale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels.ops import (chunked_prefill_attention,
                           packed_prefill_attention, packed_verify_attention,
                           paged_decode_attention)
from ..models.layers import apply_norm, apply_rope, gelu_mlp, swiglu
from ..models.model import ArchConfig, _qkv


def _mlp(cfg, lp, h):
    if cfg.family == "moe":
        from ..models.moe import moe_forward
        return moe_forward(h, lp["moe"], cfg.top_k, cfg.capacity_factor)
    return swiglu(h, lp["mlp"]) if cfg.act == "swiglu" else gelu_mlp(h, lp["mlp"])


def _decode_forward(cfg: ArchConfig, params, pool_kv, tokens, tables, lens):
    """Shared decode forward pass (traced by both ``decode_batch`` and the
    fused ``decode_step`` so the two jit variants run the identical graph).
    tokens: (B,) int32; tables: (B, maxp); lens: (B,) context BEFORE this
    step.  Returns (logits (B, V), new pool)."""
    b = tokens.shape[0]
    bs = pool_kv.shape[3]
    x = params["embed"][tokens][:, None, :].astype(pool_kv.dtype)
    positions = lens[:, None]
    block_of = tables[jnp.arange(b), lens // bs]          # (B,)
    slot_of = lens % bs

    def layer(carry, xs):
        x, pool = carry
        lp, li = xs["p"], xs["i"]
        h = apply_norm(x, lp["ln1"], cfg.norm)
        q, k, v = _qkv(cfg, lp["attn"], h)
        if cfg.rope_fraction > 0:
            q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
        # write the new K/V into each request's current block slot
        layer_kv = jax.lax.dynamic_index_in_dim(pool, li, 0, keepdims=False)
        layer_kv = layer_kv.at[0, block_of, slot_of].set(k[:, 0])
        layer_kv = layer_kv.at[1, block_of, slot_of].set(v[:, 0])
        pool = jax.lax.dynamic_update_index_in_dim(pool, layer_kv, li, 0)
        o = paged_decode_attention(q[:, 0], layer_kv[0], layer_kv[1],
                                   tables, lens + 1)
        a_out = jnp.einsum("bk,kd->bd", o.reshape(b, -1),
                           lp["attn"]["wo"])[:, None]
        x = x + a_out
        h2 = apply_norm(x, lp["ln2"], cfg.norm)
        x = x + _mlp(cfg, lp, h2)
        return (x, pool), None

    xs = {"p": params["layers"],
          "i": jnp.arange(cfg.n_layers, dtype=jnp.int32)}
    (x, pool_kv), _ = jax.lax.scan(layer, (x, pool_kv), xs)
    x = apply_norm(x, params["ln_f"], cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"])[:, 0]
    return logits, pool_kv


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
def decode_batch(cfg: ArchConfig, params, pool_kv, tokens, tables, lens):
    """One token for B requests, returning the full logits for host-side
    sampling.  tokens: (B,) int32; tables: (B, maxp); lens: (B,) context
    BEFORE this step.  Returns (logits (B, V), new pool)."""
    return _decode_forward(cfg, params, pool_kv, tokens, tables, lens)


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
def decode_step(cfg: ArchConfig, params, pool_kv, tokens, tables, lens):
    """Fused decode step: the same forward pass as ``decode_batch`` with
    the greedy argmax folded into the jitted graph, so the device->host
    fetch shrinks from (B, V) float logits to (B,) int32 tokens.

    The batch dimension may be padded to a bucket (``seg_bucket``) and the
    table width to ``table_bucket``: padding rows carry token 0, length 0
    and an all-zero table row, so their single K/V write lands in the
    reserved null block 0 (the packed-prefill convention) and their output
    token is garbage the caller discards.  Real rows are unaffected — every
    per-row computation is independent and the paged-attention kernel masks
    table entries past ``lens``."""
    logits, pool_kv = _decode_forward(cfg, params, pool_kv, tokens, tables,
                                      lens)
    return jnp.argmax(logits, -1).astype(jnp.int32), pool_kv


def _verify_forward(cfg: ArchConfig, params, pool_kv, tokens, tables, lens,
                    row_seg):
    """Packed speculative-verify forward: ``_decode_forward`` over an
    EXPANDED row set — one row per (request, draft position j), where row
    j carries the token at position l_kv + j and ``lens`` = l_kv + j.
    ``tables`` stays compact at (S, maxp): ``row_seg`` maps each row to
    its request's table row (the packed-verify kernel reads it via
    scalar prefetch; the K/V scatter gathers it host-of-kernel).

    Causality inside one launch follows the decode convention: every
    row's K/V is scattered BEFORE attention within each layer, and row
    j's length mask (lens + 1) covers exactly rows <= j of its own
    request — so row j+1 attends to row j's same-launch write and the
    packed rows reproduce sequential greedy decode bitwise."""
    r = tokens.shape[0]
    bs = pool_kv.shape[3]
    x = params["embed"][tokens][:, None, :].astype(pool_kv.dtype)
    positions = lens[:, None]
    row_tables = tables[row_seg]                          # (R, maxp)
    block_of = row_tables[jnp.arange(r), lens // bs]      # (R,)
    slot_of = lens % bs

    def layer(carry, xs):
        x, pool = carry
        lp, li = xs["p"], xs["i"]
        h = apply_norm(x, lp["ln1"], cfg.norm)
        q, k, v = _qkv(cfg, lp["attn"], h)
        if cfg.rope_fraction > 0:
            q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
        layer_kv = jax.lax.dynamic_index_in_dim(pool, li, 0, keepdims=False)
        layer_kv = layer_kv.at[0, block_of, slot_of].set(k[:, 0])
        layer_kv = layer_kv.at[1, block_of, slot_of].set(v[:, 0])
        pool = jax.lax.dynamic_update_index_in_dim(pool, layer_kv, li, 0)
        o = packed_verify_attention(q[:, 0], layer_kv[0], layer_kv[1],
                                    tables, lens + 1, row_seg)
        a_out = jnp.einsum("bk,kd->bd", o.reshape(r, -1),
                           lp["attn"]["wo"])[:, None]
        x = x + a_out
        h2 = apply_norm(x, lp["ln2"], cfg.norm)
        x = x + _mlp(cfg, lp, h2)
        return (x, pool), None

    xs = {"p": params["layers"],
          "i": jnp.arange(cfg.n_layers, dtype=jnp.int32)}
    (x, pool_kv), _ = jax.lax.scan(layer, (x, pool_kv), xs)
    x = apply_norm(x, params["ln_f"], cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"])[:, 0]
    return logits, pool_kv


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
def verify_step(cfg: ArchConfig, params, pool_kv, tokens, tables, lens,
                row_seg):
    """Fused speculative-verify step: greedy argmax for all packed rows
    in one launch.  tokens/lens/row_seg: (R,) int32 (row-bucket padded);
    tables: (S, maxp) int32 (segment-bucket padded).  Padding rows carry
    token 0, length 0 and point at an all-zero pad table row, so their
    K/V write lands in the reserved null block 0 (decode_step
    convention) and their output token is discarded by the caller.
    Returns ((R,) int32 argmax tokens, new pool)."""
    logits, pool_kv = _verify_forward(cfg, params, pool_kv, tokens, tables,
                                      lens, row_seg)
    return jnp.argmax(logits, -1).astype(jnp.int32), pool_kv


@functools.partial(jax.jit, static_argnums=(0, 6), donate_argnums=(2,))
def prefill_chunk(cfg: ArchConfig, params, pool_kv, tokens, table, ctx_len,
                  max_ctx: int):
    """One request's chunk.  tokens: (1, c) int32 (pad with 0 to the
    bucket size); table: (1, maxp); ctx_len: (1,) tokens already cached;
    ``max_ctx``: static staging size (>= ctx+chunk).  Returns
    (last-position logits (1, V), new pool, valid_len)."""
    c = tokens.shape[1]
    bs = pool_kv.shape[3]
    x = params["embed"][tokens].astype(pool_kv.dtype)
    positions = ctx_len[:, None] + jnp.arange(c)[None, :]
    maxp_stage = max_ctx // bs

    def layer(carry, xs):
        x, pool = carry
        lp, li = xs["p"], xs["i"]
        h = apply_norm(x, lp["ln1"], cfg.norm)
        q, k, v = _qkv(cfg, lp["attn"], h)
        if cfg.rope_fraction > 0:
            q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
        layer_kv = jax.lax.dynamic_index_in_dim(pool, li, 0, keepdims=False)
        # scatter the chunk's K/V into pool blocks position by position
        pos = ctx_len[0] + jnp.arange(c)
        blocks = table[0, pos // bs]
        slots = pos % bs
        layer_kv = layer_kv.at[0, blocks, slots].set(k[0])
        layer_kv = layer_kv.at[1, blocks, slots].set(v[0])
        pool = jax.lax.dynamic_update_index_in_dim(pool, layer_kv, li, 0)
        # stage the context (gather blocks) into a contiguous buffer
        stage_blocks = table[0, :maxp_stage]
        k_stage = layer_kv[0, stage_blocks].reshape(
            1, max_ctx, cfg.n_kv_heads, cfg.hd)
        v_stage = layer_kv[1, stage_blocks].reshape(
            1, max_ctx, cfg.n_kv_heads, cfg.hd)
        o = chunked_prefill_attention(q, k_stage, v_stage, ctx_len + c)
        a_out = jnp.einsum("bsk,kd->bsd", o.reshape(1, c, -1),
                           lp["attn"]["wo"])
        x = x + a_out
        h2 = apply_norm(x, lp["ln2"], cfg.norm)
        x = x + _mlp(cfg, lp, h2)
        return (x, pool), None

    xs = {"p": params["layers"],
          "i": jnp.arange(cfg.n_layers, dtype=jnp.int32)}
    (x, pool_kv), _ = jax.lax.scan(layer, (x, pool_kv), xs)
    x = apply_norm(x, params["ln_f"], cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"])
    return logits, pool_kv


@functools.partial(jax.jit, static_argnums=(0, 12, 13), donate_argnums=(2,))
def prefill_packed(cfg: ArchConfig, params, pool_kv, tokens, positions,
                   q_rows, q_cols, scatter_blocks, scatter_slots, tables,
                   ctx_lens, last_idx, smax: int, sq: int):
    """Packed multi-request prefill: several requests' chunks concatenated
    into ONE flat token stream and executed in a single jitted call.

    The dense ops (embedding, QKV/output projections, MLP) run directly on
    the packed stream — no padding FLOPs.  Attention regroups queries into
    a per-segment padded layout and stages only the blocks each segment
    actually needs (``smax`` covers the longest segment, not the engine-wide
    ``max_ctx``), then runs the packed Pallas kernel.

      tokens:          (1, T) int32 flat stream, 0-padded to the T bucket
      positions:       (1, T) absolute position of each token (pad: 0)
      q_rows / q_cols: (T,)  attention scatter target: segment row /
                       within-chunk offset.  Padding tokens point at the
                       extra row ``S`` so they never touch real queries.
      scatter_blocks / scatter_slots: (T,) physical KV destination of each
                       token (padding tokens write the null block 0)
      tables:          (S, smax // block_size) staging tables (pad rows: 0)
      ctx_lens:        (S,) tokens already cached before each chunk
      last_idx:        (S,) flat index of each segment's last real token
      smax, sq:        static staging length / chunk-pad length

    Returns (last-position logits per segment (S, V), new pool)."""
    t_len = tokens.shape[1]
    n_seg = tables.shape[0]
    x = params["embed"][tokens].astype(pool_kv.dtype)      # (1, T, d)

    def layer(carry, xs):
        x, pool = carry
        lp, li = xs["p"], xs["i"]
        h = apply_norm(x, lp["ln1"], cfg.norm)
        q, k, v = _qkv(cfg, lp["attn"], h)                 # (1, T, H|Hkv, hd)
        if cfg.rope_fraction > 0:
            q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
        layer_kv = jax.lax.dynamic_index_in_dim(pool, li, 0, keepdims=False)
        # one flat scatter writes every segment's chunk K/V
        layer_kv = layer_kv.at[0, scatter_blocks, scatter_slots].set(k[0])
        layer_kv = layer_kv.at[1, scatter_blocks, scatter_slots].set(v[0])
        pool = jax.lax.dynamic_update_index_in_dim(pool, layer_kv, li, 0)
        # stage each segment's blocks (only the ones it needs)
        k_stage = layer_kv[0, tables].reshape(
            n_seg, smax, cfg.n_kv_heads, cfg.hd)
        v_stage = layer_kv[1, tables].reshape(
            n_seg, smax, cfg.n_kv_heads, cfg.hd)
        # regroup flat queries into the padded per-segment layout; the
        # extra row n_seg absorbs padding tokens
        q_pad = jnp.zeros((n_seg + 1, sq) + q.shape[2:], q.dtype)
        q_pad = q_pad.at[q_rows, q_cols].set(q[0])
        # kv_block matched to the staging length: a fixed 512 would pad
        # every segment's scores 4x when smax is 128 (masked positions are
        # bitwise no-ops, but their FLOPs are real)
        o = packed_prefill_attention(q_pad[:n_seg], k_stage, v_stage,
                                     ctx_lens, kv_block=min(512, smax))
        o_ext = jnp.concatenate(
            [o, jnp.zeros((1,) + o.shape[1:], o.dtype)], axis=0)
        o_flat = o_ext[q_rows, q_cols]                     # (T, H, hd)
        a_out = jnp.einsum("tk,kd->td", o_flat.reshape(t_len, -1),
                           lp["attn"]["wo"])[None]
        x = x + a_out
        h2 = apply_norm(x, lp["ln2"], cfg.norm)
        x = x + _mlp(cfg, lp, h2)
        return (x, pool), None

    xs = {"p": params["layers"],
          "i": jnp.arange(cfg.n_layers, dtype=jnp.int32)}
    (x, pool_kv), _ = jax.lax.scan(layer, (x, pool_kv), xs)
    x = apply_norm(x, params["ln_f"], cfg.norm)
    # only each segment's LAST chunk token can be sampled — skip the
    # (V x T) logit matmul for every other position
    x_last = x[0, last_idx]                                # (S, d)
    logits = jnp.einsum("sd,vd->sv", x_last, params["lm_head"])
    return logits, pool_kv


def bucket(n: int, buckets=(16, 32, 64, 128, 256, 512, 1024, 2048)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return -(-n // buckets[-1]) * buckets[-1]


def _geom_bucket(n: int, lo: int) -> int:
    """Round up to the next {2^k, 1.5*2^k} step at or above ``lo``: pad
    waste is bounded at 1.33x while the number of distinct jit variants
    stays logarithmic in n (each static shape recompiles the full model
    forward, so linear-step buckets would explode the variant count)."""
    b = lo
    while True:
        if n <= b:
            return b
        if n <= b + b // 2:
            return b + b // 2
        b <<= 1


def flat_bucket(n: int) -> int:
    """Bucket for the packed flat token stream: power-of-two steps up to
    2048, then geometric half-steps — the coarse 2048-step tail of
    ``bucket`` would pad a 2.3k-token pack to 4k (real FLOPs on every
    dense op)."""
    return bucket(n) if n <= 2048 else _geom_bucket(n, 2048)


def chunk_bucket(n: int) -> int:
    """Bucket for the packed per-segment pad length (sq) and staging span:
    power-of-two steps up to 128, then geometric half-steps — the
    attention score tile is (G*sq, smax), so the plain pow2 tail would pad
    a 160-token chunk's scores by 1.6x."""
    return bucket(n) if n <= 128 else _geom_bucket(n, 128)


def table_bucket(p: int) -> int:
    """Bucket for the decode block-table width (maxp): {2^k, 1.5*2^k}
    steps from 4.  Together with ``seg_bucket`` on the batch dimension this
    makes the decode jit cache persistent across steps — batches of
    (B in 5..6, maxp in 9..12) all hit one compiled variant instead of
    compiling per exact shape."""
    return _geom_bucket(p, 4)


def seg_bucket(s: int) -> int:
    """Bucket for the packed segment count: powers of two up to 8, then
    multiples of 8 (bounds jit variants without padding 24 segments
    to 32)."""
    if s <= 8:
        b = 1
        while b < s:
            b <<= 1
        return b
    return -(-s // 8) * 8
