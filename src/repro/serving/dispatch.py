"""Router-side bookkeeping shared by the synchronous ``ServiceController``
and the asynchronous ``ServiceFrontend``.

One ``RouterBook`` owns everything GoRouting needs to see about a fleet of
engine replicas: per-instance :class:`InstanceState` (prefill queue mirror,
decode counts, free blocks, EWMA speed), the durable request log used for
failure recovery, and the dispatch step itself (router ``select`` + state
mutation + logging).  Neither caller touches ``InstanceState`` directly —
the frontend serialises access with a lock, the controller runs single
threaded.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.estimator import BatchLatencyEstimator
from ..core.gorouting import InstanceState, QueuedStub
from ..core.request import Request


class RouterBook:
    def __init__(self, router, est: BatchLatencyEstimator,
                 speed_ewma: float = 0.2):
        self.router = router
        self.est = est
        self.speed_ewma = speed_ewma
        self.states: dict[int, InstanceState] = {}
        # durable request log: request + prompt + tokens streamed so far —
        # failover resumes generation exactly where the dead replica stopped.
        self.request_log: dict[int, tuple[Request, np.ndarray, list]] = {}

    # --- instance lifecycle -------------------------------------------
    def add_instance(self, iid: int, total_blocks: int,
                     free_blocks: int) -> InstanceState:
        st = InstanceState(iid=iid, b_f=free_blocks,
                           total_blocks=total_blocks)
        self.states[iid] = st
        return st

    def drop_instance(self, iid: int) -> None:
        st = self.states.pop(iid, None)
        if st is not None:
            st.alive = False

    # --- request log ---------------------------------------------------
    def log_request(self, req: Request, prompt_tokens) -> None:
        self.request_log[req.rid] = (req, np.asarray(prompt_tokens), [])

    def logged_partial(self, rid: int) -> Optional[list]:
        logged = self.request_log.get(rid)
        return None if logged is None else logged[2]

    def forget(self, rid: int) -> None:
        self.request_log.pop(rid, None)

    # --- dispatch ------------------------------------------------------
    def route(self, req: Request, now: float,
              exec_est: Optional[float] = None) -> Optional[int]:
        """Pick an instance via the router and record the dispatch."""
        pools = list(self.states.values())
        if exec_est is None:
            exec_est = self.est.prefill_time(req.prompt_len)
        iid, _ = self.router.select(req, pools, None, now,
                                    exec_est=exec_est)
        if iid is None:
            return None
        self.states[iid].on_dispatch(
            QueuedStub(req.rid, now, req.priority, req.weight,
                       req.prompt_len, req.arrival + req.slo.ttft,
                       exec_est), now)
        return iid

    # --- event-driven state updates (§4.4 monitoring) ------------------
    def heartbeat(self, iid: int, free_blocks: int) -> None:
        """Periodic b_f refresh with no latency observation."""
        st = self.states.get(iid)
        if st is not None:
            st.b_f = free_blocks

    def observe_step(self, iid: int, *, free_blocks: int, est_time: float,
                     latency: float) -> None:
        st = self.states.get(iid)
        if st is None:
            return
        st.b_f = free_blocks
        # straggler EWMA: observed vs estimated batch latency
        ratio = max(est_time, 1e-9) / max(latency, 1e-9)
        st.speed = ((1 - self.speed_ewma) * st.speed
                    + self.speed_ewma * min(max(ratio, 0.05), 2.0))

    def on_first_token(self, iid: int, rid: int, now: float) -> None:
        st = self.states.get(iid)
        if st is not None:
            st.on_prefill_done(rid, now)

    def on_finished(self, iid: int, rid: int) -> None:
        st = self.states.get(iid)
        if st is not None:
            st.on_finished(rid)
        self.forget(rid)
