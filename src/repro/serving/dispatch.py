"""Router-side bookkeeping shared by the synchronous ``ServiceController``
and the asynchronous ``ServiceFrontend``.

One ``RouterBook`` owns everything GoRouting needs to see about a fleet of
engine replicas: per-instance :class:`InstanceState` (prefill queue mirror,
decode counts, free blocks, EWMA speed), the durable request log used for
failure recovery, the prefix-affinity registry (which replica has recently
prefilled which prompt prefix — so repeated prefixes land on the replica
whose radix cache already holds their KV), and the dispatch step itself
(router ``select`` + state mutation + logging).  Neither caller touches
``InstanceState`` directly — the frontend serialises access with a lock,
the controller runs single threaded.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.estimator import BatchLatencyEstimator
from ..core.gorouting import InstanceState, QueuedStub
from ..core.prefix import PrefixRegistry, chunk_hashes, usable_prefix
from ..core.request import Request


class RouterBook:
    def __init__(self, router, est: BatchLatencyEstimator,
                 speed_ewma: float = 0.2, *, prefix_affinity: bool = True,
                 block_size: int = 16):
        self.router = router
        self.est = est
        self.speed_ewma = speed_ewma
        self.states: dict[int, InstanceState] = {}
        self.registry: Optional[PrefixRegistry] = (
            PrefixRegistry(block_size) if prefix_affinity else None)
        # durable request log: request + prompt + tokens streamed so far —
        # failover resumes generation exactly where the dead replica stopped.
        self.request_log: dict[int, tuple[Request, np.ndarray, list]] = {}

    # --- instance lifecycle -------------------------------------------
    def add_instance(self, iid: int, total_blocks: int,
                     free_blocks: int, *,
                     has_prefix_cache: bool = True) -> InstanceState:
        st = InstanceState(iid=iid, b_f=free_blocks,
                           total_blocks=total_blocks)
        self.states[iid] = st
        if not has_prefix_cache:
            # a cache-less replica joined: affinity claims (cache-discounted
            # stub costs, prefix-holder tiebreaks) would be false for it, so
            # turn prefix-affinity routing off for the whole fleet
            self.registry = None
        return st

    def drop_instance(self, iid: int) -> None:
        st = self.states.pop(iid, None)
        if st is not None:
            st.alive = False
        if self.registry is not None:
            self.registry.drop(iid)

    # --- request log ---------------------------------------------------
    def log_request(self, req: Request, prompt_tokens) -> None:
        self.request_log[req.rid] = (req, np.asarray(prompt_tokens), [])

    def logged_partial(self, rid: int) -> Optional[list]:
        logged = self.request_log.get(rid)
        return None if logged is None else logged[2]

    def forget(self, rid: int) -> None:
        self.request_log.pop(rid, None)

    # --- dispatch ------------------------------------------------------
    def route(self, req: Request, now: float,
              exec_est: Optional[float] = None,
              prompt_tokens=None) -> Optional[int]:
        """Pick an instance via the router and record the dispatch."""
        pools = list(self.states.values())
        if exec_est is None:
            exec_est = self.est.prefill_time(req.prompt_len)
        affinity, chain = None, None
        if self.registry is not None and prompt_tokens is not None:
            # hash the prompt once; lookup and observe both consume it
            chain = chunk_hashes(prompt_tokens, self.registry.block_size)
            affinity = self.registry.lookup(prompt_tokens,
                                            chain=chain) or None
        iid, _ = self.router.select(req, pools, None, now,
                                    exec_est=exec_est, affinity=affinity)
        if iid is None:
            return None
        # the stub mirrors what the replica will actually compute: after a
        # prefix-cache hit, only the uncached suffix
        stub_exec = exec_est
        if affinity and affinity.get(iid):
            cached = usable_prefix(affinity[iid], req.prompt_len,
                                   self.registry.block_size)
            stub_exec = self.est.prefill_time_cached(req.prompt_len, cached)
        self.states[iid].on_dispatch(
            QueuedStub(req.rid, now, req.priority, req.weight,
                       req.prompt_len, req.arrival + req.slo.ttft,
                       stub_exec), now)
        if self.registry is not None and chain is not None:
            self.registry.observe(iid, prompt_tokens, chain=chain)
        return iid

    # --- event-driven state updates (§4.4 monitoring) ------------------
    def heartbeat(self, iid: int, free_blocks: int) -> None:
        """Periodic b_f refresh with no latency observation."""
        st = self.states.get(iid)
        if st is not None:
            st.b_f = free_blocks

    def observe_step(self, iid: int, *, free_blocks: int, est_time: float,
                     latency: float) -> None:
        st = self.states.get(iid)
        if st is None:
            return
        st.b_f = free_blocks
        # straggler EWMA: observed vs estimated batch latency
        ratio = max(est_time, 1e-9) / max(latency, 1e-9)
        st.speed = ((1 - self.speed_ewma) * st.speed
                    + self.speed_ewma * min(max(ratio, 0.05), 2.0))

    def on_first_token(self, iid: int, rid: int, now: float) -> None:
        st = self.states.get(iid)
        if st is not None:
            st.on_prefill_done(rid, now)

    def on_finished(self, iid: int, rid: int) -> None:
        st = self.states.get(iid)
        if st is not None:
            st.on_finished(rid)
        self.forget(rid)
