"""Router-side bookkeeping shared by the synchronous ``ServiceController``
and the asynchronous ``ServiceFrontend``.

One ``RouterBook`` owns everything GoRouting needs to see about a fleet of
engine replicas: per-instance :class:`InstanceState` (prefill queue mirror,
decode counts, free blocks, EWMA speed), the durable request log used for
failure recovery, the prefix-affinity registry (which replica has recently
prefilled which prompt prefix — so repeated prefixes land on the replica
whose radix cache already holds their KV), and the dispatch step itself
(router ``select`` + state mutation + logging).  Neither caller touches
``InstanceState`` directly — the frontend serialises access with a lock,
the controller runs single threaded.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.estimator import BatchLatencyEstimator
from ..core.gorouting import InstanceState, QueuedStub, decode_need_blocks
from ..core.prefix import PrefixRegistry, chunk_hashes, usable_prefix
from ..core.request import Request


class RouterBook:
    def __init__(self, router, est: BatchLatencyEstimator,
                 speed_ewma: float = 0.2, *, prefix_affinity: bool = True,
                 block_size: int = 16):
        self.router = router
        self.est = est
        self.speed_ewma = speed_ewma
        self.block_size = block_size
        self.states: dict[int, InstanceState] = {}
        self.registry: Optional[PrefixRegistry] = (
            PrefixRegistry(block_size) if prefix_affinity else None)
        # durable request log: request + prompt + tokens streamed so far —
        # failover resumes generation exactly where the dead replica stopped.
        self.request_log: dict[int, tuple[Request, np.ndarray, list]] = {}
        # disagg two-leg lifecycle: rid -> (decode target iid, blocks
        # reserved there at admission); released at adoption/failure
        self.reservations: dict[int, tuple[int, int]] = {}
        # fleet-wide disagg counters (mirrored by ClusterSim for parity)
        self.reservation_hits = 0    # adoption landed on the reserved
        self.reservation_misses = 0  # target with the promised blocks
        self.reserved_blocks_total = 0
        self.adopted_blocks_total = 0
        self.handoffs = 0
        self.handoff_blocks = 0
        self.handoff_bytes = 0

    # --- instance lifecycle -------------------------------------------
    def add_instance(self, iid: int, total_blocks: int,
                     free_blocks: int, *,
                     has_prefix_cache: bool = True,
                     role: str = "coloc") -> InstanceState:
        st = InstanceState(iid=iid, b_f=free_blocks,
                           total_blocks=total_blocks, role=role)
        self.states[iid] = st
        if not has_prefix_cache:
            # a cache-less replica joined: affinity claims (cache-discounted
            # stub costs, prefix-holder tiebreaks) would be false for it, so
            # turn prefix-affinity routing off for the whole fleet
            self.registry = None
        return st

    def drop_instance(self, iid: int) -> None:
        st = self.states.pop(iid, None)
        if st is not None:
            st.alive = False
        if self.registry is not None:
            self.registry.drop(iid)
        # reservations on a dead decode replica are void; requests mid-
        # handoff to it are re-dispatched by the frontend's failover
        for rid, (d_iid, _) in list(self.reservations.items()):
            if d_iid == iid:
                self.reservations.pop(rid, None)

    # --- request log ---------------------------------------------------
    def log_request(self, req: Request, prompt_tokens) -> None:
        self.request_log[req.rid] = (req, np.asarray(prompt_tokens), [])

    def logged_partial(self, rid: int) -> Optional[list]:
        logged = self.request_log.get(rid)
        return None if logged is None else logged[2]

    def forget(self, rid: int) -> None:
        self.request_log.pop(rid, None)

    # --- dispatch ------------------------------------------------------
    def route(self, req: Request, now: float,
              exec_est: Optional[float] = None,
              prompt_tokens=None) -> Optional[int]:
        """Pick an instance via the router and record the dispatch.

        Role-aware (disagg): the prefill pool is coloc + prefill replicas
        and the decode pool is the decode replicas — the router picks a
        prefill target AND a decode target, whose blocks for the eventual
        KV handoff are reserved here, at admission.  With no live decode
        replica the prefill-role replicas are excluded too (a request
        must be able to finish where it prefills), which is exactly the
        churn-failover path: re-dispatch lands on a coloc replica.
        """
        # a re-dispatch supersedes any reservation the prior leg held
        self.release_reservation(req.rid)
        pools = list(self.states.values())
        decode_pool = [st for st in pools if st.role == "decode"]
        live_decode = [d for d in decode_pool if d.alive]
        if live_decode:
            prefill_pool = [st for st in pools
                            if st.role in ("coloc", "prefill")]
        else:
            prefill_pool = [st for st in pools if st.role == "coloc"]
        if exec_est is None:
            exec_est = self.est.prefill_time(req.prompt_len)
        affinity, chain = None, None
        if self.registry is not None and prompt_tokens is not None:
            # hash the prompt once; lookup and observe both consume it
            chain = chunk_hashes(prompt_tokens, self.registry.block_size)
            affinity = self.registry.lookup(prompt_tokens,
                                            chain=chain) or None
        iid, d_iid = self.router.select(
            req, prefill_pool, decode_pool if live_decode else None, now,
            block_size=self.block_size, exec_est=exec_est,
            affinity=affinity)
        if iid is None:
            return None
        if d_iid is not None and self.states[iid].role == "prefill":
            # reserve the handoff blocks on the decode target now, so
            # concurrent admissions see them as spoken for.  Never
            # oversubscribe: an unfittable reservation is recorded as a
            # zero-block miss (the adoption-time eviction path covers it).
            st_d = self.states[d_iid]
            need = decode_need_blocks(req, self.block_size)
            if st_d.reserved_blocks + need > st_d.total_blocks:
                need = 0
            st_d.reserve(need)
            self.reserved_blocks_total += need
            self.reservations[req.rid] = (d_iid, need)
        # the stub mirrors what the replica will actually compute: after a
        # prefix-cache hit, only the uncached suffix
        stub_exec = exec_est
        if affinity and affinity.get(iid):
            cached = usable_prefix(affinity[iid], req.prompt_len,
                                   self.registry.block_size)
            stub_exec = self.est.prefill_time_cached(req.prompt_len, cached)
        self.states[iid].on_dispatch(
            QueuedStub(req.rid, now, req.priority, req.weight,
                       req.prompt_len, req.arrival + req.slo.ttft,
                       stub_exec), now)
        if self.registry is not None and chain is not None:
            self.registry.observe(iid, prompt_tokens, chain=chain)
        return iid

    # --- event-driven state updates (§4.4 monitoring) ------------------
    def heartbeat(self, iid: int, free_blocks: int) -> None:
        """Periodic b_f refresh with no latency observation."""
        st = self.states.get(iid)
        if st is not None:
            st.b_f = free_blocks

    def observe_step(self, iid: int, *, free_blocks: int, est_time: float,
                     latency: float) -> None:
        st = self.states.get(iid)
        if st is None:
            return
        st.b_f = free_blocks
        # straggler EWMA: observed vs estimated batch latency
        ratio = max(est_time, 1e-9) / max(latency, 1e-9)
        st.speed = ((1 - self.speed_ewma) * st.speed
                    + self.speed_ewma * min(max(ratio, 0.05), 2.0))

    def on_first_token(self, iid: int, rid: int, now: float) -> None:
        st = self.states.get(iid)
        if st is None:
            return
        if st.role == "prefill":
            # the request leaves at handoff: clear the prefill stub but
            # leave n_d alone — the decode replica's n_d is bumped when
            # the payload is adopted (on_handoff_delivered)
            st.on_prefill_exported(rid, now)
        else:
            st.on_prefill_done(rid, now)

    def on_finished(self, iid: int, rid: int) -> None:
        st = self.states.get(iid)
        if st is not None:
            st.on_finished(rid)
        self.release_reservation(rid)
        self.forget(rid)

    # --- disagg handoff lifecycle --------------------------------------
    def decode_target(self, rid: int) -> Optional[int]:
        """Decode replica reserved for rid at admission (None if the
        reservation is gone — e.g. the target died)."""
        res = self.reservations.get(rid)
        return None if res is None else res[0]

    def on_handoff_sent(self, src_iid: int, rid: int, now: float) -> None:
        """Prefill replica exported rid's KV (covers failover recomputes,
        which emit no first token on the prefill leg)."""
        st = self.states.get(src_iid)
        if st is not None:
            st.on_prefill_exported(rid, now)

    def on_handoff_delivered(self, rid: int, iid: int, n_blocks: int,
                             wire_bytes: int, now: float) -> None:
        """A decode replica adopted rid's payload: settle the reservation
        (hit iff it landed on the reserved target with the promised
        blocks) and start the decode leg there."""
        res = self.reservations.pop(rid, None)
        if res is not None:
            d_iid, need = res
            st_r = self.states.get(d_iid)
            if st_r is not None:
                st_r.unreserve(need)
            if d_iid == iid and need == n_blocks:
                self.reservation_hits += 1
            else:
                self.reservation_misses += 1
        else:
            self.reservation_misses += 1
        self.adopted_blocks_total += n_blocks
        st = self.states.get(iid)
        if st is not None:
            st.n_d += 1
            st.ts = now
        self.handoffs += 1
        self.handoff_blocks += n_blocks
        self.handoff_bytes += wire_bytes

    def release_reservation(self, rid: int) -> None:
        """Void rid's decode reservation (finish/failure/re-dispatch)."""
        res = self.reservations.pop(rid, None)
        if res is None:
            return
        d_iid, need = res
        st = self.states.get(d_iid)
        if st is not None:
            st.unreserve(need)
