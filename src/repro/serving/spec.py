"""Draft-model runner for greedy speculative decoding.

The ``DraftRunner`` keeps a small draft model (its own params + its own
``PagedKVPool``) in lockstep with the target engine's token streams.  For
each speculating request the engine hands over the full known sequence
(prompt + outputs) and a depth ``k``; the runner

  1. catches the draft KV up to the sequence (large gaps — the first
     engagement's prompt — ingest via ``prefill_chunk``, exactly like the
     target did; small gaps ride the decode feed rounds below, so output
     tokens get their draft KV from the same decode math the target used),
  2. feeds the remaining known tokens and then its own proposals through
     batched ``decode_step`` rounds shared across all speculating
     requests, collecting ``k`` greedy proposals per request.

Draft KV slots are position-addressed, so a rejected proposal's stale KV
is simply overwritten when the (corrected) token at that position is fed
on a later engagement — ``observe`` records how far the draft context is
known-good after each verify.  All draft state for a request dies with
``drop`` (finish / evict / handoff / kill): re-engagement re-ingests from
the target's authoritative sequence.

Nothing here affects the emitted streams — the target's packed verify
recomputes every position and greedy acceptance keeps the output bitwise
identical to non-speculative decode; the draft only decides how many
positions are worth verifying.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..models.model import ArchConfig
from . import model_exec
from .kv_pool import PagedKVPool

# gaps larger than this are ingested with one prefill_chunk call instead
# of riding the per-token decode feed rounds (first engagement = prompt)
GAP_PREFILL = 8


class DraftRunner:
    def __init__(self, cfg: ArchConfig, params, *, num_blocks: int = 512,
                 block_size: int = 16, max_ctx: int = 1024):
        self.cfg = cfg
        self.params = params
        self.pool = PagedKVPool(cfg, num_blocks, block_size)
        self.max_ctx = max_ctx
        # rid -> leading draft-KV positions that match the target stream
        self.ctx: dict[int, int] = {}
        # rid -> target context at propose time (awaiting observe())
        self._pending: dict[int, int] = {}
        self.launches = 0      # draft jit calls (prefill + decode rounds)
        self.syncs = 0         # device->host fetches (decode rounds only)

    # ------------------------------------------------------------------
    def drop(self, rid: int) -> None:
        """Forget a request's draft state and free its draft-pool blocks
        (finish / evict / handoff export / engine kill)."""
        if rid in self.ctx or rid in self._pending:
            self.ctx.pop(rid, None)
            self._pending.pop(rid, None)
            self.pool.release(rid)

    def observe(self, rid: int, depth: int, accepted: int) -> None:
        """Verify outcome for the last propose(): positions up to the last
        accepted proposal hold correct KV (the proposal at ``accepted``
        was refuted and its successors were never written)."""
        tgt = self._pending.pop(rid, None)
        if tgt is not None:
            self.ctx[rid] = tgt + min(accepted + 1, depth)

    # ------------------------------------------------------------------
    def _ingest(self, rid: int, seq: np.ndarray, ctx: int, tgt: int) -> None:
        """Catch the draft KV up over [ctx, tgt) with one chunked prefill
        (same bucketing as the engine's per-request fallback path)."""
        n = tgt - ctx
        c = model_exec.bucket(n)
        toks = np.zeros((1, c), np.int32)
        toks[0, :n] = seq[ctx:tgt]
        max_ctx = model_exec.bucket(ctx + c, buckets=(
            self.max_ctx,)) if ctx + c <= self.max_ctx else ctx + c
        table = self.pool.table_array(
            [rid], maxp=max_ctx // self.pool.block_size)
        _, self.pool.kv = model_exec.prefill_chunk(
            self.cfg, self.params, self.pool.kv, jnp.asarray(toks),
            table, jnp.asarray([ctx], jnp.int32), max_ctx)
        self.launches += 1
        self.ctx[rid] = tgt

    def propose(self, items: list[tuple[int, np.ndarray, int]]
                ) -> dict[int, list[int]]:
        """Greedy draft proposals for a batch of speculating requests.

        ``items``: (rid, full known token sequence, depth > 0).  Returns
        rid -> depth proposals; a rid missing from the result could not be
        engaged (draft pool exhausted) and should run at depth 0.
        """
        out: dict[int, list[int]] = {}
        live: list[dict] = []
        for rid, seq, depth in items:
            tgt = len(seq) - 1
            if not self.pool.ensure_capacity(rid, tgt + depth):
                self.drop(rid)
                continue
            ctx = self.ctx.get(rid, 0)
            if tgt - ctx > GAP_PREFILL:
                self._ingest(rid, seq, ctx, tgt)
                ctx = tgt
            # feed positions ctx..tgt+depth-1: known tokens first, then
            # each round's own proposal; outputs at positions >= tgt are
            # the proposals
            live.append({"rid": rid, "pos": ctx, "last": 0,
                         "feeds": [int(t) for t in seq[ctx:tgt + 1]],
                         "n_left": (tgt - ctx) + depth})
            self._pending[rid] = tgt
            self.ctx[rid] = tgt
            out[rid] = []
        while True:
            active = [s for s in live if s["n_left"] > 0]
            if not active:
                break
            nb = len(active)
            b_b = model_exec.seg_bucket(nb)
            maxp = max(len(self.pool.tables[s["rid"]]) for s in active)
            maxp_b = model_exec.table_bucket(maxp)
            lens = np.zeros(b_b, np.int32)
            last = np.zeros(b_b, np.int32)
            for i, s in enumerate(active):
                lens[i] = s["pos"]
                last[i] = s["feeds"].pop(0) if s["feeds"] else s["last"]
            table = self.pool.table_array([s["rid"] for s in active],
                                          maxp=maxp_b, rows=b_b)
            toks, self.pool.kv = model_exec.decode_step(
                self.cfg, self.params, self.pool.kv, jnp.asarray(last),
                table, jnp.asarray(lens))
            self.launches += 1
            self.syncs += 1
            nxt = np.asarray(toks)[:nb]
            for s, t in zip(active, nxt):
                s["pos"] += 1
                s["n_left"] -= 1
                s["last"] = int(t)
                if s["pos"] > self._pending[s["rid"]]:
                    out[s["rid"]].append(int(t))
        return out
