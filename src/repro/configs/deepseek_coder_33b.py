"""DeepSeek-Coder-33B [arXiv:2401.14196; hf].

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256 — llama architecture
(RMSNorm, SwiGLU, RoPE).
"""
import dataclasses
from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab=32256, head_dim=128,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab=256)
