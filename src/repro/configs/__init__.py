"""Assigned architecture configs (--arch <id>).

Each module defines ``CONFIG`` (the exact published configuration) and
``SMOKE`` (a reduced same-family variant for CPU tests).  ``get(name)``
returns the full config, ``get_smoke(name)`` the reduced one.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "qwen2_moe_a2_7b",
    "olmoe_1b_7b",
    "whisper_small",
    "mamba2_1_3b",
    "chameleon_34b",
    "hymba_1_5b",
    "deepseek_coder_33b",
    "qwen1_5_0_5b",
    "chatglm3_6b",
    "phi4_mini_3_8b",
    # paper evaluation models (§5.1)
    "qwen2_7b",
    "qwen3_32b",
]

_ALIASES = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "whisper-small": "whisper_small",
    "mamba2-1.3b": "mamba2_1_3b",
    "chameleon-34b": "chameleon_34b",
    "hymba-1.5b": "hymba_1_5b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "chatglm3-6b": "chatglm3_6b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "qwen2-7b": "qwen2_7b",
    "qwen3-32b": "qwen3_32b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get(name: str):
    mod = importlib.import_module(f".{canonical(name)}", __package__)
    return mod.CONFIG


def get_smoke(name: str):
    mod = importlib.import_module(f".{canonical(name)}", __package__)
    return mod.SMOKE


def all_configs():
    return {n: get(n) for n in ARCH_IDS}
