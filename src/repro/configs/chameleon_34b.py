"""Chameleon-34B [arXiv:2405.09818; unverified].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.  Early-fusion VLM:
VQ image tokens are ordinary ids in the 65536 vocab, so the backbone is a
pure decoder; the modality frontend is a stub (input_specs supplies token
ids).  QK-norm per the paper's training-stability fix.
"""
import dataclasses
from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=65536, head_dim=128,
    qk_norm=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab=256)
