"""OLMoE-1B-7B [arXiv:2409.02060; hf].

16L d_model=2048 16H (GQA kv=16) d_ff(expert)=1024 vocab=50304,
MoE: 64 routed experts, top-8, no shared experts.  QK-norm per OLMoE.
"""
import dataclasses
from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab=50304, head_dim=128,
    n_experts=64, n_shared=0, top_k=8, d_expert=1024,
    qk_norm=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=32, d_expert=32, n_experts=8, top_k=2, vocab=256,
    capacity_factor=4.0)  # = E/k: provably dropless at smoke scale
