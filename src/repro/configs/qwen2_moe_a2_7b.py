"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L d_model=2048 16H (GQA kv=16) d_ff(expert)=1408 vocab=151936,
MoE: 4 shared + 60 routed experts, top-4.  QKV bias (Qwen1.5 family).
60 routed experts are padded to 64 at sharding time for even EP over the
16-way model axis (dispatch masks the 4 dummies) — see distributed/sharding.
"""
import dataclasses
from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=151936, head_dim=128,
    n_experts=60, n_shared=4, top_k=4, d_expert=1408,
    qkv_bias=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=32, d_expert=32, n_experts=8, n_shared=1, top_k=2, vocab=256,
    capacity_factor=4.0)  # = E/k: provably dropless at smoke scale
