"""Mamba2-1.3B [arXiv:2405.21060; unverified].

48L d_model=2048, attention-free SSD (state-space duality), ssm_state=128,
d_inner=4096, head_dim=64 (64 ssm heads), vocab=50280.
Constant per-request state => long_500k decode RUNS.
"""
import dataclasses
from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280, head_dim=64,
    ssm_state=128, ssm_head_dim=64,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=16, vocab=256)
