"""Qwen3-32B — the paper's large evaluation model (§5.1, §5.6)."""
import dataclasses
from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
    d_ff=25600, vocab=151936, head_dim=128,
    qk_norm=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab=256)
