"""Qwen2-7B — the paper's small evaluation model (§5.1)."""
import dataclasses
from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064, head_dim=128,
    qkv_bias=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256)
