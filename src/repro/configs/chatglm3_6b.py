"""ChatGLM3-6B [arXiv:2406.12793; hf].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
2D-RoPE = rotary on HALF the head dims (rope_fraction=0.5); QKV bias.
kv=2 < 16-way TP => decode uses the sequence-sharded flash-decode path.
"""
import dataclasses
from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=65024, head_dim=128,
    qkv_bias=True, rope_fraction=0.5,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab=256)
