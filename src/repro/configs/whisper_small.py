"""Whisper-small [arXiv:2212.04356; unverified].

Encoder-decoder: 12+12L d_model=768 12H d_ff=3072 vocab=51865.
LayerNorm + GELU, sinusoidal positions.  The conv audio frontend is a STUB:
``input_specs()`` supplies precomputed (batch, 1500, 768) frame embeddings.
Enc-dec (not encoder-only) => decode shapes RUN (DESIGN.md §4).
"""
import dataclasses
from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, head_dim=64,
    enc_frames=1500, rope_fraction=0.0, norm="layernorm", act="gelu",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab=256, enc_frames=32)
