"""Hymba-1.5B [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504, ssm_state=16.  Hybrid-head:
attention heads and SSM heads run in PARALLEL on the same input; outputs
are normalized then averaged.  Sliding-window attention (window=1024) for
all layers (the 3 published full-attention layers are approximated by SWA —
structural deviation noted in DESIGN.md; meta-tokens omitted).
Sub-quadratic => long_500k RUNS.
"""
import dataclasses
from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, head_dim=64,
    ssm_state=16, ssm_head_dim=64, window=1024,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, ssm_state=8, ssm_head_dim=16, ssm_chunk=16, window=16,
    vocab=256)
