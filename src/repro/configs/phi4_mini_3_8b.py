"""Phi-4-mini-3.8B [arXiv:2412.08905; hf].

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064,
RoPE (partial 0.75) + SwiGLU + GQA.
"""
import dataclasses
from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab=200064, head_dim=128,
    rope_fraction=0.75,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab=256)
