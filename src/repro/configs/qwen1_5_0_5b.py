"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B; hf].

24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936, QKV bias.
Smallest assigned arch — also used for the real CPU serving example.
"""
import dataclasses
from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=2816, vocab=151936, head_dim=64,
    qkv_bias=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256)
