"""Mamba2 (state-space duality / SSD) block, pure JAX.

Follows arXiv:2405.21060: per head h with scalar decay a_h = -exp(A_log_h),
inputs x (B,S,H,P), gates dt (B,S,H), shared B/C projections (B,S,G,N)
(G groups = 1 here).  Two execution modes:

* ``ssd_chunked`` — training / prefill: sequence split into chunks of Q;
  intra-chunk term is a (masked, decay-weighted) quadratic attention-like
  product, inter-chunk term propagates the (H, P, N) state with a
  lax.scan over chunks — O(S·Q) work, O(S/Q) sequential depth.
* ``ssd_decode_step`` — serving: constant-time recurrent update of the
  (B, H, P, N) state; this is why mamba2 runs the 500k-token decode shape
  that full-attention models cannot (DESIGN.md §4).

A depthwise causal conv (width 4) precedes the SSM; its rolling state is
carried for decode.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm, split_keys


class SSMSpec(NamedTuple):
    d_model: int
    d_inner: int          # = expand * d_model (expand=2)
    head_dim: int         # P
    n_heads: int          # H = d_inner // P
    d_state: int          # N
    conv_width: int = 4
    chunk: int = 256


def spec_for(d_model: int, d_state: int, head_dim: int = 64,
             expand: int = 2, chunk: int = 256) -> SSMSpec:
    d_inner = expand * d_model
    return SSMSpec(d_model, d_inner, head_dim, d_inner // head_dim,
                   d_state, 4, chunk)


def init_ssm(key, spec: SSMSpec, dtype=jnp.float32) -> dict:
    ks = split_keys(key, 6)
    di, H, N = spec.d_inner, spec.n_heads, spec.d_state
    conv_ch = di + 2 * N          # x, B, C all pass through the conv
    return {
        # in_proj -> [z (di), x (di), B (N), C (N), dt (H)]
        "w_in": dense_init(ks[0], (spec.d_model, 2 * di + 2 * N + H),
                           dtype=dtype),
        "conv_w": dense_init(ks[1], (spec.conv_width, conv_ch),
                             scale=1.0 / math.sqrt(spec.conv_width),
                             dtype=dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype=dtype),
        "A_log": jnp.zeros((H,), dtype=jnp.float32),
        "D": jnp.ones((H,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((H,), dtype=jnp.float32),
        "norm_scale": jnp.ones((di,), dtype=dtype),
        "w_out": dense_init(ks[2], (di, spec.d_model), dtype=dtype),
    }


class SSMState(NamedTuple):
    ssm: jax.Array        # (B, H, P, N) running state
    conv: jax.Array       # (B, conv_width-1, conv_ch) rolling conv inputs


def init_state(spec: SSMSpec, batch: int, dtype=jnp.float32) -> SSMState:
    conv_ch = spec.d_inner + 2 * spec.d_state
    return SSMState(
        ssm=jnp.zeros((batch, spec.n_heads, spec.head_dim, spec.d_state),
                      dtype=jnp.float32),
        conv=jnp.zeros((batch, spec.conv_width - 1, conv_ch), dtype=dtype))


def _split_proj(h: jax.Array, spec: SSMSpec):
    di, N, H = spec.d_inner, spec.d_state, spec.n_heads
    z = h[..., :di]
    xBC = h[..., di:di + di + 2 * N]
    dt = h[..., di + di + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array,
                 prev: jax.Array):
    """Depthwise causal conv along seq. xBC: (B,S,C); prev: (B,W-1,C)."""
    W = w.shape[0]
    xp = jnp.concatenate([prev.astype(xBC.dtype), xBC], axis=1)
    out = sum(xp[:, i:i + xBC.shape[1], :] * w[i] for i in range(W))
    new_prev = xp[:, -(W - 1):, :] if W > 1 else prev
    return jax.nn.silu(out + b), new_prev


def _segsum_decay(log_a: jax.Array) -> jax.Array:
    """L[i,j] = exp(sum_{j<t<=i} log_a_t) for j<=i else 0 — (…,Q,Q)."""
    Q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # (…,Q,Q)
    mask = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(params: dict, spec: SSMSpec, u: jax.Array,
                state: SSMState | None = None,
                ) -> tuple[jax.Array, SSMState]:
    """Full-sequence SSD. u: (B, S, d_model) -> (B, S, d_model)."""
    B_, S, _ = u.shape
    H, P, N, Q = spec.n_heads, spec.head_dim, spec.d_state, spec.chunk
    h = u @ params["w_in"].astype(u.dtype)
    z, xBC, dt = _split_proj(h, spec)
    if state is None:
        state = init_state(spec, B_, dtype=u.dtype)
    xBC, conv_state = _causal_conv(xBC, params["conv_w"].astype(u.dtype),
                                   params["conv_b"].astype(u.dtype),
                                   state.conv)
    x = xBC[..., :spec.d_inner].reshape(B_, S, H, P)
    Bm = xBC[..., spec.d_inner:spec.d_inner + N]          # (B,S,N) G=1
    Cm = xBC[..., spec.d_inner + N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])             # (B,S,H)
    a = -jnp.exp(params["A_log"])                         # (H,)
    log_a = (dt * a).transpose(0, 2, 1)                   # (B,H,S) negative

    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, 0), (0, pad)))
    nC = (S + pad) // Q

    xc = x.reshape(B_, nC, Q, H, P)
    Bc = Bm.reshape(B_, nC, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(B_, nC, Q, N).astype(jnp.float32)
    dtc = dt.reshape(B_, nC, Q, H)
    lac = log_a.reshape(B_, H, nC, Q)

    # --- intra-chunk (quadratic within Q) ------------------------------
    L = _segsum_decay(lac)                                # (B,H,nC,Q,Q)
    xdt = (xc.astype(jnp.float32)
           * dtc[..., None])                              # (B,nC,Q,H,P)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)        # (B,nC,Q,Q)
    y_intra = jnp.einsum("bhcqk,bcqk,bckhp->bcqhp",
                         L, scores, xdt)

    # --- chunk states + inter-chunk scan --------------------------------
    cum = jnp.cumsum(lac, axis=-1)                        # (B,H,nC,Q)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)           # (B,H,nC,Q)
    chunk_state = jnp.einsum("bckn,bhck,bckhp->bchpn",
                             Bc, decay_to_end, xdt)       # (B,nC,H,P,N)
    chunk_decay = jnp.exp(cum[..., -1])                   # (B,H,nC)

    def scan_body(s, inp):
        cs, cd = inp                                      # (B,H,P,N),(B,H)
        s_out = s                                         # state BEFORE chunk
        s_new = s * cd[..., None, None] + cs
        return s_new, s_out

    cs_t = chunk_state.transpose(1, 0, 2, 3, 4)           # (nC,B,H,P,N)
    cd_t = chunk_decay.transpose(2, 0, 1)                 # (nC,B,H)
    final_state, states_before = jax.lax.scan(
        scan_body, state.ssm, (cs_t, cd_t))
    states_before = states_before.transpose(1, 0, 2, 3, 4)  # (B,nC,H,P,N)

    decay_from_start = jnp.exp(cum)                       # (B,H,nC,Q)
    y_inter = jnp.einsum("bcqn,bhcq,bchpn->bcqhp",
                         Cc, decay_from_start, states_before)

    y = (y_intra + y_inter).reshape(B_, nC * Q, H, P)[:, :S]
    y = y + x[:, :S].astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(B_, S, spec.d_inner).astype(u.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_scale"])
    out = y @ params["w_out"].astype(u.dtype)
    return out, SSMState(ssm=final_state, conv=conv_state)


def ssd_decode_step(params: dict, spec: SSMSpec, u: jax.Array,
                    state: SSMState) -> tuple[jax.Array, SSMState]:
    """One token. u: (B, 1, d_model)."""
    B_ = u.shape[0]
    H, P, N = spec.n_heads, spec.head_dim, spec.d_state
    h = u @ params["w_in"].astype(u.dtype)
    z, xBC, dt = _split_proj(h, spec)
    xBC, conv_state = _causal_conv(xBC, params["conv_w"].astype(u.dtype),
                                   params["conv_b"].astype(u.dtype),
                                   state.conv)
    x = xBC[..., :spec.d_inner].reshape(B_, H, P)
    Bm = xBC[:, 0, spec.d_inner:spec.d_inner + N].astype(jnp.float32)
    Cm = xBC[:, 0, spec.d_inner + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * a)                               # (B,H)
    xdt = x.astype(jnp.float32) * dt[..., None]           # (B,H,P)
    new_state = (state.ssm * decay[..., None, None]
                 + jnp.einsum("bhp,bn->bhpn", xdt, Bm))
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm)
    y = y + x.astype(jnp.float32) * params["D"][None, :, None]
    y = y.reshape(B_, 1, spec.d_inner).astype(u.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_scale"])
    return y @ params["w_out"].astype(u.dtype), SSMState(new_state, conv_state)
