"""Config-driven model assembly for all assigned architectures.

One ``ArchConfig`` describes any of the five families
(dense / moe / ssm / hybrid / encdec); ``init_params`` builds a pytree with
layer parameters STACKED along a leading axis so the forward pass is a
single ``lax.scan`` over layers — this keeps the HLO size independent of
depth (62-layer deepseek compiles as fast as 16-layer olmoe) and is what
makes the 512-device dry-run tractable.

Public entry points:
  * ``forward(cfg, params, tokens, ...)``          full-sequence (train)
  * ``prefill(cfg, params, tokens, max_seq, ...)`` build a serving cache
  * ``decode_step(cfg, params, cache, tok, ...)``  one token with cache
  * encoder–decoder variants take ``enc_inputs`` (stub frontend embeddings).

Sharding is injected via an optional ``shard_fn(x, kind)`` callback
(distributed/sharding.py) — the model stays mesh-agnostic.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import kvcache
from .layers import (apply_norm, apply_rope, chunked_attention,
                     decode_attention, dense_attention, dense_init, gelu_mlp,
                     rmsnorm, sinusoidal_positions, split_keys, swiglu)
from .moe import init_moe, moe_forward
from .ssm import (SSMSpec, SSMState, init_ssm, spec_for, ssd_chunked,
                  ssd_decode_step)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    n_shared: int = 0
    top_k: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # --- attention details ---
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_fraction: float = 1.0
    rope_theta: float = 1e4
    window: int = 0              # sliding-window size (hybrid)
    # --- encoder-decoder ---
    n_enc_layers: int = 0
    enc_frames: int = 0          # stub-frontend sequence length
    # --- misc ---
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "swiglu"          # swiglu | gelu
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md §4 skip rule)."""
        return self.family in ("ssm", "hybrid")

    @property
    def ssm_spec(self) -> SSMSpec:
        return spec_for(self.d_model, self.ssm_state,
                        head_dim=self.ssm_head_dim, chunk=self.ssm_chunk)

    def param_count(self) -> float:
        """Analytic total parameter count."""
        d, hd = self.d_model, self.hd
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        if self.family == "moe":
            ff = self.n_experts * 3 * d * self.d_expert \
                + (3 * d * self.n_shared * self.d_expert) + d * self.n_experts
        elif self.family == "ssm":
            attn = 0
            ff = 0
        else:
            ff = 3 * d * self.d_ff if self.act == "swiglu" else 2 * d * self.d_ff
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            sp = self.ssm_spec
            ssm = d * (2 * sp.d_inner + 2 * sp.d_state + sp.n_heads) \
                + sp.d_inner * d
        per_layer = attn + ff + ssm
        total = self.n_layers * per_layer + 2 * self.vocab * d
        if self.family == "encdec":
            enc_ff = 2 * d * self.d_ff
            total += self.n_enc_layers * (attn + enc_ff) \
                + self.n_layers * attn        # cross attention
        return float(total)

    def active_param_count(self) -> float:
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dense_part = self.param_count() - self.n_layers * (
            self.n_experts * 3 * d * self.d_expert)
        return dense_part + self.n_layers * (
            self.top_k * 3 * d * self.d_expert)


# --------------------------------------------------------------------------
# parameter init
# --------------------------------------------------------------------------

def _init_norm(cfg, dtype):
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def _init_attn(cfg: ArchConfig, key, dtype):
    d, hd = cfg.d_model, cfg.hd
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d),
                         scale=1.0 / math.sqrt(cfg.n_heads * hd * 2
                                               * cfg.n_layers), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _init_mlp(cfg: ArchConfig, key, dtype):
    d = cfg.d_model
    ks = split_keys(key, 3)
    if cfg.act == "gelu":
        return {"w_up": dense_init(ks[0], (d, cfg.d_ff), dtype=dtype),
                "b_up": jnp.zeros((cfg.d_ff,), dtype),
                "w_down": dense_init(ks[1], (cfg.d_ff, d), dtype=dtype),
                "b_down": jnp.zeros((d,), dtype)}
    return {"w_gate": dense_init(ks[0], (d, cfg.d_ff), dtype=dtype),
            "w_up": dense_init(ks[1], (d, cfg.d_ff), dtype=dtype),
            "w_down": dense_init(ks[2], (cfg.d_ff, d), dtype=dtype)}


def _init_layer(cfg: ArchConfig, key, dtype):
    ks = split_keys(key, 4)
    p = {"ln1": _init_norm(cfg, dtype), "ln2": _init_norm(cfg, dtype)}
    if cfg.family != "ssm":
        p["attn"] = _init_attn(cfg, ks[0], dtype)
    if cfg.family == "moe":
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.d_expert, cfg.n_experts,
                            cfg.n_shared, dtype=dtype)
    elif cfg.family == "ssm":
        p["ssm"] = init_ssm(ks[1], cfg.ssm_spec, dtype=dtype)
    else:
        p["mlp"] = _init_mlp(cfg, ks[1], dtype)
    if cfg.family == "hybrid":
        p["ssm"] = init_ssm(ks[2], cfg.ssm_spec, dtype=dtype)
        p["attn_out_norm"] = {"scale": jnp.ones((cfg.d_model,), dtype)}
        p["ssm_out_norm"] = {"scale": jnp.ones((cfg.d_model,), dtype)}
    return p


def _init_cross_layer(cfg: ArchConfig, key, dtype):
    """Decoder layer of an enc-dec model: self-attn + cross-attn + mlp."""
    ks = split_keys(key, 3)
    return {"ln1": _init_norm(cfg, dtype),
            "attn": _init_attn(cfg, ks[0], dtype),
            "ln_x": _init_norm(cfg, dtype),
            "xattn": _init_attn(cfg, ks[1], dtype),
            "ln2": _init_norm(cfg, dtype),
            "mlp": _init_mlp(cfg, ks[2], dtype)}


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    ks = split_keys(key, 6)
    p = {
        "embed": dense_init(ks[0], (cfg.vocab, cfg.d_model),
                            scale=0.02, dtype=dtype),
        "lm_head": dense_init(ks[1], (cfg.vocab, cfg.d_model),
                              scale=0.02, dtype=dtype),
        "ln_f": _init_norm(cfg, dtype),
    }
    if cfg.family == "encdec":
        enc_cfg = dataclasses.replace(cfg, family="dense",
                                      n_layers=cfg.n_enc_layers)
        enc_keys = jnp.stack(split_keys(ks[2], cfg.n_enc_layers))
        p["enc_layers"] = jax.vmap(
            lambda k: _init_layer(enc_cfg, k, dtype))(enc_keys)
        p["enc_ln_f"] = _init_norm(cfg, dtype)
        dec_keys = jnp.stack(split_keys(ks[3], cfg.n_layers))
        p["layers"] = jax.vmap(
            lambda k: _init_cross_layer(cfg, k, dtype))(dec_keys)
    else:
        layer_keys = jnp.stack(split_keys(ks[3], cfg.n_layers))
        p["layers"] = jax.vmap(
            lambda k: _init_layer(cfg, k, dtype))(layer_keys)
    return p


# --------------------------------------------------------------------------
# attention sub-block (full sequence)
# --------------------------------------------------------------------------

def _qkv(cfg: ArchConfig, ap: dict, x: jax.Array):
    b, s, _ = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dk->bsk", x, ap["wq"])
    k = jnp.einsum("bsd,dk->bsk", x, ap["wk"])
    v = jnp.einsum("bsd,dk->bsk", x, ap["wv"])
    if cfg.qkv_bias:
        q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, ap["q_norm"])
        k = rmsnorm(k, ap["k_norm"])
    return q, k, v


def _attn_block(cfg: ArchConfig, ap: dict, x: jax.Array,
                positions: jax.Array, *, causal: bool, attn_impl: str,
                q_offset: int = 0,
                shard_fn=None) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (out, k, v) — k/v pre-repeat, post-rope, for cache storage."""
    q, k, v = _qkv(cfg, ap, x)
    if cfg.rope_fraction > 0:
        q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
    if attn_impl == "chunked":
        o = chunked_attention(q, k, v, causal=causal, q_offset=q_offset,
                              window=cfg.window)
    else:
        o = dense_attention(q, k, v, causal=causal, window=cfg.window,
                            shard_fn=shard_fn)
    b, s = x.shape[:2]
    out = jnp.einsum("bsk,kd->bsd", o.reshape(b, s, -1), ap["wo"])
    return out, k, v


# --------------------------------------------------------------------------
# full-sequence forward (training / prefill)
# --------------------------------------------------------------------------

def forward(cfg: ArchConfig, params: dict, tokens: jax.Array, *,
            attn_impl: str = "dense",
            shard_fn: Optional[Callable] = None,
            remat: bool = False,
            enc_inputs: Optional[jax.Array] = None,
            collect_cache: bool = False,
            last_only: bool = False,
            max_seq: int = 0) -> tuple[jax.Array, Optional[dict]]:
    """Token logits for a full sequence.  ``collect_cache`` additionally
    returns a serving cache of size ``max_seq`` (prefill path).
    ``last_only`` computes logits for the final position only (prefill
    never materializes the (B, S, V) logits tensor).
    """
    sh = shard_fn or (lambda x, kind: x)
    b, s = tokens.shape
    x = params["embed"][tokens].astype(params["embed"].dtype)
    x = sh(x, "act")
    positions = jnp.arange(s)[None, :]

    if cfg.family == "encdec":
        enc_out = _encoder(cfg, params, enc_inputs, attn_impl, sh, remat)
        return _decoder_encdec(cfg, params, x, positions, enc_out,
                               attn_impl, sh, collect_cache, max_seq,
                               last_only, remat)

    spec = cfg.ssm_spec if cfg.family in ("ssm", "hybrid") else None

    def layer(x, lp):
        h = apply_norm(x, lp["ln1"], cfg.norm)
        if cfg.family == "ssm":
            mix, st = ssd_chunked(lp["ssm"], spec, h)
        elif cfg.family == "hybrid":
            a_out, k, v = _attn_block(cfg, lp["attn"], h, positions,
                                      causal=True, attn_impl=attn_impl,
                                      shard_fn=sh)
            s_out, st = ssd_chunked(lp["ssm"], spec, h)
            mix = 0.5 * (rmsnorm(a_out, lp["attn_out_norm"]["scale"])
                         + rmsnorm(s_out, lp["ssm_out_norm"]["scale"]))
        else:
            mix, k, v = _attn_block(cfg, lp["attn"], h, positions,
                                    causal=True, attn_impl=attn_impl,
                                    shard_fn=sh)
            st = None
        x = sh(x + mix, "act")
        h2 = apply_norm(x, lp["ln2"], cfg.norm)
        if cfg.family == "moe":
            ff = moe_forward(h2, lp["moe"], cfg.top_k, cfg.capacity_factor,
                             shard_fn=sh)
        elif cfg.family == "ssm":
            ff = 0.0
        else:
            ff = swiglu(h2, lp["mlp"]) if cfg.act == "swiglu" \
                else gelu_mlp(h2, lp["mlp"])
        x = sh(x + ff, "act") if cfg.family != "ssm" else x
        extras = {}
        if collect_cache:
            if cfg.family not in ("ssm",):
                extras["k"] = sh(k, "kv_stack")
                extras["v"] = sh(v, "kv_stack")
            if st is not None:
                extras["ssm"], extras["conv"] = st.ssm, st.conv
        return x, extras

    def scan_body(x, lp):
        f = jax.checkpoint(layer) if remat else layer
        return f(x, lp)

    x, extras = jax.lax.scan(scan_body, x, params["layers"])
    x = apply_norm(x, params["ln_f"], cfg.norm)
    if last_only:
        x = x[:, -1:]
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"])
    logits = sh(logits, "logits")

    cache = None
    if collect_cache:
        cache = _build_cache(cfg, extras, b, s, max_seq or s)
    return logits, cache


def _build_cache(cfg: ArchConfig, extras: dict, b: int, s: int,
                 max_seq: int) -> dict:
    cache = {"len": jnp.full((b,), s, jnp.int32)}
    if "k" in extras:
        k, v = extras["k"], extras["v"]              # (L,B,S,Hkv,hd)
        if cfg.window > 0:
            w = cfg.window
            kc = jnp.zeros((k.shape[0], b, w, cfg.n_kv_heads, cfg.hd),
                           k.dtype)
            vc = jnp.zeros_like(kc)
            # write the trailing `window` positions into ring slots
            pos = jnp.arange(max(s - w, 0), s)
            slot = pos % w
            kc = kc.at[:, :, slot].set(k[:, :, pos])
            vc = vc.at[:, :, slot].set(v[:, :, pos])
            cache["k"], cache["v"] = kc, vc
        else:
            pad = max_seq - s
            cache["k"] = jnp.pad(k, ((0, 0), (0, 0), (0, pad),
                                     (0, 0), (0, 0)))
            cache["v"] = jnp.pad(v, ((0, 0), (0, 0), (0, pad),
                                     (0, 0), (0, 0)))
    if "ssm" in extras:
        cache["ssm"], cache["conv"] = extras["ssm"], extras["conv"]
    return cache


# --------------------------------------------------------------------------
# encoder-decoder (whisper-style; frontend = stub embeddings)
# --------------------------------------------------------------------------

def _encoder(cfg: ArchConfig, params: dict, enc_inputs: jax.Array,
             attn_impl: str, sh, remat: bool = False) -> jax.Array:
    x = enc_inputs + sinusoidal_positions(
        enc_inputs.shape[1], cfg.d_model, enc_inputs.dtype)[None]
    x = sh(x, "act")
    positions = jnp.arange(enc_inputs.shape[1])[None, :]
    enc_cfg = dataclasses.replace(cfg, family="dense", rope_fraction=0.0)

    def layer(x, lp):
        h = apply_norm(x, lp["ln1"], cfg.norm)
        mix, _, _ = _attn_block(enc_cfg, lp["attn"], h, positions,
                                causal=False, attn_impl=attn_impl)
        x = sh(x + mix, "act")
        h2 = apply_norm(x, lp["ln2"], cfg.norm)
        ff = gelu_mlp(h2, lp["mlp"]) if cfg.act == "gelu" \
            else swiglu(h2, lp["mlp"])
        return sh(x + ff, "act"), None

    body = jax.checkpoint(layer) if remat else layer
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(x, params["enc_ln_f"], cfg.norm)


def _decoder_encdec(cfg: ArchConfig, params: dict, x: jax.Array,
                    positions: jax.Array, enc_out: jax.Array,
                    attn_impl: str, sh, collect_cache: bool, max_seq: int,
                    last_only: bool = False, remat: bool = False):
    b, s = x.shape[:2]
    x = x + sinusoidal_positions(s, cfg.d_model, x.dtype)[None]
    dec_cfg = dataclasses.replace(cfg, rope_fraction=0.0)

    def layer(x, lp):
        h = apply_norm(x, lp["ln1"], cfg.norm)
        mix, k, v = _attn_block(dec_cfg, lp["attn"], h, positions,
                                causal=True, attn_impl=attn_impl)
        x = sh(x + mix, "act")
        # cross attention over encoder output
        hx = apply_norm(x, lp["ln_x"], cfg.norm)
        qx, kx, vx = _qkv(dec_cfg, lp["xattn"], hx)
        # queries from decoder, keys/values from encoder states
        kx_e = jnp.einsum("bsd,dk->bsk", enc_out, lp["xattn"]["wk"])
        vx_e = jnp.einsum("bsd,dk->bsk", enc_out, lp["xattn"]["wv"])
        if cfg.qkv_bias:
            kx_e, vx_e = kx_e + lp["xattn"]["bk"], vx_e + lp["xattn"]["bv"]
        kx_e = kx_e.reshape(b, enc_out.shape[1], cfg.n_kv_heads, cfg.hd)
        vx_e = vx_e.reshape(b, enc_out.shape[1], cfg.n_kv_heads, cfg.hd)
        if attn_impl == "chunked":
            xo = chunked_attention(qx, kx_e, vx_e, causal=False)
        else:
            xo = dense_attention(qx, kx_e, vx_e, causal=False)
        x = sh(x + jnp.einsum(
            "bsk,kd->bsd", xo.reshape(b, s, -1), lp["xattn"]["wo"]), "act")
        h2 = apply_norm(x, lp["ln2"], cfg.norm)
        ff = gelu_mlp(h2, lp["mlp"]) if cfg.act == "gelu" \
            else swiglu(h2, lp["mlp"])
        x = sh(x + ff, "act")
        extras = {}
        if collect_cache:
            extras["k"] = sh(k, "kv_stack")
            extras["v"] = sh(v, "kv_stack")
            extras["cross_k"] = sh(kx_e, "kv_stack")
            extras["cross_v"] = sh(vx_e, "kv_stack")
        return x, extras

    body = jax.checkpoint(layer) if remat else layer
    x, extras = jax.lax.scan(body, x, params["layers"])
    x = apply_norm(x, params["ln_f"], cfg.norm)
    if last_only:
        x = x[:, -1:]
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"])
    logits = sh(logits, "logits")
    cache = None
    if collect_cache:
        cache = _build_cache(cfg, {"k": extras["k"], "v": extras["v"]},
                             b, s, max_seq or s)
        cache["cross_k"], cache["cross_v"] = extras["cross_k"], extras["cross_v"]
    return logits, cache


# --------------------------------------------------------------------------
# serving: prefill + single-token decode
# --------------------------------------------------------------------------

def prefill(cfg: ArchConfig, params: dict, tokens: jax.Array, max_seq: int,
            *, attn_impl: str = "dense",
            shard_fn: Optional[Callable] = None,
            enc_inputs: Optional[jax.Array] = None):
    """Full-prompt prefill.  Returns (last-position logits, serving cache)."""
    logits, cache = forward(cfg, params, tokens, attn_impl=attn_impl,
                            shard_fn=shard_fn, enc_inputs=enc_inputs,
                            collect_cache=True, last_only=True,
                            max_seq=max_seq)
    return logits[:, -1], cache


def decode_step(cfg: ArchConfig, params: dict, cache: dict,
                tokens: jax.Array, *,
                shard_fn: Optional[Callable] = None):
    """One decode step.  tokens: (B,) int32.  Returns (logits, new cache)."""
    sh = shard_fn or (lambda x, kind: x)
    b = tokens.shape[0]
    x = params["embed"][tokens][:, None, :].astype(params["embed"].dtype)
    x = sh(x, "act_decode")
    lens = cache["len"]
    positions = lens[:, None]
    spec = cfg.ssm_spec if cfg.family in ("ssm", "hybrid") else None
    is_encdec = cfg.family == "encdec"
    if is_encdec:
        # sinusoidal position embedding gathered at each request's length
        pe = sinusoidal_positions(cache["k"].shape[2], cfg.d_model, x.dtype)
        x = x + pe[lens][:, None, :]

    # The mutable cache rides in the scan CARRY and is updated per layer
    # with dynamic-update-slice — XLA keeps while-loop carries in place, so
    # with donation the decode step allocates no second cache (scan xs->ys
    # would double-buffer the full (L, B, S, ...) arrays).
    CARRY_KEYS = tuple(k for k in ("k", "v", "ssm", "conv") if k in cache)

    def layer(carry, xs):
        x, cstate = carry
        lp, li = xs["p"], xs["i"]
        h = apply_norm(x, lp["ln1"], cfg.norm)
        new = {}

        def get(key):
            return jax.lax.dynamic_index_in_dim(cstate[key], li, axis=0,
                                                keepdims=False)

        if cfg.family == "ssm":
            mix, st = ssd_decode_step(
                lp["ssm"], spec, h, SSMState(get("ssm"), get("conv")))
            new["ssm"], new["conv"] = st.ssm, st.conv
        else:
            dec_cfg = dataclasses.replace(cfg, rope_fraction=0.0) \
                if is_encdec else cfg
            q, k, v = _qkv(dec_cfg, lp["attn"], h)
            if dec_cfg.rope_fraction > 0:
                q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
            if cfg.window > 0:
                kc, vc = kvcache.update_ring_cache(
                    get("k"), get("v"), k, v, lens, cfg.window)
                eff_len = jnp.minimum(lens + 1, cfg.window)
                o = decode_attention(q, kc, vc, eff_len, shard_fn=sh)
            else:
                kc, vc = kvcache.update_layer_cache(get("k"), get("v"),
                                                    k, v, lens)
                o = decode_attention(q, kc, vc, lens + 1, shard_fn=sh)
            new["k"], new["v"] = kc, vc
            a_out = jnp.einsum("bsk,kd->bsd", o.reshape(b, 1, -1),
                               lp["attn"]["wo"])
            if cfg.family == "hybrid":
                s_out, st = ssd_decode_step(
                    lp["ssm"], spec, h, SSMState(get("ssm"), get("conv")))
                new["ssm"], new["conv"] = st.ssm, st.conv
                mix = 0.5 * (rmsnorm(a_out, lp["attn_out_norm"]["scale"])
                             + rmsnorm(s_out, lp["ssm_out_norm"]["scale"]))
            else:
                mix = a_out
        x = x + mix
        if is_encdec:
            hx = apply_norm(x, lp["ln_x"], cfg.norm)
            dec_cfg = dataclasses.replace(cfg, rope_fraction=0.0)
            qx, _, _ = _qkv(dec_cfg, lp["xattn"], hx)
            enc_len = jnp.full((b,), xs["cross_k"].shape[1], jnp.int32)
            xo = decode_attention(qx, xs["cross_k"], xs["cross_v"], enc_len,
                                  shard_fn=sh)
            x = x + jnp.einsum("bsk,kd->bsd", xo.reshape(b, 1, -1),
                               lp["xattn"]["wo"])
        h2 = apply_norm(x, lp["ln2"], cfg.norm)
        if cfg.family == "moe":
            ff = moe_forward(h2, lp["moe"], cfg.top_k, cfg.capacity_factor,
                             shard_fn=sh)
        elif cfg.family == "ssm":
            ff = 0.0
        else:
            ff = swiglu(h2, lp["mlp"]) if cfg.act == "swiglu" \
                else gelu_mlp(h2, lp["mlp"])
        x = x + ff if cfg.family != "ssm" else x
        cstate = {key: jax.lax.dynamic_update_index_in_dim(
                      cstate[key], new[key].astype(cstate[key].dtype), li, 0)
                  for key in CARRY_KEYS} if CARRY_KEYS else cstate
        return (x, cstate), None

    xs = {"p": params["layers"],
          "i": jnp.arange(cfg.n_layers, dtype=jnp.int32)}
    for key in ("cross_k", "cross_v"):
        if key in cache:
            xs[key] = cache[key]
    cstate0 = {key: cache[key] for key in CARRY_KEYS}
    (x, cstate), _ = jax.lax.scan(layer, (x, cstate0), xs)
    x = apply_norm(x, params["ln_f"], cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"])[:, 0]
    logits = sh(logits, "logits_decode")

    new_cache = dict(cache)
    new_cache.update(cstate)
    new_cache["len"] = lens + 1
    return logits, new_cache
