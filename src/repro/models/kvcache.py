"""KV / state cache pytrees for serving.

Contiguous per-request caches (dense layout) are used by `serve_step` and
the dry-run; the real CPU engine uses the paged pool in serving/kv_pool.py
(same bytes, block-granular).  Hybrid archs carry a ring-buffer window cache
plus SSM state; pure SSM archs carry state only — that is what makes the
``long_500k`` decode shape feasible (DESIGN.md §4).
"""
from __future__ import annotations


import jax.numpy as jnp


def init_attn_cache(n_layers: int, batch: int, max_seq: int,
                    n_kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
    shape = (n_layers, batch, max_seq, n_kv_heads, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "len": jnp.zeros((batch,), jnp.int32)}


def update_layer_cache(k_cache, v_cache, k_new, v_new, lengths):
    """Insert (B, S_new, Hkv, D) at per-batch offsets into (B, Smax, ...)."""
    s_new = k_new.shape[1]
    idx = lengths[:, None] + jnp.arange(s_new)[None, :]      # (B, S_new)
    b_idx = jnp.arange(k_new.shape[0])[:, None]
    k_cache = k_cache.at[b_idx, idx].set(k_new)
    v_cache = v_cache.at[b_idx, idx].set(v_new)
    return k_cache, v_cache


def update_ring_cache(k_cache, v_cache, k_new, v_new, lengths, window: int):
    """Ring-buffer insert for sliding-window caches (slot = pos % window)."""
    s_new = k_new.shape[1]
    pos = lengths[:, None] + jnp.arange(s_new)[None, :]
    slot = pos % window
    b_idx = jnp.arange(k_new.shape[0])[:, None]
    k_cache = k_cache.at[b_idx, slot].set(k_new)
    v_cache = v_cache.at[b_idx, slot].set(v_new)
    return k_cache, v_cache
