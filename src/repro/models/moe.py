"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

Implementation strategy (TPU/SPMD-friendly, DESIGN.md §5):

* router -> top-k expert ids + normalized gates per token;
* *per-row capacity dispatch*: tokens are scattered into a
  ``(B, E, C, d)`` buffer with C = ceil(k·S/E·cf) PER BATCH ROW.  Keeping
  the batch dimension leading means the scatter stays local to the
  data-parallel shard (no data-dependent cross-shard writes); the EP
  all-to-all appears exactly once, as the resharding of the dispatch
  buffer from batch-sharded to expert-sharded (``shard_fn`` hook
  "moe_dispatch") before the expert einsum — mirroring the dispatch/
  combine collectives of a real MoE system;
* overflow tokens beyond C are dropped (capacity-factor approximation of
  the dropless reference, cf = 1.25 default; smoke configs use cf = E/k
  which is provably dropless);
* experts run as one einsum batched over the expert axis — sharded over
  "model" when E divides the axis (EP), otherwise the expert-internal ffn
  dim is sharded ("TP-within-expert", e.g. qwen2-moe's 60 experts on a
  16-way axis).

Shared experts (Qwen2-MoE) run densely on every token.
"""
from __future__ import annotations

import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .layers import dense_init, split_keys


def init_moe(key, d_model: int, d_expert: int, n_experts: int,
             n_shared: int, dtype=jnp.float32) -> dict:
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (d_model, n_experts), dtype=dtype),
        "w_gate": dense_init(ks[1], (n_experts, d_model, d_expert), dtype=dtype),
        "w_up": dense_init(ks[2], (n_experts, d_model, d_expert), dtype=dtype),
        "w_down": dense_init(ks[3], (n_experts, d_expert, d_model), dtype=dtype),
    }
    if n_shared:
        sk = split_keys(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(sk[0], (d_model, n_shared * d_expert), dtype=dtype),
            "w_up": dense_init(sk[1], (d_model, n_shared * d_expert), dtype=dtype),
            "w_down": dense_init(sk[2], (n_shared * d_expert, d_model), dtype=dtype),
        }
    return p


def moe_forward(x: jax.Array, p: dict, top_k: int,
                capacity_factor: float = 1.25,
                shard_fn: Optional[Callable] = None,
                router_dtype=jnp.float32) -> jax.Array:
    """x: (B, S, d) -> (B, S, d)."""
    sh = shard_fn or (lambda a, kind: a)
    B, S, d = x.shape
    E = p["router"].shape[1]

    logits = jnp.einsum("bsd,de->bse", x.astype(router_dtype),
                        p["router"].astype(router_dtype))
    gates, idx = jax.lax.top_k(logits, top_k)               # (B, S, k)
    gates = jax.nn.softmax(gates, axis=-1)

    cap = max(int(math.ceil(top_k * S / E * capacity_factor)), 1)
    # position-in-expert: sort-free cumsum per row.  All indexing below is
    # vmapped over the batch row — vmapped scatters/gathers lower to
    # BATCHED scatter/gather ops, which the SPMD partitioner keeps local
    # to the data shard (explicit-batch-index scatters get replicated!).
    e_flat = idx.reshape(B, S * top_k)                      # (B, S*k)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)     # (B, S*k, E)
    pos = jnp.cumsum(onehot, axis=1) - 1
    pos_in_e = jnp.take_along_axis(
        pos, e_flat[..., None], axis=2)[..., 0]             # (B, S*k)
    keep = pos_in_e < cap
    safe_pos = jnp.where(keep, pos_in_e, cap - 1)

    x_rep = jnp.repeat(x[:, :, None, :], top_k, axis=2
                       ).reshape(B, S * top_k, d)
    contrib = jnp.where(keep[..., None], x_rep, 0)

    def _dispatch_row(c_row, e_row, p_row):
        return jnp.zeros((E, cap, d), c_row.dtype).at[e_row, p_row].add(
            c_row, mode="drop")

    buf = jax.vmap(_dispatch_row)(contrib, e_flat, safe_pos)
    buf = sh(buf, "moe_dispatch")          # <- EP all-to-all happens here

    # expert FFN batched over the expert axis
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    y_e = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
    y_e = sh(y_e, "moe_combine")           # <- and back to batch-sharded

    # gather + gate combine (vmapped row gather, batch-local)
    def _combine_row(y_row, e_row, p_row):
        return y_row[e_row, p_row]

    y_tok = jax.vmap(_combine_row)(y_e, e_flat, safe_pos)   # (B, S*k, d)
    y_tok = jnp.where(keep[..., None], y_tok, 0)
    y = (y_tok.reshape(B, S, top_k, d)
         * gates[..., None].astype(x.dtype)).sum(axis=2)

    if "shared" in p:
        sp = p["shared"]
        sg = jnp.einsum("bsd,df->bsf", x, sp["w_gate"].astype(x.dtype))
        su = jnp.einsum("bsd,df->bsf", x, sp["w_up"].astype(x.dtype))
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(sg) * su,
                           sp["w_down"].astype(x.dtype))
    return y


def moe_ref(x: jax.Array, p: dict, top_k: int) -> jax.Array:
    """Dropless dense reference: every expert on every token, masked combine.
    O(E) compute — only for tiny test configs."""
    orig = x.shape
    d = orig[-1]
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    gates, idx = jax.lax.top_k(logits, top_k)
    gates = jax.nn.softmax(gates, axis=-1)
    g = jnp.einsum("td,edf->tef", xt, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("td,edf->tef", xt, p["w_up"].astype(x.dtype))
    y_all = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u,
                       p["w_down"].astype(x.dtype))          # (T, E, d)
    E = y_all.shape[1]
    comb = jnp.zeros((xt.shape[0], E), jnp.float32)
    comb = comb.at[jnp.arange(xt.shape[0])[:, None], idx].add(gates)
    y = jnp.einsum("ted,te->td", y_all.astype(jnp.float32), comb)
    out = y.astype(x.dtype).reshape(orig)
    if "shared" in p:
        sp = p["shared"]
        sg = xt @ sp["w_gate"].astype(x.dtype)
        su = xt @ sp["w_up"].astype(x.dtype)
        out = out + ((jax.nn.silu(sg) * su) @ sp["w_down"].astype(x.dtype)
                     ).reshape(orig)
    return out
