"""Shared neural building blocks (pure-functional JAX).

Everything here is sharding-agnostic: distribution is imposed from outside
via parameter PartitionSpecs and ``with_sharding_constraint`` on activations
(src/repro/distributed/sharding.py).

Attention comes in three interchangeable implementations:
  * ``dense``   — plain softmax(QKᵀ)V; reference + smoke tests.
  * ``chunked`` — flash-style online-softmax lax.scan over KV blocks; the
    XLA production path for long-context prefill (no S² score buffer).
  * the Pallas kernels in repro.kernels are the TPU hot path and are
    validated against ``dense`` in interpret mode.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# normalization
# --------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dtype) * scale


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dtype) * scale + bias


def apply_norm(x, p, kind: str):
    if kind == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


# --------------------------------------------------------------------------
# rotary embeddings (full, partial — chatglm "2d" = half dims — and none)
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, fraction: float, theta: float = 1e4):
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x: jax.Array, positions: jax.Array, fraction: float = 1.0,
               theta: float = 1e4) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    inv, rot = rope_frequencies(head_dim, fraction, theta)
    if rot == 0:
        return x
    ang = positions.astype(jnp.float32)[..., None] * inv      # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]                          # (..., S, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([y.astype(x.dtype), x_pass], axis=-1)


def sinusoidal_positions(seq: int, d: int, dtype=jnp.float32) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(1e4, dim / d)
    pe = jnp.zeros((seq, d), dtype=jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang[:, : (d // 2)]))
    return pe.astype(dtype)


# --------------------------------------------------------------------------
# attention cores
# --------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, Hkv, D) -> (B, S, Hkv*groups, D) for GQA."""
    if groups == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, groups, d)
                            ).reshape(b, s, h * groups, d)


def dense_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True,
                    q_offset: Optional[jax.Array] = None,
                    kv_len: Optional[jax.Array] = None,
                    window: int = 0, shard_fn=None) -> jax.Array:
    """Reference attention.

    q: (B, Sq, H, D);  k, v: (B, Skv, Hkv, D).
    ``q_offset``: absolute position of q[0] (for chunked prefill the chunk
    starts at the existing context length).  ``kv_len``: per-batch valid KV
    length (for decode over padded caches).  ``window``: sliding-window
    size (0 = full).
    """
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    sh = shard_fn or (lambda x, kind: x)
    g = h // hkv
    if g == 1:
        # MHA: plain layout (the 5-D grouped form only adds transposes)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k)[:, :, None] / math.sqrt(d)
        scores = scores.reshape(b, hkv, 1, sq, skv)
    else:
        # GQA-aware contraction: K/V are NEVER materialized at h query
        # heads — repeating K before the seq all-gather moves G x the bytes.
        q5 = q.reshape(b, sq, hkv, g, d)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", q5, k) / math.sqrt(d)
    # keep the score tile q-sharded: without this XLA may replicate the
    # whole attention across the model axis
    scores = sh(scores.astype(jnp.float32), "attn_scores")
    q_pos = jnp.arange(sq)
    if q_offset is not None:
        q_pos = q_pos + q_offset[..., None] if q_offset.ndim else q_pos + q_offset
    k_pos = jnp.arange(skv)
    if q_pos.ndim == 1:
        rel = q_pos[:, None] >= k_pos[None, :]
        mask = rel if causal else jnp.ones((sq, skv), dtype=bool)
        if window > 0:
            mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    if kv_len is not None:
        valid = k_pos[None, :] < kv_len[:, None]            # (B, Skv)
        scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    probs = sh(jax.nn.softmax(scores, axis=-1).astype(q.dtype),
               "attn_scores")
    if g == 1:
        o = jnp.einsum("bhqk,bkhd->bqhd", probs[:, :, 0], v)
        return o
    o = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return o.reshape(b, sq, h, d)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      *, causal: bool = True, q_offset: int = 0,
                      window: int = 0, kv_chunk: int = 512) -> jax.Array:
    """Flash-style attention: lax.scan over KV chunks with an online softmax
    so the (Sq, Skv) score matrix is never materialized — the XLA path for
    32k+ prefill.  Assumes un-padded contiguous KV.
    """
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    groups = h // hkv
    nchunks = -(-skv // kv_chunk)
    pad = nchunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunks, kv_chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunks, kv_chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(sq) + q_offset
    scale = 1.0 / math.sqrt(d)

    def body(carry, xs):
        m, l, acc = carry                     # (B,H,Sq), (B,H,Sq), (B,H,Sq,D)
        kb, vb, ci = xs                       # (B,C,Hkv,D), (B,C,Hkv,D), ()
        kb = _repeat_kv(kb, groups)
        vb = _repeat_kv(vb, groups)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
        k_pos = ci * kv_chunk + jnp.arange(kv_chunk)
        mask = k_pos[None, :] < skv           # in-bounds (chunk padding)
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        if window > 0:
            mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((b, h, sq), dtype=jnp.float32)
    a0 = jnp.zeros((b, h, sq, d), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(nchunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype).transpose(0, 2, 1, 3)          # (B,Sq,H,D)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, *, window: int = 0,
                     shard_fn=None) -> jax.Array:
    """Single-step decode over a padded contiguous cache.

    q: (B, 1, H, D); caches: (B, Smax, Hkv, D); kv_len: (B,) valid lengths.
    With ``window`` > 0 only the trailing ``window`` positions attend.

    GQA is handled by reshaping q to (B, Hkv, G, D) and contracting against
    the UN-repeated cache — no (B, S, H, D) broadcast is ever materialized.
    Distributed decode: the cache arrives sequence-sharded over the "model"
    axis; constraining the score tensor to the same sharding ("dec_scores")
    keeps the big tensors local, and the softmax/PV reductions over the
    sharded axis lower to small all-reduces (flash-decode combine).
    """
    sh = shard_fn or (lambda x, kind: x)
    b, _, h, d = q.shape
    skv, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    q5 = q.reshape(b, hkv, g, d)
    s = jnp.einsum("bkgd,bskd->bkgs", q5, k_cache).astype(jnp.float32)
    s = s / math.sqrt(d)
    s = sh(s, "dec_scores")                       # (B, Hkv, G, Skv)
    k_pos = jnp.arange(skv)[None, :]
    valid = k_pos < kv_len[:, None]
    if window > 0:
        valid = valid & (k_pos >= kv_len[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(q.dtype), v_cache)
    o = o / l[..., 0][..., None].astype(q.dtype)
    return o.reshape(b, 1, h, d)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def swiglu(x: jax.Array, p: dict) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, p["w_down"])


def gelu_mlp(x: jax.Array, p: dict) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["w_up"]) + p["b_up"]
    h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, p["w_down"]) + p["b_down"]


# --------------------------------------------------------------------------
# parameter init helpers
# --------------------------------------------------------------------------

def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))
