"""JAX model substrate: config-driven transformers (dense/MoE/SSM/hybrid/
enc-dec) with scan-over-layers, serving caches and sharding hooks."""
from .model import ArchConfig, init_params, forward, prefill, decode_step
from .layers import (dense_attention, chunked_attention, decode_attention,
                     apply_rope, rmsnorm, layernorm)
from .ssm import SSMSpec, SSMState, ssd_chunked, ssd_decode_step
from .moe import moe_forward, moe_ref

__all__ = ["ArchConfig", "init_params", "forward", "prefill", "decode_step",
           "dense_attention", "chunked_attention", "decode_attention",
           "apply_rope", "rmsnorm", "layernorm", "SSMSpec", "SSMState",
           "ssd_chunked", "ssd_decode_step", "moe_forward", "moe_ref"]
