"""Sharding rules: map every parameter / activation / cache tensor onto the
production mesh ``("pod", "data", "model")`` (DESIGN.md §5).

Strategy per mode
-----------------
* ``train``  — FSDP×TP: weight matrices sharded over BOTH the data axis
  (ZeRO-style) and the model axis (Megatron TP); batch over pod×data;
  optional sequence-parallel residual stream (seq over "model") which is
  what bounds per-layer activation checkpoints for the d_model≥7k archs.
* ``serve``  — TP only: weights sharded over "model", replicated across
  pod/data; request batch over pod×data; decode KV caches sharded over
  batch AND sequence (seq over "model") so any kv_heads count works — the
  attention reductions over the sharded seq axis lower to small
  all-reduces (flash-decode-style combine) instead of KV all-gathers.

Divisibility guard: a dimension is sharded only when divisible by the axis
size (e.g. whisper's vocab 51865 and mamba2's 50280 are NOT divisible by 16
⇒ vocab replicated for those archs; qwen2-moe's 60 experts are not
divisible ⇒ experts stay unsharded and the EXPERT-INTERNAL ffn dim is TP
sharded instead — "TP-within-expert").
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.model import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    mesh: Mesh
    mode: str = "train"            # train | serve
    sp: bool = True                # sequence-parallel residual (train)
    fsdp: bool = True              # shard params over the data axis (train)
    seq_sharded_kv: bool = True    # serve: shard KV seq over "model"

    @property
    def dp(self) -> tuple:
        axes = tuple(n for n in self.mesh.axis_names if n in ("pod", "data"))
        return axes

    @property
    def tp(self) -> str:
        return "model"

    def axis_size(self, name) -> int:
        if isinstance(name, tuple):
            out = 1
            for n in name:
                out *= self.mesh.shape[n]
            return out
        return self.mesh.shape[name]

    def dp_if(self, dim: int):
        """dp axes when the dim divides the dp extent, else None (small
        batches — e.g. long_500k's batch of 1 — replicate)."""
        return self.dp if dim % self.axis_size(self.dp) == 0 else None


def _div(dim: int, policy: ShardingPolicy, axis) -> bool:
    return dim % policy.axis_size(axis) == 0


def _matrix_spec(policy: ShardingPolicy, rows: int, cols: int,
                 col_is_tp: bool) -> P:
    """Spec for a (rows, cols) weight: TP on one dim, FSDP on the other."""
    tp, dpa = policy.tp, "data"
    tp_dim_ok = _div(cols if col_is_tp else rows, policy, tp)
    if policy.mode == "serve" or not policy.fsdp:
        fs = None
    else:
        fs_dim = rows if col_is_tp else cols
        fs = dpa if _div(fs_dim, policy, dpa) else None
    if col_is_tp:
        return P(fs, tp) if tp_dim_ok else P(fs, None)
    return P(tp, fs) if tp_dim_ok else P(None, fs)


def param_specs(cfg: ArchConfig, policy: ShardingPolicy, params: dict):
    """PartitionSpec pytree mirroring ``init_params`` output.

    Layer params carry a LEADING layer axis (scan stacking) — specs gain a
    ``None`` in front via the path check.
    """
    d = cfg.d_model

    def spec_for(path: tuple, leaf) -> P:
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        stacked = "layers" in names[0] if names else False
        shape = leaf.shape[1:] if stacked else leaf.shape
        s = _leaf_spec(names, shape)
        return P(*((None,) + tuple(s))) if stacked else s

    def _leaf_spec(names, shape) -> P:
        n = names[-1]
        parent = names[-2] if len(names) > 1 else ""
        # --- embeddings / unembedding: (V, d) ---
        if n in ("embed", "lm_head"):
            v_ok = _div(shape[0], policy, policy.tp)
            if policy.mode == "serve" or not policy.fsdp:
                return P(policy.tp if v_ok else None, None)
            d_ok = _div(shape[1], policy, "data")
            return P(policy.tp if v_ok else None, "data" if d_ok else None)
        # --- norms / scalars / small vectors: replicate ---
        if n in ("scale", "bias", "q_norm", "k_norm", "A_log", "D",
                 "dt_bias", "norm_scale", "conv_b"):
            return P(*([None] * len(shape)))
        # --- attention projections ---
        if n in ("wq", "wk", "wv"):
            return _matrix_spec(policy, shape[0], shape[1], col_is_tp=True)
        if n == "wo":
            return _matrix_spec(policy, shape[0], shape[1], col_is_tp=False)
        if n in ("bq", "bk", "bv"):
            return P(policy.tp if _div(shape[0], policy, policy.tp) else None)
        # --- dense MLP ---
        if n in ("w_gate", "w_up") and parent != "moe" and len(shape) == 2:
            return _matrix_spec(policy, shape[0], shape[1], col_is_tp=True)
        if n == "w_down" and len(shape) == 2:
            return _matrix_spec(policy, shape[0], shape[1], col_is_tp=False)
        if n in ("b_up",):
            return P(policy.tp if _div(shape[0], policy, policy.tp) else None)
        if n in ("b_down",):
            return P(None)
        # --- MoE experts: (E, d, f) / (E, f, d) ---
        if len(shape) == 3:
            E = shape[0]
            if _div(E, policy, policy.tp):          # expert parallelism
                return P(policy.tp, None, None)
            # TP-within-expert fallback (e.g. qwen2-moe's 60 experts)
            if n in ("w_gate", "w_up") and _div(shape[2], policy, policy.tp):
                return P(None, None, policy.tp)
            if n == "w_down" and _div(shape[1], policy, policy.tp):
                return P(None, policy.tp, None)
            return P(None, None, None)
        if n == "router":
            return P(None, None)
        # --- SSM ---
        if n == "w_in":
            fs = "data" if (policy.mode == "train" and policy.fsdp
                            and _div(shape[0], policy, "data")) else None
            return P(fs, None)
        if n == "w_out":
            fs = "data" if (policy.mode == "train" and policy.fsdp
                            and _div(shape[1], policy, "data")) else None
            return P(None, fs)
        if n == "conv_w":
            return P(None, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec_for, params)


# --------------------------------------------------------------------------
# activation sharding callback
# --------------------------------------------------------------------------

def make_shard_fn(cfg: ArchConfig, policy: ShardingPolicy):
    """Returns shard_fn(x, kind) applying with_sharding_constraint."""
    dp = policy.dp
    tp = policy.tp
    mesh = policy.mesh

    def spec_of(kind: str, x) -> Optional[P]:
        if kind == "act":                      # (B, S, d) residual stream
            # sequence-parallel residual in BOTH modes: bounds per-layer
            # activation footprint (train remat carries, 32k prefill temps)
            b = policy.dp_if(x.shape[0])
            if policy.sp and x.shape[1] % policy.axis_size(tp) == 0:
                return P(b, tp, None)
            return P(b, None, None)
        if kind == "logits":                   # (B, S, V)
            v_ok = x.shape[-1] % policy.axis_size(tp) == 0
            return P(policy.dp_if(x.shape[0]), None, tp if v_ok else None)
        if kind == "act_decode":               # (B, 1, d)
            return P(policy.dp_if(x.shape[0]), None, None)
        if kind == "logits_decode":            # (B, V)
            v_ok = x.shape[-1] % policy.axis_size(tp) == 0
            return P(policy.dp_if(x.shape[0]), tp if v_ok else None)
        if kind in ("moe_dispatch", "moe_combine"):   # (B, E, C, d)
            e_ok = x.shape[1] % policy.axis_size(tp) == 0
            # EP when E divides the axis — this constraint IS the all-to-all
            return P(policy.dp_if(x.shape[0]), tp if e_ok else None,
                     None, None)
        if kind == "kv_stack":                 # per-layer (B, S, Hkv, hd)
            s_ok = (policy.seq_sharded_kv
                    and x.shape[1] % policy.axis_size(tp) == 0)
            return P(policy.dp_if(x.shape[0]), tp if s_ok else None,
                     None, None)
        if kind == "attn_scores":          # (B, Hkv, G, Sq, Skv)
            q_ok = x.shape[3] % policy.axis_size(tp) == 0
            return P(policy.dp_if(x.shape[0]), None, None,
                     tp if q_ok else None, None)
        if kind == "dec_scores":               # (B, Hkv, G, Skv)
            s_ok = x.shape[-1] % policy.axis_size(tp) == 0
            return P(policy.dp_if(x.shape[0]), None, None,
                     tp if s_ok else None)
        return None

    def shard_fn(x, kind: str):
        s = spec_of(kind, x)
        if s is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))

    return shard_fn


def cache_specs(cfg: ArchConfig, policy: ShardingPolicy, cache: dict):
    """Specs for the serving cache pytree.

    KV: (L, B, S, Hkv, hd) — batch over pod×data; seq over "model" when
    enabled (flash-decode combine; works for ANY kv_heads count including
    chatglm3's kv=2).  SSM state: (L, B, H, P, N) — batch over pod×data,
    heads over "model" when divisible.
    """
    tp = policy.tp
    specs = {}
    for k, v in cache.items():
        if k == "len":
            specs[k] = P(policy.dp_if(v.shape[0]))
        elif k in ("k", "v", "cross_k", "cross_v"):
            b = policy.dp_if(v.shape[1])
            seq_ok = (policy.seq_sharded_kv
                      and v.shape[2] % policy.axis_size(tp) == 0)
            specs[k] = P(None, b, tp if seq_ok else None, None, None)
        elif k == "ssm":
            b = policy.dp_if(v.shape[1])
            h_ok = v.shape[2] % policy.axis_size(tp) == 0
            specs[k] = P(None, b, tp if h_ok else None, None, None)
        elif k == "conv":
            specs[k] = P(None, policy.dp_if(v.shape[1]), None, None)
        else:
            specs[k] = P(*([None] * v.ndim))
    return specs


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))
