from .sharding import (ShardingPolicy, param_specs, make_shard_fn,
                       cache_specs, named)

__all__ = ["ShardingPolicy", "param_specs", "make_shard_fn", "cache_specs",
           "named"]
