"""Windowed cross-replica event loop: batch replica advancement between
cluster-level sync points.

``ClusterSim.run``/``run_stream`` interleave every replica's STEP events
through one global heap — at 10⁵–10⁶ requests the heap traffic and
per-event Python dispatch dominate wall time.  This loop exploits a
structural property of the coloc simulator: **between two cluster-level
sync points, replica step chains commute.**  A STEP on replica *i* reads
and writes only engine *i* (its queue, block manager, prefix cache) and
``states[i]`` — never another replica.  The only events that read global
state are

* **arrivals** — ``_route`` reads every ``InstanceState`` (and, with
  prefix affinity, every engine's cache) to pick a replica; and
* **heartbeats** — refresh every ``states[iid].b_f`` from its engine.

So the loop runs: pop the earliest *global* event; if it is an arrival,
route it exactly as the reference does; otherwise advance **each**
replica's private step chain as far as the next sync barrier
(``min(next arrival, next heartbeat threshold)``), one replica at a time
with no interleaving.  Every step executes at the same simulated time,
on the same engine state, observing the same frontend state as in the
reference interleaving — results are **bitwise identical**, which
tests/test_windowed_sim.py asserts per-request (token timestamps,
finish times, preemption counts) and BENCH_replay_scale.json records as
an equivalence row (docs/ARCHITECTURE.md "Windowed event loop").

Reference semantics replicated exactly:

* **arrival-wins-ties** — an arrival at the same timestamp as a step is
  processed first (``run`` pushes arrivals with the lowest seqs;
  ``run_stream`` takes ``nxt.arrival <= heap[0][0]``).  Here:
  ``t_arr <= t_step`` selects the arrival.
* **heartbeat timing** — the reference fires when a popped event
  satisfies ``now - last_hb >= interval`` and sets ``last_hb = now``
  (the *event's* time, not the threshold).  Here the global next-event
  time is exactly that ``now``, and chains are barriered *below*
  ``last_hb + interval`` so no step can run past an unfired heartbeat.
* **duplicate wake-ups** — dispatch pushes a STEP whenever the engine
  is idle, so an engine can hold several pending wakes; a wake at
  ``t < eng.busy_until`` is stale and skipped.  Per-engine min-heaps
  preserve exactly these semantics (a dict of next-wake times would
  drop the duplicates the reference later consumes).
* **until** — events with ``t > until`` are never executed (the
  reference breaks at the first such global event; since every earlier
  event has already run and later ones never affect requests already
  terminated, skipping them per-chain is equivalent).

Disaggregated mode shares HANDOFF events across tiers (prefill step →
decode arrival), whose tie-breaking depends on global heap sequence
numbers — chains there do NOT commute, so ``pd_mode="disagg"`` (and
kill/scale-up schedules) falls back to the inherited reference loop.

One observable difference, NOT part of the contract: ``on_finished``
callbacks within a window are delivered replica-by-replica rather than
globally time-interleaved.  Every derived metric is fold-order
independent (``StreamingSummary`` percentiles are multiset statistics;
its counters and integer-gain sums are associative), and each finished
``Request`` carries identical timestamps, so only a consumer that
depends on cross-replica callback interleaving could tell — none in
this repo does.
"""
from __future__ import annotations

import heapq
from typing import Optional

from ..core.request import Request
from .vector import VectorClusterSim

_INF = float("inf")


class WindowedClusterSim(VectorClusterSim):
    """``VectorClusterSim`` with the windowed outer loop (coloc traces);
    construction args are identical.  Falls back to the reference loop
    whenever the trace needs cross-replica events (disagg, kills,
    scale-ups), so it is always safe to use as a drop-in."""

    def run(self, requests: list[Request], *,
            until: Optional[float] = None,
            kills=None, scale_ups=None) -> list[Request]:
        if kills or scale_ups or self.ccfg.pd_mode != "coloc":
            return super().run(requests, until=until, kills=kills,
                               scale_ups=scale_ups)
        self._run_windowed(iter(sorted(requests, key=lambda r: r.arrival)),
                           until)
        return requests

    def run_stream(self, request_iter, *, until: Optional[float] = None,
                   on_finished=None) -> int:
        if self.ccfg.pd_mode != "coloc":
            return super().run_stream(request_iter, until=until,
                                      on_finished=on_finished)
        self.on_finished = on_finished
        try:
            return self._run_windowed(iter(request_iter), until)
        finally:
            self.on_finished = None

    # ------------------------------------------------------------------
    def _run_windowed(self, it, until: Optional[float]) -> int:
        hb_iv = self.ccfg.heartbeat_interval
        engines = self.engines
        # iid -> min-heap of pending wake times (see module docstring on
        # why duplicates must be kept, not collapsed)
        wake: dict[int, list[float]] = {}
        nxt = next(it, None)
        n_seen = 0
        last_hb = 0.0
        while True:
            t_arr = nxt.arrival if nxt is not None else _INF
            t_step = _INF
            for h in wake.values():
                if h and h[0] < t_step:
                    t_step = h[0]
            t_ev = t_arr if t_arr <= t_step else t_step
            if t_ev == _INF:
                break
            if until is not None and t_ev > until:
                break
            if t_ev - last_hb >= hb_iv:
                self._heartbeat(t_ev)
                last_hb = t_ev
            if t_arr <= t_step:
                n_seen += 1
                p_iid = self._route(nxt, t_arr)
                if p_iid is not None:
                    eng = engines[p_iid]
                    if eng.idle:
                        h = wake.get(p_iid)
                        if h is None:
                            h = wake[p_iid] = []
                        heapq.heappush(h, max(t_arr, eng.busy_until))
                nxt = next(it, None)
            else:
                # advance all replica chains to the next sync barrier
                barrier = last_hb + hb_iv
                if t_arr < barrier:
                    barrier = t_arr
                for iid, h in wake.items():
                    if h and h[0] < barrier:
                        self._advance_chain(iid, h, barrier, until)
        return n_seen

    def _advance_chain(self, iid: int, h: list[float], barrier: float,
                       until: Optional[float]) -> None:
        """Run replica ``iid``'s private step chain up to (not including)
        ``barrier``.  Commutes with every other replica's chain — see the
        module docstring."""
        eng = self.engines[iid]
        while h and h[0] < barrier:
            if until is not None and h[0] > until:
                return
            t = heapq.heappop(h)
            if not eng.alive or t < eng.busy_until:
                continue           # stale duplicate wake (reference no-op)
            res = eng.step(t)
            if res is None:
                continue
            self._on_step_result(iid, eng, res, None, None)
            heapq.heappush(h, res.end)
