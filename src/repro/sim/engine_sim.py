"""Single-instance engine simulation: iteration-level continuous batching.

One ``EngineSim`` = one serving instance (a TP group of chips).  Each
iteration the configured policy forms a batch (mutating the block manager:
growth/eviction/reload), the analytical executor provides ground-truth
latency, and output tokens are stamped at iteration end — the same
granularity real engines (vLLM/xLLM) schedule at.

Transfer critical-path rules (§4.3):
  * pipelined H2D reloads overlap compute; if the enqueued copies outlast
    the forward, the batch end extends to the copy completion (this is what
    the adaptive copy budget exists to prevent);
  * with synchronous offloading (the "w/o async" ablation) evictions stall
    the engine until the D2H copy drains.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from ..core.batching import (BatchPlan, EngineConfig, SchedView,
                             compute_remaining)
from ..core.blocks import BlockManager
from ..core.estimator import BatchLatencyEstimator
from ..core.prefix import SimPrefixCache
from ..core.request import Phase, Request
from ..core.spec import SIM_TRUE_ACCEPT_RATE, sim_accept_draw
from .executor import AnalyticalExecutor


@dataclass
class StepResult:
    end: float
    plan: BatchPlan
    emitted: list[Request] = field(default_factory=list)
    finished: list[Request] = field(default_factory=list)
    prefill_done: list[Request] = field(default_factory=list)


class DecodeAllPolicy:
    """PD-disaggregation decode instance: batch every ready decode (§4.2).
    Evicted requests whose KV was (partially) dropped are recomputed with
    chunked prefill so preemption on the decode tier cannot strand them."""
    name = "decode_all"

    def form_batch(self, view: SchedView) -> BatchPlan:
        from ..core.schedulers import (_admit_decode, _admit_prefill_chunk,
                                       _finalize)
        plan = BatchPlan()
        protect: set[int] = set()
        stranded = []
        for r in sorted(view.queue, key=lambda r: r.arrival):
            if r.phase == Phase.FINISHED:
                continue
            todo, _ = compute_remaining(r, view.bm)
            if todo == 0 and r.generated > 0:
                _admit_decode(view, r, plan, protect)
            elif todo > 0:
                stranded.append((r, todo))
        for r, todo in stranded:
            _admit_prefill_chunk(view, r, min(todo, view.cfg.chunk_size),
                                 plan, protect)
        return _finalize(view, plan)


class EngineSim:
    def __init__(self, iid: int, policy, executor: AnalyticalExecutor,
                 est: BatchLatencyEstimator, cfg: EngineConfig,
                 bm: Optional[BlockManager] = None,
                 prefix_cache: Optional[SimPrefixCache] = None):
        self.iid = iid
        self.policy = policy
        self.executor = executor
        self.est = est
        self.cfg = cfg
        self.bm = bm or BlockManager(executor.num_blocks,
                                     executor.block_size, executor.t_block,
                                     beta=cfg.beta)
        self.prefix_cache = prefix_cache
        if prefix_cache is not None:
            prefix_cache.bm = self.bm
            self.bm.cache = prefix_cache
        self.queue: list[Request] = []
        self.busy_until = 0.0
        self.idle = True
        self.alive = True
        self.iterations = 0
        self.prefill_tokens = 0    # prompt/recompute tokens actually computed
        self.copy_blocks = 0       # H2D reload blocks consumed (§4.3 lane;
        # the real engine surfaces the same signal via StepEvent.reload_blocks)
        self.batch_log: list[tuple[float, int, float]] = []  # (t, n, latency)
        # speculative decoding mirror (cfg.spec_k > 0): per-entry depth
        # comes from the policy's BatchPlan; acceptance is drawn from the
        # deterministic oracle below (overridable, e.g. perf_smoke pins
        # always-accept to match an equal-params live run) at the fixed
        # workload truth ``spec_true_rate`` — the policy's EWMA then
        # estimates that truth from outcomes, like the live engine
        # estimates draft/target agreement.  Counters use the live
        # EngineStats names so sim<->live parity is dict equality.
        self.spec_accept_fn = sim_accept_draw
        self.spec_true_rate = SIM_TRUE_ACCEPT_RATE
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.spec_rejected = 0
        self.spec_depth_hist: dict[int, int] = {}

    # ------------------------------------------------------------------
    def add_request(self, req: Request, now: float) -> None:
        req.instance = self.iid
        self.queue.append(req)
        if self.prefix_cache is not None:
            hit = self.prefix_cache.match(req, now)
            req.prefilled = hit
            if hit:
                self.bm.attach_cached(req, hit)
                self.prefix_cache.attach(req.rid, req.prefix_group)

    def has_work(self) -> bool:
        return any(r.phase != Phase.FINISHED for r in self.queue)

    def kill(self) -> list[Request]:
        """Instance failure: return unfinished requests for re-dispatch.
        Device state is lost — residency resets (host copies die with the
        host of this instance's node in the worst case, which we assume)."""
        self.alive = False
        orphans = [r for r in self.queue if r.phase != Phase.FINISHED]
        for r in orphans:
            self.bm.release(r)
            r.instance = None
        self.queue.clear()
        return orphans

    # ------------------------------------------------------------------
    def step(self, now: float) -> Optional[StepResult]:
        if not self.alive:
            return None
        self.bm.complete_offloads(now)
        view = SchedView(self.queue, self.bm, self.est, self.cfg, now)
        plan = self.policy.form_batch(view)
        if not plan.entries:
            self.idle = True
            return None
        self.idle = False
        latency = self.executor.batch_latency(plan.work_items())
        if self.cfg.spec_k > 0:
            # verify rows + draft steps ride the same launch: price the
            # per-entry overhead on top of the plain decode batch time
            latency += sum(self.est.spec_overhead(e.l_kv, e.depth)
                           for e in plan.entries if e.depth > 0)
        end = now + latency
        # pipelined reload that outlasts the forward extends the batch
        end = max(end, self.bm.h2d.busy_until)
        # synchronous offload stalls (w/o-async ablation)
        if not self.bm.async_offload and not self.bm.recompute_only:
            end = max(end, self.bm.d2h.busy_until)

        res = StepResult(end=end, plan=plan)
        for e in plan.entries:
            r = e.req
            s = self.bm.state(r)
            if e.is_prefill:
                self.prefill_tokens += e.n_tokens
                # the pass that brings residency to prompt_len produces the
                # first token; recompute passes for resumed decodes emit
                # nothing (their next decode pass does).
                if r.generated == 0 and s.dev_tokens >= r.prompt_len:
                    r.emit_token(end)
                    res.emitted.append(r)
                    res.prefill_done.append(r)
                    if self.prefix_cache is not None:
                        adopted = self.prefix_cache.insert(r, end)
                        if adopted:
                            self.bm.donate_to_cache(r, adopted)
                        self.prefix_cache.shrink_to_capacity()
            else:
                accepted = 0
                if e.depth > 0:
                    accepted = self.spec_accept_fn(
                        r.rid, r.generated, e.depth, self.spec_true_rate)
                    self.policy.spec_accept.update(e.depth, accepted)
                if self.cfg.spec_k > 0:
                    self.spec_proposed += e.depth
                    self.spec_accepted += accepted
                    self.spec_rejected += e.depth - accepted
                    self.spec_depth_hist[e.depth] = \
                        self.spec_depth_hist.get(e.depth, 0) + 1
                r.emit_token(end)
                res.emitted.append(r)
                for _ in range(accepted):
                    # bonus tokens verified this step: same timestamp (one
                    # launch), context advances within the blocks already
                    # reserved (depth was capped to the block remainder)
                    r.emit_token(end)
                s.dev_tokens += accepted
            if r.phase == Phase.FINISHED:
                r.finish_time = end
                self.bm.release(r)
                res.finished.append(r)
        self.queue = [r for r in self.queue if r.phase != Phase.FINISHED]
        self.busy_until = end
        self.iterations += 1
        self.copy_blocks += plan.copy_blocks
        self.batch_log.append((now, len(plan.entries), end - now))
        return res

    # --- PD-disaggregation handoff --------------------------------------
    def export_request(self, req: Request) -> int:
        """Prefill side: release blocks after KV push; returns pushed tokens."""
        s = self.bm.state(req)
        tokens = s.dev_tokens
        self.bm.release(req)
        self.queue = [r for r in self.queue if r.rid != req.rid]
        return tokens

    def import_request(self, req: Request, tokens: int, now: float) -> bool:
        """Decode side: account the pushed KV blocks."""
        req.instance = self.iid
        ok = self.bm.grow(req, tokens, now)
        if not ok:
            # decode pool exhausted: evict per policy to make room
            from ..core.batching import evict_for_space
            view = SchedView(self.queue, self.bm, self.est, self.cfg, now)
            need = self.bm.blocks_needed_for_growth(req, tokens)
            evict_for_space(view, need, {req.rid})
            ok = self.bm.grow(req, tokens, now)
        self.queue.append(req)
        return ok
