"""Vectorized SlideBatching + ClusterSim wrapper for 10⁵–10⁶-request traces.

``SlideBatching.form_batch`` dominates large replays (profiling a 2·10³
request coloc replay puts ~85 % of wall time inside it: per-request metric
dicts, Python ``sorted``, and per-request ``_admit`` calls).  This module
re-implements the hot path with numpy columns while keeping the
``Request`` / ``BlockManager`` objects authoritative — state mutations go
through exactly the same code paths.

EQUIVALENCE CONTRACT (docs/ARCHITECTURE.md "Vectorized simulation"): for
any queue state, ``VectorSlideBatching.form_batch`` produces a bitwise
identical ``BatchPlan`` (same entries in the same order, same chunk sizes,
same evictions, same ``est_time``/``t_budget``/``copy_blocks``) and leaves
the block manager in the same state as ``SlideBatching.form_batch``.
``tests/test_vector_sim.py`` asserts this end-to-end: per-request token
timestamps, finish times and preemption counts must match exactly across
priority mixes, overload, kills and PD disaggregation.

The rules that make the contract hold:

* every vectorized formula keeps the scalar code's floating-point
  expression shape (same association order, e.g. ``a_p*todo*todo`` not
  ``a_p*todo**2``), so IEEE-754 results are identical elementwise;
* reductions that the scalar code performs sequentially use
  ``np.add.accumulate(...)[-1]`` — NOT ``np.sum`` (pairwise) — masked
  contributions enter as ``+0.0`` which is exact for the non-negative
  terms involved;
* ordering uses ``np.lexsort`` which, like ``sorted``, is stable, with the
  same key tuple (starving, urgent, -density | remain, arrival);
* admission walks the sorted order with the same break conditions,
  re-reading LIVE ``ReqBlocks`` state each step so evictions triggered by
  earlier admissions are observed exactly as in the reference loop; any
  case the fast path does not model bit-exactly (host-resident tokens,
  block-pool pressure) falls back to the inherited ``_admit``.
"""
from __future__ import annotations

import numpy as np

from ..core.batching import (BatchEntry, BatchPlan, SchedView,
                             grow_with_eviction, max_chunk_for_budget)
from ..core.request import Phase
from ..core.slidebatching import NORMAL, URGENT, SlideBatching, _Metrics
from .cluster import ClusterSim

# below this queue length the columnar gather costs more than it saves
MIN_VECTOR_QUEUE = 4


class VectorSlideBatching(SlideBatching):
    """Drop-in SlideBatching with a vectorized ``form_batch`` hot path."""

    name = "slidebatching_vec"

    def form_batch(self, view: SchedView) -> BatchPlan:
        if not self.latency_aware_budget:
            # token-budget ablation: cold path, keep the reference loop
            return super().form_batch(view)
        queue = [r for r in view.queue if r.phase != Phase.FINISHED]
        n = len(queue)
        if n < MIN_VECTOR_QUEUE:
            return super().form_batch(view)
        cfg, now, bm, est = view.cfg, view.now, view.bm, view.est

        # ---- columnar gather (objects stay authoritative) ----------------
        # bm.state() (not bm.table[...]) so the setdefault side effect of
        # the scalar path is preserved for fresh requests.
        states = [bm.state(r) for r in queue]
        arrival = np.empty(n)
        weight = np.empty(n)
        ttft = np.empty(n)
        tpot = np.empty(n)
        gen = np.empty(n, np.int64)
        prompt = np.empty(n, np.int64)
        dev = np.empty(n, np.int64)
        host = np.empty(n, np.int64)
        starv = np.empty(n, bool)
        for i, r in enumerate(queue):
            s = states[i]
            arrival[i] = r.arrival
            weight[i] = r.weight
            slo = r.slo
            ttft[i] = slo.ttft
            tpot[i] = slo.tpot
            gen[i] = len(r.out_times)
            prompt[i] = r.prompt_len
            dev[i] = s.dev_tokens
            host[i] = s.host_tokens
            starv[i] = r.starving

        # ---- Alg. 1 lines 1-6: metrics (exec / remain / density) ---------
        needed = prompt + np.maximum(gen - 1, 0)       # needed_context
        resident = dev + host
        todo = np.maximum(needed - resident, 0)        # compute_remaining
        pre_t = est.a_p * todo * todo + est.b_p * todo * resident \
            + est.c_p * todo                           # prefill_time
        dec_t = est.a_d * (needed + 1) + est.b_d       # decode_time(ctx+1)
        t_exec = np.where(todo > 0, pre_t, 0.0) + np.where(gen > 0, dec_t,
                                                           0.0)
        t_exec = np.maximum(t_exec, 1e-9)
        remain = arrival + ttft + gen * tpot - now     # r.remain(now)
        density = np.where(gen == 0, cfg.w_p, cfg.w_d) * weight / t_exec

        # ---- line 7: latency budget --------------------------------------
        pos = remain > 0
        t_min = float(np.min(remain[pos])) if pos.any() else float(
            np.max(tpot))
        t_budget = max(t_min, cfg.eta)

        # ---- lines 8-12: urgency partition (phi, Eq. 8) ------------------
        total_exec = float(np.add.accumulate(t_exec)[-1])
        t_c = est.t_c
        if cfg.pd_mode == "prefill":
            phi = total_exec + n * t_c                 # phi_p
        else:
            phi = (t_budget / max(t_budget - t_c, 1e-9)) * total_exec
        urgent = remain < cfg.gamma * phi
        if not self.use_deadline:
            urgent = np.ones(n, bool)
        if not self.use_density:
            urgent = np.zeros(n, bool)

        # ---- line 13: ordering (starvation promotion + stable sort) ------
        fresh_starv = (~starv) & (gen == 0) & (now - arrival > cfg.tau)
        if fresh_starv.any():
            for i in np.nonzero(fresh_starv)[0]:
                queue[i].starving = True
            starv = starv | fresh_starv
        head = starv | urgent
        k1 = (~starv).astype(np.int64)                 # starving first
        k2 = (~head).astype(np.int64)                  # then urgent
        k3 = np.where(head, -density, remain)          # greedy | EDF
        idx = np.lexsort((arrival, k3, k2, k1))
        order = [queue[i] for i in idx]
        view.queue[:] = order

        # ---- line 14: adaptive copy budget -------------------------------
        if not host.any():
            copy_budget = 0
        else:
            metrics = {r.rid: _Metrics(
                exec=float(t_exec[i]), remain=float(remain[i]),
                density=float(density[i]),
                state=URGENT if urgent[i] else NORMAL)
                for i, r in enumerate(queue)}
            copy_budget = self._copy_budget(view, order, metrics, t_budget)

        # ---- lines 15-23: admission --------------------------------------
        plan = BatchPlan(t_budget=t_budget)
        entries = plan.entries
        t_batch = t_c
        dec_admit = est.a_d * needed + est.b_d         # decode_time(ctx)
        admitted: list[int] = []
        protect: set[int] | None = None                # built lazily
        bs = bm.block_size
        fast_offload = bm.async_offload and not bm.recompute_only
        n_off_map = bm.n_off_by_priority
        n_off_default = max(n_off_map.values())
        lq_col: list[int] = []
        lkv_col: list[int] = []
        isp_col: list[bool] = []
        max_seqs = cfg.max_seqs

        for j in range(n):
            if len(entries) >= max_seqs:
                break
            if t_batch >= t_budget:
                break
            t_left = t_budget - t_batch
            i = int(idx[j])
            r = queue[i]
            s = states[i]
            if s.host_tokens > 0:
                # reload coordination: reference path (consumes copy budget)
                if protect is None:
                    protect = set(admitted)
                entry, t, used_copy = self._admit(view, r, t_left, None, 0,
                                                  copy_budget, protect, plan)
                copy_budget -= used_copy
                plan.copy_blocks += used_copy
                if entry is None:
                    continue
                entries.append(entry)
                protect.add(r.rid)
                admitted.append(r.rid)
                t_batch += t
                lq_col.append(entry.n_tokens)
                lkv_col.append(entry.l_kv)
                isp_col.append(entry.is_prefill)
                continue
            needed_i = int(needed[i])
            dev_now = s.dev_tokens
            if needed_i <= dev_now:                    # todo == 0
                if gen[i] == 0:
                    continue                           # nothing to compute
                # --- decode step (context fully resident) -----------------
                t = float(dec_admit[i])
                depth = 0
                if cfg.spec_k > 0:
                    depth, t = self._assign_depth(view, r, needed_i, t,
                                                  t_left, t_budget)
                if t > t_left and entries:
                    continue
                need_blk = 1 if dev_now % bs == 0 else 0
                if need_blk > bm.free_blocks:
                    if protect is None:
                        protect = set(admitted)
                    if not grow_with_eviction(view, r, 1, protect | {r.rid},
                                              plan.evictions):
                        continue
                else:
                    s.dev_tokens = dev_now + 1
                    bm.used_blocks += need_blk
                    if fast_offload:
                        full = s.dev_tokens // bs
                        if full - s.mirrored_blocks - s.pending_offload >= \
                                n_off_map.get(r.priority, n_off_default):
                            bm._maybe_offload(r, now)
                entries.append(BatchEntry(r, 1, needed_i, False, depth))
                lkv_col.append(needed_i)
                lq_col.append(1)
                isp_col.append(False)
            else:
                # --- (chunked) prefill / recompute ------------------------
                cap = needed_i - dev_now
                chunk, t = max_chunk_for_budget(est, dev_now, t_left, cap)
                if chunk == 0:
                    if entries:
                        continue
                    chunk = min(cap, max(1, cfg.chunk_size))
                    t = est.prefill_time(chunk, dev_now)
                need_blk = (dev_now + chunk + bs - 1) // bs \
                    - (dev_now + bs - 1) // bs
                if need_blk > bm.free_blocks:
                    if protect is None:
                        protect = set(admitted)
                    if not grow_with_eviction(view, r, chunk,
                                              protect | {r.rid},
                                              plan.evictions):
                        continue
                else:
                    s.dev_tokens = dev_now + chunk
                    bm.used_blocks += need_blk
                    if fast_offload:
                        full = s.dev_tokens // bs
                        if full - s.mirrored_blocks - s.pending_offload >= \
                                n_off_map.get(r.priority, n_off_default):
                            bm._maybe_offload(r, now)
                entries.append(BatchEntry(r, chunk, s.dev_tokens - chunk,
                                          True))
                lkv_col.append(s.dev_tokens - chunk)
                lq_col.append(chunk)
                isp_col.append(True)
            admitted.append(r.rid)
            if protect is not None:
                protect.add(r.rid)
            t_batch += t

        plan.est_time = est.batch_time_cols(lq_col, lkv_col, isp_col)
        return plan


def vectorize_policy(policy):
    """Swap a reference ``SlideBatching`` for its vectorized equivalent;
    other policies (baselines, ``DecodeAllPolicy``) pass through unchanged
    — they run the reference code and trivially satisfy the contract."""
    if type(policy) is SlideBatching:
        return VectorSlideBatching(
            use_density=policy.use_density,
            use_deadline=policy.use_deadline,
            latency_aware_budget=policy.latency_aware_budget)
    return policy


class VectorClusterSim(ClusterSim):
    """ClusterSim whose local schedulers are vectorized transparently.

    Construction args are identical to :class:`ClusterSim`; the policy
    factory's products are passed through :func:`vectorize_policy`, so
    ``VectorClusterSim(lambda: make_policy("slidebatching"), ...)`` replays
    a trace with per-request results identical to the reference simulator.
    """

    def __init__(self, make_policy_fn, *args, **kwargs):
        super().__init__(lambda: vectorize_policy(make_policy_fn()),
                         *args, **kwargs)
