"""Analytical TPU-v5e executor: ground-truth batch latency for the cluster
simulator.

The paper measures wall-clock on Ascend-910B NPUs; offline we substitute a
roofline-grounded analytical model of a TPU v5e serving instance (DESIGN.md
§2).  Per batch:

    compute_s = FLOPs / (chips * PEAK * mfu)
    memory_s  = bytes  / (chips * HBM_BW * hbm_eff)
    latency   = max(compute_s, memory_s) + t_launch

FLOPs: linear layers 2*N_active per token + attention 4*L*d*sum(c*(k+c/2)).
Bytes: weights read ONCE per batch (the true nonlinearity the paper's linear
estimator approximates) + per-request KV reads + KV writes.

The schedulers never see this model — they use the fitted linear estimator
(Eq. 4-6), trained on profiled batches generated against this executor, so
estimator error propagates into scheduling realistically.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.estimator import BatchLatencyEstimator, WorkItem

# TPU v5e hardware constants (also used by the roofline analysis)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
HBM_BYTES = 16 * 1024**3     # per chip
HOST_LINK_BW = 32e9          # host<->device (PCIe gen4 x16 class)


@dataclass(frozen=True)
class ModelProfile:
    """Minimal model description for latency modeling."""
    name: str
    n_params: float              # total parameters
    n_active: float              # active per token (MoE: shared + top-k)
    n_layers: int
    d_model: int
    n_kv_heads: int
    head_dim: int
    dtype_bytes: int = 2

    @property
    def kv_bytes_per_token(self) -> float:
        return (2 * self.n_layers * self.n_kv_heads * self.head_dim
                * self.dtype_bytes)


QWEN2_7B = ModelProfile("qwen2-7b", 7.6e9, 7.6e9, 28, 3584, 4, 128)
QWEN3_32B = ModelProfile("qwen3-32b", 32.8e9, 32.8e9, 64, 5120, 8, 128)


@dataclass
class InstanceHardware:
    chips: int = 4               # TP degree of one serving instance
    mfu: float = 0.5             # achieved fraction of peak on prefill
    hbm_eff: float = 0.8         # achieved fraction of HBM bandwidth
    t_launch: float = 3e-3       # per-iteration constant overhead (s)

    @property
    def flops_per_s(self) -> float:
        return self.chips * PEAK_FLOPS * self.mfu

    @property
    def bytes_per_s(self) -> float:
        return self.chips * HBM_BW * self.hbm_eff


class AnalyticalExecutor:
    """Ground-truth batch latency + derived block-pool geometry."""

    def __init__(self, model: ModelProfile, hw: InstanceHardware,
                 block_size: int = 16,
                 kv_memory_fraction: float = 0.35):
        self.model = model
        self.hw = hw
        self.block_size = block_size
        kv_pool_bytes = kv_memory_fraction * hw.chips * HBM_BYTES
        self.num_blocks = int(kv_pool_bytes //
                              (model.kv_bytes_per_token * block_size))
        # host<->device copy time for one KV block
        self.t_block = (model.kv_bytes_per_token * block_size) / HOST_LINK_BW

    # ------------------------------------------------------------------
    def batch_latency(self, items: list[WorkItem]) -> float:
        """items: (l_q, l_kv, is_prefill) per request in the batch."""
        if not items:
            return 0.0
        m = self.model
        if len(items) >= 32:
            # vectorized path, bitwise identical to the loop below: the two
            # per-item flops terms are interleaved into one array so the
            # sequential np.add.accumulate reproduces the loop's exact
            # rounding (np.sum's pairwise reduction would not)
            arr = np.asarray(items, dtype=np.float64)
            l_q, l_kv = arr[:, 0], arr[:, 1]
            terms = np.empty(2 * len(items))
            terms[0::2] = 2.0 * m.n_active * l_q
            terms[1::2] = 4.0 * m.n_layers * m.d_model * l_q \
                * (l_kv + l_q / 2.0)
            flops = float(np.add.accumulate(terms)[-1])
            kv_read = float(np.add.accumulate(
                (l_kv + l_q) * m.kv_bytes_per_token)[-1])
            new_tokens = int(arr[:, 0].astype(np.int64).sum())
        else:
            flops = 0.0
            kv_read = 0.0
            new_tokens = 0
            for l_q, l_kv, is_prefill in items:
                flops += 2.0 * m.n_active * l_q
                flops += 4.0 * m.n_layers * m.d_model * l_q * (l_kv + l_q / 2.0)
                kv_read += (l_kv + l_q) * m.kv_bytes_per_token
                new_tokens += l_q
        weight_read = m.n_params * m.dtype_bytes      # once per batch
        kv_write = new_tokens * m.kv_bytes_per_token
        compute_s = flops / self.hw.flops_per_s
        memory_s = (weight_read + kv_read + kv_write) / self.hw.bytes_per_s
        return max(compute_s, memory_s) + self.hw.t_launch

    # ------------------------------------------------------------------
    def profile_batches(self, rng: np.random.Generator, n: int = 400,
                        max_prefill: int = 4096, max_ctx: int = 16384,
                        noise: float = 0.02,
                        ) -> tuple[list[list[WorkItem]], list[float]]:
        """Offline profiling set for fitting the linear estimator (§4.1)."""
        batches, lats = [], []
        for _ in range(n):
            kind = rng.random()
            items: list[WorkItem] = []
            if kind < 0.4:        # decode-heavy batch
                for _ in range(int(rng.integers(1, 64))):
                    items.append((1, int(rng.integers(16, max_ctx)), False))
            elif kind < 0.7:      # mixed
                for _ in range(int(rng.integers(1, 8))):
                    items.append((int(rng.integers(16, max_prefill // 4)),
                                  int(rng.integers(0, max_ctx // 4)), True))
                for _ in range(int(rng.integers(1, 32))):
                    items.append((1, int(rng.integers(16, max_ctx)), False))
            else:                 # prefill-heavy
                for _ in range(int(rng.integers(1, 4))):
                    items.append((int(rng.integers(64, max_prefill)),
                                  int(rng.integers(0, max_ctx // 2)), True))
            batches.append(items)
            lat = self.batch_latency(items)
            lats.append(lat * (1.0 + noise * rng.standard_normal()))
        return batches, lats

    def fit_estimator(self, seed: int = 0, n: int = 400,
                      ) -> tuple[BatchLatencyEstimator, float]:
        """Fit Eq. 4-6 on profiled batches; returns (estimator, MAPE)."""
        rng = np.random.default_rng(seed)
        batches, lats = self.profile_batches(rng, n=n)
        est = BatchLatencyEstimator.fit(batches, lats)
        hold_b, hold_l = self.profile_batches(
            np.random.default_rng(seed + 1), n=max(64, n // 4))
        return est, est.mape(hold_b, hold_l)
