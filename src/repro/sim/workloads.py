"""Workload generators: synthetic facsimiles of the paper's five datasets.

Real ShareGPT / Azure / BurstGPT / QwenTrace / industrial traces are not
available offline, so each generator reproduces the published *shape* of its
namesake (length distributions, arrival burstiness, priority mix) with a
seeded RNG — see DESIGN.md §7.  All experiments report results on these
facsimiles and validate relative claims.

Priorities follow §5.1: requests are high/low with 50 % probability and
weights (2, 1) by default; the industrial workload uses 3 classes with
phase-shifted diurnal load (Fig. 1) and business-value weights.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from ..core.request import Request, SLO


@dataclass
class WorkloadSpec:
    name: str
    mean_in: float
    mean_out: float
    ttft_slo: float = 2.0        # s
    tpot_slo: float = 0.1        # s
    priorities: tuple = (1, 2)
    weights: tuple = (2.0, 1.0)
    prio_probs: tuple = (0.5, 0.5)
    # optional per-request SLO classes [(ttft, tpot), ...] with probs —
    # heterogeneous-SLO workloads (multi-SLO motivation studies, §3.2)
    slo_classes: Optional[tuple] = None
    slo_probs: Optional[tuple] = None


def _lognormal_lengths(rng, mean, sigma, lo, hi, n):
    mu = math.log(mean) - sigma * sigma / 2.0
    v = np.exp(rng.normal(mu, sigma, size=n))
    return np.clip(v, lo, hi).astype(int)


def _assign_priority(rng, spec: WorkloadSpec, n):
    idx = rng.choice(len(spec.priorities), size=n, p=spec.prio_probs)
    prio = np.array(spec.priorities)[idx]
    wts = np.array(spec.weights)[idx]
    return prio, wts


def _build(arrivals, in_lens, out_lens, prio, wts, spec,
           clients: Optional[np.ndarray] = None,
           rng: Optional[np.random.Generator] = None) -> list[Request]:
    reqs = []
    rng = rng or np.random.default_rng(0)
    for i, t in enumerate(arrivals):
        if spec.slo_classes:
            k = rng.choice(len(spec.slo_classes), p=spec.slo_probs)
            slo = SLO(*spec.slo_classes[k])
        else:
            slo = SLO(spec.ttft_slo, spec.tpot_slo)
        reqs.append(Request(
            prompt_len=int(in_lens[i]), output_len=max(1, int(out_lens[i])),
            arrival=float(t), slo=slo,
            priority=int(prio[i]), weight=float(wts[i]),
            client=int(clients[i]) if clients is not None else int(prio[i])))
    return reqs


# --------------------------------------------------------------------------

def sharegpt(rate: float, duration: float, seed: int = 0,
             spec: Optional[WorkloadSpec] = None) -> list[Request]:
    """ShareGPT-like: conversational, moderate prompts, Poisson arrivals
    (the paper uses Poisson for datasets without timestamps)."""
    spec = spec or WorkloadSpec("sharegpt", mean_in=280, mean_out=230)
    rng = np.random.default_rng(seed)
    n = max(1, int(rate * duration * 1.2))
    gaps = rng.exponential(1.0 / rate, size=n)
    arrivals = np.cumsum(gaps)
    arrivals = arrivals[arrivals < duration]
    n = len(arrivals)
    in_lens = _lognormal_lengths(rng, spec.mean_in, 0.9, 8, 4096, n)
    out_lens = _lognormal_lengths(rng, spec.mean_out, 0.9, 4, 2048, n)
    prio, wts = _assign_priority(rng, spec, n)
    return _build(arrivals, in_lens, out_lens, prio, wts, spec, rng=rng)


def azure(rate: float, duration: float, seed: int = 0,
          spec: Optional[WorkloadSpec] = None) -> list[Request]:
    """Azure-LLM-inference-like: mix of short chat and long code prompts,
    heavier-tailed lengths, timestamps replayed after rate scaling."""
    spec = spec or WorkloadSpec("azure", mean_in=1024, mean_out=190)
    rng = np.random.default_rng(seed)
    n = max(1, int(rate * duration * 1.2))
    # mildly bursty: gamma(k=0.6) inter-arrivals scaled to the target rate
    gaps = rng.gamma(0.6, 1.0 / (0.6 * rate), size=n)
    arrivals = np.cumsum(gaps)
    arrivals = arrivals[arrivals < duration]
    n = len(arrivals)
    is_code = rng.random(n) < 0.4
    in_lens = np.where(is_code,
                       _lognormal_lengths(rng, 2048, 0.8, 64, 8192, n),
                       _lognormal_lengths(rng, 512, 0.9, 8, 4096, n))
    out_lens = np.where(is_code,
                        _lognormal_lengths(rng, 60, 0.8, 4, 512, n),
                        _lognormal_lengths(rng, 280, 0.8, 4, 2048, n))
    prio, wts = _assign_priority(rng, spec, n)
    return _build(arrivals, in_lens, out_lens, prio, wts, spec, rng=rng)


def burstgpt(rate: float, duration: float, seed: int = 0,
             spec: Optional[WorkloadSpec] = None) -> list[Request]:
    """BurstGPT-like: pronounced request bursts (KDD'25 trace character)."""
    spec = spec or WorkloadSpec("burstgpt", mean_in=400, mean_out=250)
    rng = np.random.default_rng(seed)
    arrivals = []
    t = 0.0
    while t < duration:
        burst = rng.random() < 0.15
        k = int(rng.integers(6, 24)) if burst else 1
        for _ in range(k):
            arrivals.append(t + rng.random() * 0.05)
        t += rng.exponential(max(k, 1) / rate)
    arrivals = np.sort(np.array([a for a in arrivals if a < duration]))
    n = len(arrivals)
    in_lens = _lognormal_lengths(rng, spec.mean_in, 1.0, 8, 6144, n)
    out_lens = _lognormal_lengths(rng, spec.mean_out, 0.9, 4, 2048, n)
    prio, wts = _assign_priority(rng, spec, n)
    return _build(arrivals, in_lens, out_lens, prio, wts, spec, rng=rng)


def qwentrace(rate: float, duration: float, seed: int = 0,
              spec: Optional[WorkloadSpec] = None) -> list[Request]:
    """QwenTrace-like: very high request-length variance (the property that
    makes GoRouting shine, §5.2) + prefix-cache-like short-context hits."""
    spec = spec or WorkloadSpec("qwentrace", mean_in=1500, mean_out=300)
    rng = np.random.default_rng(seed)
    n = max(1, int(rate * duration * 1.2))
    gaps = rng.exponential(1.0 / rate, size=n)
    arrivals = np.cumsum(gaps)
    arrivals = arrivals[arrivals < duration]
    n = len(arrivals)
    bucket = rng.choice(3, size=n, p=[0.5, 0.35, 0.15])
    in_lens = np.select(
        [bucket == 0, bucket == 1, bucket == 2],
        [_lognormal_lengths(rng, 180, 0.7, 8, 1024, n),
         _lognormal_lengths(rng, 2200, 0.6, 256, 16384, n),
         _lognormal_lengths(rng, 9000, 0.5, 2048, 32768, n)])
    out_lens = _lognormal_lengths(rng, spec.mean_out, 1.0, 4, 2048, n)
    prio, wts = _assign_priority(rng, spec, n)
    return _build(arrivals, in_lens, out_lens, prio, wts, spec, rng=rng)


def industrial(rate: float, duration: float, seed: int = 0,
               spec: Optional[WorkloadSpec] = None) -> list[Request]:
    """Industrial-like (Fig. 1): three priority classes with distinct,
    phase-shifted diurnal load patterns and business-value weights."""
    spec = spec or WorkloadSpec("industrial", mean_in=600, mean_out=220,
                                priorities=(1, 2, 3), weights=(4.0, 2.0, 1.0),
                                prio_probs=(0.2, 0.35, 0.45))
    rng = np.random.default_rng(seed)
    reqs: list[Request] = []
    period = max(duration, 1e-9)
    # per-class sinusoidal intensity with phase shifts (Fig. 1 shape)
    phases = {1: 0.0, 2: 2.1, 3: 4.2}
    for ci, p in enumerate(spec.priorities):
        lam = rate * spec.prio_probs[ci]
        t = 0.0
        while t < duration:
            intensity = lam * (1.0 + 0.7 * math.sin(
                2 * math.pi * t / period + phases[p]))
            t += rng.exponential(1.0 / max(intensity, 0.05 * lam))
            if t < duration:
                in_len = int(_lognormal_lengths(rng, spec.mean_in, 0.9,
                                                8, 8192, 1)[0])
                out_len = int(_lognormal_lengths(rng, spec.mean_out, 0.9,
                                                 4, 2048, 1)[0])
                reqs.append(Request(
                    prompt_len=in_len, output_len=out_len, arrival=t,
                    slo=SLO(spec.ttft_slo, spec.tpot_slo),
                    priority=p, weight=spec.weights[ci], client=p))
    reqs.sort(key=lambda r: r.arrival)
    return reqs


def shared_prefix(rate: float, duration: float, seed: int = 0,
                  spec: Optional[WorkloadSpec] = None, *,
                  n_groups: int = 4, prefix_len: int = 512,
                  p_shared: float = 0.8,
                  suffix_mean: float = 96.0) -> list[Request]:
    """Shared-system-prompt workload (multi-turn chat / agent loops /
    few-shot templates): a ``p_shared`` fraction of requests draws one of
    ``n_groups`` common system prompts of ``prefix_len`` tokens followed by
    a unique lognormal suffix; the rest are fully unique.  Requests are
    stamped with ``prefix_group`` / ``shared_prefix_len`` so the simulator
    can model cache hits and the trace replayer can synthesize
    byte-identical prefixes for the real radix cache."""
    spec = spec or WorkloadSpec("shared_prefix", mean_in=prefix_len + 96,
                                mean_out=160)
    rng = np.random.default_rng(seed)
    n = max(1, int(rate * duration * 1.2))
    gaps = rng.exponential(1.0 / rate, size=n)
    arrivals = np.cumsum(gaps)
    arrivals = arrivals[arrivals < duration]
    n = len(arrivals)
    shared = rng.random(n) < p_shared
    groups = rng.integers(0, n_groups, size=n)
    suffix = _lognormal_lengths(rng, suffix_mean, 0.8, 8, 2048, n)
    in_lens = np.where(shared, prefix_len + suffix,
                       _lognormal_lengths(rng, spec.mean_in, 0.9, 8, 4096, n))
    out_lens = _lognormal_lengths(rng, spec.mean_out, 0.9, 4, 1024, n)
    prio, wts = _assign_priority(rng, spec, n)
    reqs = _build(arrivals, in_lens, out_lens, prio, wts, spec, rng=rng)
    for i, r in enumerate(reqs):
        if shared[i]:
            r.prefix_group = int(groups[i])
            r.shared_prefix_len = prefix_len
    return reqs


SCALE_SPEC = WorkloadSpec("scale_mix", mean_in=360, mean_out=64,
                          priorities=(1, 2, 3), weights=(4.0, 2.0, 1.0),
                          prio_probs=(0.2, 0.35, 0.45))

_M64 = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    """One splitmix64 step: the standard 64-bit finalizer, used to derive
    statistically independent per-chunk RNG seeds from (seed, chunk)."""
    x = (x + 0x9E3779B97F4A7C15) & _M64
    z = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
    return (z ^ (z >> 31)) & _M64


def _chunk_seed(seed: int, chunk_index: int) -> int:
    return _splitmix64(_splitmix64(seed & _M64) ^ chunk_index)


class _ChunkBufs:
    """Preallocated per-chunk scratch arrays for ``iter_scale_trace``.

    Profiling the 10⁵ replay showed the generator's allocation churn
    (fresh exponential/lognormal/choice arrays every chunk) as a steady
    background cost; these buffers are allocated once and refilled in
    place each chunk (``Generator.random``/``standard_normal`` support
    ``out=``), so steady-state generation allocates only the ``Request``
    objects themselves."""

    __slots__ = ("u", "f", "arrivals", "in_len", "out_len", "prio", "wts")

    def __init__(self, chunk: int):
        self.u = np.empty(chunk)
        self.f = np.empty(chunk)
        self.arrivals = np.empty(chunk)
        self.in_len = np.empty(chunk, np.int64)
        self.out_len = np.empty(chunk, np.int64)
        self.prio = np.empty(chunk, np.int64)
        self.wts = np.empty(chunk)


def _lognormal_into(rng, mean: float, sigma: float, lo: int, hi: int,
                    scratch: np.ndarray, out: np.ndarray, k: int) -> None:
    """In-place ``_lognormal_lengths``: fill ``out[:k]`` reusing
    ``scratch[:k]`` as the float workspace."""
    mu = math.log(mean) - sigma * sigma / 2.0
    s = scratch[:k]
    rng.standard_normal(out=s)
    np.multiply(s, sigma, out=s)
    np.add(s, mu, out=s)
    np.exp(s, out=s)
    np.clip(s, lo, hi, out=s)
    out[:k] = s            # float -> int64 truncation, as .astype(int) did


def iter_scale_trace(n_requests: int, *, rate: float = 200.0, seed: int = 0,
                     spec: Optional[WorkloadSpec] = None, chunk: int = 8192,
                     start_chunk: int = 0):
    """Streaming 10⁵–10⁶-request trace generator (docs/WORKLOADS.md).

    Yields exactly ``n_requests`` 3-priority requests in arrival order
    (lognormal lengths, mean arrival rate ``rate``/s) while holding only
    ``chunk`` requests' worth of RNG output at a time — pair it with
    ``ClusterSim.run_stream`` for constant-memory replay.

    Chunks are INDEPENDENT: chunk ``c`` draws from its own
    ``default_rng(splitmix64(seed, c))`` and covers the fixed trace-time
    span ``[c*chunk/rate, c*chunk/rate + k/rate)`` with ``k`` sorted
    uniform arrivals (the order statistics of a rate-conditioned Poisson
    process), so any consumer — a sharded worker, a partitioned metrics
    test, a resumed generator — can regenerate chunk ``c`` without
    replaying chunks ``0..c-1`` (``start_chunk`` skips straight to it).
    The tuple ``(n_requests, rate, seed, spec, chunk)`` fully determines
    the trace; a different ``chunk`` is a DIFFERENT trace — treat it as
    part of the trace identity.  Scratch buffers are preallocated once
    and reused across chunks (see ``_ChunkBufs``).
    """
    spec = spec or SCALE_SPEC
    bufs = _ChunkBufs(chunk)
    cum_probs = np.cumsum(spec.prio_probs)
    prios = np.asarray(spec.priorities, np.int64)
    weights = np.asarray(spec.weights)
    c = start_chunk
    while c * chunk < n_requests:
        k = min(chunk, n_requests - c * chunk)
        rng = np.random.default_rng(_chunk_seed(seed, c))
        span_start = c * (chunk / rate)
        u = bufs.u[:k]
        rng.random(out=u)
        u.sort()
        arrivals = bufs.arrivals[:k]
        np.multiply(u, k / rate, out=arrivals)
        np.add(arrivals, span_start, out=arrivals)
        _lognormal_into(rng, spec.mean_in, 0.9, 8, 4096,
                        bufs.f, bufs.in_len, k)
        _lognormal_into(rng, spec.mean_out, 0.9, 4, 512,
                        bufs.f, bufs.out_len, k)
        rng.random(out=u)      # arrivals already copied out of bufs.u
        idx = np.searchsorted(cum_probs, u, side="right")
        np.clip(idx, 0, len(prios) - 1, out=idx)
        np.take(prios, idx, out=bufs.prio[:k])
        np.take(weights, idx, out=bufs.wts[:k])
        yield from _build(arrivals, bufs.in_len, bufs.out_len,
                          bufs.prio, bufs.wts, spec, rng=rng)
        c += 1


def scale_mix(rate: float, duration: float, seed: int = 0,
              spec: Optional[WorkloadSpec] = None) -> list[Request]:
    """List-form ``iter_scale_trace`` wrapper taking the same
    ``(rate, duration, seed)`` arguments as the ``WORKLOADS`` generators
    (``n = rate * duration`` requests).

    Count-sized: the last arrivals routinely land past ``duration``
    (Poisson gaps, fixed n), so this is NOT in the ``WORKLOADS``
    registry, whose contract bounds arrivals to ``[0, duration)``.
    Use ``--n-requests`` in the replay CLI instead of ``--workload``.
    """
    n = max(1, int(rate * duration))
    return list(iter_scale_trace(n, rate=rate, seed=seed, spec=spec))


WORKLOADS: dict[str, Callable] = {
    "sharegpt": sharegpt,
    "azure": azure,
    "burstgpt": burstgpt,
    "qwentrace": qwentrace,
    "industrial": industrial,
    "shared_prefix": shared_prefix,
}
