"""Event-driven multi-instance cluster simulation (service layer in the loop).

Supports the paper's two deployment modes:

* **PD co-location** — each instance runs prefill+decode; GoRouting picks
  one instance per request (decode pool = None).
* **PD disaggregation** — prefill instances run the local scheduler
  (SlideBatching with φ_p or a baseline); on prefill completion the request
  and its KV are pushed (xLLM layer-wise push mode — modeled as a small
  handoff delay since the push overlaps prefill) to the chosen decode
  instance, which batches all ready decodes each iteration.

Fault tolerance: instances can be killed at scheduled times; their in-flight
requests are re-dispatched by the router (prefill progress lost — KV dies
with the instance).  Instances can also be added at runtime (elastic scale).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Optional

from ..core.batching import EngineConfig
from ..core.blocks import blocks_for
from ..core.estimator import BatchLatencyEstimator
from ..core.gorouting import InstanceState, QueuedStub, decode_need_blocks
from ..core.request import Phase, Request
from .engine_sim import DecodeAllPolicy, EngineSim
from .executor import AnalyticalExecutor

ARRIVAL, STEP, KILL, SCALE_UP, HANDOFF = 0, 1, 2, 3, 4
HANDOFF_DELAY = 2e-3   # s; layer-wise KV push overlaps prefill (App. B)


@dataclass
class ClusterConfig:
    pd_mode: str = "coloc"           # "coloc" | "disagg"
    n_prefill: int = 4               # instances (coloc: all instances)
    n_decode: int = 0                # disagg only
    heartbeat_interval: float = 0.5  # b_f refresh period (s)
    heartbeat_timeout: float = 2.0   # declare dead after silence (unused in
                                     # sim — kills are explicit — kept for API)
    # per-instance prefix cache (sim model of serving/prefix_cache.py) +
    # prefix-affinity routing.  Only requests stamped with a
    # ``prefix_group`` participate, so workloads without shared prefixes
    # behave identically with this on or off.
    prefix_cache: bool = True
    cache_frac: float = 0.25         # cap: fraction of device blocks
    # tiered KV (sim mirror of serving/kv_pool.KVTierStore): a host-tier
    # budget in blocks.  Evicted request KV beyond it demotes to the int8
    # cold tier (BlockManager.host_budget_blocks), and the prefix cache
    # spills evicted entries into the same-size host tier instead of
    # destroying them (SimPrefixCache spill model).  None = legacy
    # unbounded host mirrors + destroy-on-evict cache.
    host_tier_blocks: Optional[int] = None
    # heterogeneous clusters: per-tier device-block budgets overriding
    # executor.num_blocks (disagg decode replicas often carry more KV
    # memory than prefill replicas).  None = homogeneous.
    prefill_blocks: Optional[int] = None
    decode_blocks: Optional[int] = None
    # bytes per KV block on the handoff wire (live fp32 handoffs move
    # exactly blocks x block_bytes, so setting this to the serving pool's
    # per-block nbytes makes ClusterSim.handoff_bytes match RouterBook's
    # live counter).  0 = don't account bytes.
    handoff_block_bytes: int = 0


class ClusterSim:
    def __init__(self, make_policy_fn, router, executor: AnalyticalExecutor,
                 est: BatchLatencyEstimator, eng_cfg: EngineConfig,
                 cluster_cfg: ClusterConfig, bm_kwargs: Optional[dict] = None):
        self.make_policy_fn = make_policy_fn
        self.router = router
        self.executor = executor
        self.est = est
        self.eng_cfg = eng_cfg
        self.ccfg = cluster_cfg
        self.bm_kwargs = bm_kwargs or {}
        self._iid = itertools.count()
        self.engines: dict[int, EngineSim] = {}
        self.states: dict[int, InstanceState] = {}
        self.decode_engines: dict[int, EngineSim] = {}
        self.decode_states: dict[int, InstanceState] = {}
        self.decode_target: dict[int, int] = {}   # rid -> decode iid (disagg)
        # disagg two-leg accounting, mirroring serving/dispatch.RouterBook:
        # rid -> (decode iid, blocks reserved there at admission)
        self.reservations: dict[int, tuple[int, int]] = {}
        self.reservation_hits = 0
        self.reservation_misses = 0
        self.reserved_blocks_total = 0
        self.adopted_blocks_total = 0
        self.handoffs = 0
        self.handoff_blocks = 0
        self.handoff_bytes = 0
        self.finished: list[Request] = []
        self.dropped: list[Request] = []
        # streaming mode (run_stream): finished requests are handed to this
        # callback instead of accumulating in self.finished
        self.on_finished = None
        for _ in range(cluster_cfg.n_prefill):
            self._new_instance(prefill=True)
        for _ in range(cluster_cfg.n_decode):
            self._new_instance(prefill=False)

    # ------------------------------------------------------------------
    # speculative-decoding counters, aggregated over both tiers so
    # sim.metrics.spec_counters works on a ClusterSim exactly like it
    # does on a single EngineSim or the live EngineStats.
    def _all_engines(self):
        yield from self.engines.values()
        yield from self.decode_engines.values()

    @property
    def spec_proposed(self) -> int:
        return sum(e.spec_proposed for e in self._all_engines())

    @property
    def spec_accepted(self) -> int:
        return sum(e.spec_accepted for e in self._all_engines())

    @property
    def spec_rejected(self) -> int:
        return sum(e.spec_rejected for e in self._all_engines())

    @property
    def spec_depth_hist(self) -> dict:
        hist: dict[int, int] = {}
        for e in self._all_engines():
            for d, n in e.spec_depth_hist.items():
                hist[d] = hist.get(d, 0) + n
        return hist

    # ------------------------------------------------------------------
    def _new_instance(self, prefill: bool) -> int:
        iid = next(self._iid)
        from ..core.blocks import BlockManager
        bmk = dict(self.bm_kwargs)
        if self.ccfg.host_tier_blocks is not None:
            bmk.setdefault("host_budget_blocks", self.ccfg.host_tier_blocks)
        # heterogeneous tiers: each side may override the executor's budget
        n_blocks = self.executor.num_blocks
        if prefill and self.ccfg.prefill_blocks is not None:
            n_blocks = self.ccfg.prefill_blocks
        elif not prefill and self.ccfg.decode_blocks is not None:
            n_blocks = self.ccfg.decode_blocks
        bm = BlockManager(n_blocks, self.executor.block_size,
                          self.executor.t_block, beta=self.eng_cfg.beta,
                          **bmk)
        if prefill:
            cfg = self.eng_cfg
            role = "coloc"
            if self.ccfg.pd_mode == "disagg":
                from dataclasses import replace
                cfg = replace(cfg, pd_mode="prefill")
                role = "prefill"
            cache = None
            if self.ccfg.prefix_cache:
                from ..core.prefix import SimPrefixCache
                cache = SimPrefixCache(
                    self.executor.block_size,
                    max(1, int(n_blocks * self.ccfg.cache_frac)),
                    spill=self.ccfg.host_tier_blocks is not None,
                    host_budget_blocks=self.ccfg.host_tier_blocks)
            eng = EngineSim(iid, self.make_policy_fn(), self.executor,
                            self.est, cfg, bm, prefix_cache=cache)
            self.engines[iid] = eng
            self.states[iid] = InstanceState(
                iid=iid, b_f=bm.num_device_blocks,
                total_blocks=bm.num_device_blocks, role=role)
        else:
            eng = EngineSim(iid, DecodeAllPolicy(), self.executor,
                            self.est, self.eng_cfg, bm)
            self.decode_engines[iid] = eng
            self.decode_states[iid] = InstanceState(
                iid=iid, b_f=bm.num_device_blocks,
                total_blocks=bm.num_device_blocks, role="decode")
        return iid

    # ------------------------------------------------------------------
    def run(self, requests: list[Request], *, until: Optional[float] = None,
            kills: Optional[list[tuple[float, int]]] = None,
            scale_ups: Optional[list[float]] = None) -> list[Request]:
        """Simulate serving ``requests``; returns all requests (terminated)."""
        seq = itertools.count()
        heap: list[tuple[float, int, int, object]] = []
        for r in sorted(requests, key=lambda r: r.arrival):
            heapq.heappush(heap, (r.arrival, next(seq), ARRIVAL, r))
        for t, iid in (kills or []):
            heapq.heappush(heap, (t, next(seq), KILL, iid))
        for t in (scale_ups or []):
            heapq.heappush(heap, (t, next(seq), SCALE_UP, None))
        last_hb = 0.0

        while heap:
            now, _, kind, payload = heapq.heappop(heap)
            if until is not None and now > until:
                break
            # periodic b_f heartbeat (§4.4 monitoring)
            if now - last_hb >= self.ccfg.heartbeat_interval:
                self._heartbeat(now)
                last_hb = now

            if kind == ARRIVAL:
                self._dispatch(payload, now, heap, seq)
            elif kind == STEP:
                self._step(payload, now, heap, seq)
            elif kind == HANDOFF:
                req, d_iid, tokens = payload
                self._arrive_decode(req, d_iid, tokens, now, heap, seq)
            elif kind == KILL:
                self._kill(payload, now, heap, seq)
            elif kind == SCALE_UP:
                iid = self._new_instance(prefill=True)
                if self.ccfg.pd_mode == "disagg":
                    pass  # scale the prefill tier; decode tier static here
        return requests

    # ------------------------------------------------------------------
    def run_stream(self, request_iter, *, until: Optional[float] = None,
                   on_finished=None) -> int:
        """``run`` with O(1)-memory arrivals: requests are pulled lazily
        from ``request_iter`` (MUST be sorted by arrival) and finished
        requests are handed to ``on_finished`` instead of accumulating —
        the 10⁵⁺-request replay entry point.

        Event ordering is identical to ``run``: there, every arrival gets
        a lower heap sequence number than any derived event, so an arrival
        wins any timestamp tie — here the pending arrival is taken while
        ``arrival <= heap[0] time``.  Kills/scale-ups are not supported in
        streaming mode.  Returns the number of requests submitted.
        """
        seq = itertools.count()
        heap: list[tuple[float, int, int, object]] = []
        self.on_finished = on_finished
        it = iter(request_iter)
        nxt = next(it, None)
        n_seen = 0
        last_hb = 0.0
        try:
            while nxt is not None or heap:
                if nxt is not None and (not heap
                                        or nxt.arrival <= heap[0][0]):
                    now, kind, payload = nxt.arrival, ARRIVAL, nxt
                    nxt = next(it, None)
                else:
                    now, _, kind, payload = heapq.heappop(heap)
                if until is not None and now > until:
                    break
                if now - last_hb >= self.ccfg.heartbeat_interval:
                    self._heartbeat(now)
                    last_hb = now
                if kind == ARRIVAL:
                    n_seen += 1
                    self._dispatch(payload, now, heap, seq)
                elif kind == STEP:
                    self._step(payload, now, heap, seq)
                elif kind == HANDOFF:
                    req, d_iid, tokens = payload
                    self._arrive_decode(req, d_iid, tokens, now, heap, seq)
        finally:
            self.on_finished = None
        return n_seen

    # ------------------------------------------------------------------
    def _heartbeat(self, now: float) -> None:
        for iid, eng in self.engines.items():
            self.states[iid].b_f = eng.bm.free_blocks
        for iid, eng in self.decode_engines.items():
            self.decode_states[iid].b_f = eng.bm.free_blocks

    def _release_reservation(self, rid: int) -> None:
        """Void rid's decode reservation (finish/failure/re-dispatch)."""
        res = self.reservations.pop(rid, None)
        if res is None:
            return
        d_iid, need = res
        st = self.decode_states.get(d_iid)
        if st is not None:
            st.unreserve(need)

    def _dispatch(self, req: Request, now: float, heap, seq) -> None:
        p_iid = self._route(req, now)
        if p_iid is None:
            return
        eng = self.engines[p_iid]
        if eng.idle:
            heapq.heappush(heap, (max(now, eng.busy_until), next(seq),
                                  STEP, p_iid))

    def _route(self, req: Request, now: float) -> Optional[int]:
        """Router half of arrival handling: select an instance, update its
        frontend view, reserve decode capacity, enqueue on the engine.
        Returns the chosen prefill iid (None = dropped); the caller owns
        scheduling the engine wake-up, so the windowed loop can reuse
        this without a global heap."""
        # a re-dispatch supersedes any reservation the prior leg held
        self._release_reservation(req.rid)
        pools = list(self.states.values())
        dpool = (list(self.decode_states.values())
                 if self.ccfg.pd_mode == "disagg" else None)
        exec_est = self.est.prefill_time(req.prompt_len)
        # prefix affinity: cached tokens usable by this request, per replica
        affinity = None
        if self.ccfg.prefix_cache and req.prefix_group >= 0:
            affinity = {iid: eng.prefix_cache.peek_tokens(req)
                        for iid, eng in self.engines.items()
                        if eng.prefix_cache is not None} or None
        p_iid, d_iid = self.router.select(
            req, pools, dpool, now,
            block_size=self.executor.block_size, exec_est=exec_est,
            affinity=affinity)
        if p_iid is None:
            self.dropped.append(req)
            return None
        if affinity and affinity.get(p_iid):
            exec_est = self.est.prefill_time_cached(
                req.prompt_len, affinity[p_iid])
        st = self.states[p_iid]
        st.on_dispatch(QueuedStub(req.rid, now, req.priority, req.weight,
                                  req.prompt_len,
                                  req.arrival + req.slo.ttft, exec_est), now)
        if d_iid is not None:
            self.decode_target[req.rid] = d_iid
            # reserve the handoff blocks on the decode target at admission
            # (RouterBook.route parity): never oversubscribe — an
            # unfittable reservation is recorded as a zero-block miss.
            st_d = self.decode_states[d_iid]
            need = decode_need_blocks(req, self.executor.block_size)
            if st_d.reserved_blocks + need > st_d.total_blocks:
                need = 0
            st_d.reserve(need)
            self.reserved_blocks_total += need
            self.reservations[req.rid] = (d_iid, need)
        eng = self.engines[p_iid]
        eng.add_request(req, now)
        return p_iid

    def _engine(self, iid: int) -> Optional[EngineSim]:
        return self.engines.get(iid) or self.decode_engines.get(iid)

    def _step(self, iid: int, now: float, heap, seq) -> None:
        eng = self._engine(iid)
        if eng is None or not eng.alive or now < eng.busy_until:
            return
        res = eng.step(now)
        if res is None:
            return
        self._on_step_result(iid, eng, res, heap, seq)
        heapq.heappush(heap, (res.end, next(seq), STEP, iid))

    def _on_step_result(self, iid: int, eng: EngineSim, res, heap,
                        seq) -> None:
        """Apply one step's outcomes to the frontend view: prefill-done /
        finished notifications, disagg handoffs, reservation release.
        Shared by the reference loop and the windowed loop (which passes
        ``heap=None`` — coloc only, so the disagg branch never fires)."""
        is_prefill_tier = iid in self.engines
        st = (self.states if is_prefill_tier else self.decode_states)[iid]
        for r in res.prefill_done:
            if self.ccfg.pd_mode == "disagg" and is_prefill_tier \
                    and r.phase != Phase.FINISHED:
                # the request leaves at handoff: clear the prefill stub
                # but leave n_d to the decode replica (live parity)
                st.on_prefill_exported(r.rid, res.end)
                self._handoff(r, eng, res.end, heap, seq)
            else:
                st.on_prefill_done(r.rid, res.end)
        for r in res.finished:
            st.on_finished(r.rid)
            self._release_reservation(r.rid)
            if self.on_finished is not None:
                self.on_finished(r)
            else:
                self.finished.append(r)

    def _handoff(self, req: Request, p_eng: EngineSim, now: float,
                 heap, seq) -> None:
        """Prefill finished at ``now``: release prefill-side KV and schedule
        the decode-side arrival after the (mostly overlapped) push delay.
        Importing must NOT happen before ``t_arrive`` or the decode tier
        could emit token 2 before token 1's timestamp."""
        d_iid = self.decode_target.get(req.rid)
        if d_iid is None or d_iid not in self.decode_engines \
                or not self.decode_states[d_iid].alive:
            self._release_reservation(req.rid)
            alive = [s for s in self.decode_states.values() if s.alive]
            if not alive:
                self.dropped.append(req)
                return
            d_iid = max(alive, key=lambda s: s.effective_free).iid
        tokens = p_eng.export_request(req)
        heapq.heappush(heap, (now + HANDOFF_DELAY, next(seq), HANDOFF,
                              (req, d_iid, tokens)))

    def _arrive_decode(self, req: Request, d_iid: int, tokens: int,
                       now: float, heap, seq) -> None:
        d_eng = self.decode_engines.get(d_iid)
        if d_eng is None or not d_eng.alive:
            self._release_reservation(req.rid)
            self.dropped.append(req)
            return
        d_eng.import_request(req, tokens, now)
        # settle the admission-time reservation: a hit iff the payload
        # landed on the reserved target with the promised block count
        # (on_handoff_delivered parity)
        nb = blocks_for(tokens, self.executor.block_size)
        res = self.reservations.pop(req.rid, None)
        if res is not None:
            r_iid, need = res
            st_r = self.decode_states.get(r_iid)
            if st_r is not None:
                st_r.unreserve(need)
            if r_iid == d_iid and need == nb:
                self.reservation_hits += 1
            else:
                self.reservation_misses += 1
        else:
            self.reservation_misses += 1
        self.adopted_blocks_total += nb
        self.handoffs += 1
        self.handoff_blocks += nb
        self.handoff_bytes += nb * self.ccfg.handoff_block_bytes
        self.decode_states[d_iid].n_d += 1
        if d_eng.idle:
            heapq.heappush(heap, (max(now, d_eng.busy_until),
                                  next(seq), STEP, d_iid))

    def _kill(self, iid: int, now: float, heap, seq) -> None:
        eng = self._engine(iid)
        if eng is None:
            return
        orphans = eng.kill()
        if iid in self.states:
            self.states[iid].alive = False
        if iid in self.decode_states:
            self.decode_states[iid].alive = False
            # reservations on a dead decode replica are void (the state
            # is dead, so no unreserve — mirrors RouterBook.drop_instance)
            for rid, (d_iid, _) in list(self.reservations.items()):
                if d_iid == iid:
                    self.reservations.pop(rid, None)
        # failure recovery: re-dispatch from the request log (KV lost)
        for r in orphans:
            self._dispatch(r, now, heap, seq)
