"""Replica sharding across processes: a GoRouting frontend exchanging
per-window dispatch/ack batches with replica shards over pipes.

The windowed loop (sim/windowed.py) removes the per-event global heap
but still routes every arrival against *instantaneous* frontend state,
which serializes routing and stepping in one process.  This module
trades that for throughput the same way the live service does: the
frontend's view of replica progress becomes **stale by up to one
window** (the live ``ServiceFrontend`` already routes on heartbeat-aged
``b_f`` and event logs that arrive after the fact — see
``core/gorouting.py`` ``InstanceState.apply_event``).  The loop:

1. the frontend routes every arrival in the next window ``[t, t+W)``
   against its current (boundary-frozen) ``InstanceState`` view,
   batching the dispatched requests per replica;
2. each shard advances its replicas through the window — arrivals and
   engine steps merged in time order per replica, exactly the windowed
   loop's chain semantics — and acks a column of replica-originated
   events ``(t, iid, kind, rid)`` plus fresh ``b_f``;
3. the frontend applies all shards' acks in deterministic
   ``(t, iid, arrival-order)`` order, refreshes ``b_f``, and opens the
   next window.

Because replicas never interact (coloc), a shard's trajectory depends
only on the dispatch batches it receives — which depend only on the
frontend's view — which is rebuilt from ack columns in an order
independent of how replicas were partitioned.  Hence **any partition of
replicas over workers (including the in-process ``workers=0`` twin)
yields identical per-request results and identical merged metrics**;
tests/test_shard_merge.py asserts this, and BENCH_replay_scale.json
carries a sharded-equivalence row.  Versus the exact (unwindowed)
simulation, window-delayed routing is a bounded *model* divergence —
quantified, not hidden: the bench's sharded rows report aggregate
metric deltas against the exact loop on the same trace.

Prefix-affinity routing reads remote cache state the frontend does not
have under sharding, so the frontend routes without affinity hints
(engine-side caches still hit at admission).  Disagg traces need
cross-shard handoffs and are not supported — use the reference loop.

Workers use the ``fork`` start method (the sim path imports no JAX, so
forking is safe and inherits the cluster factory without pickling);
platforms without ``fork`` get ``workers=0``.
"""
from __future__ import annotations

import heapq
import time
from typing import Iterable, Optional

from ..core.gorouting import EV_FINISHED, EV_PREFILL_DONE, QueuedStub
from ..core.request import Request
from .metrics import StreamingSummary
from .replay import ReplayReport

_INF = float("inf")

# per-engine counters summed into each shard's counter dict; integer,
# so cross-shard merge (plain addition) is exact under any partition
ENGINE_COUNTERS = ("iterations", "prefill_tokens", "copy_blocks",
                   "spec_proposed", "spec_accepted", "spec_rejected")


def merge_counters(into: dict, other: dict) -> dict:
    for k, v in other.items():
        into[k] = into.get(k, 0) + v
    return into


class ReplicaShard:
    """One worker's share of the cluster: a subset of replica engines
    advanced window by window.  Used identically in-process
    (``workers=0``) and inside forked workers, so both modes run the
    same code on identically constructed engines."""

    def __init__(self, cluster, iids: list[int], *, w_p: float, w_d: float,
                 bounded: bool = False, collect: bool = False):
        self.engines = {iid: cluster.engines[iid] for iid in iids}
        self.wake: dict[int, list[float]] = {iid: [] for iid in iids}
        self.summary = StreamingSummary(w_p=w_p, w_d=w_d, bounded=bounded)
        self.collect: Optional[list[Request]] = [] if collect else None

    def advance(self, t_end: float,
                batches: dict[int, list[Request]]) -> tuple:
        """Advance every owned replica through ``[prev t_end, t_end)``:
        the window's dispatched arrivals and the engine's pending wakes
        merged in time order (the windowed loop's chain semantics).
        Returns ``(events, b_f, pending)`` — the ack column."""
        events: list[tuple[float, int, int, int]] = []
        for iid, eng in self.engines.items():
            arr = batches.get(iid, ())
            h = self.wake[iid]
            ai = 0
            while True:
                t_a = arr[ai].arrival if ai < len(arr) else _INF
                t_s = h[0] if h else _INF
                if (t_a if t_a <= t_s else t_s) >= t_end:
                    break
                if t_a <= t_s:                         # arrival wins ties
                    req = arr[ai]
                    ai += 1
                    eng.add_request(req, t_a)
                    if eng.idle:
                        heapq.heappush(h, max(t_a, eng.busy_until))
                    continue
                t = heapq.heappop(h)
                if not eng.alive or t < eng.busy_until:
                    continue                           # stale duplicate wake
                res = eng.step(t)
                if res is None:
                    continue
                for r in res.prefill_done:
                    events.append((res.end, iid, EV_PREFILL_DONE, r.rid))
                for r in res.finished:
                    events.append((res.end, iid, EV_FINISHED, r.rid))
                    self.summary.add(r)
                    if self.collect is not None:
                        self.collect.append(r)
                    else:
                        r.out_times.clear()            # release timestamps
                heapq.heappush(h, res.end)
            # any arrival at t >= t_end would mean the frontend batched
            # it into the wrong window
            assert ai == len(arr), "arrival beyond window end"
        b_f = {iid: eng.bm.free_blocks for iid, eng in self.engines.items()}
        pending = any(self.wake[iid] for iid in self.engines)
        return events, b_f, pending

    def counters(self) -> dict:
        out = {k: 0 for k in ENGINE_COUNTERS}
        for eng in self.engines.values():
            for k in ENGINE_COUNTERS:
                out[k] += int(getattr(eng, k))
        return out


def _shard_worker(conn, cluster_factory, iids, w_p, w_d, bounded, collect):
    """Forked worker loop: build the cluster from the inherited factory
    (identical construction in every process), keep only the owned
    replicas, serve window messages until ``finish``."""
    try:
        cluster = cluster_factory()
        shard = ReplicaShard(cluster, iids, w_p=w_p, w_d=w_d,
                             bounded=bounded, collect=collect)
        while True:
            msg = conn.recv()
            if msg[0] == "window":
                conn.send(("ack",) + shard.advance(msg[1], msg[2]))
            elif msg[0] == "finish":
                conn.send(("done", shard.summary, shard.counters(),
                           shard.collect))
                return
    except Exception:                                  # pragma: no cover
        import traceback
        conn.send(("error", traceback.format_exc()))
    finally:
        conn.close()


class ShardedWindowReplay:
    """Stale-view windowed replay: frontend here, replicas in shards.

    ``cluster_factory`` must build the SAME coloc cluster on every call
    (workers rebuild it post-fork); ``workers=0`` runs one in-process
    shard through the identical code path — the equivalence baseline
    the property tests compare multi-process runs against.
    """

    def __init__(self, cluster_factory, *, workers: int = 0,
                 window: Optional[float] = None,
                 w_p: float = 1.0, w_d: float = 1.0,
                 bounded: bool = False, collect: bool = False,
                 partition: Optional[list[list[int]]] = None):
        self.cluster = cluster_factory()
        if self.cluster.ccfg.pd_mode != "coloc":
            raise ValueError("sharded replay supports coloc clusters only")
        self.factory = cluster_factory
        self.states = self.cluster.states
        self.router = self.cluster.router
        self.est = self.cluster.est
        self.block_size = self.cluster.executor.block_size
        self.window = window or self.cluster.ccfg.heartbeat_interval
        self.w_p, self.w_d = w_p, w_d
        self.bounded, self.collect = bounded, collect
        self.workers = workers
        iids = sorted(self.cluster.engines)
        if partition is None:
            n = max(1, workers)
            partition = [iids[i::n] for i in range(n)]
            partition = [p for p in partition if p]
        self.partition = partition
        self.dropped: list[Request] = []
        self.n_windows = 0

    # ------------------------------------------------------------------
    def _route(self, req: Request, now: float) -> Optional[int]:
        """Stale-view routing: the reference ``ClusterSim._route`` minus
        affinity peeks and engine enqueue (those live replica-side)."""
        exec_est = self.est.prefill_time(req.prompt_len)
        p_iid, _ = self.router.select(
            req, list(self.states.values()), None, now,
            block_size=self.block_size, exec_est=exec_est, affinity=None)
        if p_iid is None:
            self.dropped.append(req)
            return None
        self.states[p_iid].on_dispatch(
            QueuedStub(req.rid, now, req.priority, req.weight,
                       req.prompt_len, req.arrival + req.slo.ttft,
                       exec_est), now)
        return p_iid

    def _apply_acks(self, acks: list[tuple]) -> bool:
        """Fold all shards' ack columns into the frontend view in
        partition-independent order: events sorted by (t, iid) with a
        stable sort (per-replica order is already chronological), then
        the boundary b_f refresh."""
        events: list[tuple[float, int, int, int]] = []
        pending = False
        for ev, b_f, pend in acks:
            events.extend(ev)
            pending = pending or pend
            for iid, b in b_f.items():
                self.states[iid].b_f = b
        events.sort(key=lambda e: (e[0], e[1]))
        for t, iid, kind, rid in events:
            self.states[iid].apply_event(kind, rid, t)
        return pending

    # ------------------------------------------------------------------
    def run_stream(self, request_iter: Iterable[Request]) -> tuple:
        """Replay sorted arrivals; returns ``(n_submitted, summary,
        counters, finished_or_None)`` with per-shard summaries/counters
        merged in shard order."""
        if self.workers > 0:
            return self._run(request_iter, _MPShards(self))
        shard = ReplicaShard(self.cluster, sorted(self.cluster.engines),
                             w_p=self.w_p, w_d=self.w_d,
                             bounded=self.bounded, collect=self.collect)
        return self._run(request_iter, _LocalShards([shard]))

    def _run(self, request_iter, shards) -> tuple:
        W = self.window
        it = iter(request_iter)
        nxt = next(it, None)
        t_end = W
        n_seen = 0
        pending = True
        try:
            while nxt is not None or pending:
                batches: dict[int, list[Request]] = {}
                while nxt is not None and nxt.arrival < t_end:
                    n_seen += 1
                    p_iid = self._route(nxt, nxt.arrival)
                    if p_iid is not None:
                        batches.setdefault(p_iid, []).append(nxt)
                    nxt = next(it, None)
                pending = self._apply_acks(shards.advance(t_end, batches))
                self.n_windows += 1
                t_end += W
            merged, counters, finished = shards.finish()
        finally:
            shards.close()
        return n_seen, merged, counters, finished


class _LocalShards:
    """In-process shard driver (workers=0)."""

    def __init__(self, shards: list[ReplicaShard]):
        self.shards = shards

    def advance(self, t_end, batches):
        return [s.advance(t_end, batches) for s in self.shards]

    def finish(self):
        merged, counters, finished = None, {}, []
        for s in self.shards:
            if merged is None:
                merged = s.summary
            else:
                merged.merge(s.summary)
            merge_counters(counters, s.counters())
            if s.collect is not None:
                finished.extend(s.collect)
        return merged, counters, (finished if finished else None)

    def close(self):
        pass


class _MPShards:
    """Forked-worker shard driver: one process + pipe per partition."""

    def __init__(self, rep: ShardedWindowReplay):
        import multiprocessing as mp
        try:
            ctx = mp.get_context("fork")
        except ValueError as e:                        # pragma: no cover
            raise RuntimeError(
                "sharded replay needs the 'fork' start method; "
                "use workers=0 on this platform") from e
        self.conns, self.procs = [], []
        self.owned = rep.partition
        for iids in rep.partition:
            parent, child = ctx.Pipe()
            p = ctx.Process(target=_shard_worker,
                            args=(child, rep.factory, iids, rep.w_p,
                                  rep.w_d, rep.bounded, rep.collect),
                            daemon=True)
            p.start()
            child.close()
            self.conns.append(parent)
            self.procs.append(p)

    def _recv(self, conn):
        msg = conn.recv()
        if msg[0] == "error":
            raise RuntimeError(f"shard worker failed:\n{msg[1]}")
        return msg[1:]

    def advance(self, t_end, batches):
        # each worker only needs its own replicas' dispatch batches
        for conn, iids in zip(self.conns, self.owned):
            sub = {iid: batches[iid] for iid in iids if iid in batches}
            conn.send(("window", t_end, sub))
        return [self._recv(conn) for conn in self.conns]

    def finish(self):
        for conn in self.conns:
            conn.send(("finish",))
        merged, counters, finished = None, {}, []
        for conn in self.conns:
            summary, cnt, coll = self._recv(conn)
            if merged is None:
                merged = summary
            else:
                merged.merge(summary)
            merge_counters(counters, cnt)
            if coll is not None:
                finished.extend(coll)
        return merged, counters, (finished if finished else None)

    def close(self):
        for conn in self.conns:
            try:
                conn.close()
            except OSError:                            # pragma: no cover
                pass
        for p in self.procs:
            p.join(timeout=30)
            if p.is_alive():                           # pragma: no cover
                p.terminate()


def replay_sim_sharded(cluster_factory, requests: Iterable[Request], *,
                       workers: int = 0, window: Optional[float] = None,
                       w_p: float = 1.0, w_d: float = 1.0,
                       bounded: bool = False, collect: bool = False,
                       partition: Optional[list[list[int]]] = None,
                       ) -> tuple[ReplayReport, dict]:
    """``replay_sim_stream`` over the sharded stale-view loop.

    Returns ``(report, extras)``; ``extras`` holds the merged engine
    counter dict, the window count, and (with ``collect=True``) the
    finished ``Request`` objects for per-request comparisons.  Dropped
    requests fold into the summary at the end, like the unsharded path.
    """
    rep = ShardedWindowReplay(cluster_factory, workers=workers,
                              window=window, w_p=w_p, w_d=w_d,
                              bounded=bounded, collect=collect,
                              partition=partition)
    t0 = time.monotonic()
    n_seen, merged, counters, finished = rep.run_stream(requests)
    wall = time.monotonic() - t0
    done = merged.n
    for r in rep.dropped:
        merged.add(r)
    report = ReplayReport(summary=merged.summary(), n_submitted=n_seen,
                          n_completed=done, n_rejected=len(rep.dropped),
                          wall=wall, speed=float("inf"))
    extras = {"counters": counters, "windows": rep.n_windows,
              "workers": rep.workers, "window_s": rep.window,
              "finished": finished}
    return report, extras
