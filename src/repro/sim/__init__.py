"""Discrete-event cluster simulator calibrated by the analytical TPU-v5e
executor — the substrate for all paper-scale experiments (DESIGN.md §2)."""
from .executor import (AnalyticalExecutor, InstanceHardware, ModelProfile,
                       QWEN2_7B, QWEN3_32B, PEAK_FLOPS, HBM_BW, ICI_BW,
                       HBM_BYTES, HOST_LINK_BW)
from .engine_sim import DecodeAllPolicy, EngineSim, StepResult
from .cluster import ClusterConfig, ClusterSim, HANDOFF_DELAY
from .workloads import WORKLOADS, WorkloadSpec
from .metrics import Summary, summarize, gain_timeline, urgent_timeout_timeline
from .replay import (ReplayReport, clip_lengths, replay_frontend,
                     replay_sim, synth_prompt)

__all__ = [
    "AnalyticalExecutor", "InstanceHardware", "ModelProfile", "QWEN2_7B",
    "QWEN3_32B", "PEAK_FLOPS", "HBM_BW", "ICI_BW", "HBM_BYTES",
    "HOST_LINK_BW", "DecodeAllPolicy", "EngineSim", "StepResult",
    "ClusterConfig", "ClusterSim", "HANDOFF_DELAY", "WORKLOADS",
    "WorkloadSpec", "Summary", "summarize", "gain_timeline",
    "urgent_timeout_timeline", "ReplayReport", "clip_lengths",
    "replay_frontend", "replay_sim", "synth_prompt",
]
