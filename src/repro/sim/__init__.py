"""Discrete-event cluster simulator calibrated by the analytical TPU-v5e
executor — the substrate for all paper-scale experiments (DESIGN.md §2)."""
from .executor import (AnalyticalExecutor, InstanceHardware, ModelProfile,
                       QWEN2_7B, QWEN3_32B, PEAK_FLOPS, HBM_BW, ICI_BW,
                       HBM_BYTES, HOST_LINK_BW)
from .engine_sim import DecodeAllPolicy, EngineSim, StepResult
from .cluster import ClusterConfig, ClusterSim, HANDOFF_DELAY
from .vector import VectorClusterSim, VectorSlideBatching, vectorize_policy
from .windowed import WindowedClusterSim
from .shard import (ReplicaShard, ShardedWindowReplay, merge_counters,
                    replay_sim_sharded)
from .workloads import (WORKLOADS, WorkloadSpec, SCALE_SPEC,
                        iter_scale_trace, scale_mix)
from .metrics import (DISAGG_COUNTERS, SPEC_COUNTERS, StreamingSummary,
                      Summary, disagg_counters, spec_counters, summarize,
                      gain_timeline, urgent_timeout_timeline)
from .replay import (ReplayReport, clip_lengths, replay_frontend,
                     replay_sim, replay_sim_stream, synth_prompt)

__all__ = [
    "AnalyticalExecutor", "InstanceHardware", "ModelProfile", "QWEN2_7B",
    "QWEN3_32B", "PEAK_FLOPS", "HBM_BW", "ICI_BW", "HBM_BYTES",
    "HOST_LINK_BW", "DecodeAllPolicy", "EngineSim", "StepResult",
    "ClusterConfig", "ClusterSim", "HANDOFF_DELAY", "VectorClusterSim",
    "VectorSlideBatching", "vectorize_policy", "WindowedClusterSim",
    "ReplicaShard", "ShardedWindowReplay", "merge_counters",
    "replay_sim_sharded", "WORKLOADS", "WorkloadSpec",
    "SCALE_SPEC", "iter_scale_trace", "scale_mix", "DISAGG_COUNTERS",
    "SPEC_COUNTERS", "StreamingSummary", "Summary", "disagg_counters",
    "spec_counters", "summarize", "gain_timeline",
    "urgent_timeout_timeline",
    "ReplayReport", "clip_lengths", "replay_frontend", "replay_sim",
    "replay_sim_stream", "synth_prompt",
]
