"""Trace-replay load generator: replay any ``sim/workloads.py`` spec in
real or scaled time against the async serving front-end, or in simulated
time against the cluster simulator — reporting the same per-priority
gain / SLO-attainment metrics either way.

This is the bridge between the paper-scale discrete-event experiments and
the real JAX engine: the identical request trace (arrivals, lengths,
priorities, SLOs) can be pushed through ``ClusterSim`` (instant, analytic)
and through ``ServiceFrontend`` (wall clock, real continuous batching,
client-edge latency), and the two ``ReplayReport``s compared row-for-row.

CLI (see docs/WORKLOADS.md for the full schema and report columns):

    PYTHONPATH=src python -m repro.sim.replay --workload shared_prefix \\
        --mode sim --rate 40 --duration 6
    PYTHONPATH=src python -m repro.sim.replay --workload industrial \\
        --mode frontend --speed 200 --replicas 2
"""
from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass
from typing import Iterable, Optional

import numpy as np

from ..core.request import Request, SLO
from .metrics import StreamingSummary, Summary, summarize


@dataclass
class ReplayReport:
    summary: Summary            # client-edge (frontend) or sim-time metrics
    n_submitted: int
    n_completed: int
    n_rejected: int
    wall: float                 # wall-clock seconds the replay took
    speed: float                # trace-time compression factor

    def row(self) -> dict:
        d = {"submitted": self.n_submitted, "completed": self.n_completed,
             "rejected": self.n_rejected, "wall_s": round(self.wall, 3),
             "speed": self.speed}
        d.update(self.summary.row())
        return d

    @property
    def per_priority(self) -> dict:
        return self.summary.per_priority


def clip_lengths(requests: Iterable[Request], *, max_in: int = 64,
                 max_out: int = 8, slo: Optional[SLO] = None,
                 ) -> list[Request]:
    """Shrink a paper-scale trace to something a tiny smoke model can chew
    in seconds, preserving arrivals / priorities / weights / clients and
    the shared-prefix identity (the shared span clips with the prompt)."""
    out = []
    for r in requests:
        prompt_len = min(r.prompt_len, max_in)
        out.append(Request(
            prompt_len=prompt_len,
            output_len=max(1, min(r.output_len, max_out)),
            arrival=r.arrival, slo=slo or r.slo,
            priority=r.priority, weight=r.weight, client=r.client,
            prefix_group=r.prefix_group,
            shared_prefix_len=min(r.shared_prefix_len, prompt_len)))
    return out


def synth_prompt(req: Request, vocab: int, rng: np.random.Generator,
                 seed: int = 0) -> np.ndarray:
    """Token content for a trace request: requests in the same
    ``prefix_group`` get byte-identical shared prefixes (deterministic in
    ``seed``+group), so the engine-side radix cache sees real shared
    content; the suffix is unique per request."""
    n_pre = min(req.shared_prefix_len, req.prompt_len) \
        if req.prefix_group >= 0 else 0
    parts = []
    if n_pre > 0:
        g = np.random.default_rng([seed, req.prefix_group])
        parts.append(g.integers(1, vocab, n_pre))
    if req.prompt_len - n_pre > 0:
        parts.append(rng.integers(1, vocab, req.prompt_len - n_pre))
    return np.concatenate(parts).astype(np.int32)


def smoke_frontend(replicas: int = 2, *, prefix_cache: bool = True,
                   router: str = "gorouting", sched: str = "slidebatching",
                   w_p: float = 4.0, max_inflight: int = 4096,
                   packed_prefill: bool = True,
                   overlap_transfers: bool = True):
    """The smoke-scale live serving stack (tiny model, refcounted paged KV,
    radix prefix cache) shared by ``examples/shared_prefix.py``, the
    ``replay_shared_prefix`` benchmark and the CLI below — one definition,
    so all three measure the same configuration.  Imports JAX lazily;
    returns ``(frontend, model_cfg)``."""
    import jax

    from ..configs import get_smoke
    from ..core import (BatchLatencyEstimator, EngineConfig, GoRouting,
                        MinLoad, RoundRobin, RouterConfig, make_policy)
    from ..models import init_params
    from ..serving import Engine, FrontendConfig, ServiceFrontend

    cfg = get_smoke("qwen1_5_0_5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    est = BatchLatencyEstimator(a_p=1e-8, b_p=1e-8, c_p=1e-4, a_d=1e-8,
                                b_d=1e-3, t_c=1e-2)
    make_router = {"gorouting": lambda: GoRouting(
                       est, RouterConfig(pd_mode="coloc")),
                   "min_load": lambda: MinLoad(est),
                   "round_robin": lambda: RoundRobin()}[router]
    fe = ServiceFrontend(make_router(), est,
                         FrontendConfig(max_inflight=max_inflight))
    for _ in range(replicas):
        fe.add_instance(Engine(
            cfg, params, EngineConfig(eta=1.0, w_p=w_p, tau=1e9),
            make_policy(sched), num_blocks=192, block_size=16,
            max_ctx=256, prefix_cache=prefix_cache,
            packed_prefill=packed_prefill,
            overlap_transfers=overlap_transfers))
    return fe, cfg


def smoke_shared_prefix_trace(n: int, max_out: int = 2) -> list[Request]:
    """The canonical smoke-scale shared-prefix trace: 80% of ``n`` streams
    share one of 2 system prompts (32 tokens = 2 KV blocks), clipped to
    smoke-model lengths."""
    from .workloads import shared_prefix
    trace = shared_prefix(rate=n / 2.0, duration=8.0, seed=3, n_groups=2,
                          prefix_len=32, p_shared=0.8)[:n]
    return clip_lengths(trace, max_in=48, max_out=max_out,
                        slo=SLO(ttft=90.0, tpot=15.0))


async def replay_frontend(frontend, requests: Iterable[Request], vocab: int,
                          *, speed: float = 1.0, seed: int = 0,
                          wait: bool = False, slo_scale: float = 1.0,
                          w_p: float = 1.0, w_d: float = 1.0,
                          ) -> ReplayReport:
    """Replay ``requests`` against a started :class:`ServiceFrontend`.

    Arrivals are honoured in wall time compressed by ``speed`` (2.0 = twice
    as fast as the trace).  Each submitted request is consumed by its own
    task so thousands of streams run concurrently; admission rejections
    (``wait=False``) are counted, ``wait=True`` applies backpressure
    instead.  Metrics are CLIENT-EDGE: stamped where the consumer receives
    each token, summarised with ``sim.metrics.summarize``.
    """
    from ..serving.frontend import AdmissionError     # lazy: pulls in jax

    rng = np.random.default_rng(seed)
    reqs = sorted(requests, key=lambda r: r.arrival)
    streams: list = []
    consumers: list[asyncio.Task] = []
    rejected = 0
    t0 = time.monotonic()
    for src in reqs:
        target = t0 + src.arrival / max(speed, 1e-9)
        delay = target - time.monotonic()
        if delay > 0:
            await asyncio.sleep(delay)
        req = Request(
            prompt_len=src.prompt_len, output_len=src.output_len,
            arrival=0.0,
            slo=SLO(src.slo.ttft * slo_scale, src.slo.tpot * slo_scale),
            priority=src.priority, weight=src.weight, client=src.client,
            prefix_group=src.prefix_group,
            shared_prefix_len=src.shared_prefix_len)
        prompt = synth_prompt(src, vocab, rng, seed=seed)
        try:
            stream = await frontend.submit(req, prompt, wait=wait)
        except AdmissionError:
            rejected += 1
            continue
        streams.append(stream)
        consumers.append(asyncio.ensure_future(stream.collect()))
    if consumers:
        await asyncio.gather(*consumers, return_exceptions=True)
    wall = time.monotonic() - t0
    clones = [s.as_request() for s in streams]
    return ReplayReport(
        summary=summarize(clones, w_p=w_p, w_d=w_d),
        n_submitted=len(streams),
        n_completed=sum(1 for s in streams if s.complete),
        n_rejected=rejected, wall=wall, speed=speed)


def replay_sim(cluster, requests: list[Request], *, w_p: float = 1.0,
               w_d: float = 1.0) -> ReplayReport:
    """Replay the same trace through a ``ClusterSim`` (simulated time)."""
    t0 = time.monotonic()
    cluster.run(requests)
    wall = time.monotonic() - t0
    done = sum(1 for r in requests if r.finish_time is not None)
    return ReplayReport(
        summary=summarize(requests, w_p=w_p, w_d=w_d),
        n_submitted=len(requests), n_completed=done,
        n_rejected=len(cluster.dropped), wall=wall, speed=float("inf"))


def replay_sim_stream(cluster, requests: Iterable[Request], *,
                      w_p: float = 1.0, w_d: float = 1.0,
                      release: bool = True,
                      bounded: bool = False) -> ReplayReport:
    """``replay_sim`` at 10⁵⁺-request scale: arrivals stream from an
    iterator (sorted by arrival — e.g. ``workloads.iter_scale_trace``) and
    metrics fold incrementally as requests finish, so neither the trace
    nor per-request metric lists are ever fully resident.  With
    ``release`` each finished request's token-timestamp list is freed
    after folding; ``bounded`` swaps exact percentile buffers for the
    bounded-memory sketch (10⁶ scale).  Dropped (router-rejected)
    requests fold in at the end, exactly as ``summarize`` counts them in
    the list path."""
    agg = StreamingSummary(w_p=w_p, w_d=w_d, bounded=bounded)

    def fold(r: Request) -> None:
        agg.add(r)
        if release:
            r.out_times.clear()

    t0 = time.monotonic()
    n = cluster.run_stream(requests, on_finished=fold)
    wall = time.monotonic() - t0
    done = agg.n
    for r in cluster.dropped:
        agg.add(r)
    return ReplayReport(
        summary=agg.summary(), n_submitted=n, n_completed=done,
        n_rejected=len(cluster.dropped), wall=wall, speed=float("inf"))


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def _main(argv: Optional[list] = None) -> None:
    import argparse
    import json
    import math

    from .workloads import WORKLOADS

    ap = argparse.ArgumentParser(
        prog="python -m repro.sim.replay",
        description="Replay a workload trace in simulated time (ClusterSim)"
                    " or scaled wall-clock time (async ServiceFrontend over"
                    " real smoke-scale JAX engines).")
    ap.add_argument("--workload", choices=sorted(WORKLOADS),
                    default="sharegpt")
    ap.add_argument("--mode", choices=["sim", "frontend"], default="sim")
    ap.add_argument("--rate", type=float, default=40.0,
                    help="arrivals per second of trace time")
    ap.add_argument("--duration", type=float, default=6.0,
                    help="trace length in seconds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--router", default="gorouting",
                    choices=["gorouting", "min_load", "round_robin"])
    ap.add_argument("--sched", default="slidebatching")
    ap.add_argument("--w-p", type=float, default=4.0,
                    help="first-token gain weight")
    ap.add_argument("--no-prefix-cache", action="store_true")
    ap.add_argument("--n-requests", type=int, default=None,
                    help="sim mode: generate exactly N requests at --rate "
                         "via the streaming scale generator "
                         "(iter_scale_trace; --workload/--duration ignored)")
    ap.add_argument("--stream", action="store_true",
                    help="sim mode: constant-memory streaming replay "
                         "(arrivals from an iterator, metrics folded "
                         "incrementally; required for 10⁵⁺ requests)")
    ap.add_argument("--vector", action="store_true",
                    help="sim mode: vectorized scheduler hot path "
                         "(VectorClusterSim — identical per-request "
                         "results, minutes instead of hours at scale)")
    ap.add_argument("--windowed", action="store_true",
                    help="sim mode: windowed cross-replica event loop "
                         "(WindowedClusterSim — bitwise-identical "
                         "results, no global event heap)")
    ap.add_argument("--workers", type=int, default=0,
                    help="sim mode: shard replicas over N forked worker "
                         "processes (stale-view window routing; 0 = "
                         "in-process twin of the same loop)")
    ap.add_argument("--window", type=float, default=None,
                    help="sharded mode window length in trace seconds "
                         "(default: the cluster heartbeat interval)")
    ap.add_argument("--bounded-metrics", action="store_true",
                    help="bounded-memory percentile sketches "
                         "(StreamingSummary(bounded=True); needed at "
                         "10⁶ scale)")
    ap.add_argument("--speed", type=float, default=200.0,
                    help="frontend mode: trace-time compression (200 = "
                         "replay 200x faster than the trace)")
    ap.add_argument("--max-in", type=int, default=48,
                    help="frontend mode: clip prompts to smoke-model size")
    ap.add_argument("--max-out", type=int, default=4,
                    help="frontend mode: clip outputs")
    args = ap.parse_args(argv)

    from ..core import (EngineConfig, GoRouting, MinLoad, RoundRobin,
                        RouterConfig, make_policy)

    if args.n_requests is not None and args.mode == "sim":
        from .workloads import iter_scale_trace
        reqs = iter_scale_trace(args.n_requests, rate=args.rate,
                                seed=args.seed)
    else:
        reqs = WORKLOADS[args.workload](rate=args.rate,
                                        duration=args.duration,
                                        seed=args.seed)
    if args.mode == "sim":
        from .cluster import ClusterConfig, ClusterSim
        from .executor import (AnalyticalExecutor, InstanceHardware,
                               QWEN2_7B)
        from .vector import VectorClusterSim
        from .windowed import WindowedClusterSim
        ex = AnalyticalExecutor(QWEN2_7B, InstanceHardware(chips=4))
        est, _ = ex.fit_estimator(n=200)

        def make_router():
            return {"gorouting": lambda: GoRouting(
                        est, RouterConfig(pd_mode="coloc")),
                    "min_load": lambda: MinLoad(est),
                    "round_robin": lambda: RoundRobin()}[args.router]()

        sim_cls = (WindowedClusterSim if (args.windowed or args.workers)
                   else VectorClusterSim if args.vector else ClusterSim)
        ccfg = ClusterConfig(pd_mode="coloc", n_prefill=args.replicas,
                             prefix_cache=not args.no_prefix_cache)

        def factory():
            return sim_cls(lambda: make_policy(args.sched), make_router(),
                           ex, est, EngineConfig(w_p=args.w_p), ccfg)

        if args.workers:
            from .shard import replay_sim_sharded
            rep, extras = replay_sim_sharded(
                factory, reqs, workers=args.workers, window=args.window,
                w_p=args.w_p, bounded=args.bounded_metrics)
            extra = {"prefill_tokens": extras["counters"]["prefill_tokens"],
                     "windows": extras["windows"],
                     "workers": extras["workers"]}
        else:
            cs = factory()
            if args.stream:
                rep = replay_sim_stream(cs, reqs, w_p=args.w_p)
            else:
                rep = replay_sim(cs, list(reqs), w_p=args.w_p)
            extra = {"prefill_tokens": sum(e.prefill_tokens
                                           for e in cs.engines.values())}
    else:
        fe, cfg = smoke_frontend(args.replicas,
                                 prefix_cache=not args.no_prefix_cache,
                                 router=args.router, sched=args.sched,
                                 w_p=args.w_p)
        trace = clip_lengths(reqs, max_in=args.max_in, max_out=args.max_out,
                             slo=SLO(ttft=90.0, tpot=15.0))

        async def go():
            await fe.start()
            rep = await replay_frontend(fe, trace, cfg.vocab,
                                        speed=args.speed, w_p=args.w_p)
            await fe.stop()
            return rep

        rep = asyncio.run(go())
        engines = list(fe.engines.values())
        extra = {"prefill_tokens": sum(e.stats.prefill_tokens
                                       for e in engines),
                 "cache_hit_tokens": sum(e.stats.cache_hit_tokens
                                         for e in engines)}
    row = {k: (None if isinstance(v, float) and not math.isfinite(v) else v)
           for k, v in {**rep.row(), **extra}.items()}  # inf -> valid JSON
    print(json.dumps(row, indent=1))


if __name__ == "__main__":
    _main()
