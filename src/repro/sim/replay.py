"""Trace-replay load generator: replay any ``sim/workloads.py`` spec in
real or scaled time against the async serving front-end, or in simulated
time against the cluster simulator — reporting the same per-priority
gain / SLO-attainment metrics either way.

This is the bridge between the paper-scale discrete-event experiments and
the real JAX engine: the identical request trace (arrivals, lengths,
priorities, SLOs) can be pushed through ``ClusterSim`` (instant, analytic)
and through ``ServiceFrontend`` (wall clock, real continuous batching,
client-edge latency), and the two ``ReplayReport``s compared row-for-row.
"""
from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass
from typing import Iterable, Optional

import numpy as np

from ..core.request import Request, SLO
from .metrics import Summary, summarize


@dataclass
class ReplayReport:
    summary: Summary            # client-edge (frontend) or sim-time metrics
    n_submitted: int
    n_completed: int
    n_rejected: int
    wall: float                 # wall-clock seconds the replay took
    speed: float                # trace-time compression factor

    def row(self) -> dict:
        d = {"submitted": self.n_submitted, "completed": self.n_completed,
             "rejected": self.n_rejected, "wall_s": round(self.wall, 3),
             "speed": self.speed}
        d.update(self.summary.row())
        return d

    @property
    def per_priority(self) -> dict:
        return self.summary.per_priority


def clip_lengths(requests: Iterable[Request], *, max_in: int = 64,
                 max_out: int = 8, slo: Optional[SLO] = None,
                 ) -> list[Request]:
    """Shrink a paper-scale trace to something a tiny smoke model can chew
    in seconds, preserving arrivals / priorities / weights / clients."""
    out = []
    for r in requests:
        out.append(Request(
            prompt_len=min(r.prompt_len, max_in),
            output_len=max(1, min(r.output_len, max_out)),
            arrival=r.arrival, slo=slo or r.slo,
            priority=r.priority, weight=r.weight, client=r.client))
    return out


async def replay_frontend(frontend, requests: Iterable[Request], vocab: int,
                          *, speed: float = 1.0, seed: int = 0,
                          wait: bool = False, slo_scale: float = 1.0,
                          w_p: float = 1.0, w_d: float = 1.0,
                          ) -> ReplayReport:
    """Replay ``requests`` against a started :class:`ServiceFrontend`.

    Arrivals are honoured in wall time compressed by ``speed`` (2.0 = twice
    as fast as the trace).  Each submitted request is consumed by its own
    task so thousands of streams run concurrently; admission rejections
    (``wait=False``) are counted, ``wait=True`` applies backpressure
    instead.  Metrics are CLIENT-EDGE: stamped where the consumer receives
    each token, summarised with ``sim.metrics.summarize``.
    """
    from ..serving.frontend import AdmissionError     # lazy: pulls in jax

    rng = np.random.default_rng(seed)
    reqs = sorted(requests, key=lambda r: r.arrival)
    streams: list = []
    consumers: list[asyncio.Task] = []
    rejected = 0
    t0 = time.monotonic()
    for src in reqs:
        target = t0 + src.arrival / max(speed, 1e-9)
        delay = target - time.monotonic()
        if delay > 0:
            await asyncio.sleep(delay)
        req = Request(
            prompt_len=src.prompt_len, output_len=src.output_len,
            arrival=0.0,
            slo=SLO(src.slo.ttft * slo_scale, src.slo.tpot * slo_scale),
            priority=src.priority, weight=src.weight, client=src.client)
        prompt = rng.integers(1, vocab, src.prompt_len).astype(np.int32)
        try:
            stream = await frontend.submit(req, prompt, wait=wait)
        except AdmissionError:
            rejected += 1
            continue
        streams.append(stream)
        consumers.append(asyncio.ensure_future(stream.collect()))
    if consumers:
        await asyncio.gather(*consumers, return_exceptions=True)
    wall = time.monotonic() - t0
    clones = [s.as_request() for s in streams]
    return ReplayReport(
        summary=summarize(clones, w_p=w_p, w_d=w_d),
        n_submitted=len(streams),
        n_completed=sum(1 for s in streams if s.complete),
        n_rejected=rejected, wall=wall, speed=speed)


def replay_sim(cluster, requests: list[Request], *, w_p: float = 1.0,
               w_d: float = 1.0) -> ReplayReport:
    """Replay the same trace through a ``ClusterSim`` (simulated time)."""
    t0 = time.monotonic()
    cluster.run(requests)
    wall = time.monotonic() - t0
    done = sum(1 for r in requests if r.finish_time is not None)
    return ReplayReport(
        summary=summarize(requests, w_p=w_p, w_d=w_d),
        n_submitted=len(requests), n_completed=done,
        n_rejected=len(cluster.dropped), wall=wall, speed=float("inf"))
