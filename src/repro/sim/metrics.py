"""Evaluation metrics (§5.1): TDG_Ratio, SLO attainment, latency
distributions, per-priority splits, and the urgent/timeout timelines of
Figs. 7 & 22."""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable

import numpy as np

from ..core.request import Request
from ..core.tdg import ideal_gain, tdg_gain, tdg_ratio


@dataclass
class Summary:
    n: int
    tdg_ratio: float
    slo_attainment: float
    ttft_p50: float
    ttft_p99: float
    tpot_p50: float
    tpot_p99: float
    per_priority: dict[int, dict[str, float]] = field(default_factory=dict)

    def row(self) -> dict:
        d = {"n": self.n, "tdg_ratio": round(self.tdg_ratio, 4),
             "slo": round(self.slo_attainment, 4),
             "ttft_p50": round(self.ttft_p50, 4),
             "ttft_p99": round(self.ttft_p99, 4),
             "tpot_p50": round(self.tpot_p50, 4),
             "tpot_p99": round(self.tpot_p99, 4)}
        for p, m in sorted(self.per_priority.items()):
            d[f"tdg_p{p}"] = round(m["tdg_ratio"], 4)
            d[f"slo_p{p}"] = round(m["slo"], 4)
        return d


def _pct(vals: list[float], q: float) -> float:
    return float(np.percentile(vals, q)) if vals else float("nan")


def summarize(reqs: Iterable[Request], w_p: float = 1.0,
              w_d: float = 1.0) -> Summary:
    reqs = list(reqs)
    ttfts = [r.ttft for r in reqs if r.ttft is not None]
    tpots = [r.tpot for r in reqs if r.tpot is not None]
    slo = (np.mean([r.met_slo() for r in reqs]) if reqs else 0.0)
    per_prio: dict[int, dict[str, float]] = {}
    for p in sorted({r.priority for r in reqs}):
        sub = [r for r in reqs if r.priority == p]
        per_prio[p] = {
            "tdg_ratio": tdg_ratio(sub, w_p, w_d),
            "slo": float(np.mean([r.met_slo() for r in sub])),
            "ttft_p99": _pct([r.ttft for r in sub if r.ttft is not None], 99),
        }
    return Summary(
        n=len(reqs),
        tdg_ratio=tdg_ratio(reqs, w_p, w_d),
        slo_attainment=float(slo),
        ttft_p50=_pct(ttfts, 50), ttft_p99=_pct(ttfts, 99),
        tpot_p50=_pct(tpots, 50), tpot_p99=_pct(tpots, 99),
        per_priority=per_prio)


class _Buf:
    """Growable float64 value buffer: O(1) amortized append, memory-compact
    (vs a Python float list: 8 bytes/value instead of ~60)."""

    __slots__ = ("_a", "_n")

    def __init__(self, cap: int = 1024):
        self._a = np.empty(cap)
        self._n = 0

    def append(self, x: float) -> None:
        if self._n == len(self._a):
            b = np.empty(2 * len(self._a))
            b[:self._n] = self._a
            self._a = b
        self._a[self._n] = x
        self._n += 1

    def values(self) -> np.ndarray:
        return self._a[:self._n]

    def __len__(self) -> int:
        return self._n

    def merge(self, other: "_Buf") -> None:
        k = other._n
        while self._n + k > len(self._a):
            b = np.empty(2 * len(self._a))
            b[:self._n] = self._a[:self._n]
            self._a = b
        self._a[self._n:self._n + k] = other._a[:k]
        self._n += k

    def percentile(self, q: float) -> float:
        return float(np.percentile(self.values(), q))


class _LogHist:
    """Bounded-memory latency sketch: log-spaced bins over [LO, HI) with
    ratio ``RATIO`` per bin, plus under/overflow bins.

    Chosen over reservoir sampling / P² because shard-merge must be
    EXACT (tests/test_shard_merge.py): int64 bin counts add exactly
    under any partition of the input, so merged percentiles equal the
    unsharded run's bit for bit.  A percentile is reported as the
    geometric midpoint of the bin holding that order statistic —
    relative error <= sqrt(RATIO) - 1 (~0.25%), inside the 1% bar the
    10⁵ reference-run assertion enforces.  Memory: NBINS int64 ≈ 44 KB
    per sketch, independent of request count.
    """

    LO, HI, RATIO = 1e-7, 1e5, 1.005
    _LOG_RATIO = math.log(RATIO)
    NBINS = int(math.ceil(math.log(HI / LO) / _LOG_RATIO)) + 2

    __slots__ = ("counts", "_n")

    def __init__(self):
        self.counts = np.zeros(self.NBINS, np.int64)
        self._n = 0

    def append(self, x: float) -> None:
        if x < self.LO:
            i = 0
        elif x >= self.HI:
            i = self.NBINS - 1
        else:
            i = 1 + int(math.log(x / self.LO) / self._LOG_RATIO)
        self.counts[i] += 1
        self._n += 1

    def __len__(self) -> int:
        return self._n

    def merge(self, other: "_LogHist") -> None:
        self.counts += other.counts
        self._n += other._n

    def _bin_value(self, i: int) -> float:
        if i <= 0:
            return self.LO
        if i >= self.NBINS - 1:
            return self.HI
        return self.LO * self.RATIO ** (i - 1) * math.sqrt(self.RATIO)

    def percentile(self, q: float) -> float:
        """numpy 'linear' interpolation between the two order statistics
        bracketing rank q/100*(n-1), each located via the bin cumsum."""
        if self._n == 0:
            return float("nan")
        r = q / 100.0 * (self._n - 1)
        k = int(math.floor(r))
        frac = r - k
        cum = np.cumsum(self.counts)
        lo = self._bin_value(int(np.searchsorted(cum, k + 1)))
        if frac <= 0.0:
            return lo
        hi = self._bin_value(int(np.searchsorted(cum, k + 2)))
        return lo + frac * (hi - lo)


class StreamingSummary:
    """Constant-overhead ``summarize``: fold requests one at a time as they
    finish (``ClusterSim.run_stream`` callback) so a 10⁶-request replay
    never holds per-request Python lists for metrics.

    Exactness vs ``summarize`` on the same request set: percentiles and
    SLO attainment are exact (same value multiset / integer counts
    regardless of fold order); the TDG gain sums accumulate in completion
    order instead of trace order, which is also exact whenever per-token
    gains are integer-valued in float64 (all bundled workloads use integer
    weights) and otherwise agrees to float rounding.

    ``bounded=True`` swaps the growable per-request latency buffers for
    ``_LogHist`` sketches: memory becomes independent of request count
    (10⁶-scale replays) at <= ~0.25% relative percentile error.

    ``merge`` folds another summary in (same ``w_p``/``w_d``/``bounded``),
    the reduction the sharded replay uses: counters and histogram bins
    add exactly, so merging per-shard summaries from ANY partition of a
    trace reproduces the unsharded metrics (property-tested in
    tests/test_shard_merge.py).
    """

    def __init__(self, w_p: float = 1.0, w_d: float = 1.0,
                 bounded: bool = False):
        self.w_p, self.w_d = w_p, w_d
        self.bounded = bounded
        self._mk = _LogHist if bounded else _Buf
        self.n = 0
        self._met = 0
        self._got = 0.0
        self._ideal = 0.0
        self._ttft = self._mk()
        self._tpot = self._mk()
        # priority -> [got, ideal, met, n, ttft_buf]
        self._prio: dict[int, list] = {}

    def add(self, r: Request) -> None:
        self.n += 1
        got = tdg_gain(r, self.w_p, self.w_d)
        ideal = ideal_gain(r, self.w_p, self.w_d)
        met = r.met_slo()
        self._got += got
        self._ideal += ideal
        self._met += met
        ttft, tpot = r.ttft, r.tpot
        if ttft is not None:
            self._ttft.append(ttft)
        if tpot is not None:
            self._tpot.append(tpot)
        acc = self._prio.get(r.priority)
        if acc is None:
            acc = self._prio[r.priority] = [0.0, 0.0, 0, 0, self._mk()]
        acc[0] += got
        acc[1] += ideal
        acc[2] += met
        acc[3] += 1
        if ttft is not None:
            acc[4].append(ttft)

    def merge(self, other: "StreamingSummary") -> None:
        if (self.w_p, self.w_d, self.bounded) != \
                (other.w_p, other.w_d, other.bounded):
            raise ValueError("merging incompatible StreamingSummary shards")
        self.n += other.n
        self._met += other._met
        self._got += other._got
        self._ideal += other._ideal
        self._ttft.merge(other._ttft)
        self._tpot.merge(other._tpot)
        for p, o in other._prio.items():
            acc = self._prio.get(p)
            if acc is None:
                acc = self._prio[p] = [0.0, 0.0, 0, 0, self._mk()]
            acc[0] += o[0]
            acc[1] += o[1]
            acc[2] += o[2]
            acc[3] += o[3]
            acc[4].merge(o[4])

    def summary(self) -> Summary:
        per_prio = {}
        for p in sorted(self._prio):
            got, ideal, met, n, ttfts = self._prio[p]
            per_prio[p] = {
                "tdg_ratio": got / ideal if ideal > 0 else 0.0,
                "slo": met / n if n else 0.0,
                "ttft_p99": (ttfts.percentile(99)
                             if len(ttfts) else float("nan")),
            }
        return Summary(
            n=self.n,
            tdg_ratio=self._got / self._ideal if self._ideal > 0 else 0.0,
            slo_attainment=self._met / self.n if self.n else 0.0,
            ttft_p50=(self._ttft.percentile(50)
                      if len(self._ttft) else float("nan")),
            ttft_p99=(self._ttft.percentile(99)
                      if len(self._ttft) else float("nan")),
            tpot_p50=(self._tpot.percentile(50)
                      if len(self._tpot) else float("nan")),
            tpot_p99=(self._tpot.percentile(99)
                      if len(self._tpot) else float("nan")),
            per_priority=per_prio)


# disagg two-leg accounting: ClusterSim and the live RouterBook expose
# these counters under identical attribute names, so the sim<->live
# parity gate (tools/perf_smoke.py) is a dict equality.
DISAGG_COUNTERS = ("handoffs", "handoff_blocks", "handoff_bytes",
                   "reservation_hits", "reservation_misses",
                   "reserved_blocks_total", "adopted_blocks_total")


def disagg_counters(source) -> dict[str, int]:
    """Disagg handoff/reservation counters from a ``ClusterSim`` or a
    live ``serving.dispatch.RouterBook``."""
    return {k: int(getattr(source, k)) for k in DISAGG_COUNTERS}


# speculative-decoding accounting: EngineSim / ClusterSim and the live
# EngineStats expose these under identical attribute names, so the
# sim<->live parity gate (tools/perf_smoke.py) is again a dict equality.
SPEC_COUNTERS = ("spec_proposed", "spec_accepted", "spec_rejected")


def spec_counters(source) -> dict:
    """Speculation counters (plus the depth histogram) from an
    ``EngineSim``, ``ClusterSim`` or live ``serving.engine.EngineStats``."""
    out: dict = {k: int(getattr(source, k)) for k in SPEC_COUNTERS}
    out["spec_depth_hist"] = {int(d): int(n) for d, n in
                              sorted(dict(source.spec_depth_hist).items())}
    return out


def gain_timeline(reqs: Iterable[Request], bucket: float = 1.0,
                  w_p: float = 1.0, w_d: float = 1.0) -> dict[int, float]:
    """TDG earned per time bucket (Fig. 21)."""
    out: dict[int, float] = {}
    for r in reqs:
        for i, t in enumerate(r.out_times, start=1):
            if t < r.slo.token_deadline(r.arrival, i):
                w = (w_p if i == 1 else w_d) * r.weight
                out[int(t // bucket)] = out.get(int(t // bucket), 0.0) + w
    return out


def urgent_timeout_timeline(reqs: Iterable[Request], horizon: float,
                            bucket: float = 1.0,
                            urgent_window: float = 1.0) -> dict:
    """Counts of urgent (approaching first-token deadline) and timed-out
    requests over time (Figs. 7/22)."""
    nb = int(horizon // bucket) + 1
    urgent = np.zeros(nb)
    timeout = np.zeros(nb)
    for r in reqs:
        dl = r.slo.token_deadline(r.arrival, 1)
        first = r.out_times[0] if r.out_times else float("inf")
        # urgent while waiting within `urgent_window` of the deadline
        t0, t1 = dl - urgent_window, min(first, dl)
        for b in range(max(0, int(t0 // bucket)),
                       min(nb - 1, int(t1 // bucket)) + 1):
            if t0 <= (b + 0.5) * bucket <= t1:
                urgent[b] += 1
        if first > dl:
            b = int(min(dl, horizon - 1e-9) // bucket)
            timeout[b] += 1
    return {"urgent": urgent.tolist(), "timeout": timeout.tolist(),
            "bucket": bucket}
