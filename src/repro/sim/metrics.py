"""Evaluation metrics (§5.1): TDG_Ratio, SLO attainment, latency
distributions, per-priority splits, and the urgent/timeout timelines of
Figs. 7 & 22."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable

import numpy as np

from ..core.request import Request
from ..core.tdg import tdg_ratio


@dataclass
class Summary:
    n: int
    tdg_ratio: float
    slo_attainment: float
    ttft_p50: float
    ttft_p99: float
    tpot_p50: float
    tpot_p99: float
    per_priority: dict[int, dict[str, float]] = field(default_factory=dict)

    def row(self) -> dict:
        d = {"n": self.n, "tdg_ratio": round(self.tdg_ratio, 4),
             "slo": round(self.slo_attainment, 4),
             "ttft_p50": round(self.ttft_p50, 4),
             "ttft_p99": round(self.ttft_p99, 4),
             "tpot_p50": round(self.tpot_p50, 4),
             "tpot_p99": round(self.tpot_p99, 4)}
        for p, m in sorted(self.per_priority.items()):
            d[f"tdg_p{p}"] = round(m["tdg_ratio"], 4)
            d[f"slo_p{p}"] = round(m["slo"], 4)
        return d


def _pct(vals: list[float], q: float) -> float:
    return float(np.percentile(vals, q)) if vals else float("nan")


def summarize(reqs: Iterable[Request], w_p: float = 1.0,
              w_d: float = 1.0) -> Summary:
    reqs = list(reqs)
    ttfts = [r.ttft for r in reqs if r.ttft is not None]
    tpots = [r.tpot for r in reqs if r.tpot is not None]
    slo = (np.mean([r.met_slo() for r in reqs]) if reqs else 0.0)
    per_prio: dict[int, dict[str, float]] = {}
    for p in sorted({r.priority for r in reqs}):
        sub = [r for r in reqs if r.priority == p]
        per_prio[p] = {
            "tdg_ratio": tdg_ratio(sub, w_p, w_d),
            "slo": float(np.mean([r.met_slo() for r in sub])),
            "ttft_p99": _pct([r.ttft for r in sub if r.ttft is not None], 99),
        }
    return Summary(
        n=len(reqs),
        tdg_ratio=tdg_ratio(reqs, w_p, w_d),
        slo_attainment=float(slo),
        ttft_p50=_pct(ttfts, 50), ttft_p99=_pct(ttfts, 99),
        tpot_p50=_pct(tpots, 50), tpot_p99=_pct(tpots, 99),
        per_priority=per_prio)


def gain_timeline(reqs: Iterable[Request], bucket: float = 1.0,
                  w_p: float = 1.0, w_d: float = 1.0) -> dict[int, float]:
    """TDG earned per time bucket (Fig. 21)."""
    out: dict[int, float] = {}
    for r in reqs:
        for i, t in enumerate(r.out_times, start=1):
            if t < r.slo.token_deadline(r.arrival, i):
                w = (w_p if i == 1 else w_d) * r.weight
                out[int(t // bucket)] = out.get(int(t // bucket), 0.0) + w
    return out


def urgent_timeout_timeline(reqs: Iterable[Request], horizon: float,
                            bucket: float = 1.0,
                            urgent_window: float = 1.0) -> dict:
    """Counts of urgent (approaching first-token deadline) and timed-out
    requests over time (Figs. 7/22)."""
    nb = int(horizon // bucket) + 1
    urgent = np.zeros(nb)
    timeout = np.zeros(nb)
    for r in reqs:
        dl = r.slo.token_deadline(r.arrival, 1)
        first = r.out_times[0] if r.out_times else float("inf")
        # urgent while waiting within `urgent_window` of the deadline
        t0, t1 = dl - urgent_window, min(first, dl)
        for b in range(max(0, int(t0 // bucket)),
                       min(nb - 1, int(t1 // bucket)) + 1):
            if t0 <= (b + 0.5) * bucket <= t1:
                urgent[b] += 1
        if first > dl:
            b = int(min(dl, horizon - 1e-9) // bucket)
            timeout[b] += 1
    return {"urgent": urgent.tolist(), "timeout": timeout.tolist(),
            "bucket": bucket}
