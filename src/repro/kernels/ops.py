"""Public jit'd wrappers for the Pallas kernels.

On the TPU target the kernels compile natively; on this CPU container they
run in ``interpret=True`` mode (the kernel body executes as traced jnp ops)
which is how the tests validate them against the ref.py oracles.
"""
from __future__ import annotations

from functools import partial

import jax

from .block_gather import block_gather as _block_gather
from .chunked_prefill import chunked_prefill_attention as _chunked_prefill
from .chunked_prefill import packed_prefill_attention as _packed_prefill
from .kv_quant import kv_block_dequantize as _kv_dequant
from .kv_quant import kv_block_quantize as _kv_quant
from .paged_attention import paged_decode_attention as _paged_decode
from .spec_verify import packed_verify_attention as _packed_verify


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths,
                           interpret: bool | None = None):
    it = _interpret_default() if interpret is None else interpret
    return _paged_decode(q, k_pages, v_pages, block_tables, lengths,
                         interpret=it)


@partial(jax.jit, static_argnames=("interpret",))
def packed_verify_attention(q, k_pages, v_pages, block_tables, lengths,
                            row_seg, interpret: bool | None = None):
    it = _interpret_default() if interpret is None else interpret
    return _packed_verify(q, k_pages, v_pages, block_tables, lengths,
                          row_seg, interpret=it)


@partial(jax.jit, static_argnames=("kv_block", "interpret"))
def chunked_prefill_attention(q, k_cache, v_cache, cache_lens,
                              kv_block: int = 512,
                              interpret: bool | None = None):
    it = _interpret_default() if interpret is None else interpret
    return _chunked_prefill(q, k_cache, v_cache, cache_lens,
                            kv_block=kv_block, interpret=it)


@partial(jax.jit, static_argnames=("kv_block", "interpret"))
def packed_prefill_attention(q, k_cache, v_cache, ctx_lens,
                             kv_block: int = 512,
                             interpret: bool | None = None):
    it = _interpret_default() if interpret is None else interpret
    return _packed_prefill(q, k_cache, v_cache, ctx_lens,
                           kv_block=kv_block, interpret=it)


@partial(jax.jit, static_argnames=("interpret",))
def block_gather(pool, indices, interpret: bool | None = None):
    it = _interpret_default() if interpret is None else interpret
    return _block_gather(pool, indices, interpret=it)


@partial(jax.jit, static_argnames=("interpret",))
def kv_block_quantize(blocks, interpret: bool | None = None):
    it = _interpret_default() if interpret is None else interpret
    return _kv_quant(blocks, interpret=it)


@partial(jax.jit, static_argnames=("interpret",))
def kv_block_dequantize(vals, scales, interpret: bool | None = None):
    it = _interpret_default() if interpret is None else interpret
    return _kv_dequant(vals, scales, interpret=it)
