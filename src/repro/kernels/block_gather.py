"""KV-block gather: compact scattered pool pages into a contiguous staging
buffer.

The §4.3 block manager offloads/reloads pages between device and host; the
host DMA engine wants CONTIGUOUS device buffers, while the paged pool
scatters a request's pages arbitrarily.  This kernel gathers the pages
named by ``indices`` into a staging buffer in one pass (index-driven
BlockSpec = one DMA per page, no compute) — the device half of the
asynchronous offloading path.
"""
from __future__ import annotations

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, pool_ref, out_ref):
    del idx_ref
    out_ref[...] = pool_ref[...]


def block_gather(pool, indices, *, interpret: bool = False):
    """pool: (P, page, Hkv, hd); indices: (n,) int32 -> (n, page, Hkv, hd)."""
    n = indices.shape[0]
    _, page, hkv, hd = pool.shape

    def in_map(i, idx):
        return (idx[i], 0, 0, 0)

    def out_map(i, idx):
        return (i, 0, 0, 0)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n,),
            in_specs=[pl.BlockSpec((1, page, hkv, hd), in_map)],
            out_specs=pl.BlockSpec((1, page, hkv, hd), out_map),
        ),
        out_shape=jax.ShapeDtypeStruct((n, page, hkv, hd), pool.dtype),
        interpret=interpret,
    )(indices, pool)
