"""Chunked-prefill flash attention over the serving KV cache.

SlideBatching admits prefill in CHUNKS sized by the latency budget (Alg. 1
GetMaxChunk); the engine writes the chunk's K/V into the cache and then
calls this kernel: queries of the chunk attend to everything already in
the cache (prefix) plus the chunk itself, causally.

  * grid = (batch, kv_head, kv_step): kv_step walks the cache in blocks,
    online softmax in VMEM scratch (flash);
  * the (G·Sq, kv_block) score tile keeps the MXU busy even for small
    chunks (G query heads per kv head stacked into rows);
  * per-request total lengths are scalar-prefetched; rows are masked
    causally against absolute positions, so ragged batches of different
    context lengths run in one call.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    lengths_ref,        # (B,) int32 — total valid tokens incl. the chunk
    q_ref,              # (1, 1, G, Sq, hd)
    k_ref,              # (1, kvb, 1, hd)
    v_ref,              # (1, kvb, 1, hd)
    o_ref,              # (1, 1, G, Sq, hd)
    m_ref,              # (G*Sq, 1) f32
    l_ref,              # (G*Sq, 1) f32
    acc_ref,            # (G*Sq, hd) f32
    *, kv_block: int, n_steps: int, sq: int,
):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = q_ref.shape[2]
    hd = q_ref.shape[-1]
    q = q_ref[0, 0].astype(jnp.float32).reshape(g * sq, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)                 # (kvb, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (1.0 / math.sqrt(hd))                          # (G*Sq, kvb)

    total = lengths_ref[b]
    row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % sq
    q_pos = total - sq + row                               # absolute q pos
    k_pos = i * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = k_pos <= q_pos                                 # causal + length
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(i == n_steps - 1)
    def _out():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = o.reshape(g, sq, hd).astype(o_ref.dtype)


def _packed_kernel(
    ctx_lens_ref,       # (S,) int32 — cached tokens BEFORE each chunk
    q_ref,              # (1, 1, G, Sq, hd)
    k_ref,              # (1, kvb, 1, hd)
    v_ref,              # (1, kvb, 1, hd)
    o_ref,              # (1, 1, G, Sq, hd)
    m_ref,              # (G*Sq, 1) f32
    l_ref,              # (G*Sq, 1) f32
    acc_ref,            # (G*Sq, hd) f32
    *, kv_block: int, n_steps: int, sq: int,
):
    """Packed multi-request prefill: one grid row per SEGMENT (request
    chunk).  Queries sit at absolute positions ``ctx_lens[b] + row``; the
    staged cache holds only the blocks this segment needs, so KV tiles
    entirely beyond the segment's causal horizon are skipped (the online
    softmax state is untouched — a bitwise no-op, see tests)."""
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ctx = ctx_lens_ref[b]

    # last causally-visible position of this segment is ctx + sq - 1; tiles
    # starting beyond it contribute exactly nothing — skip their FLOPs.
    @pl.when(i * kv_block <= ctx + sq - 1)
    def _accumulate():
        g = q_ref.shape[2]
        hd = q_ref.shape[-1]
        q = q_ref[0, 0].astype(jnp.float32).reshape(g * sq, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)             # (kvb, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / math.sqrt(hd))                      # (G*Sq, kvb)

        row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % sq
        q_pos = ctx + row                                  # absolute q pos
        k_pos = i * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape,
                                                        1)
        valid = k_pos <= q_pos                             # causal + length
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(i == n_steps - 1)
    def _out():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        g = q_ref.shape[2]
        hd = q_ref.shape[-1]
        o_ref[0, 0] = o.reshape(g, sq, hd).astype(o_ref.dtype)


def packed_prefill_attention(q, k_cache, v_cache, ctx_lens,
                             *, kv_block: int = 512,
                             interpret: bool = False):
    """Multi-request packed prefill attention (one call, S segments).

    q: (S, Sq, H, hd) — per-segment chunk queries, right-padded to a common
    ``Sq`` (padded rows are masked out by the consumer); k/v_cache:
    (S, Smax, Hkv, hd) staged per-segment caches with each chunk's K/V
    already written at [ctx, ctx+chunk); ctx_lens: (S,) cached tokens
    BEFORE each chunk.  Query row r of segment s sits at absolute position
    ``ctx_lens[s] + r`` — identical masking to the per-request kernel with
    ``cache_lens = ctx_lens + Sq``, so per-segment results are bitwise
    equal to S separate ``chunked_prefill_attention`` calls.
    Returns (S, Sq, H, hd)."""
    s_, sq, h, hd = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    n_steps = -(-smax // kv_block)
    if smax % kv_block:
        padlen = n_steps * kv_block - smax
        k_cache = jnp.pad(k_cache, ((0, 0), (0, padlen), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, padlen), (0, 0), (0, 0)))
    q5 = q.reshape(s_, sq, hkv, g, hd).transpose(0, 2, 3, 1, 4)

    grid = (s_, hkv, n_steps)

    def q_map(bi, hi, ii, ln):
        return (bi, hi, 0, 0, 0)

    def kv_map(bi, hi, ii, ln):
        return (bi, ii, hi, 0)

    out = pl.pallas_call(
        functools.partial(_packed_kernel, kv_block=kv_block,
                          n_steps=n_steps, sq=sq),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, sq, hd), q_map),
                pl.BlockSpec((1, kv_block, 1, hd), kv_map),
                pl.BlockSpec((1, kv_block, 1, hd), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, g, sq, hd), q_map),
            scratch_shapes=[
                pltpu.VMEM((g * sq, 1), jnp.float32),
                pltpu.VMEM((g * sq, 1), jnp.float32),
                pltpu.VMEM((g * sq, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((s_, hkv, g, sq, hd), q.dtype),
        interpret=interpret,
    )(ctx_lens, q5, k_cache, v_cache)
    return out.transpose(0, 3, 1, 2, 4).reshape(s_, sq, h, hd)


def chunked_prefill_attention(q, k_cache, v_cache, cache_lens,
                              *, kv_block: int = 512,
                              interpret: bool = False):
    """q: (B, Sq, H, hd); k/v_cache: (B, Smax, Hkv, hd) with the chunk's K/V
    already written at [len-Sq, len); cache_lens: (B,) valid lengths
    INCLUDING the chunk.  Returns (B, Sq, H, hd)."""
    b, sq, h, hd = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    n_steps = -(-smax // kv_block)
    if smax % kv_block:
        padlen = n_steps * kv_block - smax
        k_cache = jnp.pad(k_cache, ((0, 0), (0, padlen), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, padlen), (0, 0), (0, 0)))
    # (B, Sq, H, hd) -> (B, Hkv, G, Sq, hd)
    q5 = q.reshape(b, sq, hkv, g, hd).transpose(0, 2, 3, 1, 4)

    grid = (b, hkv, n_steps)

    def q_map(bi, hi, ii, ln):
        return (bi, hi, 0, 0, 0)

    def kv_map(bi, hi, ii, ln):
        return (bi, ii, hi, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, kv_block=kv_block, n_steps=n_steps,
                          sq=sq),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, sq, hd), q_map),
                pl.BlockSpec((1, kv_block, 1, hd), kv_map),
                pl.BlockSpec((1, kv_block, 1, hd), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, g, sq, hd), q_map),
            scratch_shapes=[
                pltpu.VMEM((g * sq, 1), jnp.float32),
                pltpu.VMEM((g * sq, 1), jnp.float32),
                pltpu.VMEM((g * sq, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, sq, hd), q.dtype),
        interpret=interpret,
    )(cache_lens, q5, k_cache, v_cache)
    # (B, Hkv, G, Sq, hd) -> (B, Sq, H, hd)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
