"""Packed speculative-verify attention — scoring k+1 draft positions at once.

Greedy speculative decoding verifies a request's draft chain by running
the decode forward for rows j = 0..depth, where row j processes the
token at position l_kv + j.  All rows of one request share the SAME
block table; materializing a (rows, maxp) table would copy each
request's table depth+1 times and make the scalar-prefetch buffer scale
with the packed row count.

This kernel is ``paged_attention._kernel`` with ONE change: a third
scalar-prefetched operand ``row_seg`` maps each verify row to its
request's row in a compact (S, maxp) block table, and the K/V index_map
reads ``bt[seg[bi], ii]`` instead of ``bt[bi, ii]``.  The kernel body —
tile shapes, online-softmax accumulation order, masking — is identical,
so every row's output is bitwise-equal to ``paged_decode_attention``
run with that row's gathered table: the property the engine's
stream-equality guarantee (and tests/test_spec_decode.py) rests on.

Per-row lengths stay a (rows,) vector: row j of a request passes
l_kv + j + 1, which masks out the same-launch KV writes of rows > j —
causality across the packed rows without any extra masking logic.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    # --- scalar prefetch ---
    row_seg_ref,         # (R,) int32: verify row -> block-table row
    block_tables_ref,    # (S, maxp) int32
    lengths_ref,         # (R,) int32
    # --- blocked operands ---
    q_ref,               # (1, 1, G, hd)
    k_ref,               # (1, page, 1, hd)
    v_ref,               # (1, page, 1, hd)
    # --- blocked output ---
    o_ref,               # (1, 1, G, hd)
    # --- scratch ---
    m_ref,               # (G, 1) f32
    l_ref,               # (G, 1) f32
    acc_ref,             # (G, hd) f32
    *, page: int, max_pages: int,
):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # (G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)                 # (page, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (1.0 / math.sqrt(q.shape[-1]))                 # (G, page)

    pos = i * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < lengths_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                    # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)          # (G, page)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(i == max_pages - 1)
    def _out():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def packed_verify_attention(q, k_pages, v_pages, block_tables, lengths,
                            row_seg, *, interpret: bool = False):
    """q: (R, H, hd) — one row per (request, draft position);
    k/v_pages: (P, page, Hkv, hd); block_tables: (S, maxp) int32 (pad
    with 0); lengths: (R,) int32 — per ROW (l_kv + j + 1);
    row_seg: (R,) int32 — row -> block-table row in [0, S).
    Returns (R, H, hd)."""
    b, h, hd = q.shape
    n_pages, page, hkv, _ = k_pages.shape
    g = h // hkv
    maxp = block_tables.shape[1]
    q4 = q.reshape(b, hkv, g, hd)

    grid = (b, hkv, maxp)

    def q_map(bi, hi, ii, seg, bt, ln):
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, ii, seg, bt, ln):
        return (bt[seg[bi], ii], 0, hi, 0)

    def o_map(bi, hi, ii, seg, bt, ln):
        return (bi, hi, 0, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, page=page, max_pages=maxp),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, hd), q_map),
                pl.BlockSpec((1, page, 1, hd), kv_map),
                pl.BlockSpec((1, page, 1, hd), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd), o_map),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        interpret=interpret,
    )(row_seg, block_tables, lengths, q4, k_pages, v_pages)
    return out.reshape(b, h, hd)
