"""Int8 KV-block (de)quantization for the cold tier of the tiered cache.

Host-tier evictions demote KV blocks into an int8 cold tier (~4x smaller
than fp32, so a cold reload moves ~4x fewer bytes over the host link).
Quantization is symmetric per (block, layer, k|v) *plane*:

    scale = absmax(plane) / 127
    q     = clip(round(x / scale), -127, 127)  as int8
    x'    = q * scale

The per-plane granularity matches the offload wire unit — one KV block is
``(L, 2, bs, Hkv, hd)`` and each of its ``L*2`` planes gets its own fp32
scale — so a single outlier key only widens the step of its own layer's
K (or V) plane, not the whole block.

Error bound: round() contributes at most half a step, so every element
satisfies ``|x - x'| <= scale/2`` (asserted by tests/test_kernels.py).
All ops are elementwise or exact reductions (abs/max), so the kernels are
bitwise-identical to the ``ref.py`` oracles in interpret mode.

Grid: one program per plane row — the input is viewed as ``(R, E)`` with
``R = n*L*2`` rows of ``E = bs*Hkv*hd`` elements; each program reduces one
row to its scale and writes the quantized row (quantize) or applies the
row's scale (dequantize).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, vals_ref, scales_ref):
    x = x_ref[...].astype(jnp.float32)
    # explicit multiply by the constant reciprocal: XLA strength-reduces
    # x/127.0 to this under jit, so spelling it out keeps the jit'd kernel
    # and the eager ref oracle bitwise identical
    scale = jnp.max(jnp.abs(x)) * (1.0 / 127.0)
    inv = jnp.where(scale > 0.0, 1.0 / scale, 0.0)
    vals_ref[...] = jnp.clip(jnp.round(x * inv), -127.0, 127.0).astype(
        jnp.int8)
    scales_ref[...] = jnp.broadcast_to(scale, scales_ref.shape)


def _dequant_kernel(vals_ref, scales_ref, out_ref):
    scale = scales_ref[0, 0]
    out_ref[...] = vals_ref[...].astype(jnp.float32) * scale


def _row_view(blocks):
    n, lyr, two, bs, hkv, hd = blocks.shape
    return blocks.reshape(n * lyr * two, bs * hkv * hd)


def kv_block_quantize(blocks, *, interpret: bool = False):
    """blocks: (n, L, 2, bs, Hkv, hd) float -> (int8 vals same shape,
    fp32 scales (n, L, 2))."""
    n, lyr, two, bs, hkv, hd = blocks.shape
    x = _row_view(blocks)
    r, e = x.shape

    def row_map(i):
        return (i, 0)

    vals, scales = pl.pallas_call(
        _quant_kernel,
        grid=(r,),
        in_specs=[pl.BlockSpec((1, e), row_map)],
        out_specs=[pl.BlockSpec((1, e), row_map),
                   pl.BlockSpec((1, 1), row_map)],
        out_shape=[jax.ShapeDtypeStruct((r, e), jnp.int8),
                   jax.ShapeDtypeStruct((r, 1), jnp.float32)],
        interpret=interpret,
    )(x)
    return (vals.reshape(blocks.shape), scales.reshape(n, lyr, two))


def kv_block_dequantize(vals, scales, *, interpret: bool = False):
    """vals: (n, L, 2, bs, Hkv, hd) int8, scales: (n, L, 2) fp32 ->
    fp32 blocks of vals' shape."""
    q = _row_view(vals)
    r, e = q.shape

    def row_map(i):
        return (i, 0)

    out = pl.pallas_call(
        _dequant_kernel,
        grid=(r,),
        in_specs=[pl.BlockSpec((1, e), row_map),
                  pl.BlockSpec((1, 1), row_map)],
        out_specs=pl.BlockSpec((1, e), row_map),
        out_shape=jax.ShapeDtypeStruct((r, e), jnp.float32),
        interpret=interpret,
    )(q, scales.reshape(r, 1))
    return out.reshape(vals.shape)
