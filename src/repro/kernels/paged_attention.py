"""Paged flash-decode attention — the serving engine's decode hot path.

ProServe's block manager stores KV in fixed-size pages with per-request
block tables (§4.3); this kernel runs one decode step for a batch of
requests directly against the paged pool:

  * grid = (batch, kv_head, page_step) — the page dimension iterates
    sequentially on-core, maintaining an online softmax in VMEM scratch
    (flash-decode), so nothing larger than one (page, head_dim) tile plus
    the (G, head_dim) accumulator ever sits in VMEM;
  * page indices are SCALAR-PREFETCHED (PrefetchScalarGridSpec): the block
    table drives the K/V BlockSpec index_map, so each grid step DMAs
    exactly the page it needs — the TPU analogue of vLLM's gather, with no
    materialized (B, S, ...) contiguous KV;
  * GQA: the G = H/Hkv query heads of a kv group are processed together as
    the row dimension of the (G, page) score tile.

TPU mapping notes (DESIGN.md §2): page_size should be a multiple of 128
(lane dim) and head_dim 128 for MXU alignment; G < 8 underfills the MXU
sublane dim — acceptable for decode, which is DMA-bound anyway.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    # --- scalar prefetch ---
    block_tables_ref,    # (B, maxp) int32
    lengths_ref,         # (B,) int32
    # --- blocked operands ---
    q_ref,               # (1, 1, G, hd)
    k_ref,               # (1, page, 1, hd)
    v_ref,               # (1, page, 1, hd)
    # --- blocked output ---
    o_ref,               # (1, 1, G, hd)
    # --- scratch ---
    m_ref,               # (G, 1) f32
    l_ref,               # (G, 1) f32
    acc_ref,             # (G, hd) f32
    *, page: int, max_pages: int,
):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # (G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)                 # (page, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (1.0 / math.sqrt(q.shape[-1]))                 # (G, page)

    pos = i * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < lengths_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                    # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)          # (G, page)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(i == max_pages - 1)
    def _out():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths,
                           *, interpret: bool = False):
    """q: (B, H, hd); k/v_pages: (P, page, Hkv, hd);
    block_tables: (B, maxp) int32 (pad with 0); lengths: (B,) int32.
    Returns (B, H, hd)."""
    b, h, hd = q.shape
    n_pages, page, hkv, _ = k_pages.shape
    g = h // hkv
    maxp = block_tables.shape[1]
    q4 = q.reshape(b, hkv, g, hd)

    grid = (b, hkv, maxp)

    def q_map(bi, hi, ii, bt, ln):
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, ii, bt, ln):
        return (bt[bi, ii], 0, hi, 0)

    def o_map(bi, hi, ii, bt, ln):
        return (bi, hi, 0, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, page=page, max_pages=maxp),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, hd), q_map),
                pl.BlockSpec((1, page, 1, hd), kv_map),
                pl.BlockSpec((1, page, 1, hd), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd), o_map),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, q4, k_pages, v_pages)
    return out.reshape(b, h, hd)
