"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

These are deliberately naive (gather everything, masked softmax) — tests
sweep shapes/dtypes and assert_allclose kernels (interpret=True) against
these.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, lengths):
    """q: (B, H, hd); pages: (P, page, Hkv, hd); block_tables: (B, maxp);
    lengths: (B,).  Returns (B, H, hd)."""
    b, h, hd = q.shape
    page = k_pages.shape[1]
    hkv = k_pages.shape[2]
    g = h // hkv
    maxp = block_tables.shape[1]
    # gather pages -> (B, maxp*page, Hkv, hd)
    k = k_pages[block_tables].reshape(b, maxp * page, hkv, hd)
    v = v_pages[block_tables].reshape(b, maxp * page, hkv, hd)
    q4 = q.reshape(b, hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", q4, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    pos = jnp.arange(maxp * page)[None, :]
    mask = pos < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(b, h, hd).astype(q.dtype)


def packed_verify_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                                row_seg):
    """Packed speculative-verify oracle: rows sharing a request share a
    block-table row via ``row_seg``.  q: (R, H, hd); pages:
    (P, page, Hkv, hd); block_tables: (S, maxp); lengths/row_seg: (R,).
    Gathers each row's table and then applies the exact
    ``paged_decode_attention_ref`` math.  Returns (R, H, hd)."""
    return paged_decode_attention_ref(q, k_pages, v_pages,
                                      block_tables[row_seg], lengths)


def chunked_prefill_attention_ref(q, k_cache, v_cache, cache_lens):
    """Chunked-prefill attention: the new chunk's K/V are ALREADY written
    into the cache at [cache_lens - Sq, cache_lens).

    q: (B, Sq, H, hd) — queries of the chunk; k/v_cache: (B, Smax, Hkv, hd);
    cache_lens: (B,) total valid tokens INCLUDING the chunk.
    Query row j sits at absolute position cache_lens - Sq + j and attends
    causally.  Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    q5 = q.reshape(b, sq, hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q5, k_cache.astype(jnp.float32))
    s = s / math.sqrt(hd)
    q_pos = (cache_lens[:, None] - sq + jnp.arange(sq)[None, :])   # (B, Sq)
    k_pos = jnp.arange(smax)[None, :]
    mask = k_pos[:, None, :] <= q_pos[..., None]                   # (B,Sq,Smax)
    s = jnp.where(mask[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def block_gather_ref(pool, indices):
    """pool: (P, page, ...); indices: (n,) -> (n, page, ...)."""
    return pool[indices]


def kv_block_quantize_ref(blocks):
    """Symmetric int8 per-(block, layer, k|v)-plane quantization.
    blocks: (n, L, 2, bs, Hkv, hd) -> (int8 vals same shape, fp32 scales
    (n, L, 2)).  Expression shapes deliberately mirror kv_quant.py so the
    kernel is BITWISE equal in interpret mode."""
    n, lyr, two = blocks.shape[:3]
    x = blocks.reshape(n * lyr * two, -1).astype(jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) * (1.0 / 127.0)
    inv = jnp.where(scale > 0.0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(x * inv), -127.0, 127.0).astype(jnp.int8)
    return q.reshape(blocks.shape), scale.reshape(n, lyr, two)


def kv_block_dequantize_ref(vals, scales):
    """vals: (n, L, 2, bs, Hkv, hd) int8, scales: (n, L, 2) -> fp32
    blocks.  Roundtrip error vs the original is bounded by scale/2 per
    element (see kv_quant.py)."""
    n, lyr, two = vals.shape[:3]
    q = vals.reshape(n * lyr * two, -1)
    out = q.astype(jnp.float32) * scales.reshape(n * lyr * two, 1)
    return out.reshape(vals.shape)
