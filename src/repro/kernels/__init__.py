"""Pallas TPU kernels for the serving hot paths (+ interpret-mode CPU
validation): paged flash-decode attention, chunked-prefill flash attention,
KV block gather.  ref.py holds the pure-jnp oracles."""
from .ops import (paged_decode_attention, packed_verify_attention,
                  chunked_prefill_attention, packed_prefill_attention,
                  block_gather, kv_block_quantize, kv_block_dequantize)
from . import ref

__all__ = ["paged_decode_attention", "packed_verify_attention",
           "chunked_prefill_attention", "packed_prefill_attention",
           "block_gather", "kv_block_quantize", "kv_block_dequantize",
           "ref"]
