"""Step builders + ShapeDtypeStruct input specs for every
(architecture × input-shape) cell — shared by dryrun.py, train.py, serve.py.

Shapes (assigned set):
    train_4k     seq 4096,   global batch 256   -> train_step
    prefill_32k  seq 32768,  global batch 32    -> prefill_step
    decode_32k   seq 32768,  global batch 128   -> serve_step (1 new token)
    long_500k    seq 524288, global batch 1     -> serve_step; SSM/hybrid only

No device memory is allocated here: params/optimizer/cache all come from
``jax.eval_shape``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..distributed.sharding import (ShardingPolicy, cache_specs,
                                    make_shard_fn, param_specs)
from ..models.model import ArchConfig, decode_step, init_params, prefill
from ..training.optimizer import init_adamw
from ..training.train import make_train_step

COMPUTE_DTYPE = jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_supported(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """DESIGN.md §4 skip rules."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 524k-token KV is not "
                       "sub-quadratic — skipped per spec")
    return True, ""


# --------------------------------------------------------------------------
# abstract inputs
# --------------------------------------------------------------------------

def params_struct(cfg: ArchConfig):
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype=COMPUTE_DTYPE),
        jax.random.PRNGKey(0))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def cache_struct(cfg: ArchConfig, batch: int, max_seq: int):
    """Mirror of the cache pytree prefill() builds (eval_shape'd)."""
    def build():
        toks = jnp.zeros((batch, 8), jnp.int32)
        kw = {}
        if cfg.family == "encdec":
            kw["enc_inputs"] = jnp.zeros(
                (batch, cfg.enc_frames, cfg.d_model), COMPUTE_DTYPE)
        _, cache = prefill(cfg, init_params(cfg, jax.random.PRNGKey(0),
                                            dtype=COMPUTE_DTYPE),
                           toks, max_seq=max_seq, **kw)
        return cache
    return jax.eval_shape(build)


def input_specs(cfg: ArchConfig, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sp = SHAPES[shape]
    if sp.kind == "train":
        d = {"tokens": _sds((sp.batch, sp.seq), jnp.int32),
             "labels": _sds((sp.batch, sp.seq), jnp.int32)}
        if cfg.family == "encdec":
            d["enc_inputs"] = _sds((sp.batch, cfg.enc_frames, cfg.d_model),
                                   COMPUTE_DTYPE)
        return d
    if sp.kind == "prefill":
        d = {"tokens": _sds((sp.batch, sp.seq), jnp.int32)}
        if cfg.family == "encdec":
            d["enc_inputs"] = _sds((sp.batch, cfg.enc_frames, cfg.d_model),
                                   COMPUTE_DTYPE)
        return d
    # decode: one new token against a cache of length `seq`
    return {"tokens": _sds((sp.batch,), jnp.int32),
            "cache": cache_struct(cfg, sp.batch, sp.seq)}


# --------------------------------------------------------------------------
# sharded step builders
# --------------------------------------------------------------------------

def default_microbatches(cfg: ArchConfig, policy: ShardingPolicy) -> int:
    """Grad-accum heuristic: keep the per-device microbatch at 1-2 seqs."""
    dp = policy.axis_size(policy.dp)
    per_dev = max(1, 256 // dp)
    if cfg.d_model >= 4096:
        target = 1
    elif (cfg.d_model >= 1536 or cfg.family in ("ssm", "hybrid", "encdec")):
        target = 2           # SSD chunk / encoder-attention tensors are heavy
    else:
        return 1
    return max(1, per_dev // target)


def build_train_step(cfg: ArchConfig, policy: ShardingPolicy,
                     microbatches: Optional[int] = None,
                     compress_grads: bool = False,
                     attn_impl: Optional[str] = None,
                     grad_rs: bool = False):
    mb = (default_microbatches(cfg, policy)
          if microbatches is None else microbatches)
    shard_fn = make_shard_fn(cfg, policy)
    if attn_impl is None:
        attn_impl = "chunked" if SHAPES["train_4k"].seq >= 2048 else "dense"

    p_struct = params_struct(cfg)
    p_specs = param_specs(cfg, policy, p_struct)
    grad_constraint = None
    if grad_rs:
        mesh_ = policy.mesh

        def grad_constraint(g):
            return jax.tree.map(
                lambda x, sp: jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh_, sp)), g, p_specs,
                is_leaf=lambda x: False)

    step = make_train_step(cfg, attn_impl=attn_impl, shard_fn=shard_fn,
                           remat=True, microbatches=mb,
                           compress_grads=compress_grads,
                           grad_constraint=grad_constraint)
    opt_struct = jax.eval_shape(init_adamw, p_struct)
    opt_specs = jax.tree.map(
        lambda _: None, opt_struct)  # placeholder, replaced below
    opt_specs = type(opt_struct)(
        step=P(),
        mu=p_specs, nu=p_specs, master=p_specs,
        err=None if opt_struct.err is None else p_specs)
    batch_spec = {"tokens": P(policy.dp, None), "labels": P(policy.dp, None)}
    if cfg.family == "encdec":
        batch_spec["enc_inputs"] = P(policy.dp, None, None)

    mesh = policy.mesh
    # None entries (e.g. err=None without compression) are empty pytree
    # nodes — tree.map skips them automatically.
    nd = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda s: isinstance(s, P))
    in_sh = (nd(p_specs), nd(opt_specs), nd(batch_spec))
    out_sh = (nd(p_specs), nd(opt_specs),
              {"loss": NamedSharding(mesh, P()),
               "grad_norm": NamedSharding(mesh, P())})
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1))
    structs = (p_struct, opt_struct, input_specs(cfg, "train_4k"))
    return jitted, structs, {"microbatches": mb}


def build_prefill_step(cfg: ArchConfig, policy: ShardingPolicy,
                       shape: str = "prefill_32k"):
    sp = SHAPES[shape]
    shard_fn = make_shard_fn(cfg, policy)

    def fn(params, tokens, enc_inputs=None):
        kw = {"enc_inputs": enc_inputs} if enc_inputs is not None else {}
        return prefill(cfg, params, tokens, max_seq=sp.seq,
                       attn_impl="chunked", shard_fn=shard_fn, **kw)

    p_struct = params_struct(cfg)
    p_specs = param_specs(cfg, policy, p_struct)
    mesh = policy.mesh
    nd = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda s: isinstance(s, P))
    ins = input_specs(cfg, shape)
    in_sh = [nd(p_specs), NamedSharding(mesh, P(policy.dp, None))]
    args = [p_struct, ins["tokens"]]
    if cfg.family == "encdec":
        in_sh.append(NamedSharding(mesh, P(policy.dp, None, None)))
        args.append(ins["enc_inputs"])
    c_struct = cache_struct(cfg, sp.batch, sp.seq)
    c_specs = cache_specs(cfg, policy, c_struct)
    v_ok = cfg.vocab % policy.axis_size(policy.tp) == 0
    out_sh = (NamedSharding(mesh, P(policy.dp, policy.tp if v_ok else None)),
              nd(c_specs))
    jitted = jax.jit(fn, in_shardings=tuple(in_sh), out_shardings=out_sh)
    return jitted, tuple(args), {}


def build_serve_step(cfg: ArchConfig, policy: ShardingPolicy,
                     shape: str = "decode_32k"):
    sp = SHAPES[shape]
    shard_fn = make_shard_fn(cfg, policy)

    def fn(params, cache, tokens):
        return decode_step(cfg, params, cache, tokens, shard_fn=shard_fn)

    p_struct = params_struct(cfg)
    p_specs = param_specs(cfg, policy, p_struct)
    ins = input_specs(cfg, shape)
    c_struct = ins["cache"]
    c_specs = cache_specs(cfg, policy, c_struct)
    mesh = policy.mesh
    nd = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda s: isinstance(s, P))
    v_ok = cfg.vocab % policy.axis_size(policy.tp) == 0
    b_ax = policy.dp_if(ins["tokens"].shape[0])
    in_sh = (nd(p_specs), nd(c_specs), NamedSharding(mesh, P(b_ax)))
    out_sh = (NamedSharding(mesh, P(b_ax, policy.tp if v_ok else None)),
              nd(c_specs))
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(1,))
    return jitted, (p_struct, c_struct, ins["tokens"]), {}


def build_cell(cfg: ArchConfig, shape: str, policy: ShardingPolicy,
               **kw):
    kind = SHAPES[shape].kind
    if kind == "train":
        return build_train_step(cfg, policy, **kw)
    if kind == "prefill":
        return build_prefill_step(cfg, policy, shape)
    return build_serve_step(cfg, policy, shape)


def parse_variant(text: Optional[str]) -> dict:
    """'mb=8,attn=dense,grad_rs=1,fsdp=0' -> build kwargs + policy tweaks."""
    out: dict = {"build": {}, "policy": {}}
    if not text:
        return out
    for kv in text.split(","):
        k, _, v = kv.partition("=")
        k, v = k.strip(), v.strip()
        if k in ("mb", "microbatches"):
            out["build"]["microbatches"] = int(v)
        elif k in ("attn", "attn_impl"):
            out["build"]["attn_impl"] = v
        elif k == "grad_rs":
            out["build"]["grad_rs"] = bool(int(v))
        elif k == "compress":
            out["build"]["compress_grads"] = bool(int(v))
        elif k == "fsdp":
            out["policy"]["fsdp"] = bool(int(v))
        elif k == "sp":
            out["policy"]["sp"] = bool(int(v))
        elif k == "seqkv":
            out["policy"]["seq_sharded_kv"] = bool(int(v))
        else:
            raise ValueError(f"unknown variant key {k}")
    return out
