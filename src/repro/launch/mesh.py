"""Production mesh definition.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; callers (dryrun.py) set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before ANY jax
import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 v5e chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires >= prod(shape) host devices)."""
    return jax.make_mesh(shape, axes)
