"""Serving launcher: run the cluster simulator at paper scale or the real
CPU engine demo.

    PYTHONPATH=src python -m repro.launch.serve --mode sim \
        --dataset industrial --rate 120 --sched slidebatching --router gorouting
    PYTHONPATH=src python -m repro.launch.serve --mode real
"""
from __future__ import annotations

import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["sim", "real"], default="sim")
    ap.add_argument("--dataset", default="sharegpt")
    ap.add_argument("--rate", type=float, default=80.0)
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--sched", default="slidebatching")
    ap.add_argument("--router", default="gorouting")
    ap.add_argument("--pd", choices=["coloc", "disagg"], default="coloc")
    ap.add_argument("--instances", type=int, default=4)
    ap.add_argument("--decode-instances", type=int, default=0)
    ap.add_argument("--model", default="qwen2-7b")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.mode == "real":
        import runpy
        runpy.run_path("examples/priority_serving.py", run_name="__main__")
        return

    import sys
    sys.path.insert(0, ".")
    from benchmarks.common import run_multi_node
    row, _ = run_multi_node(
        args.dataset, args.rate, args.sched, args.router,
        pd_mode=args.pd, n_prefill=args.instances,
        n_decode=args.decode_instances, model=args.model,
        duration=args.duration, seed=args.seed)
    print(json.dumps(row, indent=1))


if __name__ == "__main__":
    main()
