"""Trip-count-aware cost model over compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` counts every computation ONCE — a 62-layer
scan (while loop) contributes a single layer's FLOPs.  This module walks
the HLO module text, multiplies each computation by the product of
enclosing while-loop trip counts (``backend_config known_trip_count``),
and reconstructs:

  * flops        — dot ops: 2 * prod(result dims) * prod(contracting dims)
  * bytes        — HBM traffic estimate: operand + result bytes of
                   fusion-boundary ops (fusion/dot/copy/scatter/gather/DUS/
                   collectives/parameters are NOT counted — parameters are
                   resident, not streamed per op — but each op's operand
                   reads and result writes are)
  * collectives  — per-kind result bytes of collective ops, trip-weighted

All numbers are PER DEVICE (the SPMD module is the per-device program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_CALLED_SINGLE_RE = re.compile(
    r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_CALLED_MULTI_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')


def _shape_list(s: str):
    """All (dtype, dims) in a type string (handles tuples)."""
    out = []
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt in _DTYPE_BYTES:
            d = [int(x) for x in dims.split(",")] if dims else []
            out.append((dt, d))
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Instr:
    name: str
    result_shapes: list
    op: str
    operands: list          # operand instruction names (same computation)
    called: list            # computation names this instruction invokes
    trip: int = 1           # while trip count (while ops only)
    dot_contract: int = 1   # product of contracting dims (dot only)
    line: str = ""


@dataclass
class _Comp:
    name: str
    instrs: dict = field(default_factory=dict)
    root: str = ""  # name of the ROOT instruction


_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def parse_module(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        # computation headers: "%name (params) -> type {" or "ENTRY %name ..."
        if (line.startswith("%") or line.startswith("ENTRY")) \
                and s.endswith("{"):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", s)
            if m:
                cur = _Comp(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    comps["__entry__"] = cur
            continue
        if s == "}" or s.startswith("}"):
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        if line.lstrip().startswith("ROOT"):
            cur.root = name
        rhs = rhs.strip()
        # split "<type> <op>(<args>)..." — the type may be a tuple "(...)"
        if rhs.startswith("("):
            depth = 0
            type_end = -1
            for i, ch in enumerate(rhs):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        type_end = i + 1
                        break
            if type_end < 0:
                continue
            type_str = rhs[:type_end]
            rest = rhs[type_end:].strip()
        else:
            paren0 = rhs.find("(")
            if paren0 < 0:
                continue
            head = rhs[:paren0].strip()
            toks = head.split()
            type_str = " ".join(toks[:-1])
            rest = (toks[-1] if toks else "") + rhs[paren0:]
        paren = rest.find("(")
        if paren < 0:
            continue
        op = rest[:paren].strip()
        args_str = rest[paren + 1:]
        depth, end = 1, 0
        for i, ch in enumerate(args_str):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPERAND_RE.findall(args_str[:end])
        attrs = args_str[end:]
        called = _CALLED_SINGLE_RE.findall(attrs)
        for grp in _CALLED_MULTI_RE.findall(attrs):
            called.extend(c.strip().lstrip("%") for c in grp.split(","))
        instr = _Instr(name=name, result_shapes=_shape_list(type_str),
                       op=op, operands=operands, called=called)
        if op == "while":
            tm = _TRIP_RE.search(rhs)
            instr.trip = int(tm.group(1)) if tm else 1
        if op == "dot":
            cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
            # contracting sizes come from the lhs operand's shape
            instr.dot_contract = -1     # resolved later
            instr._cdims = [int(x) for x in
                            cdims.group(1).split(",")] if cdims and \
                cdims.group(1) else []
        cur.instrs[name] = instr
    return comps


def _multipliers(comps: dict[str, _Comp]) -> dict[str, int]:
    """computation name -> product of enclosing trip counts."""
    mult: dict[str, int] = {}
    entry = comps.get("__entry__")
    if entry is None:
        return {}
    stack = [(entry.name, 1)]
    while stack:
        cname, m = stack.pop()
        if cname not in comps:
            continue
        if mult.get(cname, 0) >= m:
            continue
        mult[cname] = max(mult.get(cname, 0), m)
        comp = comps[cname]
        for ins in comp.instrs.values():
            for callee in ins.called:
                k = m * (ins.trip if ins.op == "while" else 1)
                stack.append((callee, k))
    return mult


# Ops whose operand/result traffic is counted as HBM bytes.  Pure
# elementwise chains (add/mul/exp/select/compare/...), broadcasts, iota,
# reshapes and converts are EXCLUDED: on the TPU target XLA fuses them into
# the producing/consuming kernel, so counting them models a no-fusion
# worst case that the CPU test backend exhibits but real hardware does not.
_MEM_OPS = {"fusion", "dot", "copy", "scatter", "gather", "dynamic-slice",
            "dynamic-update-slice", "transpose", "concatenate", "pad",
            "reduce", "convolution", "slice", "reduce-window",
            "select-and-scatter", "sort"}
_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "while", "conditional", "call", "after-all",
             "custom-call", "partition-id", "replica-id", "domain",
             "opt-barrier", "rng", "rng-bit-generator", "convert",
             "broadcast", "iota", "reshape", "add", "multiply", "select",
             "compare", "exponential", "rsqrt", "tanh", "divide",
             "subtract", "maximum", "minimum", "clamp", "negate", "power",
             "and", "or", "xor", "sqrt", "log", "sign", "floor", "ceil"}


def _bf16_entry_dims(text: str) -> set:
    dims = set()
    in_entry = False
    for line in text.splitlines():
        if line.startswith("ENTRY "):
            in_entry = True
            continue
        if in_entry and line.startswith("}"):
            break
        if in_entry:
            m = re.search(r"= bf16\[([\d,]+)\][^=]*parameter\(", line)
            if m:
                d = tuple(int(x) for x in m.group(1).split(","))
                dims.add(d)
                if len(d) > 1:
                    dims.add(d[1:])   # per-layer slice of a scan-stacked param
    return dims


def xla_cost_analysis(compiled) -> dict:
    """Normalise ``Compiled.cost_analysis()``: a dict on new jax, a
    single-element list of dicts on jax<=0.4.x."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        return ca[0] if ca else {}
    return ca


def analyze(text: str) -> dict:
    comps = parse_module(text)
    mult = _multipliers(comps)
    bf16_dims = _bf16_entry_dims(text)

    def tpu_bytes(shapes) -> int:
        """Bytes with f32 mirrors of bf16 inputs charged at bf16 width —
        XLA-CPU upcasts bf16 dot operands to f32; the TPU MXU reads bf16
        natively, so those tensors are half the size on the target."""
        total = 0
        for dt, dims in shapes:
            n = 1
            for d in dims:
                n *= d
            w = _DTYPE_BYTES[dt]
            if dt == "f32" and tuple(dims) in bf16_dims:
                w = 2
            total += n * w
        return total
    # computations that are fusion bodies: their interior ops are NOT at the
    # HBM boundary — count their dot flops but not their bytes.
    fusion_bodies: set = set()
    for comp in comps.values():
        for ins in comp.instrs.values():
            if ins.op == "fusion":
                fusion_bodies.update(ins.called)
    flops = 0.0
    bytes_hbm = 0.0
    coll = {k: 0.0 for k in COLLECTIVES}
    coll_counts = {k: 0 for k in COLLECTIVES}
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0)
        if m == 0:
            continue
        for ins in comp.instrs.values():
            rbytes = _bytes_of(ins.result_shapes)
            # --- collectives ---
            matched = None
            for ck in COLLECTIVES:
                if ins.op == ck or ins.op == ck + "-start":
                    matched = ck
                    break
            if matched:
                # XLA-CPU upcasts the bf16 compute stream to f32, so its
                # collectives carry f32 payloads; the TPU target keeps
                # weights/activations/grads in bf16 end-to-end and its
                # collectives move HALF the bytes.  Charge f32 collective
                # payloads at bf16 width (f32-native payloads — e.g. CE
                # statistics — are small).
                cb = 0
                for dt, dims in ins.result_shapes:
                    n = 1
                    for d in dims:
                        n *= d
                    w = 2 if dt == "f32" else _DTYPE_BYTES[dt]
                    cb += n * w
                coll[matched] += m * cb
                coll_counts[matched] += m
                bytes_hbm += m * cb
                continue
            # --- dot flops ---
            if ins.op == "dot":
                lhs = comp.instrs.get(ins.operands[0]) if ins.operands else None
                csize = 1
                if lhs is not None and lhs.result_shapes:
                    dims = lhs.result_shapes[0][1]
                    for cd in getattr(ins, "_cdims", []):
                        if cd < len(dims):
                            csize *= dims[cd]
                n_out = 1
                for _, dd in ins.result_shapes:
                    for d in dd:
                        n_out *= d
                flops += m * 2.0 * n_out * csize
            # --- bytes: result write + operand reads at fusion boundary ---
            if cname in fusion_bodies:
                continue
            if ins.op in _SKIP_OPS:
                continue
            if ins.op == "fusion" or ins.op in _MEM_OPS:
                rb = tpu_bytes(ins.result_shapes)
                operand_bytes = []
                for on in ins.operands:
                    src = comp.instrs.get(on)
                    if src is None:
                        continue
                    # charge converts (CPU f32-upcast artifact) at the
                    # size of their source operand
                    if src.op == "convert" and src.operands:
                        src2 = comp.instrs.get(src.operands[0])
                        if src2 is not None:
                            operand_bytes.append(
                                tpu_bytes(src2.result_shapes))
                            continue
                    operand_bytes.append(tpu_bytes(src.result_shapes))
                ob = sum(operand_bytes)
                # dynamic-update-slice executes IN PLACE on the TPU target
                # (buffer aliasing): traffic = the update slice read+write,
                # not the whole target buffer.
                def _root_is_dus() -> bool:
                    for cal in ins.called:
                        cc = comps.get(cal)
                        if cc is None or not cc.root:
                            continue
                        r = cc.instrs.get(cc.root)
                        hops = 0
                        while r is not None and hops < 8:
                            if r.op == "dynamic-update-slice":
                                return True
                            if r.op in ("convert", "bitcast") and r.operands:
                                r = cc.instrs.get(r.operands[0])
                                hops += 1
                                continue
                            break
                    return False

                def _root_is(opname: str) -> bool:
                    for cal in ins.called:
                        cc = comps.get(cal)
                        if cc is None or not cc.root:
                            continue
                        r = cc.instrs.get(cc.root)
                        hops = 0
                        while r is not None and hops < 8:
                            if r.op == opname:
                                return True
                            if r.op in ("convert", "bitcast") and r.operands:
                                r = cc.instrs.get(r.operands[0])
                                hops += 1
                                continue
                            break
                    return False

                is_dus = (ins.op == "dynamic-update-slice"
                          or (ins.op == "fusion" and _root_is_dus()))
                is_ds = (ins.op == "dynamic-slice"
                         or (ins.op == "fusion"
                             and _root_is("dynamic-slice")))
                if is_dus and operand_bytes:
                    big = max(operand_bytes)
                    upd = ob - big
                    bytes_hbm += m * 2 * upd
                elif is_ds:
                    # dynamic-slice reads only the slice, not the operand
                    bytes_hbm += m * rb
                else:
                    bytes_hbm += m * (rb + ob)
    coll_total = sum(coll.values())
    return {"flops": flops, "bytes": bytes_hbm,
            "collectives": {**{k: v for k, v in coll.items()},
                            "total": coll_total, "counts": coll_counts}}


def breakdown(text: str, top: int = 15):
    """Debug: top byte contributors as (bytes, op, mult, result-shape)."""
    import collections
    comps = parse_module(text)
    mult = _multipliers(comps)
    full = analyze(text)          # ensures same semantics
    rows = collections.Counter()
    bf16_dims = _bf16_entry_dims(text)

    def tb(shapes):
        total = 0
        for dt, dims in shapes:
            n = 1
            for d in dims:
                n *= d
            w = _DTYPE_BYTES[dt]
            if dt == "f32" and tuple(dims) in bf16_dims:
                w = 2
            total += n * w
        return total

    fusion_bodies = set()
    for comp in comps.values():
        for ins in comp.instrs.values():
            if ins.op == "fusion":
                fusion_bodies.update(ins.called)
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0)
        if not m or cname in fusion_bodies:
            continue
        for ins in comp.instrs.values():
            if ins.op in _SKIP_OPS or (ins.op != "fusion"
                                       and ins.op not in _MEM_OPS):
                continue
            ob = []
            for on in ins.operands:
                src = comp.instrs.get(on)
                if src is None:
                    continue
                if src.op == "convert" and src.operands:
                    s2 = comp.instrs.get(src.operands[0])
                    if s2 is not None:
                        ob.append(tb(s2.result_shapes))
                        continue
                ob.append(tb(src.result_shapes))

            def root_is(opname):
                for cal in ins.called:
                    cc = comps.get(cal)
                    if cc is None or not cc.root:
                        continue
                    r = cc.instrs.get(cc.root)
                    hops = 0
                    while r is not None and hops < 8:
                        if r.op == opname:
                            return True
                        if r.op in ("convert", "bitcast") and r.operands:
                            r = cc.instrs.get(r.operands[0])
                            hops += 1
                            continue
                        break
                return False

            rb = tb(ins.result_shapes)
            if (ins.op == "dynamic-update-slice"
                    or (ins.op == "fusion" and root_is("dynamic-update-slice"))):
                tot = m * 2 * (sum(ob) - max(ob)) if ob else 0
                tag = "DUS"
            elif (ins.op == "dynamic-slice"
                  or (ins.op == "fusion" and root_is("dynamic-slice"))):
                tot = m * rb
                tag = "DS"
            else:
                tot = m * (rb + sum(ob))
                tag = ins.op
            sh = str(ins.result_shapes[0]) if ins.result_shapes else "?"
            rows[(tag, m, sh[:64])] += tot
    return rows.most_common(top), full
