import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape) cell on the 16×16 single-pod mesh AND the
2×16×16 multi-pod mesh, prove it fits 16 GiB/chip, and extract the roofline
terms (FLOPs / bytes / collective bytes) from the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single --out experiments/dryrun

Results land as JSON under --out (default experiments/dryrun/) — one file
per (arch, shape, mesh) — and feed EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse
import json
import re
import time
import traceback


from . import hlo_cost
from ..configs import ARCH_IDS, get
from ..distributed.sharding import ShardingPolicy
from .mesh import make_production_mesh
from .steps import SHAPES, build_cell, cell_supported

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_BYTES = 16 * 1024 ** 3

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def cpu_upcast_bytes(hlo_text: str) -> int:
    """XLA-CPU cannot execute bf16 dots, so it materializes fp32 copies of
    bf16 weight/cache operands (convert ops).  These temps would NOT exist
    on the TPU target (native bf16 MXU), so the fit check subtracts them.
    Heuristic: sum distinct f32 ``convert`` results whose dims exactly match
    a bf16 ENTRY parameter shard shape."""
    bf16_param_dims: set[str] = set()
    in_entry = False
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY "):
            in_entry = True
            continue
        if in_entry and line.startswith("}"):
            in_entry = False
        if in_entry:
            m = re.search(r"= bf16\[([\d,]+)\][^=]*parameter\(", line)
            if m:
                bf16_param_dims.add(m.group(1))
    seen: set[str] = set()
    total = 0
    for line in hlo_text.splitlines():
        m = re.search(r"%(\S+) = f32\[([\d,]+)\]\S* convert\(", line.strip())
        if m and m.group(2) in bf16_param_dims and m.group(1) not in seen:
            seen.add(m.group(1))
            n = 1
            for d in m.group(2).split(","):
                n *= int(d)
            total += 4 * n
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device result bytes of every collective op in the SPMD module."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.split(" = ", 1)
        if len(eq) != 2:
            continue
        rhs = eq[1]
        for coll in _COLLECTIVES:
            # match the op name exactly (e.g. "all-reduce(" / "all-reduce-start(")
            if re.search(rf"\b{coll}(-start)?\(", rhs):
                lhs_shape = rhs.split(coll)[0]
                out[coll] += _shape_bytes(lhs_shape)
                counts[coll] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             verbose: bool = True, save_hlo: bool = False,
             variant: str = "") -> dict:
    from .steps import parse_variant
    var = parse_variant(variant)
    cfg = get(arch)
    ok, why = cell_supported(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "variant": variant, "ok": False, "skipped": False}
    if not ok:
        rec.update(skipped=True, reason=why, ok=True)
        _save(rec, out_dir)
        if verbose:
            print(f"[skip] {arch} × {shape}: {why}")
        return rec
    try:
        t0 = time.time()
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.devices.size
        mode = "train" if SHAPES[shape].kind == "train" else "serve"
        policy = ShardingPolicy(mesh=mesh, mode=mode, **var["policy"])
        with mesh:
            jitted, structs, meta = build_cell(cfg, shape, policy,
                                               **var["build"])
            lowered = jitted.lower(*structs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = hlo_cost.xla_cost_analysis(compiled)
        hlo = compiled.as_text()
        # trip-count-aware reconstruction (XLA cost_analysis counts while
        # bodies ONCE — hlo_cost multiplies by known_trip_count)
        acc = hlo_cost.analyze(hlo)
        coll = {k: acc["collectives"].get(k, 0.0)
                for k in _COLLECTIVES}
        coll["total"] = acc["collectives"]["total"]
        coll["counts"] = acc["collectives"]["counts"]

        flops = acc["flops"]
        bytes_hbm = acc["bytes"]
        raw_flops = float(cost.get("flops", 0.0)) if cost else 0.0
        arg_b = getattr(mem, "argument_size_in_bytes", 0)
        out_b = getattr(mem, "output_size_in_bytes", 0)
        tmp_b = getattr(mem, "temp_size_in_bytes", 0)
        alias_b = getattr(mem, "alias_size_in_bytes", 0)
        per_dev = arg_b + out_b + tmp_b - alias_b
        upcast_b = cpu_upcast_bytes(hlo)
        # distinct converts may share buffers — never subtract below args+out
        per_dev_tpu = arg_b + out_b - alias_b + max(tmp_b - upcast_b, 0)

        # roofline terms (seconds) — spec formulas; flops/bytes from the
        # partitioned per-device module are multiplied back to cluster
        # totals by XLA already? No: cost_analysis on the SPMD-compiled
        # executable reports PER-DEVICE numbers, so divide by per-chip peaks.
        compute_s = flops / PEAK_FLOPS
        memory_s = bytes_hbm / HBM_BW
        collective_s = coll["total"] / ICI_BW

        # 6ND for training (fwd+bwd), 2ND for inference passes
        flop_factor = 6.0 if SHAPES[shape].kind == "train" else 2.0
        model_flops = flop_factor * cfg.active_param_count() * _tokens(shape)
        rec.update(
            ok=True, chips=chips, lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2), meta=meta,
            memory={"argument": arg_b, "output": out_b, "temp": tmp_b,
                    "alias": alias_b, "per_device_total": per_dev,
                    "cpu_upcast_artifact": upcast_b,
                    "per_device_tpu_estimate": per_dev_tpu,
                    "fits_16GiB": bool(per_dev_tpu <= HBM_BYTES),
                    "utilization": per_dev_tpu / HBM_BYTES},
            hlo_flops_per_device=flops,
            hlo_bytes_per_device=bytes_hbm,
            xla_cost_analysis_flops_unscaled=raw_flops,
            collectives=coll,
            roofline={"compute_s": compute_s, "memory_s": memory_s,
                      "collective_s": collective_s,
                      "dominant": max(
                          [("compute", compute_s), ("memory", memory_s),
                           ("collective", collective_s)],
                          key=lambda kv: kv[1])[0]},
            model_flops_total=model_flops,
            useful_flops_ratio=(model_flops / (flops * chips)
                                if flops else 0.0),
        )
        if save_hlo:
            with open(os.path.join(out_dir,
                                   f"{arch}__{shape}__{mesh_name}.hlo.txt"),
                      "w") as f:
                f.write(hlo)
        if verbose:
            r = rec["roofline"]
            print(f"[ok]   {arch} × {shape} × {mesh_name}: "
                  f"{per_dev_tpu/2**30:.2f} GiB/dev "
                  f"(fits={rec['memory']['fits_16GiB']}), "
                  f"compute={r['compute_s']*1e3:.2f}ms "
                  f"memory={r['memory_s']*1e3:.2f}ms "
                  f"coll={r['collective_s']*1e3:.2f}ms "
                  f"-> {r['dominant']}-bound; "
                  f"compile {t_compile:.0f}s")
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[FAIL] {arch} × {shape} × {mesh_name}: {rec['error']}")
    _save(rec, out_dir)
    return rec


def _tokens(shape: str) -> int:
    sp = SHAPES[shape]
    if sp.kind == "train":
        return sp.batch * sp.seq
    if sp.kind == "prefill":
        return sp.batch * sp.seq
    return sp.batch            # decode: one token per request


def _save(rec: dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{rec['variant'].replace('=','').replace(',','_')}" \
        if rec.get("variant") else ""
    path = os.path.join(
        out_dir,
        f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json")
    slim = {k: v for k, v in rec.items() if k != "trace"}
    with open(path, "w") as f:
        json.dump(slim, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--variant", default="",
                    help="e.g. 'mb=8,attn=dense,grad_rs=1,fsdp=0'")
    ap.add_argument("--assigned-only", action="store_true",
                    help="skip the two paper-eval models")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    if args.assigned_only:
        archs = [a for a in archs if a not in ("qwen2_7b", "qwen3_32b")]
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, args.out,
                               save_hlo=args.save_hlo,
                               variant=args.variant)
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
