"""Training launcher: smoke-scale real training on CPU, or lower/compile a
full-scale sharded train step (see dryrun.py for the multi-pod version).

    PYTHONPATH=src python -m repro.launch.train --arch qwen1_5_0_5b --steps 50
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_0_5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke
    from repro.models import init_params
    from repro.training import (CheckpointManager, TokenPipeline,
                                init_adamw, make_train_step)

    cfg = get_smoke(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_adamw(params, compress=args.compress_grads)
    step_fn = jax.jit(make_train_step(cfg, remat=False, lr=args.lr,
                                      compress_grads=args.compress_grads))
    pipe = TokenPipeline(cfg.vocab, args.batch, args.seq, seed=0,
                         enc_frames=cfg.enc_frames, d_model=cfg.d_model)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        restored, start = mgr.restore({"p": params, "o": opt})
        params, opt = restored["p"], restored["o"]
        print(f"resumed from step {start}")

    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        params, opt, m = step_fn(params, opt, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f}")
        if mgr is not None and (i + 1) % args.ckpt_every == 0:
            mgr.save_async(i + 1, {"p": params, "o": opt})
    if mgr is not None:
        mgr.wait()
    print(f"done: {args.steps - start} steps in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
