"""End-to-end serving driver (deliverable b): a REAL JAX model served with
batched multi-priority requests through the full ProServe stack —
SlideBatching, paged KV pool, chunked prefill, paged flash-decode
(Pallas kernels in interpret mode on CPU), priority preemption with host
offload/reload — and verifies outputs against uninterrupted greedy
generation.

    PYTHONPATH=src python examples/priority_serving.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax                                                         # noqa: E402
import jax.numpy as jnp                                            # noqa: E402
import numpy as np                                                 # noqa: E402

from repro.configs import get_smoke                                # noqa: E402
from repro.core import EngineConfig, Request, SLO, make_policy     # noqa: E402
from repro.core.tdg import tdg_ratio                               # noqa: E402
from repro.models import forward, init_params                      # noqa: E402
from repro.serving import Engine                                   # noqa: E402


def main():
    cfg = get_smoke("qwen1_5_0_5b")      # reduced qwen1.5 family config
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # deliberately tiny pool so high-priority arrivals preempt low-priority
    eng = Engine(cfg, params,
                 EngineConfig(eta=1.0, w_p=4.0, tau=1e9),
                 make_policy("slidebatching"),
                 num_blocks=20, block_size=16, max_ctx=256)

    reqs = []
    for i in range(8):
        prio = 1 if i % 3 == 0 else 2
        plen = int(rng.integers(16, 48))
        r = Request(prompt_len=plen, output_len=8, arrival=0.0,
                    slo=SLO(ttft=30.0, tpot=10.0), priority=prio,
                    weight=2.0 if prio == 1 else 1.0)
        prompt = rng.integers(1, cfg.vocab, plen).astype(np.int32)
        eng.add_request(r, prompt)
        reqs.append((r, prompt))

    t0 = time.time()
    eng.run_until_drained()
    wall = time.time() - t0

    print(f"served {len(reqs)} multi-priority requests in {wall:.1f}s "
          f"({eng.stats.iterations} iterations, "
          f"{eng.stats.tokens_out} tokens, "
          f"{eng.stats.evictions} preemption evictions, "
          f"{eng.stats.reload_blocks} blocks reloaded)")
    print(f"TDG_Ratio = {tdg_ratio([r for r, _ in reqs], w_p=4.0):.3f}")

    # verify every output against uninterrupted greedy generation
    print("\nverifying against teacher-forced greedy reference...")
    mismatches = 0
    for r, prompt in reqs:
        cur = jnp.asarray(prompt)[None, :]
        ref = []
        for _ in range(r.output_len):
            logits, _ = forward(cfg, params, cur)
            nxt = int(jnp.argmax(logits[0, -1]))
            ref.append(nxt)
            cur = jnp.concatenate([cur, jnp.asarray([[nxt]])], axis=1)
        ok = eng.outputs[r.rid] == ref
        mismatches += not ok
        print(f"  rid={r.rid} prio={r.priority} "
              f"preemptions={r.preemptions} exact={ok}")
    assert mismatches == 0, "preemption path corrupted generation!"
    print("\nall outputs token-for-token exact through preemption ✓")


if __name__ == "__main__":
    main()
