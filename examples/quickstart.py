"""Quickstart: ProServe's scheduling core on the cluster simulator.

Runs a multi-priority ShareGPT-like workload through SlideBatching and two
baselines on a simulated 4-chip TPU-v5e instance and prints the paper's
headline metrics (TDG_Ratio + SLO attainment, overall and per priority).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import EngineConfig, make_policy                   # noqa: E402
from repro.sim import (AnalyticalExecutor, EngineSim,              # noqa: E402
                       InstanceHardware, QWEN2_7B, summarize)
from repro.sim.workloads import sharegpt                           # noqa: E402


def drive(engine, reqs):
    pending = sorted(reqs, key=lambda r: r.arrival)
    now, i = 0.0, 0
    while i < len(pending) or engine.has_work():
        while i < len(pending) and pending[i].arrival <= now:
            engine.add_request(pending[i], now)
            i += 1
        res = engine.step(now)
        if res is None:
            if i >= len(pending):
                break
            now = pending[i].arrival
        else:
            now = res.end


def main():
    executor = AnalyticalExecutor(QWEN2_7B, InstanceHardware(chips=4))
    estimator, mape = executor.fit_estimator()
    print(f"batch-latency estimator fitted: MAPE={mape:.1%} "
          f"(paper reports ~4.5%)\n")

    print(f"{'scheduler':18s} {'TDG':>6s} {'SLO':>6s} "
          f"{'TDG hi':>7s} {'TDG lo':>7s} {'ttft p99':>9s}")
    for name in ("slidebatching", "sarathi_fcfs", "vllm_fcfs",
                 "sarathi_priority", "weighted_vtc", "fair_batching"):
        reqs = sharegpt(rate=85, duration=20, seed=0)
        eng = EngineSim(0, make_policy(name), executor, estimator,
                        EngineConfig(w_p=4.0))
        drive(eng, reqs)
        s = summarize(reqs, w_p=4.0)
        print(f"{name:18s} {s.tdg_ratio:6.3f} {s.slo_attainment:6.3f} "
              f"{s.per_priority[1]['tdg_ratio']:7.3f} "
              f"{s.per_priority[2]['tdg_ratio']:7.3f} "
              f"{s.ttft_p99:9.3f}")


if __name__ == "__main__":
    main()
