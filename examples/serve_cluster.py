"""Async multi-replica serving demo: the industrial diurnal trace replayed
through the streaming ``ServiceFrontend`` — 64+ concurrent requests of
three priority classes, GoRouting dispatch over real JAX engine replicas,
continuous batching on per-replica driver threads, and per-priority
TTFT/TPOT SLO attainment + gain measured at the CLIENT edge.

    PYTHONPATH=src python examples/serve_cluster.py             # full demo
    PYTHONPATH=src python examples/serve_cluster.py --smoke     # CI-sized
"""
import argparse
import asyncio
import sys

sys.path.insert(0, "src")

import jax                                                         # noqa: E402

from repro.configs import get_smoke                                # noqa: E402
from repro.core import (EngineConfig, GoRouting, RouterConfig,     # noqa: E402
                        SLO, make_policy)
from repro.core.estimator import BatchLatencyEstimator             # noqa: E402
from repro.models import init_params                               # noqa: E402
from repro.serving import Engine, FrontendConfig, ServiceFrontend  # noqa: E402
from repro.sim import clip_lengths, replay_frontend                # noqa: E402
from repro.sim.workloads import industrial                         # noqa: E402

CFG = get_smoke("qwen1_5_0_5b")
PARAMS = init_params(CFG, jax.random.PRNGKey(0))


def make_engine():
    return Engine(CFG, PARAMS, EngineConfig(eta=1.0, w_p=4.0, tau=1e9),
                  make_policy("slidebatching"),
                  num_blocks=160, block_size=16, max_ctx=256)


async def serve(n_requests: int, n_replicas: int, max_out: int) -> None:
    est = BatchLatencyEstimator(a_p=1e-8, b_p=1e-8, c_p=1e-4, a_d=1e-8,
                                b_d=1e-3, t_c=1e-2)
    frontend = ServiceFrontend(
        GoRouting(est, RouterConfig(pd_mode="coloc")), est,
        FrontendConfig(max_inflight=max(n_requests, 64)))
    iids = [frontend.add_instance(make_engine()) for _ in range(n_replicas)]
    await frontend.start()
    print(f"cluster up: {n_replicas} engine replicas {iids}")

    # industrial mix (Fig. 1): 3 priority classes, diurnal phase shifts.
    # Clipped to smoke-model lengths; replayed at 1000x so the whole trace
    # is in flight concurrently.  SLOs sized for CPU wall-clock.
    trace = industrial(rate=n_requests / 2.0, duration=8.0,
                       seed=1)[:n_requests]
    trace = clip_lengths(trace, max_in=48, max_out=max_out,
                         slo=SLO(ttft=90.0, tpot=15.0))
    prios = sorted({r.priority for r in trace})
    print(f"replaying {len(trace)} requests, priorities {prios} ...")

    report = await replay_frontend(frontend, trace, CFG.vocab,
                                   speed=1000.0, w_p=4.0)
    await frontend.stop()

    print(f"\n{report.n_completed}/{report.n_submitted} streams completed "
          f"({report.n_rejected} rejected) in {report.wall:.1f}s wall")
    s = report.summary
    print(f"client-edge overall: gain(TDG)={s.tdg_ratio:.3f} "
          f"SLO={s.slo_attainment:.2%} ttft_p50={s.ttft_p50:.2f}s "
          f"tpot_p50={s.tpot_p50:.3f}s")
    for p, m in sorted(report.per_priority.items()):
        print(f"  priority {p}: gain={m['tdg_ratio']:.3f} "
              f"SLO={m['slo']:.2%} ttft_p99={m['ttft_p99']:.2f}s")
    for iid, eng in frontend.engines.items():
        st = frontend.book.states.get(iid)
        speed = f", speed-EWMA {st.speed:.2f}" if st else ""
        print(f"  replica {iid}: {eng.stats.iterations} iters, "
              f"{eng.stats.tokens_out} tokens{speed}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: few requests, short outputs")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--replicas", type=int, default=2)
    args = ap.parse_args()
    n = args.requests or (8 if args.smoke else 64)
    max_out = 2 if args.smoke else 4
    asyncio.run(serve(n, args.replicas, max_out))


if __name__ == "__main__":
    main()
