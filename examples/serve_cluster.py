"""Multi-instance serving with GoRouting + fault tolerance + elasticity:
three real engines behind the service controller; one is killed mid-flight
(requests resume exactly from the durable log), a fresh one is added
(elastic scale-up), and everything completes.

    PYTHONPATH=src python examples/serve_cluster.py
"""
import sys

sys.path.insert(0, "src")

import jax                                                         # noqa: E402
import numpy as np                                                 # noqa: E402

from repro.configs import get_smoke                                # noqa: E402
from repro.core import (EngineConfig, GoRouting, Request,          # noqa: E402
                        RouterConfig, SLO, make_policy)
from repro.core.estimator import BatchLatencyEstimator             # noqa: E402
from repro.models import init_params                               # noqa: E402
from repro.serving import Engine, ServiceController                # noqa: E402

CFG = get_smoke("qwen1_5_0_5b")
PARAMS = init_params(CFG, jax.random.PRNGKey(0))


def make_engine():
    return Engine(CFG, PARAMS, EngineConfig(eta=1.0, w_p=4.0, tau=1e9),
                  make_policy("slidebatching"),
                  num_blocks=96, block_size=16, max_ctx=256)


def main():
    est = BatchLatencyEstimator(a_p=1e-8, b_p=1e-8, c_p=1e-4, a_d=1e-8,
                                b_d=1e-3, t_c=1e-2)
    svc = ServiceController(GoRouting(est, RouterConfig(pd_mode="coloc")),
                            est)
    iids = [svc.add_instance(make_engine()) for _ in range(3)]
    print(f"cluster up: instances {iids}")

    rng = np.random.default_rng(1)
    for k in range(12):
        plen = int(rng.integers(12, 40))
        r = Request(prompt_len=plen, output_len=6, arrival=0.0,
                    slo=SLO(600.0, 600.0), priority=1 + k % 2,
                    weight=2.0 if k % 2 == 0 else 1.0)
        iid = svc.submit(r, rng.integers(1, CFG.vocab, plen).astype(np.int32))
        print(f"  req {r.rid} (prio {r.priority}) -> instance {iid}")

    svc.step_all()
    print(f"\nkilling instance {iids[0]} (hard failure)...")
    svc.kill_instance(iids[0])
    new_iid = svc.add_instance(make_engine())
    print(f"elastic scale-up: instance {new_iid} joins")

    svc.serve_until_drained()
    print(f"\nall {len(svc.finished)} requests completed "
          f"(orphans resumed from the request log mid-generation)")
    for iid, eng in svc.engines.items():
        print(f"  instance {iid}: {eng.stats.iterations} iters, "
              f"{eng.stats.tokens_out} tokens, speed-EWMA "
              f"{svc.states[iid].speed:.2f}")


if __name__ == "__main__":
    main()
