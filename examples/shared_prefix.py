"""Shared-prefix serving demo: many streams sharing a common system prompt,
served by real JAX engine replicas with the radix prefix cache ON vs OFF.

With the cache on, only the first request of each prefix group prefills the
shared span; every later request points its block table at the cached
blocks (copy-on-write paged KV) and prefills just its unique suffix — and
GoRouting's prefix-affinity term keeps each group pinned to the replica
already holding its KV.  The demo prints prefill tokens actually computed,
cache hit tokens, and client-edge TTFT for both runs.

    PYTHONPATH=src python examples/shared_prefix.py             # full demo
    PYTHONPATH=src python examples/shared_prefix.py --smoke     # CI-sized
"""
import argparse
import asyncio
import sys

sys.path.insert(0, "src")

from repro.sim import replay_frontend                              # noqa: E402
from repro.sim.replay import (smoke_frontend,                      # noqa: E402
                              smoke_shared_prefix_trace)


async def serve(n_requests: int, n_replicas: int, max_out: int,
                prefix_cache: bool) -> dict:
    frontend, cfg = smoke_frontend(n_replicas, prefix_cache=prefix_cache,
                                   w_p=4.0)
    await frontend.start()
    # 80% of streams share one of 2 system prompts; clipped to smoke size
    # (48-token prompts, 32-token shared span = 2 KV blocks).
    trace = smoke_shared_prefix_trace(n_requests, max_out=max_out)
    # speed 200x spreads arrivals over ~40ms so later requests of a group
    # actually find the first one's prefix in cache
    report = await replay_frontend(frontend, trace, cfg.vocab,
                                   speed=200.0, w_p=4.0)
    engines = list(frontend.engines.values())
    out = {
        "completed": f"{report.n_completed}/{report.n_submitted}",
        "prefill_tokens": sum(e.stats.prefill_tokens for e in engines),
        "cache_hit_tokens": sum(e.stats.cache_hit_tokens for e in engines),
        "ttft_p50_s": round(report.summary.ttft_p50, 2),
        "wall_s": round(report.wall, 1),
    }
    await frontend.stop()
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: few requests, short outputs")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--replicas", type=int, default=2)
    args = ap.parse_args()
    n = args.requests or (8 if args.smoke else 48)
    max_out = 2 if args.smoke else 4
    for cache in (True, False):
        # first pass pays one-off JIT compilation; report the warm pass so
        # the on/off comparison is apples-to-apples
        asyncio.run(serve(n, args.replicas, max_out, cache))
        res = asyncio.run(serve(n, args.replicas, max_out, cache))
        print(f"prefix_cache={'on ' if cache else 'off'}  "
              + "  ".join(f"{k}={v}" for k, v in res.items()))


if __name__ == "__main__":
    main()
