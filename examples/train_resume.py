"""Training example: a reduced-config model trained for a few hundred steps
with async checkpointing and a simulated crash + restart — the restarted
run replays the deterministic pipeline and lands on identical parameters.

    PYTHONPATH=src python examples/train_resume.py [--steps 120]
"""
import argparse
import sys
import tempfile
import time

sys.path.insert(0, "src")

import jax                                                         # noqa: E402
import jax.numpy as jnp                                            # noqa: E402

from repro.configs import get_smoke                                # noqa: E402
from repro.models import init_params                               # noqa: E402
from repro.training import (CheckpointManager, TokenPipeline,      # noqa: E402
                            init_adamw, make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--arch", default="qwen1_5_0_5b")
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_adamw(params)
    step_fn = jax.jit(make_train_step(cfg, remat=False, lr=3e-3))
    pipe = TokenPipeline(cfg.vocab, batch=8, seq=64, seed=0)
    ckdir = tempfile.mkdtemp(prefix="proserve_ck_")
    mgr = CheckpointManager(ckdir, keep=2)

    t0, losses = time.time(), []
    crash_at = args.steps // 2
    for i in range(crash_at):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
        if i % 20 == 19:
            mgr.save_async(i + 1, {"p": params, "o": opt})
            print(f"step {i+1:4d} loss {losses[-1]:.3f} "
                  f"(async checkpoint)")
    mgr.wait()
    print(f"\n-- simulated crash at step {crash_at} --")

    restored, at = mgr.restore({"p": params, "o": opt})
    params, opt = restored["p"], restored["o"]
    print(f"restarted from checkpoint step {at}; replaying pipeline...")
    for i in range(at, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
        if i % 20 == 19:
            print(f"step {i+1:4d} loss {float(m['loss']):.3f}")

    print(f"\ntrained {args.steps} steps (with restart) in "
          f"{time.time()-t0:.1f}s; loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss should descend"


if __name__ == "__main__":
    main()
