"""Repo tooling: docs link check, perf smoke gate (importable so the
benchmark suite can reuse the perf-smoke harness)."""
