#!/usr/bin/env python3
"""Markdown link check: every relative link/image target in README.md and
docs/ must exist in the repo (anchors and external URLs are skipped).

    python tools/check_docs_links.py            # from the repo root
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")

# docs that must exist — the docs/*.md glob silently skips missing files,
# so a deleted BENCHMARKS.md would otherwise pass the link check
REQUIRED = ("README.md", "docs/ARCHITECTURE.md", "docs/BENCHMARKS.md",
            "docs/TESTING.md", "docs/WORKLOADS.md")


def check(root: pathlib.Path) -> list[str]:
    errors = [f"{rel}: required doc missing" for rel in REQUIRED
              if not (root / rel).exists()]
    files = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    for md in files:
        if not md.exists():
            errors.append(f"{md}: file missing")
            continue
        for ln, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK.findall(line):
                if target.startswith(("http://", "https://", "mailto:", "#")):
                    continue
                path = (md.parent / target.split("#")[0]).resolve()
                if not path.exists():
                    errors.append(f"{md.relative_to(root)}:{ln}: "
                                  f"broken link -> {target}")
    return errors


def main() -> int:
    root = pathlib.Path(__file__).resolve().parent.parent
    errors = check(root)
    for e in errors:
        print(e, file=sys.stderr)
    n_files = 1 + len(list((root / "docs").glob("*.md")))
    print(f"checked {n_files} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken link(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
