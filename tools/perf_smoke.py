"""Wall-clock sanity check for the overlapped execution engine.

Runs the same prefill-heavy request set through two smoke-scale engines —
baseline (per-request prefill, synchronous transfers) vs overlapped
(packed prefill + async transfer lanes) — and asserts that

  * both produce byte-identical token streams, and
  * the overlapped engine's prefill throughput (prompt tokens/s) improves
    by at least ``--min-speedup`` (a deliberately conservative CI gate;
    see benchmarks/replay_bench.py:replay_overlap for the measured
    numbers).

Each configuration gets one warm-up pass so JIT compilation does not
pollute the comparison.

    PYTHONPATH=src python tools/perf_smoke.py [--min-speedup 1.1]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core import EngineConfig, Request, SLO, make_policy
from repro.models import init_params
from repro.serving import Engine


def build_engine(cfg, params, *, packed: bool, overlap: bool,
                 max_ctx: int = 1024) -> Engine:
    # max_ctx matches the Engine default: the per-request fallback stages
    # the full max_ctx span per chunk, which is precisely the quadratic
    # term the packed path eliminates
    return Engine(cfg, params, EngineConfig(eta=1.0, w_p=4.0, tau=1e9),
                  make_policy("slidebatching"), num_blocks=512,
                  block_size=16, max_ctx=max_ctx,
                  packed_prefill=packed, overlap_transfers=overlap)


def make_trace(cfg, n_req: int, prompt_len: int, out_len: int, seed: int):
    rng = np.random.default_rng(seed)
    return [(Request(prompt_len=prompt_len, output_len=out_len, arrival=0.0,
                     slo=SLO(3600.0, 3600.0), priority=2),
             rng.integers(1, cfg.vocab, prompt_len).astype(np.int32))
            for _ in range(n_req)]


def run_once(cfg, params, trace, *, packed: bool,
             overlap: bool) -> tuple[dict, dict]:
    eng = build_engine(cfg, params, packed=packed, overlap=overlap)
    for req, prompt in trace:
        eng.add_request(req, prompt)
    t0 = time.monotonic()
    eng.run_until_drained(max_iters=5000)
    wall = time.monotonic() - t0
    outputs = {i: eng.outputs[req.rid] for i, (req, _) in enumerate(trace)}
    decode_tokens = eng.stats.tokens_out - len(trace)  # first tokens excluded
    row = {
        "packed": packed, "overlap": overlap, "wall_s": round(wall, 3),
        "prefill_tokens": eng.stats.prefill_tokens,
        "prefill_tok_per_s": round(eng.stats.prefill_tokens / wall, 1),
        "decode_tokens": decode_tokens,
        "tpot_proxy_ms": round(1e3 * wall / max(decode_tokens, 1), 3),
        "iterations": eng.stats.iterations,
        "packed_calls": eng.stats.packed_prefill_calls,
    }
    eng.kill()
    return row, outputs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-speedup", type=float, default=1.1,
                    help="CI gate on prefill tokens/s — set well below the "
                         "typically measured ~1.8x so shared-runner noise "
                         "can't flake the job; it still catches the packed "
                         "path regressing to (or below) baseline")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=160)
    ap.add_argument("--decode-len", type=int, default=8,
                    help="output length of the decode-TPOT trace")
    ap.add_argument("--max-tpot-ratio", type=float, default=1.3,
                    help="CI gate: overlapped decode TPOT may not exceed "
                         "baseline by more than this factor")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke("qwen1_5_0_5b")
    params = init_params(cfg, jax.random.PRNGKey(0))

    def measure(out_len):
        rows, streams = [], {}
        for packed, overlap in ((False, False), (True, True)):
            for warm in (True, False):
                trace = make_trace(cfg, args.requests, args.prompt_len,
                                   out_len, args.seed)
                row, outs = run_once(cfg, params, trace, packed=packed,
                                     overlap=overlap)
            rows.append(row)
            streams[(packed, overlap)] = outs
        return rows, streams[(False, False)] == streams[(True, True)]

    # prefill-heavy trace: one output token, so wall time IS prefill time
    (base_p, fast_p), same_p = measure(1)
    # decode trace: several output tokens; decode path is untouched by the
    # overlap engine, so its TPOT must not regress
    (base_d, fast_d), same_d = measure(args.decode_len)

    speedup = fast_p["prefill_tok_per_s"] / max(base_p["prefill_tok_per_s"],
                                                1e-9)
    tpot_ratio = fast_d["tpot_proxy_ms"] / max(base_d["tpot_proxy_ms"],
                                               1e-9)
    print(json.dumps({
        "prefill": {"baseline": base_p, "overlapped": fast_p,
                    "speedup": round(speedup, 2)},
        "decode": {"baseline": base_d, "overlapped": fast_d,
                   "tpot_ratio": round(tpot_ratio, 2)},
        "streams_identical": same_p and same_d}, indent=1))
    if not (same_p and same_d):
        print("FAIL: token streams diverged between baseline and "
              "overlapped engines", file=sys.stderr)
        return 1
    if speedup < args.min_speedup:
        print(f"FAIL: prefill speedup {speedup:.2f}x < "
              f"{args.min_speedup}x gate", file=sys.stderr)
        return 1
    if tpot_ratio > args.max_tpot_ratio:
        print(f"FAIL: decode TPOT ratio {tpot_ratio:.2f}x > "
              f"{args.max_tpot_ratio}x gate", file=sys.stderr)
        return 1
    print(f"OK: {speedup:.2f}x prefill throughput, decode TPOT ratio "
          f"{tpot_ratio:.2f}x, identical streams")
    return 0


if __name__ == "__main__":
    sys.exit(main())
