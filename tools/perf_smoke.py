"""Wall-clock sanity check for the engine hot loop.

Runs the same request sets through smoke-scale engines in contrasting
configurations and asserts that

  * baseline (per-request prefill, sync transfers) vs overlapped (packed
    prefill + async lanes) produce byte-identical token streams and the
    overlapped engine's prefill throughput improves by at least
    ``--min-speedup``;
  * logits-fetch decode vs fused decode (argmax on device, shapes padded
    to persistent jit buckets) produce byte-identical token streams and
    fused decode-step latency does not exceed the logits path by more
    than ``--max-fused-ratio``;
  * the hot loop performs exactly ONE device->host fetch per model
    launch: ``stats.host_syncs == decode_launches + packed_prefill_calls``
    (any hidden sync added to the step path fails the gate).

Each configuration gets one warm-up pass so JIT compilation does not
pollute the comparison.  ``--bench-out`` writes the measurements as
``BENCH_engine_step.json`` (see docs/BENCHMARKS.md); ``--bench-check``
validates a checked-in copy against the current run's gates.

    PYTHONPATH=src python tools/perf_smoke.py [--min-speedup 1.1]
    PYTHONPATH=src python tools/perf_smoke.py --bench-out BENCH_engine_step.json
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core import EngineConfig, Request, SLO, make_policy
from repro.models import init_params
from repro.serving import Engine

BENCH_SCHEMA = 1


def build_engine(cfg, params, *, packed: bool, overlap: bool,
                 fused: bool = True, max_ctx: int = 1024) -> Engine:
    # max_ctx matches the Engine default: the per-request fallback stages
    # the full max_ctx span per chunk, which is precisely the quadratic
    # term the packed path eliminates
    return Engine(cfg, params, EngineConfig(eta=1.0, w_p=4.0, tau=1e9),
                  make_policy("slidebatching"), num_blocks=512,
                  block_size=16, max_ctx=max_ctx,
                  packed_prefill=packed, overlap_transfers=overlap,
                  fused_decode=fused)


def make_trace(cfg, n_req: int, prompt_len: int, out_len: int, seed: int,
               vary_out: bool = False, priority: int = 2):
    """``vary_out`` draws per-request output lengths in
    [out_len/2, out_len], so the decode batch SHRINKS over the run —
    the shape churn that makes bucketed jit caching matter."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n_req):
        ol = (int(rng.integers(max(1, out_len // 2), out_len + 1))
              if vary_out else out_len)
        reqs.append((Request(prompt_len=prompt_len, output_len=ol,
                             arrival=0.0, slo=SLO(3600.0, 3600.0),
                             priority=priority),
                     rng.integers(1, cfg.vocab, prompt_len).astype(np.int32)))
    return reqs


def run_once(cfg, params, trace, *, packed: bool, overlap: bool,
             fused: bool = True) -> tuple[dict, dict]:
    eng = build_engine(cfg, params, packed=packed, overlap=overlap,
                       fused=fused)
    for req, prompt in trace:
        eng.add_request(req, prompt)
    t0 = time.monotonic()
    eng.run_until_drained(max_iters=5000)
    wall = time.monotonic() - t0
    outputs = {i: eng.outputs[req.rid] for i, (req, _) in enumerate(trace)}
    decode_tokens = eng.stats.tokens_out - len(trace)  # first tokens excluded
    row = {
        "packed": packed, "overlap": overlap, "fused": fused,
        "wall_s": round(wall, 3),
        "prefill_tokens": eng.stats.prefill_tokens,
        "prefill_tok_per_s": round(eng.stats.prefill_tokens / wall, 1),
        "decode_tokens": decode_tokens,
        "tpot_proxy_ms": round(1e3 * wall / max(decode_tokens, 1), 3),
        "iterations": eng.stats.iterations,
        "packed_calls": eng.stats.packed_prefill_calls,
        "decode_launches": eng.stats.decode_launches,
        "host_syncs": eng.stats.host_syncs,
        # no-hidden-syncs accounting: exactly one fetch per model launch
        # (the fallback prefill path does one extra fetch per finishing
        # chunk, so the invariant is only asserted for packed engines)
        "hot_loop_fetches_ok": (
            not packed or eng.stats.host_syncs ==
            eng.stats.decode_launches + eng.stats.packed_prefill_calls),
    }
    eng.kill()
    return row, outputs


def measure_overlap(cfg, params, args, out_len):
    """Baseline vs overlapped engine on the same trace (both fused)."""
    rows, streams = [], {}
    for packed, overlap in ((False, False), (True, True)):
        for _warm in (True, False):
            trace = make_trace(cfg, args.requests, args.prompt_len,
                               out_len, args.seed)
            row, outs = run_once(cfg, params, trace, packed=packed,
                                 overlap=overlap)
        rows.append(row)
        streams[(packed, overlap)] = outs
    return rows, streams[(False, False)] == streams[(True, True)]


def measure_fused(cfg, params, args):
    """Logits-fetch vs fused decode on a decode-heavy trace with varied
    output lengths (batch shrinks over the run, exercising the bucketed
    jit cache instead of one compile per exact batch shape)."""
    rows, streams = [], {}
    for fused in (False, True):
        for _warm in (True, False):
            trace = make_trace(cfg, args.requests, args.prompt_len // 2,
                               args.decode_len * 2, args.seed,
                               vary_out=True)
            row, outs = run_once(cfg, params, trace, packed=True,
                                 overlap=True, fused=fused)
        rows.append(row)
        streams[fused] = outs
    return rows, streams[False] == streams[True]


def measure_tier(cfg, params, args):
    """Tiered-KV invariants in exact mode (fp32 cold tier, no quantize):
    a preemption-heavy trace replayed with the legacy unbounded host
    mirror and with a 2-block byte-bounded host tier must emit identical
    token streams, and the bounded run must (a) keep the host tier within
    its byte budget with ``EngineStats.host_bytes`` agreeing with the
    pool's own accounting, and (b) actually push mirror/spill traffic
    through the tier (spills + LRU demotions to the cold dict)."""
    rows = {}
    streams = {}
    budget = None
    for label, bounded in (("unbounded", False), ("bounded", True)):
        eng = Engine(cfg, params, EngineConfig(eta=1.0, w_p=4.0, tau=1e9),
                     make_policy("slidebatching"), num_blocks=10,
                     block_size=16, max_ctx=256,
                     host_tier_bytes=budget if bounded else None,
                     cold_quantize=False)
        if budget is None:
            budget = 2 * eng.pool.tier.block_bytes
            if bounded:          # first engine must already be bounded
                raise AssertionError("probe ordering bug")
        trace = make_trace(cfg, 4, 40, 6, args.seed)
        for req, prompt in trace:
            eng.add_request(req, prompt)
        eng.run_until_drained(max_iters=400)
        s, t = eng.stats, eng.pool.tier
        rows[label] = {
            "host_tier_bytes": budget if bounded else None,
            "evictions": s.evictions,
            "spill_blocks": s.spill_blocks,
            "cold_blocks": s.cold_blocks,
            "host_bytes": s.host_bytes,
            "tier_host_bytes": t.host_bytes,
            "demoted_blocks": t.demoted_blocks,
            "cold_reload_blocks": t.cold_reload_blocks,
        }
        streams[label] = {i: eng.outputs[req.rid]
                          for i, (req, _) in enumerate(trace)}
        eng.kill()
    b = rows["bounded"]
    failures = []
    if streams["unbounded"] != streams["bounded"]:
        failures.append("token streams diverged between unbounded host "
                        "mirror and byte-bounded tier (exact mode)")
    if b["host_bytes"] != b["tier_host_bytes"]:
        failures.append("EngineStats.host_bytes %d != tier accounting %d"
                        % (b["host_bytes"], b["tier_host_bytes"]))
    if b["host_bytes"] > budget:
        failures.append("host tier %d bytes exceeds its %d-byte budget"
                        % (b["host_bytes"], budget))
    if not (b["spill_blocks"] > 0 and b["demoted_blocks"] > 0):
        failures.append("bounded run saw no tier traffic (spills=%d, "
                        "demotions=%d) — not a preemption regime"
                        % (b["spill_blocks"], b["demoted_blocks"]))
    rows["streams_identical"] = streams["unbounded"] == streams["bounded"]
    return rows, failures


def measure_disagg(cfg, params, args):
    """Disaggregated serving parity: live disagg (1 prefill + 1 decode
    replica, KV handed off over the transfer lanes) vs a coloc engine on
    the same trace must emit bitwise-identical token streams with every
    admission-time reservation settling exactly (all hits, reserved ==
    adopted blocks); a matched ClusterSim replay must then reproduce the
    RouterBook's disagg counters verbatim (``sim.metrics.disagg_counters``
    dict equality — the sim<->live accounting contract)."""
    from repro.core import GoRouting, RouterConfig, SLO, Request
    from repro.core.estimator import BatchLatencyEstimator
    from repro.serving import ServiceController
    from repro.sim import (AnalyticalExecutor, ClusterConfig, ClusterSim,
                           InstanceHardware, QWEN2_7B, disagg_counters,
                           replay_sim)

    n = max(4, args.requests // 3)
    plen, olen = max(16, args.prompt_len // 4), 4
    trace = make_trace(cfg, n, plen, olen, args.seed)

    # coloc reference: one engine, direct drive
    ref = build_engine(cfg, params, packed=True, overlap=True, max_ctx=256)
    for req, prompt in trace:
        ref.add_request(Request(prompt_len=req.prompt_len,
                                output_len=req.output_len, arrival=0.0,
                                slo=SLO(3600.0, 3600.0),
                                priority=req.priority), prompt)
    ref.run_until_drained(max_iters=2000)
    ref_streams = [v for _, v in sorted(ref.outputs.items())]
    ref.kill()

    # live disagg: prefill + decode replicas behind the controller
    est = BatchLatencyEstimator(a_p=1e-8, b_p=1e-8, c_p=1e-4, a_d=1e-8,
                                b_d=1e-3, t_c=1e-2)
    svc = ServiceController(GoRouting(est, RouterConfig(pd_mode="disagg")),
                            est)
    pe = Engine(cfg, params, EngineConfig(eta=1.0, w_p=4.0, tau=1e9),
                make_policy("slidebatching"), num_blocks=512,
                block_size=16, max_ctx=256, role="prefill")
    de = Engine(cfg, params, EngineConfig(eta=1.0, w_p=4.0, tau=1e9),
                make_policy("slidebatching"), num_blocks=512,
                block_size=16, max_ctx=256, role="decode")
    svc.add_instance(pe)
    svc.add_instance(de)
    for req, prompt in trace:
        svc.submit(req, prompt)
    svc.serve_until_drained()
    live_streams = [de.outputs.get(req.rid) for req, _ in trace]
    live = disagg_counters(svc.book)
    block_bytes = pe.pool.tier.block_bytes

    # matched sim replay: same request shapes through ClusterSim's disagg
    # path, wire bytes priced at the live pool's per-block footprint
    ex = AnalyticalExecutor(QWEN2_7B, InstanceHardware(chips=4))
    sim_est, _ = ex.fit_estimator(n=300)
    cs = ClusterSim(lambda: make_policy("slidebatching"),
                    GoRouting(sim_est, RouterConfig(pd_mode="disagg")),
                    ex, sim_est, EngineConfig(w_p=4.0),
                    ClusterConfig(pd_mode="disagg", n_prefill=1,
                                  n_decode=1, prefix_cache=False,
                                  handoff_block_bytes=block_bytes))
    sim_reqs = [Request(prompt_len=req.prompt_len,
                        output_len=req.output_len, arrival=0.0,
                        slo=SLO(3600.0, 3600.0), priority=req.priority)
                for req, _ in trace]
    replay_sim(cs, sim_reqs, w_p=4.0)
    sim = disagg_counters(cs)

    # stream comparison is positional: requests enter both fleets in the
    # same submission order, and rids ascend with it on each side
    row = {"n_requests": n, "prompt_len": plen, "out_len": olen,
           "block_bytes": block_bytes, "live": live, "sim": sim,
           "streams_identical": (
               [tuple(s) for s in live_streams if s is not None]
               == [tuple(s) for s in ref_streams]
               and all(s is not None for s in live_streams)),
           "parity": live == sim}
    failures = []
    if not row["streams_identical"]:
        failures.append("disagg token streams diverged from coloc")
    if not row["parity"]:
        failures.append(f"disagg sim<->live counter parity broke: "
                        f"live={live} sim={sim}")
    if live["reserved_blocks_total"] != live["adopted_blocks_total"]:
        failures.append("disagg reserved blocks %d != adopted blocks %d"
                        % (live["reserved_blocks_total"],
                           live["adopted_blocks_total"]))
    if live["reservation_hits"] != n or live["reservation_misses"]:
        failures.append("disagg reservations did not all settle as hits "
                        "(%d hits / %d misses over %d requests)"
                        % (live["reservation_hits"],
                           live["reservation_misses"], n))
    for eng in (pe, de):
        eng.kill()
    return row, failures


def measure_spec(cfg, params, args):
    """Speculative decoding (draft propose + packed verify, high-priority
    decode trace): spec-on with a same-params draft — every proposal
    matches the target argmax, the maximum-speculation regime — must emit
    token streams BITWISE identical to spec-off while finishing in fewer
    target launches (each accepted draft token rides a verify launch
    instead of buying its own decode launch); acceptance accounting must
    conserve with everything accepted; and an ``EngineSim`` replay with
    the acceptance draw pinned to always-accept must reproduce the live
    speculation counters verbatim (``sim.metrics.spec_counters`` dict
    equality — the sim<->live accounting contract).  Depth decisions are
    timing-free at this scale (load ~ 1e-12 of the tau budget; the
    acceptance EWMA only rises from its 0.8 prior, never crossing a
    pricing threshold for k=2), so sharing the estimator and pinning the
    online refit off makes the counter trajectory deterministic."""
    from repro.core.estimator import BatchLatencyEstimator
    from repro.sim import (AnalyticalExecutor, EngineSim, InstanceHardware,
                           QWEN2_7B, spec_counters)

    n = max(4, args.requests // 3)
    plen, olen = max(16, args.prompt_len // 2), args.decode_len * 2
    est = BatchLatencyEstimator(a_p=1e-8, b_p=1e-8, c_p=1e-4, a_d=1e-8,
                                b_d=1e-3, t_c=1e-2)

    rows, streams, live = {}, {}, None
    for label, spec_k in (("off", 0), ("on", 2)):
        for _warm in (True, False):
            trace = make_trace(cfg, n, plen, olen, args.seed,
                               vary_out=True, priority=1)
            kw = {"spec_draft": (cfg, params)} if spec_k else {}
            eng = Engine(cfg, params,
                         EngineConfig(eta=1.0, w_p=4.0, tau=1e9,
                                      spec_k=spec_k),
                         make_policy("slidebatching"), num_blocks=512,
                         block_size=16, max_ctx=512, est=est, **kw)
            eng.refit_every = 10 ** 9   # freeze pricing for sim parity
            for req, prompt in trace:
                eng.add_request(req, prompt)
            t0 = time.monotonic()
            eng.run_until_drained(max_iters=5000)
            wall = time.monotonic() - t0
            outs = {i: eng.outputs[req.rid]
                    for i, (req, _) in enumerate(trace)}
            st = eng.stats
            eng.kill()
        decode_tokens = st.tokens_out - n
        rows[label] = {
            "wall_s": round(wall, 3),
            "decode_tokens": decode_tokens,
            "decode_tok_per_s": round(decode_tokens / wall, 1),
            "decode_launches": st.decode_launches,
            "draft_launches": st.draft_launches,
        }
        streams[label] = outs
        if spec_k:
            live = spec_counters(st)
            rows[label].update(live)

    # matched EngineSim replay: same request shapes, same estimator, the
    # acceptance oracle pinned to the equal-params regime
    ex = AnalyticalExecutor(QWEN2_7B, InstanceHardware(chips=4))
    sim = EngineSim(0, make_policy("slidebatching"), ex, est,
                    EngineConfig(eta=1.0, w_p=4.0, tau=1e9, spec_k=2))
    sim.spec_accept_fn = lambda rid, step, depth, rate: depth
    now, guard = 0.0, 0
    for req, _ in make_trace(cfg, n, plen, olen, args.seed,
                             vary_out=True, priority=1):
        sim.add_request(req, now)
    while sim.has_work() and guard < 10000:
        guard += 1
        res = sim.step(now)
        if res is None:
            break
        now = res.end
    sim_c = spec_counters(sim)

    row = {"n_requests": n, "prompt_len": plen, "out_len": olen,
           "off": rows["off"], "on": rows["on"], "sim": sim_c,
           "streams_identical": streams["off"] == streams["on"],
           "launch_reduction": round(
               rows["off"]["decode_launches"]
               / max(rows["on"]["decode_launches"], 1), 2),
           "parity": live == sim_c}
    failures = []
    if not row["streams_identical"]:
        failures.append("token streams diverged between spec-off and "
                        "spec-on engines")
    if live["spec_proposed"] <= 0:
        failures.append("speculation never engaged (0 proposals)")
    if live["spec_accepted"] != live["spec_proposed"]:
        failures.append("same-params draft must be fully accepted "
                        "(%d/%d)" % (live["spec_accepted"],
                                     live["spec_proposed"]))
    if rows["on"]["decode_launches"] >= rows["off"]["decode_launches"]:
        failures.append("spec-on did not reduce target decode launches "
                        "(%d vs %d)" % (rows["on"]["decode_launches"],
                                        rows["off"]["decode_launches"]))
    if not row["parity"]:
        failures.append(f"spec sim<->live counter parity broke: "
                        f"live={live} sim={sim_c}")
    return row, failures


def collect(args) -> tuple[dict, list[str]]:
    """Run every measurement; return (bench payload, failure messages)."""
    cfg = get_smoke("qwen1_5_0_5b")
    params = init_params(cfg, jax.random.PRNGKey(0))

    # prefill-heavy trace: one output token, so wall time IS prefill time
    (base_p, fast_p), same_p = measure_overlap(cfg, params, args, 1)
    # decode trace: several output tokens; the overlap engine leaves the
    # decode path alone, so its TPOT must not regress
    (base_d, fast_d), same_d = measure_overlap(cfg, params, args,
                                               args.decode_len)
    (logits_row, fused_row), same_f = measure_fused(cfg, params, args)
    tier_rows, tier_failures = measure_tier(cfg, params, args)
    disagg_row, disagg_failures = measure_disagg(cfg, params, args)
    spec_row, spec_failures = measure_spec(cfg, params, args)

    speedup = fast_p["prefill_tok_per_s"] / max(base_p["prefill_tok_per_s"],
                                                1e-9)
    tpot_ratio = fast_d["tpot_proxy_ms"] / max(base_d["tpot_proxy_ms"],
                                               1e-9)
    fused_ratio = fused_row["tpot_proxy_ms"] / max(
        logits_row["tpot_proxy_ms"], 1e-9)

    failures = (list(tier_failures) + list(disagg_failures)
                + list(spec_failures))
    if not (same_p and same_d):
        failures.append("token streams diverged between baseline and "
                        "overlapped engines")
    if not same_f:
        failures.append("token streams diverged between logits and fused "
                        "decode")
    if speedup < args.min_speedup:
        failures.append(f"prefill speedup {speedup:.2f}x < "
                        f"{args.min_speedup}x gate")
    if tpot_ratio > args.max_tpot_ratio:
        failures.append(f"decode TPOT ratio {tpot_ratio:.2f}x > "
                        f"{args.max_tpot_ratio}x gate")
    if fused_ratio > args.max_fused_ratio:
        failures.append(f"fused decode TPOT ratio {fused_ratio:.2f}x > "
                        f"{args.max_fused_ratio}x gate")
    for row in (fast_p, fast_d, logits_row, fused_row):
        if not row["hot_loop_fetches_ok"]:
            failures.append(
                "hidden host sync: host_syncs=%d != decode_launches=%d + "
                "packed_calls=%d" % (row["host_syncs"],
                                     row["decode_launches"],
                                     row["packed_calls"]))

    payload = {
        "schema": BENCH_SCHEMA,
        "model": "qwen1_5_0_5b (smoke scale)",
        "generated_by": "tools/perf_smoke.py --bench-out",
        "prefill": {"baseline": base_p, "overlapped": fast_p,
                    "speedup": round(speedup, 2)},
        "decode": {"baseline": base_d, "overlapped": fast_d,
                   "tpot_ratio": round(tpot_ratio, 2)},
        "decode_fusion": {"logits": logits_row, "fused": fused_row,
                          "fused_tpot_ratio": round(fused_ratio, 2),
                          "streams_identical": same_f},
        "kv_tier": tier_rows,
        "disagg": disagg_row,
        "spec": spec_row,
        "streams_identical": (same_p and same_d and same_f
                              and tier_rows["streams_identical"]
                              and disagg_row["streams_identical"]
                              and spec_row["streams_identical"]),
        "gates": {"min_prefill_speedup": args.min_speedup,
                  "max_tpot_ratio": args.max_tpot_ratio,
                  "max_fused_ratio": args.max_fused_ratio,
                  "passed": not failures},
    }
    return payload, failures


def _git_commit() -> str:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10,
                             cwd=root).stdout.strip()
        return out or "unknown"
    except OSError:
        return "unknown"


def merge_trajectory(payload: dict, path: str) -> None:
    """Attach the commit-keyed perf trajectory to ``payload`` before it
    is written: prior entries from the existing file are kept verbatim
    (append-only, timestamp-free), a prior entry for the SAME commit is
    replaced, and the current run's headline numbers become the newest
    point — so the checked-in file accumulates a commit-over-commit
    speed trace that ``--bench-check`` can gate against."""
    traj = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                traj = list(json.load(f).get("trajectory", []))
        except (OSError, ValueError):
            pass
    entry = {
        "commit": _git_commit(),
        "prefill_tok_per_s":
            payload["prefill"]["overlapped"]["prefill_tok_per_s"],
        "prefill_speedup": payload["prefill"]["speedup"],
        "fused_tpot_ms":
            payload["decode_fusion"]["fused"]["tpot_proxy_ms"],
    }
    traj = [e for e in traj if e.get("commit") != entry["commit"]]
    traj.append(entry)
    payload["trajectory"] = traj


def check_bench_file(path: str, payload: dict) -> list[str]:
    """Validate a checked-in BENCH_engine_step.json: schema + the
    correctness facts (identical streams, gates passed) must hold in the
    committed trajectory point, and the current run's prefill throughput
    may not collapse below HALF the best recorded trajectory entry (the
    generous factor absorbs shared-runner noise while still catching a
    real hot-loop regression).  Wall-clock numbers are otherwise
    trajectory data, not compared exactly — the current run is gated on
    its own ratios."""
    errors = []
    try:
        with open(path) as f:
            ref = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    if ref.get("schema") != BENCH_SCHEMA:
        errors.append(f"{path}: schema {ref.get('schema')!r} != "
                      f"{BENCH_SCHEMA}")
    for section in ("prefill", "decode", "decode_fusion", "spec", "gates"):
        if section not in ref:
            errors.append(f"{path}: missing section {section!r}")
    if not ref.get("streams_identical", False):
        errors.append(f"{path}: committed run has streams_identical=false")
    if not ref.get("gates", {}).get("passed", False):
        errors.append(f"{path}: committed run did not pass its gates")
    if not payload["gates"]["passed"]:
        errors.append("current run failed its gates (see above)")
    best = max((e.get("prefill_tok_per_s", 0)
                for e in ref.get("trajectory", [])), default=0)
    cur = payload["prefill"]["overlapped"]["prefill_tok_per_s"]
    if best and cur < 0.5 * best:
        errors.append(f"prefill throughput {cur} tok/s fell below half "
                      f"the best trajectory point ({best} tok/s)")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-speedup", type=float, default=1.1,
                    help="CI gate on prefill tokens/s — set well below the "
                         "typically measured ~1.8x so shared-runner noise "
                         "can't flake the job; it still catches the packed "
                         "path regressing to (or below) baseline")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=160)
    ap.add_argument("--decode-len", type=int, default=8,
                    help="output length of the decode-TPOT trace")
    ap.add_argument("--max-tpot-ratio", type=float, default=1.3,
                    help="CI gate: overlapped decode TPOT may not exceed "
                         "baseline by more than this factor")
    ap.add_argument("--max-fused-ratio", type=float, default=1.2,
                    help="CI gate: fused decode TPOT may not exceed the "
                         "logits-fetch path by more than this factor "
                         "(typically measured at or below 1.0)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--bench-out", default=None,
                    help="write the measurements as BENCH_engine_step.json")
    ap.add_argument("--bench-check", default=None,
                    help="validate a checked-in BENCH_engine_step.json")
    args = ap.parse_args(argv)

    payload, failures = collect(args)
    print(json.dumps(payload, indent=1))
    if args.bench_out:
        merge_trajectory(payload, args.bench_out)
        with open(args.bench_out, "w") as f:
            json.dump(payload, f, indent=1)
            f.write("\n")
        print(f"wrote {args.bench_out}")
    if args.bench_check:
        failures += check_bench_file(args.bench_check, payload)
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    if failures:
        return 1
    print(f"OK: {payload['prefill']['speedup']:.2f}x prefill throughput, "
          f"decode TPOT ratio {payload['decode']['tpot_ratio']:.2f}x, "
          f"fused decode ratio "
          f"{payload['decode_fusion']['fused_tpot_ratio']:.2f}x, "
          f"spec launch reduction "
          f"{payload['spec']['launch_reduction']:.2f}x, "
          "identical streams (incl. disagg handoff and speculative "
          "decode, sim<->live counter parity), no hidden host syncs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
