"""Prefix-cache invariants: pool refcount / copy-on-write conservation,
radix matching, eviction safety (never frees a block with >1 reference),
shared-table attention exactness, and router prefix-affinity scoring."""
import numpy as np
import pytest

from repro.core import (BatchLatencyEstimator, BlockManager, EngineConfig,
                        GoRouting, InstanceState, PrefixRegistry, Request,
                        RouterConfig, SLO, SimPrefixCache, make_policy)
from repro.core.prefix import usable_prefix

RNG = np.random.default_rng(7)


def make_req(plen=100, prio=1, group=-1, shared=0):
    return Request(prompt_len=plen, output_len=10, arrival=0.0,
                   slo=SLO(3600.0, 3600.0), priority=prio,
                   prefix_group=group, shared_prefix_len=shared)


# --- PagedKVPool: refcounts + copy-on-write ---------------------------------

@pytest.fixture(scope="module")
def pool_cls():
    from repro.configs import get_smoke
    from repro.serving import PagedKVPool

    cfg = get_smoke("qwen1_5_0_5b")

    def make(num_blocks=32, block_size=16):
        return PagedKVPool(cfg, num_blocks, block_size)
    return make


def pool_invariant(pool):
    """Every non-reserved block is free xor referenced; refcounts of
    table-referenced blocks are consistent."""
    free = set(pool.free)
    for b in range(1, pool.num_blocks):
        refs = sum(t.count(b) for t in pool.tables.values())
        if b in free:
            assert pool.refcount[b] == 0, f"free block {b} has references"
        else:
            assert pool.refcount[b] >= refs > 0 or pool.refcount[b] > 0
    assert len(free) == len(pool.free), "free list has duplicates"


def test_pool_alloc_share_release_conservation(pool_cls):
    pool = pool_cls()
    total_free = len(pool.free)
    assert pool.alloc(rid=1, n=4)
    pool.share(rid=2, blocks=pool.tables[1][:3])   # rid 2 shares 3 blocks
    assert [pool.refcount[b] for b in pool.tables[1]] == [2, 2, 2, 1]
    pool_invariant(pool)
    pool.release(1)                      # shared blocks survive under rid 2
    assert len(pool.free) == total_free - 3
    assert all(pool.refcount[b] == 1 for b in pool.tables[2])
    pool_invariant(pool)
    pool.release(2)
    assert len(pool.free) == total_free
    pool_invariant(pool)


def test_pool_cow_fork_preserves_sharing(pool_cls):
    pool = pool_cls()
    assert pool.alloc(1, 2)
    pool.share(2, pool.tables[1])
    shared_b = pool.tables[2][0]
    assert not pool.ensure_writable(1, 5)          # out of range: no-op
    assert pool.ensure_writable(2, 0)              # shared -> forked
    assert pool.tables[2][0] != shared_b
    assert pool.refcount[shared_b] == 1            # rid 1 keeps the original
    assert pool.refcount[pool.tables[2][0]] == 1
    assert not pool.ensure_writable(2, 0)          # already private
    pool_invariant(pool)
    # forked block holds a faithful copy of the original's KV
    import jax.numpy as jnp
    assert bool(jnp.array_equal(pool.kv[:, :, shared_b],
                                pool.kv[:, :, pool.tables[2][0]]))


def test_pool_random_alloc_share_fork_release(pool_cls):
    pool = pool_cls(num_blocks=64)
    rng = np.random.default_rng(0)
    live = []
    for step in range(300):
        op = rng.random()
        if op < 0.4 or not live:
            rid = 1000 + step
            if pool.alloc(rid, int(rng.integers(1, 4))):
                live.append(rid)
        elif op < 0.6 and live:
            src = int(rng.choice(live))
            rid = 2000 + step
            k = int(rng.integers(1, len(pool.tables[src]) + 1))
            pool.share(rid, pool.tables[src][:k])
            live.append(rid)
        elif op < 0.8 and live:
            rid = int(rng.choice(live))
            t = pool.tables.get(rid, [])
            if t and pool.free:
                pool.ensure_writable(rid, int(rng.integers(0, len(t))))
        else:
            rid = live.pop(int(rng.integers(0, len(live))))
            pool.release(rid)
        pool_invariant(pool)
    for rid in live:
        pool.release(rid)
    assert len(pool.free) == 63                    # block 0 reserved


def test_pool_reload_batched_roundtrip(pool_cls):
    """Offload -> drop -> reload restores byte-identical KV (single
    scatter path) and host state survives O(1) release of other rids."""
    import jax.numpy as jnp
    pool = pool_cls()
    assert pool.alloc(1, 3)
    pool.kv = pool.kv.at[:, :, pool.tables[1]].set(1.5)
    before = [np.asarray(pool.kv[:, :, b]) for b in pool.tables[1]]
    pool.offload_blocks(1, [0, 1, 2])
    assert pool.host_blocks(1) == 3
    pool.drop_device_blocks(1)
    pool.alloc(9, 1)                     # unrelated rid
    pool.release(9)                      # must not disturb rid 1's host set
    assert pool.host_blocks(1) == 3
    assert pool.reload_blocks(1, 3) == 3 * pool.block_size
    for want, b in zip(before, pool.tables[1]):
        assert bool(jnp.array_equal(jnp.asarray(want), pool.kv[:, :, b]))


# --- RadixPrefixCache --------------------------------------------------------

@pytest.fixture()
def cache_env(pool_cls):
    from repro.serving import RadixPrefixCache

    pool = pool_cls(num_blocks=64)
    bm = BlockManager(63, 16, 1e-3)
    cache = RadixPrefixCache(pool, bm, max_blocks=32)
    return pool, bm, cache


def _prefill(pool, rid, tokens):
    """Pretend rid prefilled ``tokens``: allocate covering blocks."""
    assert pool.ensure_capacity(rid, len(tokens))
    return pool.tables[rid]


def test_radix_match_block_aligned_and_capped(cache_env):
    pool, bm, cache = cache_env
    toks = RNG.integers(1, 999, 80).astype(np.int32)
    _prefill(pool, 1, toks)
    assert cache.insert(toks, pool.tables[1], rid=1, now=0.0) == 5
    # identical prompt: matches all FULL blocks except it must leave >= 1
    # token to prefill -> 80 tokens = 5 blocks, cap at 79 -> 4 blocks
    n, blocks = cache.match(toks, now=1.0, rid=2)
    assert n == 64 and blocks == pool.tables[1][:4]
    # diverging after 2 blocks: matches exactly the shared 2 blocks
    other = toks.copy()
    other[40] += 1
    n2, blocks2 = cache.match(other, now=1.0, rid=3)
    assert n2 == 32 and blocks2 == pool.tables[1][:2]
    # short prompt never matches (nothing would remain to prefill)
    assert cache.match(toks[:16], now=1.0, rid=4)[0] == 0


def test_radix_insert_splits_and_adopts_suffix_only(cache_env):
    pool, bm, cache = cache_env
    a = RNG.integers(1, 999, 64).astype(np.int32)
    b = np.concatenate([a[:32], RNG.integers(1, 999, 32)]).astype(np.int32)
    _prefill(pool, 1, a)
    _prefill(pool, 2, b)
    assert cache.insert(a, pool.tables[1], rid=1, now=0.0) == 4
    bm.charge_cache(4)
    # b shares 2 blocks with a -> splits a's node, adopts only b's suffix
    assert cache.insert(b, pool.tables[2], rid=2, now=0.0) == 2
    bm.charge_cache(2)
    assert cache.cached_blocks == 6
    n, blocks = cache.match(b, now=1.0, rid=3)
    assert n == 48                       # 2 shared + 1 of b's own (cap 63)
    assert blocks[:2] == pool.tables[1][:2]
    assert blocks[2] == pool.tables[2][2]


def test_radix_eviction_never_frees_shared_or_pinned(cache_env):
    pool, bm, cache = cache_env
    toks = RNG.integers(1, 999, 64).astype(np.int32)
    _prefill(pool, 1, toks)
    adopted = cache.insert(toks, pool.tables[1], rid=1, now=0.0)
    bm.charge_cache(adopted)
    # rid 1 still references the blocks (pinned): nothing evictable
    assert cache.reclaim(100) == 0
    cache.detach(1)
    # unpinned but still shared with rid 1's table: still not evictable
    assert cache.reclaim(100) == 0
    pool.release(1)
    # now uniquely cache-owned: evictable, blocks return to the free list
    free_before = len(pool.free)
    assert cache.reclaim(100) == 4
    assert len(pool.free) == free_before + 4
    assert bm.cache_charge == 0


def test_radix_lru_priority_weighted_eviction(cache_env):
    pool, bm, cache = cache_env
    lo = RNG.integers(1, 999, 32).astype(np.int32)
    hi = RNG.integers(1, 999, 32).astype(np.int32)
    _prefill(pool, 1, lo)
    _prefill(pool, 2, hi)
    bm.charge_cache(cache.insert(lo, pool.tables[1], 1, now=5.0, weight=1.0))
    bm.charge_cache(cache.insert(hi, pool.tables[2], 2, now=0.0, weight=2.0))
    for rid in (1, 2):
        cache.detach(rid)
        pool.release(rid)
    # hi is OLDER but priority-weighted: lo evicts first
    assert cache.reclaim(1) == 2
    assert cache.match(hi, now=6.0, rid=9)[0] == 16


def test_radix_release_detaches_zero_adoption_pins(cache_env):
    """Cold-start race: two requests prefill the same prompt concurrently;
    the second's insert adopts nothing (path already present) yet pins it.
    Release must still detach, or the entry is unevictable forever."""
    pool, bm, cache = cache_env
    toks = RNG.integers(1, 999, 64).astype(np.int32)
    r1, r2 = make_req(plen=64), make_req(plen=64)
    for r in (r1, r2):
        _prefill(pool, r.rid, toks)
        assert bm.grow(r, 64, 0.0)
    a1 = cache.insert(toks, pool.tables[r1.rid], r1.rid, now=0.0)
    a2 = cache.insert(toks, pool.tables[r2.rid], r2.rid, now=0.0)
    assert a1 == 4 and a2 == 0
    bm.donate_to_cache(r1, a1)
    for r in (r1, r2):
        bm.release(r)
        pool.release(r.rid)
    assert cache.reclaim(100) == 4          # no stale pin blocks eviction
    assert bm.cache_charge == 0


# --- BlockManager <-> cache accounting --------------------------------------

def test_bm_cache_charge_conservation():
    bm = BlockManager(64, 16, 1e-3)
    cache = SimPrefixCache(16, 32)
    cache.bm = bm
    bm.cache = cache
    r1 = make_req(plen=100, group=0, shared=64)
    assert bm.grow(r1, 100, 0.0)                    # prefill fully
    assert bm.used_blocks == 7
    adopted = cache.insert(r1, 0.0)
    assert adopted == 4                             # 64 shared tokens
    bm.donate_to_cache(r1, adopted)
    assert bm.used_blocks == 3 and bm.cache_charge == 4
    assert bm.free_blocks == 64 - 7
    # second request of the group: attaches without new charge
    r2 = make_req(plen=100, group=0, shared=64)
    hit = cache.match(r2, 1.0)
    assert hit == 64
    bm.attach_cached(r2, hit)
    cache.attach(r2.rid, 0)
    assert bm.grow(r2, 36, 1.0)                     # only the suffix
    assert bm.used_blocks == 3 + 3                  # ceil(100/16)-4 = 3
    bm.release(r2)
    assert bm.used_blocks == 3
    bm.release(r1)
    assert bm.used_blocks == 0 and bm.cache_charge == 4
    # entry unpinned now: reclaim pressure frees it
    assert bm.reclaim_cache(4) == 4
    assert bm.free_blocks == 64


def test_bm_eviction_spares_cache_blocks():
    bm = BlockManager(16, 16, 1e-3)
    cache = SimPrefixCache(16, 8)
    cache.bm = bm
    bm.cache = cache
    r = make_req(plen=64, group=1, shared=32)
    assert bm.grow(r, 64, 0.0)
    bm.donate_to_cache(r, cache.insert(r, 0.0))
    assert bm.cache_charge == 2
    bm.complete_offloads(1.0)
    freed = bm.evict(r, 1.0)
    assert freed == 2                               # only unique blocks
    assert bm.cache_charge == 2                     # cache entry intact
    assert bm.used_blocks == 0
    assert cache.peek_tokens(make_req(plen=64, group=1, shared=32)) == 32


def test_sim_release_detaches_zero_adoption_pins():
    """Same cold-start race on the simulator cache model."""
    bm = BlockManager(64, 16, 1e-3)
    cache = SimPrefixCache(16, 32)
    cache.bm = bm
    bm.cache = cache
    r1 = make_req(plen=100, group=0, shared=64)
    r2 = make_req(plen=100, group=0, shared=64)
    for r in (r1, r2):
        assert bm.grow(r, 100, 0.0)      # both miss: concurrent cold start
    bm.donate_to_cache(r1, cache.insert(r1, 0.0))
    assert cache.insert(r2, 0.0) == 0    # entry already present, still pins
    bm.release(r1)
    bm.release(r2)
    assert bm.reclaim_cache(100) == 4    # no stale pin blocks eviction
    assert bm.cache_charge == 0


def test_sim_cache_usable_prefix_alignment():
    assert usable_prefix(64, 100, 16) == 64
    assert usable_prefix(64, 64, 16) == 48      # leave >=1 token to prefill
    assert usable_prefix(100, 33, 16) == 32
    assert usable_prefix(8, 100, 16) == 0


# --- engine end-to-end: shared tables are bitwise-exact ----------------------

def test_engine_shared_prefix_outputs_bitwise_match():
    """Requests sharing a prompt prefix through the radix cache must emit
    exactly the tokens of an uncached engine (shared block tables + CoW
    change memory layout, never results)."""
    import jax

    from repro.configs import get_smoke
    from repro.models import init_params
    from repro.serving import Engine

    cfg = get_smoke("qwen1_5_0_5b")
    params = init_params(cfg, jax.random.PRNGKey(0))

    def make_engine(prefix_cache):
        return Engine(cfg, params, EngineConfig(eta=1.0, w_p=4.0, tau=1e9),
                      make_policy("slidebatching"), num_blocks=96,
                      block_size=16, max_ctx=256, prefix_cache=prefix_cache)

    shared = RNG.integers(1, cfg.vocab, 32).astype(np.int32)
    prompts = [np.concatenate([shared,
                               RNG.integers(1, cfg.vocab, 8 + 4 * i)
                               .astype(np.int32)]) for i in range(4)]
    outs = {}
    for cache_on in (True, False):
        eng = make_engine(cache_on)
        reqs = []
        # staged admission: the first request prefills (and seeds the
        # cache) before the rest arrive and share its prefix blocks
        for wave in (prompts[:1], prompts[1:]):
            for p in wave:
                r = make_req(plen=len(p))
                r.output_len = 4
                eng.add_request(r, p)
                reqs.append(r)
            eng.run_until_drained(max_iters=200)
        outs[cache_on] = [eng.outputs[r.rid] for r in reqs]
        if cache_on:
            assert eng.stats.cache_hit_tokens >= 3 * 32, \
                "test must actually exercise prefix sharing"
    assert outs[True] == outs[False]


# --- router prefix affinity --------------------------------------------------

EST = BatchLatencyEstimator(a_p=0.0, b_p=0.0, c_p=1e-3, a_d=0.0,
                            b_d=0.0, t_c=0.0)  # 1 ms per prefill token


def test_registry_longest_prefix_lookup():
    reg = PrefixRegistry(block_size=16)
    t = RNG.integers(1, 999, 64).astype(np.int32)
    reg.observe(3, t)
    assert reg.lookup(t).get(3) == 48           # capped: 63 usable tokens
    div = t.copy()
    div[20] += 1
    assert reg.lookup(div).get(3) == 16         # only the first block agrees
    assert reg.lookup(RNG.integers(1, 999, 64)) == {}
    reg.drop(3)
    assert reg.lookup(t) == {}


def test_gorouting_prefix_affinity_tiebreak():
    """Equal-load replicas: the one holding the prefix wins; a replica
    holding the prefix but hopelessly overloaded still loses."""
    gr = GoRouting(EST, RouterConfig(pd_mode="disagg", alpha=0.0))
    r = make_req(plen=200)
    a, b = InstanceState(iid=0, b_f=100), InstanceState(iid=1, b_f=100)
    pick, _ = gr.select(r, [a, b], None, now=0.0, affinity={1: 128})
    assert pick == 1
    # same but instance 1 is overloaded far beyond what affinity saves
    from repro.core import QueuedStub
    b.on_dispatch(QueuedStub(99, 0.0, 2, 1.0, 3000, 10.0, 3.0), 0.0)
    pick2, _ = gr.select(r, [a, b], None, now=0.0, affinity={1: 128})
    assert pick2 == 0


def test_routerbook_routes_repeat_prefix_to_same_replica():
    from repro.serving import RouterBook

    book = RouterBook(GoRouting(EST, RouterConfig(pd_mode="disagg")), EST)
    book.add_instance(0, 1000, 1000)
    book.add_instance(1, 1000, 1000)
    prompt = RNG.integers(1, 999, 64).astype(np.int32)
    first = book.route(make_req(plen=64), 0.0, prompt_tokens=prompt)
    assert first is not None
    # the repeat lands where the prefix lives, despite the queued stub
    again = book.route(make_req(plen=64), 0.0, prompt_tokens=prompt)
    assert again == first
    # ... and its stub reflects only the uncached suffix
    stub = list(book.states[first].pre_queue.values())[-1]
    assert stub.exec == pytest.approx(EST.prefill_time_cached(64, 48))


def test_routerbook_disables_affinity_for_cacheless_fleet():
    """A replica without a prefix cache joins: affinity routing must turn
    off, so a cache-OFF baseline is a true no-cache baseline (stub costs
    are full prefills, no prefix-holder bias)."""
    from repro.serving import RouterBook

    book = RouterBook(GoRouting(EST, RouterConfig(pd_mode="disagg")), EST)
    book.add_instance(0, 1000, 1000, has_prefix_cache=False)
    book.add_instance(1, 1000, 1000)
    assert book.registry is None
    prompt = RNG.integers(1, 999, 64).astype(np.int32)
    for _ in range(2):                       # repeats get no cache discount
        iid = book.route(make_req(plen=64), 0.0, prompt_tokens=prompt)
        stub = list(book.states[iid].pre_queue.values())[-1]
        assert stub.exec == pytest.approx(EST.prefill_time(64))
