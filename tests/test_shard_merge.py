"""Shard-merge exactness: the reductions the sharded replay relies on.

``StreamingSummary.merge`` and ``merge_counters`` must reproduce the
unsharded metrics from ANY partition of a trace — integer counters and
histogram bins add exactly, TDG gain sums are exact for the bundled
integer-weight workloads, and ``np.percentile`` sorts its inputs so
buffer concatenation order cannot matter.  The bounded (``_LogHist``)
variant additionally guarantees p50/p99 within 1% of exact at 10⁵
samples.  Finally, the multiprocess replay itself must be partition-
independent: ``workers=0`` (in-process twin) and forked workers produce
identical per-request results, summaries and engine counters."""
import numpy as np
import pytest

from repro.core import EngineConfig, GoRouting, RouterConfig
from repro.core.slidebatching import SlideBatching
from repro.sim import (AnalyticalExecutor, ClusterConfig,
                       InstanceHardware, QWEN2_7B, StreamingSummary,
                       WindowedClusterSim, iter_scale_trace,
                       merge_counters)
from repro.sim.metrics import _Buf, _LogHist
from repro.sim.shard import ENGINE_COUNTERS

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                                      reason="hypothesis not installed")


@pytest.fixture(scope="module")
def exec_est():
    ex = AnalyticalExecutor(QWEN2_7B, InstanceHardware(chips=4))
    est, _ = ex.fit_estimator(n=200)
    return ex, est


def make_factory(ex, est, n_prefill=4):
    def factory():
        return WindowedClusterSim(
            lambda: SlideBatching(),
            GoRouting(est, RouterConfig(pd_mode="coloc")),
            ex, est, EngineConfig(w_p=4.0),
            ClusterConfig(pd_mode="coloc", n_prefill=n_prefill))
    return factory


def trace(n, rate, seed=7):
    reqs = list(iter_scale_trace(n, rate=rate, seed=seed))
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


@pytest.fixture(scope="module")
def pool(exec_est):
    """Deterministic set of terminated requests for partition tests —
    real sim output, so every metric field is mutually consistent."""
    ex, est = exec_est
    cs = make_factory(ex, est, n_prefill=2)()
    reqs = trace(240, 900.0)
    cs.run(reqs)
    assert sum(r.finish_time is not None for r in reqs) > 100
    return reqs


def fold(reqs, bounded):
    s = StreamingSummary(w_p=4.0, bounded=bounded)
    for r in reqs:
        s.add(r)
    return s


# ---------------------------------------------------------------------------
# partition-merge properties
# ---------------------------------------------------------------------------

@needs_hypothesis
@pytest.mark.parametrize("bounded", [False, True])
def test_partition_merge_property(pool, bounded):
    """ANY assignment of requests to shards merges back to the
    unsharded summary — same Summary dataclass, field for field."""
    whole = fold(pool, bounded).summary()

    @given(st.data())
    @settings(max_examples=25, deadline=None)
    def check(data):
        n_shards = data.draw(st.integers(1, 5))
        assign = data.draw(st.lists(st.integers(0, n_shards - 1),
                                    min_size=len(pool),
                                    max_size=len(pool)))
        shards = [StreamingSummary(w_p=4.0, bounded=bounded)
                  for _ in range(n_shards)]
        for r, s in zip(pool, assign):
            shards[s].add(r)
        merged = shards[0]
        for s in shards[1:]:
            merged.merge(s)
        assert merged.summary() == whole

    check()


@needs_hypothesis
def test_counter_merge_property():
    """Per-shard engine-counter dicts add to the global dict for any
    split of the counts."""
    @given(st.data())
    @settings(max_examples=50, deadline=None)
    def check(data):
        totals = {k: data.draw(st.integers(0, 10 ** 9))
                  for k in ENGINE_COUNTERS}
        n_shards = data.draw(st.integers(1, 4))
        shards = [dict.fromkeys(ENGINE_COUNTERS, 0)
                  for _ in range(n_shards)]
        for k, total in totals.items():
            left = total
            for s in shards[:-1]:
                s[k] = data.draw(st.integers(0, left))
                left -= s[k]
            shards[-1][k] = left
        merged: dict = {}
        for s in shards:
            merge_counters(merged, s)
        assert merged == totals

    check()


def test_merge_incompatible_raises():
    with pytest.raises(ValueError):
        StreamingSummary(w_p=4.0).merge(StreamingSummary(w_p=1.0))
    with pytest.raises(ValueError):
        StreamingSummary(bounded=True).merge(StreamingSummary())


# ---------------------------------------------------------------------------
# bounded-sketch accuracy
# ---------------------------------------------------------------------------

def test_loghist_accuracy_1e5():
    """p50/p99 of the bounded sketch within 1% of exact on 10⁵ samples
    spanning the TTFT/TPOT range, and exact under partition merge."""
    rng = np.random.default_rng(0)
    xs = np.exp(rng.normal(-3.0, 1.5, 100_000))   # ~50us .. ~10s
    whole = _LogHist()
    parts = [_LogHist() for _ in range(4)]
    for i, v in enumerate(xs):
        whole.append(float(v))
        parts[i % 4].append(float(v))
    merged = parts[0]
    for p in parts[1:]:
        merged.merge(p)
    for q in (50, 90, 99):
        exact = float(np.percentile(xs, q))
        approx = whole.percentile(q)
        assert abs(approx - exact) / exact < 0.01, (q, approx, exact)
        assert merged.percentile(q) == whole.percentile(q)


def test_buf_merge_matches_concat():
    rng = np.random.default_rng(1)
    xs = rng.random(5000)
    a, b = _Buf(), _Buf()
    for v in xs[:1200]:
        a.append(float(v))
    for v in xs[1200:]:
        b.append(float(v))
    a.merge(b)
    assert len(a) == len(xs)
    assert a.percentile(99) == float(np.percentile(xs, 99))


# ---------------------------------------------------------------------------
# multiprocess partition-independence
# ---------------------------------------------------------------------------

_WORKERS_IDENTITY_SCRIPT = """
from repro.core import EngineConfig, GoRouting, RouterConfig
from repro.core.slidebatching import SlideBatching
from repro.sim import (AnalyticalExecutor, ClusterConfig,
                       InstanceHardware, QWEN2_7B, WindowedClusterSim,
                       iter_scale_trace, replay_sim_sharded)

ex = AnalyticalExecutor(QWEN2_7B, InstanceHardware(chips=4))
est, _ = ex.fit_estimator(n=200)


def factory():
    return WindowedClusterSim(
        lambda: SlideBatching(),
        GoRouting(est, RouterConfig(pd_mode="coloc")),
        ex, est, EngineConfig(w_p=4.0),
        ClusterConfig(pd_mode="coloc", n_prefill=4))


def trace():
    reqs = list(iter_scale_trace(600, rate=300.0, seed=7))
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


results = {}
for w in (0, 2):
    rep, extras = replay_sim_sharded(factory, trace(), workers=w,
                                     window=0.5, w_p=4.0, collect=True)
    sig = sorted((r.rid, tuple(r.out_times), r.finish_time,
                  r.preemptions) for r in extras["finished"])
    results[w] = (sig, rep.summary, extras["counters"],
                  rep.n_completed, rep.n_rejected)
assert results[0] == results[2], "sharded replay diverged across workers"
print("IDENTICAL", results[2][3], results[2][4])
"""


def test_workers_identity():
    """workers=0 (in-process twin of the worker protocol) and forked
    workers produce IDENTICAL per-request results, merged summaries and
    engine counters on the same trace.

    Runs in a fresh subprocess: the sim/shard path never imports JAX,
    but THIS pytest process has it loaded from other test modules, and
    forking a process that carries JAX's thread pool is the documented
    deadlock recipe — so the fork happens in a clean interpreter."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, "-c", _WORKERS_IDENTITY_SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         env=env)
    assert res.returncode == 0, res.stderr
    assert res.stdout.startswith("IDENTICAL"), res.stdout
