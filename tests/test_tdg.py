"""Gain-function properties (§2): TDG's trick-immunity vs the strawmen."""
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import Request, SLO
from repro.core.tdg import (ideal_gain, ta_slo_gain, tdg_gain, tdg_ratio,
                            weighted_slo_gain)


def make_req(out_times, ttft=1.0, tpot=0.1, weight=1.0, output_len=None):
    r = Request(prompt_len=10, output_len=output_len or len(out_times),
                arrival=0.0, slo=SLO(ttft, tpot), weight=weight)
    for t in out_times:
        r.emit_token(t)
    return r


# --- deterministic behaviour ------------------------------------------------

def test_all_on_time_equals_ideal():
    times = [0.5 + 0.05 * i for i in range(10)]
    r = make_req(times)
    assert tdg_gain(r, 2.0, 1.0) == ideal_gain(r, 2.0, 1.0)


def test_late_first_token_loses_only_first_weight():
    times = [1.5] + [1.5 + 0.05 * i for i in range(1, 10)]
    r = make_req(times)
    # token 1 late; tokens 2..10 have deadlines 1.0+0.1*(i-1)
    expected = sum(1.0 for i in range(2, 11)
                   if times[i - 1] < 1.0 + 0.1 * (i - 1))
    assert tdg_gain(r, 5.0, 1.0) == expected


def test_priority_weight_scales_gain():
    times = [0.5, 0.6, 0.7]
    assert tdg_gain(make_req(times, weight=2.0)) == \
        2.0 * tdg_gain(make_req(times, weight=1.0))


# --- the postpone trick (§2): TDG immune, TA-SLO vulnerable -----------------

def test_postpone_trick_helps_ta_slo_but_not_tdg():
    # token 2 is late; delaying token 2 makes token 3's TBT pass under
    # TA-SLO (the trick) but can never increase TDG.
    honest = [0.5, 0.9, 0.95]          # TBT(3) = 0.05 < 0.1 ok
    tricked = [0.5, 1.2, 1.25]         # postponed token 2 even later
    slo = dict(ttft=1.0, tpot=0.1)
    ta_h = ta_slo_gain(make_req(honest, **slo))
    tdg_h = tdg_gain(make_req(honest, **slo))
    tdg_t = tdg_gain(make_req(tricked, **slo))
    assert tdg_t <= tdg_h              # trick never pays under TDG
    # and TA-SLO credits the tricked schedule's token-3 TBT regardless
    assert ta_slo_gain(make_req(tricked, **slo)) >= 2.0


def test_weighted_slo_discard_trick():
    """Once TTFT is missed, Weighted-SLO gives 0 — discarding is free.
    TDG still pays for on-time later tokens, discouraging the discard."""
    r = make_req([1.5, 1.55, 1.6], ttft=1.0, tpot=0.5)
    assert weighted_slo_gain(r) == 0.0
    assert tdg_gain(r) > 0.0


# --- hypothesis properties ---------------------------------------------------

@st.composite
def timelines(draw):
    n = draw(st.integers(1, 12))
    gaps = draw(st.lists(st.floats(0.0, 0.5), min_size=n, max_size=n))
    t, times = 0.0, []
    for g in gaps:
        t += g
        times.append(t)
    return times


@given(timelines(), st.integers(0, 11), st.floats(0.01, 2.0))
@settings(max_examples=200, deadline=None)
def test_delaying_any_token_never_increases_tdg(times, idx, delay):
    """Monotonicity: push token idx (and successors, to keep ordering)
    later — TDG must not increase."""
    if idx >= len(times):
        idx = len(times) - 1
    delayed = list(times)
    for j in range(idx, len(times)):
        delayed[j] = times[j] + delay
    g0 = tdg_gain(make_req(times))
    g1 = tdg_gain(make_req(delayed))
    assert g1 <= g0 + 1e-12


@given(timelines())
@settings(max_examples=100, deadline=None)
def test_tdg_bounded_by_ideal(times):
    r = make_req(times)
    assert 0.0 <= tdg_gain(r, 3.0, 1.0) <= ideal_gain(r, 3.0, 1.0) + 1e-12


@given(timelines(), st.floats(0.0, 1.0))
@settings(max_examples=100, deadline=None)
def test_earlier_delivery_never_hurts(times, shrink):
    """Delivering every token earlier (scaling all times down) cannot
    reduce TDG — the positive-impact-of-early-completion property."""
    earlier = [t * shrink for t in times]
    assert tdg_gain(make_req(earlier)) >= tdg_gain(make_req(times)) - 1e-12


def test_tdg_ratio_range():
    rs = [make_req([0.5, 0.6]), make_req([5.0, 6.0])]
    assert 0.0 <= tdg_ratio(rs) <= 1.0
