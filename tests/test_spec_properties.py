"""Property tests for the speculation depth controller and acceptance
accounting (core/spec.py).  Requires hypothesis (CI installs it via the
``test`` extra; skipped where absent)."""
import pytest

from repro.core.spec import (AcceptanceEWMA, SpecAccounting, expected_tokens,
                             policy_depth, price_depth, sim_accept_draw,
                             useful_depth)


def test_depth_bounds_grid():
    """Exhaustive small grid (no hypothesis needed): depth in [0, k]."""
    for k in range(0, 5):
        for pr in range(1, 4):
            for load in (0.0, 0.3, 0.9, 1.0, 2.0, -1.0):
                for rate in (0.0, 0.2, 0.8, 1.0):
                    d = policy_depth(load, pr, rate, k)
                    assert 0 <= d <= k


def test_depth_property_matrix():
    hyp = pytest.importorskip("hypothesis")
    hst = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=60, deadline=None)
    @hyp.given(load=hst.floats(min_value=-1.0, max_value=2.0,
                               allow_nan=False),
               priority=hst.integers(min_value=1, max_value=5),
               rate=hst.floats(min_value=0.0, max_value=1.0,
                               allow_nan=False),
               k=hst.integers(min_value=0, max_value=8))
    def run(load, priority, rate, k):
        d = policy_depth(load, priority, rate, k)
        assert 0 <= d <= k
        # priority penalty: lower priority never speculates deeper
        assert d >= policy_depth(load, priority + 1, rate, k)

    run()


def test_depth_monotone_under_load():
    """For fixed priority/rate/k, rising load never INCREASES depth —
    the controller collapses speculation before shedding batch width."""
    hyp = pytest.importorskip("hypothesis")
    hst = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=60, deadline=None)
    @hyp.given(loads=hst.lists(hst.floats(min_value=0.0, max_value=1.0,
                                          allow_nan=False),
                               min_size=2, max_size=10),
               priority=hst.integers(min_value=1, max_value=3),
               rate=hst.floats(min_value=0.05, max_value=1.0,
                               allow_nan=False),
               k=hst.integers(min_value=1, max_value=6))
    def run(loads, priority, rate, k):
        depths = [policy_depth(x, priority, rate, k)
                  for x in sorted(loads)]
        assert all(a >= b for a, b in zip(depths, depths[1:]))

    run()


def test_accounting_conservation():
    """proposed == accepted + rejected across ANY event sequence, and the
    depth histogram counts every event exactly once."""
    hyp = pytest.importorskip("hypothesis")
    hst = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=60, deadline=None)
    @hyp.given(events=hst.lists(
        hst.tuples(hst.integers(min_value=0, max_value=8),
                   hst.floats(min_value=0.0, max_value=1.0,
                              allow_nan=False)),
        max_size=50))
    def run(events):
        acc = SpecAccounting()
        ewma = AcceptanceEWMA()
        for depth, frac in events:
            accepted = min(depth, int(frac * (depth + 1)))
            acc.record(depth, accepted)
            if depth > 0:
                ewma.update(depth, accepted)
            assert 0.0 <= ewma.rate <= 1.0
        acc.check()
        assert acc.proposed == acc.accepted + acc.rejected
        assert sum(acc.depth_hist.values()) == len(events)

    run()


def test_probe_recovers_from_declined_state():
    """Zero-speculation must not be absorbing: with the rate stuck below
    every engagement threshold, every probe_every-th declined
    opportunity still fires a depth-1 probe, and a streak of accepted
    probes lifts the estimate back above the pricing cliff."""
    ewma = AcceptanceEWMA(init=0.1, probe_every=4)
    fires = [ewma.probe() for _ in range(12)]
    assert fires == [False, False, False, True] * 3
    for _ in range(20):                # probes keep observing accepts
        ewma.update(1, 1)
    assert ewma.rate > 0.9


def test_accounting_rejects_invalid():
    acc = SpecAccounting()
    with pytest.raises(ValueError):
        acc.record(2, 3)      # accepted > depth
    with pytest.raises(ValueError):
        acc.record(-1, 0)


def test_sim_accept_draw_properties():
    hyp = pytest.importorskip("hypothesis")
    hst = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=60, deadline=None)
    @hyp.given(rid=hst.integers(min_value=0, max_value=10**6),
               step=hst.integers(min_value=0, max_value=10**4),
               depth=hst.integers(min_value=0, max_value=8),
               rate=hst.floats(min_value=0.0, max_value=1.0,
                               allow_nan=False))
    def run(rid, step, depth, rate):
        a = sim_accept_draw(rid, step, depth, rate)
        assert 0 <= a <= depth
        # deterministic: the sim replays identically
        assert a == sim_accept_draw(rid, step, depth, rate)

    run()
    # degenerate rates are exact
    assert sim_accept_draw(1, 1, 5, 1.0) == 5
    assert sim_accept_draw(1, 1, 5, 0.0) == 0


def test_pricing_sanity():
    # higher acceptance rate never prices a SHALLOWER depth
    t0 = 1e-4

    def oh(d):
        return 0.55 * d * t0

    prev = 0
    for rate in (0.1, 0.3, 0.5, 0.8, 0.95, 1.0):
        d = price_depth(t0, oh, 4, rate)
        assert d >= prev
        prev = d
    # expected_tokens is monotone in depth and rate
    assert expected_tokens(3, 0.9) > expected_tokens(2, 0.9)
    assert expected_tokens(3, 0.9) > expected_tokens(3, 0.5)
    assert useful_depth(0.0, 4) == 0
    assert useful_depth(1.0, 4) == 4
