"""Fused decode step (model_exec.decode_step): token streams must stay
bitwise identical to the logits-fetch path despite on-device argmax and
batch/table shape bucketing, and the hot loop must do exactly one
device->host fetch per model launch."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import EngineConfig, Request, SLO, make_policy
from repro.models import init_params
from repro.serving import Engine
from repro.serving.model_exec import seg_bucket, table_bucket

# real-model end-to-end matrix: runs in the CI slow shard
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke("qwen1_5_0_5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run(cfg, params, fused, n=6, plen=48):
    # varied output lengths: the decode batch SHRINKS over the run, so
    # the fused path crosses several (B, maxp) buckets
    rng = np.random.default_rng(0)
    eng = Engine(cfg, params, EngineConfig(eta=1.0, w_p=4.0, tau=1e9),
                 make_policy("slidebatching"), num_blocks=256,
                 block_size=16, max_ctx=512, fused_decode=fused)
    trace = []
    for _ in range(n):
        r = Request(prompt_len=plen, output_len=int(rng.integers(3, 9)),
                    arrival=0.0, slo=SLO(3600.0, 3600.0), priority=2)
        trace.append(r)
        eng.add_request(r,
                        rng.integers(1, cfg.vocab, plen).astype(np.int32))
    eng.run_until_drained(max_iters=2000)
    outs = {i: eng.outputs[r.rid] for i, r in enumerate(trace)}
    stats = eng.stats
    eng.kill()
    return outs, stats


def test_fused_stream_bitwise_identical(model):
    cfg, params = model
    outs_fused, st_fused = _run(cfg, params, True)
    outs_logits, st_logits = _run(cfg, params, False)
    assert outs_fused == outs_logits
    # same scheduling -> same launch structure on both paths
    assert st_fused.decode_launches == st_logits.decode_launches
    assert st_fused.decode_launches > 0


def test_host_sync_accounting(model):
    """One fetch per launch: any hidden sync added to the step path
    breaks this exact count (the perf-smoke gate's invariant)."""
    cfg, params = model
    _, st = _run(cfg, params, True)
    assert st.host_syncs == st.decode_launches + st.packed_prefill_calls


def test_shape_buckets():
    assert [seg_bucket(s) for s in (1, 2, 3, 5, 8, 9, 17)] == \
        [1, 2, 4, 8, 8, 16, 24]
    assert table_bucket(1) == 4
    assert table_bucket(5) == 6
    assert table_bucket(7) == 8
    assert table_bucket(13) == 16
    # monotone and idempotent on its own outputs
    for p in range(1, 64):
        b = table_bucket(p)
        assert b >= p and table_bucket(b) == b
