"""Speculative decoding equivalence: spec-on token streams must be
BITWISE identical to plain greedy decode across the full feature matrix
(prefix cache, packed prefill, overlapped transfers), including
mid-speculation preemption and a draft that disagrees with the target —
greedy verify re-derives every emitted token from the target argmax, so
the draft can only change WHEN tokens appear, never WHICH.

Kernel level: every packed-verify row must be bitwise-equal to
``paged_decode_attention`` run with that row's gathered block table (the
contract the engine guarantee rests on), and allclose to the naive
softmax oracle in ref.py (online softmax rounds differently, same as the
other attention kernels)."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import EngineConfig, Request, SLO, make_policy
from repro.kernels import packed_verify_attention, paged_decode_attention
from repro.kernels.ref import packed_verify_attention_ref
from repro.models import init_params
from repro.serving import Engine


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke("qwen1_5_0_5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    draft_params = init_params(cfg, jax.random.PRNGKey(7))
    return cfg, params, draft_params


def _run(cfg, params, *, spec_k=0, draft=None, n=6, plen=48,
         num_blocks=256, prefix_cache=True, packed=True, overlap=True,
         prio=1, out_lo=3, out_hi=9):
    rng = np.random.default_rng(0)
    kw = {}
    if spec_k:
        kw["spec_draft"] = draft
    eng = Engine(cfg, params,
                 EngineConfig(eta=1.0, w_p=4.0, tau=1e9, spec_k=spec_k),
                 make_policy("slidebatching"), num_blocks=num_blocks,
                 block_size=16, max_ctx=512, prefix_cache=prefix_cache,
                 packed_prefill=packed, overlap_transfers=overlap, **kw)
    trace = []
    for _ in range(n):
        r = Request(prompt_len=plen,
                    output_len=int(rng.integers(out_lo, out_hi)),
                    arrival=0.0, slo=SLO(3600.0, 3600.0), priority=prio)
        trace.append(r)
        eng.add_request(r, rng.integers(1, cfg.vocab, plen).astype(np.int32))
    eng.run_until_drained(max_iters=2000)
    outs = {i: eng.outputs[r.rid] for i, r in enumerate(trace)}
    stats = eng.stats
    eng.kill()
    return outs, stats


@pytest.fixture(scope="module")
def reference(model):
    cfg, params, _ = model
    outs, _ = _run(cfg, params)
    return outs


@pytest.mark.slow
@pytest.mark.parametrize("prefix_cache,packed,overlap", [
    (True, True, True),
    (False, True, False),
    (True, False, True),
])
def test_spec_stream_matrix(model, reference, prefix_cache, packed, overlap):
    """Spec on, same-params draft (full acceptance — maximum speculative
    writes) across engine feature combos: streams bitwise-identical to
    the plain-decode reference, with real speculation happening."""
    cfg, params, _ = model
    outs, st = _run(cfg, params, spec_k=2, draft=(cfg, params),
                    prefix_cache=prefix_cache, packed=packed,
                    overlap=overlap)
    assert outs == reference
    assert st.spec_proposed > 0
    assert st.spec_accepted == st.spec_proposed    # same params: all match
    assert st.spec_proposed == st.spec_accepted + st.spec_rejected
    assert max(st.spec_depth_hist) == 2            # priority 1: full depth


@pytest.mark.slow
def test_spec_rejecting_draft_stream_identical(model, reference):
    """A draft with different weights proposes garbage; greedy verify
    rejects it and the stream stays bitwise-identical (only throughput,
    never content, depends on draft quality)."""
    cfg, params, draft_params = model
    outs, st = _run(cfg, params, spec_k=2, draft=(cfg, draft_params))
    assert outs == reference
    assert st.spec_rejected > 0
    assert st.spec_proposed == st.spec_accepted + st.spec_rejected
    # rejections crash the acceptance EWMA -> the controller collapses
    # depth toward 0 instead of burning verify rows
    assert st.spec_depth_hist.get(0, 0) > 0


@pytest.mark.slow
def test_spec_preemption_mid_stream(model):
    """Memory pressure forces evictions while requests are mid-decode
    with live draft state: preempted requests drop their draft context,
    re-engage after reload, and still emit the exact reference stream."""
    cfg, params, _ = model
    base, _ = _run(cfg, params, n=8, num_blocks=28, out_lo=6, out_hi=12)
    outs, st = _run(cfg, params, spec_k=2, draft=(cfg, params), n=8,
                    num_blocks=28, out_lo=6, out_hi=12)
    assert outs == base
    assert st.evictions > 0, "config must actually force preemption"
    assert st.spec_proposed > 0


def test_spec_counters_and_launch_accounting(model):
    cfg, params, _ = model
    outs, st = _run(cfg, params, spec_k=2, draft=(cfg, params))
    assert st.spec_proposed == st.spec_accepted + st.spec_rejected
    # every decode entry lands in the depth histogram
    assert sum(st.spec_depth_hist.values()) > 0
    assert st.draft_launches > 0
    # accepted bonus tokens shrink the launch count vs one-per-token
    total_out = sum(len(v) for v in outs.values())
    assert st.decode_launches + st.spec_accepted <= total_out
    # one host fetch per target launch; draft decode rounds add at most
    # draft_launches more (draft prefill ingests don't fetch)
    target = st.decode_launches + st.packed_prefill_calls
    assert target <= st.host_syncs <= target + st.draft_launches


def test_spec_requires_draft(model):
    cfg, params, _ = model
    with pytest.raises(ValueError):
        Engine(cfg, params, EngineConfig(spec_k=2),
               make_policy("slidebatching"), num_blocks=64)


# ---------------------------------------------------------------------------
# kernel-level contract
# ---------------------------------------------------------------------------

@pytest.mark.kernel
def test_packed_verify_kernel_contract():
    """Row-for-row the packed kernel must be BITWISE equal to the plain
    paged-decode kernel run with gathered per-row tables (same body, same
    accumulation order) and allclose to the naive softmax oracle."""
    key = jax.random.PRNGKey(3)
    page, hkv, g, hd = 8, 2, 4, 16
    n_pages, maxp, n_seg = 24, 3, 3
    depth = 2
    k1, k2, k3 = jax.random.split(key, 3)
    k_pages = jax.random.normal(k1, (n_pages, page, hkv, hd), jax.numpy.float32)
    v_pages = jax.random.normal(k2, (n_pages, page, hkv, hd), jax.numpy.float32)
    rng = np.random.default_rng(5)
    tables = rng.permutation(np.arange(1, n_pages))[:n_seg * maxp]
    tables = tables.reshape(n_seg, maxp).astype(np.int32)
    # rows: (seg, j) for j = 0..depth; per-row length l_kv + j + 1
    base = np.array([9, 14, 20], np.int32)
    row_seg = np.repeat(np.arange(n_seg, dtype=np.int32), depth + 1)
    lengths = np.concatenate(
        [b + np.arange(depth + 1, dtype=np.int32) + 1 for b in base])
    q = jax.random.normal(k3, (len(row_seg), hkv * g, hd), jax.numpy.float32)

    out = packed_verify_attention(q, k_pages, v_pages,
                                  jax.numpy.asarray(tables),
                                  jax.numpy.asarray(lengths),
                                  jax.numpy.asarray(row_seg), interpret=True)
    gathered = paged_decode_attention(
        q, k_pages, v_pages, jax.numpy.asarray(tables[row_seg]),
        jax.numpy.asarray(lengths), interpret=True)
    assert np.array_equal(np.asarray(out), np.asarray(gathered))
    ref = packed_verify_attention_ref(q, k_pages, v_pages,
                                      jax.numpy.asarray(tables),
                                      jax.numpy.asarray(lengths),
                                      jax.numpy.asarray(row_seg))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
