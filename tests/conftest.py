"""Shared pytest plumbing.

The live-engine test modules each compile dozens of jit variants
(packed prefill / fused decode / handoff quantize buckets).  XLA's
compilation caches are never evicted within a process, so by the time
the later modules compile their own graphs the accumulated executables
can push the CPU backend into a hard crash on small CI machines.
Dropping the caches at module teardown keeps peak footprint bounded at
the cost of per-module recompilation.
"""
import pytest


@pytest.fixture(scope="module", autouse=True)
def _bound_jax_compile_cache():
    yield
    try:
        import jax
        jax.clear_caches()
    except Exception:
        pass
