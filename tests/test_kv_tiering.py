"""Tiered KV cache (hot host tier + int8 cold tier): KVTierStore units,
radix-cache spill/restore/re-adoption, transfer-worker churn under tier
traffic, the engine equivalence matrix (cache on/off x tier on/off), and
the simulator mirror (SimPrefixCache spill + BlockManager host budget)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BlockManager, EngineConfig, Request, SLO,
                        SimPrefixCache, make_policy)
from repro.core.estimator import COLD_WIRE_RATIO, BatchLatencyEstimator
from repro.serving.kv_pool import KVTierStore
from repro.serving.transfer import TransferWorker

# real-model end-to-end matrix: runs in the CI slow shard
pytestmark = pytest.mark.slow

RNG = np.random.default_rng(11)

# synthetic block shape (L, 2, bs, Hkv, hd) — small but full-rank
BSHAPE = (2, 2, 4, 1, 4)


def blk():
    return RNG.standard_normal(BSHAPE).astype(np.float32)


def make_req(plen=100, prio=1, group=-1, shared=0, arrival=0.0):
    return Request(prompt_len=plen, output_len=10, arrival=arrival,
                   slo=SLO(3600.0, 3600.0), priority=prio,
                   prefix_group=group, shared_prefix_len=shared)


# --------------------------------------------------------------------------
# KVTierStore
# --------------------------------------------------------------------------

def test_tier_unbounded_never_demotes():
    tier = KVTierStore(block_bytes=1, budget_bytes=None)
    for rid in range(8):
        tier.put(rid, {0: blk(), 1: blk()})
    assert tier.cold_blocks == 0 and tier.demoted_blocks == 0
    assert tier.hot_blocks == 16


def test_tier_budget_demotes_lru_whole_groups():
    tier = KVTierStore(block_bytes=1, budget_bytes=2, cold_quantize=False)
    tier.put(1, {0: blk(), 1: blk()})
    tier.put(2, {0: blk(), 1: blk()})     # over budget: rid 1 (LRU) demotes
    assert tier.is_cold(1) and not tier.is_cold(2)
    assert tier.hot_blocks == 2 and tier.cold_blocks == 2
    assert tier.host_bytes <= 2
    # whole-group invariant: no rid straddles tiers
    assert not tier.hot.get(1) and not tier.cold.get(2)
    # touching rid 1 (read) makes rid 2 the next victim
    tier.get_block(1, 0)
    tier.put(3, {0: blk()})
    assert tier.is_cold(2)


def test_tier_exact_mode_roundtrip_bitwise():
    tier = KVTierStore(block_bytes=1, budget_bytes=1, cold_quantize=False)
    a = blk()
    tier.put(1, {0: a})
    tier.put(2, {0: blk()})               # demotes rid 1 (raw fp32 cold)
    assert tier.is_cold(1)
    got = tier.get_block(1, 0)
    np.testing.assert_array_equal(got, a)


def test_tier_quantized_roundtrip_error_bound():
    tier = KVTierStore(block_bytes=1, budget_bytes=1, cold_quantize=True)
    a = blk()
    tier.put(1, {0: a})
    tier.put(2, {0: blk()})               # demotes rid 1 via int8 wire
    assert tier.is_cold(1) and tier.demoted_blocks == 1
    got = tier.get_block(1, 0)
    # documented bound (kernels/kv_quant.py): |x - deq| <= scale/2 per
    # element, scale = plane_absmax / 127
    planes = a.reshape(BSHAPE[0] * BSHAPE[1], -1)
    scale = np.abs(planes).max(axis=1) * (1.0 / 127.0)
    err = np.abs(got - a).reshape(BSHAPE[0] * BSHAPE[1], -1).max(axis=1)
    assert np.all(err <= scale * 0.5 + 1e-7)
    assert tier.cold_reload_blocks == 1


def test_tier_promotion_reunites_group_hot():
    tier = KVTierStore(block_bytes=1, budget_bytes=4, cold_quantize=False)
    tier.put(1, {0: blk(), 1: blk()})
    tier.put(2, {0: blk(), 1: blk(), 2: blk()})   # rid 1 demotes
    assert tier.is_cold(1)
    tier.put(1, {2: blk()})               # new hot put promotes the group
    assert not tier.is_cold(1) and tier.n_blocks(1) == 3


def test_tier_split_group_rekeys_lower_half():
    tier = KVTierStore(block_bytes=1, budget_bytes=None)
    blocks = {i: blk() for i in range(4)}
    tier.put(1, dict(blocks))
    tier.split_group(1, 2, new_rid=-5)
    assert sorted(tier.hot[1]) == [0, 1]
    assert sorted(tier.hot[-5]) == [0, 1]      # old 2,3 re-keyed from 0
    np.testing.assert_array_equal(tier.hot[-5][0], blocks[2])
    np.testing.assert_array_equal(tier.hot[-5][1], blocks[3])


def test_tier_prefer_cold_and_payload_kinds():
    tier = KVTierStore(block_bytes=1, budget_bytes=2, cold_quantize=True)
    assert not tier.prefer_cold(2)        # fits the empty budget
    tier.put(1, {0: blk(), 1: blk()})
    assert tier.prefer_cold(1)            # would land demote-bound
    tier.put(2, {0: blk()})               # demotes rid 1
    hot_payloads = tier.payloads(2, [0])
    cold_payloads = tier.payloads(1, [0, 1])
    assert isinstance(hot_payloads[0], np.ndarray)
    assert all(isinstance(p, tuple) for p in cold_payloads)
    assert tier.payloads(1, [0, 7]) is None    # any-missing -> None


# --------------------------------------------------------------------------
# TransferWorker churn under tier traffic (failure paths)
# --------------------------------------------------------------------------

def _host_group(n=2):
    return [blk() for _ in range(n)]


def test_worker_invalidate_races_reload_and_frees_slot():
    w = TransferWorker(max_staged=1)
    try:
        assert w.prefetch(5, 0, _host_group())
        assert w.flush()
        w.invalidate(5)                    # eviction races the staged buffer
        assert w.take_staged(5, 0) is None
        # the slot is free again: a new group can stage immediately
        assert w.prefetch(6, 0, _host_group())
        assert w.flush()
        assert w.take_staged(6, 0) is not None
    finally:
        w.stop()


def test_worker_stale_epoch_completion_discarded():
    """A staging job that lands AFTER the rid's residency epoch moved on
    must not be consumed, and discard_stale must free its ring slot."""
    w = TransferWorker(max_staged=1)
    try:
        assert w.prefetch(5, 0, _host_group())
        assert w.flush()
        assert w.take_staged(5, 1) is None     # epoch bumped: stale
        w.discard_stale(5, 1)                  # reap the dead buffer
        assert w.prefetch(5, 1, _host_group())
        assert w.flush()
        n, arr = w.take_staged(5, 1)
        assert n == 2 and arr.shape[0] == 2
    finally:
        w.stop()


def test_worker_quantized_wire_dequantizes_on_device():
    from repro.kernels.ref import (kv_block_dequantize_ref,
                                   kv_block_quantize_ref)
    group = np.stack(_host_group(3))
    vals, scales = kv_block_quantize_ref(jnp.asarray(group))
    vals, scales = np.asarray(vals), np.asarray(scales)
    payloads = [(vals[i], scales[i]) for i in range(3)]
    w = TransferWorker(max_staged=1)
    try:
        assert w.prefetch(7, 0, payloads)
        assert w.flush()
        done = w.drain()
        assert any(d.kind == "h2d" and d.quantized for d in done)
        n, arr = w.take_staged(7, 0)
        assert n == 3
        want = np.asarray(kv_block_dequantize_ref(
            jnp.asarray(vals), jnp.asarray(scales)))
        np.testing.assert_allclose(np.asarray(arr), want, atol=1e-6)
    finally:
        w.stop()


# --------------------------------------------------------------------------
# RadixPrefixCache spill / restore / re-adoption
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_cfg():
    from repro.configs import get_smoke
    return get_smoke("qwen1_5_0_5b")


@pytest.fixture()
def spill_env(smoke_cfg):
    from repro.serving import PagedKVPool, RadixPrefixCache
    pool = PagedKVPool(smoke_cfg, 32, 16,
                       host_tier_bytes=1 << 30, cold_quantize=False)
    bm = BlockManager(31, 16, 1e-3)
    cache = RadixPrefixCache(pool, bm, max_blocks=16, spill=True)
    return pool, bm, cache


def _prefill(pool, rid, tokens, fill=None):
    assert pool.ensure_capacity(rid, len(tokens))
    if fill is not None:
        for b in pool.tables[rid]:
            pool.kv = pool.kv.at[:, :, b].set(fill)
    return pool.tables[rid]


def test_cache_spill_restore_roundtrip_exact(spill_env):
    pool, bm, cache = spill_env
    toks = RNG.integers(1, 999, 64).astype(np.int32)
    # match with a longer prompt so the whole 4-block node is usable
    # (usable_prefix keeps >=1 prompt token uncached)
    q = np.concatenate([toks, RNG.integers(1, 999, 16)]).astype(np.int32)
    fill = jnp.asarray(RNG.standard_normal(
        pool.kv.shape[:2] + pool.kv.shape[3:]).astype(np.float32))
    _prefill(pool, 1, toks, fill)
    adopted = cache.insert(toks, pool.tables[1], rid=1, now=0.0)
    assert adopted == 4
    bm.charge_cache(adopted)
    cache.detach(1)
    pool.release(1)
    free_before = len(pool.free)
    # eviction SPILLS to the host tier instead of destroying the blocks
    assert cache.reclaim(4) == 4
    assert len(pool.free) == free_before + 4
    assert cache.stats.spilled_blocks == 4
    assert bm.cache_charge == 0
    assert pool.tier.hot_blocks == 4       # parked under a pseudo-rid
    # a later match RESTORES the spilled node (device blocks + charge back)
    n, blocks = cache.match(q, now=1.0, rid=2)
    assert n == 64 and len(blocks) == 4
    assert cache.stats.restored_blocks == 4
    assert bm.cache_charge == 4
    assert pool.tier.hot_blocks == 0       # host copy consumed
    for b in blocks:
        assert bool(jnp.array_equal(pool.kv[:, :, b], fill))


def test_cache_spill_readopt_on_insert(spill_env):
    pool, bm, cache = spill_env
    toks = RNG.integers(1, 999, 64).astype(np.int32)
    q = np.concatenate([toks, RNG.integers(1, 999, 16)]).astype(np.int32)
    _prefill(pool, 1, toks)
    bm.charge_cache(cache.insert(toks, pool.tables[1], rid=1, now=0.0))
    cache.detach(1)
    pool.release(1)
    assert cache.reclaim(4) == 4
    # a new request recomputed the same prompt: insert re-adopts its table
    # blocks and supersedes the host-tier copy (no reload)
    _prefill(pool, 2, toks)
    adopted = cache.insert(toks, pool.tables[2], rid=2, now=2.0)
    assert adopted == 4
    assert cache.stats.readopted_blocks == 4
    assert cache.stats.restored_blocks == 0
    assert pool.tier.hot_blocks == 0       # spill group dropped
    n, _ = cache.match(q, now=3.0, rid=3)
    assert n == 64


def test_cache_restore_pool_full_is_plain_miss(spill_env):
    pool, bm, cache = spill_env
    toks = RNG.integers(1, 999, 64).astype(np.int32)
    q = np.concatenate([toks, RNG.integers(1, 999, 16)]).astype(np.int32)
    _prefill(pool, 1, toks)
    bm.charge_cache(cache.insert(toks, pool.tables[1], rid=1, now=0.0))
    cache.detach(1)
    pool.release(1)
    assert cache.reclaim(4) == 4
    hog = pool._alloc_free_blocks(len(pool.free))     # exhaust the device
    assert not pool.free
    n, blocks = cache.match(q, now=1.0, rid=2)
    assert n == 0 and blocks == []
    # the spilled copy survives for a later, less-pressured match
    assert pool.tier.hot_blocks == 4
    for b in hog:
        pool.decref(b)
    n2, _ = cache.match(q, now=2.0, rid=3)
    assert n2 == 64


def test_cache_readopt_mid_reload_invalidates_staged_buffer(spill_env):
    """Re-adoption while the worker holds a pre-staged H2D buffer for the
    spilled group must invalidate that buffer (it would otherwise pin a
    staging slot for a group that no longer exists)."""
    pool, bm, cache = spill_env
    w = TransferWorker(max_staged=2)
    cache.worker = w
    try:
        toks = RNG.integers(1, 999, 64).astype(np.int32)
        _prefill(pool, 1, toks)
        bm.charge_cache(cache.insert(toks, pool.tables[1], rid=1, now=0.0))
        cache.detach(1)
        pool.release(1)
        assert cache.reclaim(4) == 4
        (host_rid, payloads), = cache.spill_candidates(limit=1)
        assert w.prefetch(host_rid, 0, payloads)
        assert w.flush()
        # mid-reload re-adoption: a request recomputed the same prompt
        _prefill(pool, 2, toks)
        assert cache.insert(toks, pool.tables[2], rid=2, now=2.0) == 4
        assert w.take_staged(host_rid, 0) is None     # buffer invalidated
        assert not cache.has_spilled(host_rid)
    finally:
        w.stop()


def test_cache_spilled_match_can_use_staged_buffer(spill_env):
    pool, bm, cache = spill_env
    w = TransferWorker(max_staged=2)
    cache.worker = w
    try:
        toks = RNG.integers(1, 999, 64).astype(np.int32)
        q = np.concatenate([toks, RNG.integers(1, 999, 16)]).astype(np.int32)
        _prefill(pool, 1, toks)
        bm.charge_cache(cache.insert(toks, pool.tables[1], rid=1, now=0.0))
        cache.detach(1)
        pool.release(1)
        assert cache.reclaim(4) == 4
        (host_rid, payloads), = cache.spill_candidates(limit=1)
        assert w.prefetch(host_rid, 0, payloads)
        assert w.flush()
        n, blocks = cache.match(q, now=1.0, rid=2)
        assert n == 64 and len(blocks) == 4
        assert cache.stats.staged_restores == 1
    finally:
        w.stop()


# --------------------------------------------------------------------------
# Simulator mirror: BlockManager host budget + SimPrefixCache spill
# --------------------------------------------------------------------------

def test_bm_host_budget_demotes_lru_and_scales_reload_wire():
    bm = BlockManager(32, 16, 1e-3, host_budget_blocks=2,
                      n_off_by_priority={1: 2, 2: 2, 3: 2})
    r1 = make_req(plen=32, prio=3)
    r2 = make_req(plen=32, prio=3)
    assert bm.grow(r1, 32, 0.0) and bm.grow(r2, 32, 0.0)
    bm.evict(r1, 1.0)                   # 2 mirrored blocks -> host (hot)
    assert bm.state(r1).host_tokens == 32
    assert bm.state(r1).cold_tokens == 0
    bm.evict(r2, 2.0)                   # over budget: r1 (LRU) demotes
    assert bm.state(r1).cold_tokens == 32
    assert bm.state(r2).cold_tokens == 0
    # cold reload occupies the H2D lane at COLD_WIRE_RATIO width
    plan = bm.plan_reload(r1, 100, 1 << 20, 1 << 20)
    assert plan.restore_blocks == 2
    done = bm.apply_reload(r1, plan, 10.0)
    assert done == pytest.approx(10.0 + 2 * 1e-3 * COLD_WIRE_RATIO)
    plan2 = bm.plan_reload(r2, 100, 1 << 20, 1 << 20)
    done2 = bm.apply_reload(r2, plan2, 20.0)
    assert done2 == pytest.approx(20.0 + 2 * 1e-3)    # hot: full width


def test_estimator_reload_time_tier_pricing():
    est = BatchLatencyEstimator()
    t = 5e-4
    assert est.reload_time(7, 0, t) == 7 * t              # legacy bitwise
    assert est.reload_time(0, 8, t) == pytest.approx(
        COLD_WIRE_RATIO * 8 * t)
    assert est.reload_time(3, 4, t) == pytest.approx((3 + 1.0) * t)


def test_sim_cache_spill_restore_and_cold_wire():
    bm = BlockManager(64, 16, 1e-3)
    cache = SimPrefixCache(16, 32, spill=True, host_budget_blocks=4)
    cache.bm = bm
    bm.cache = cache
    r1 = make_req(plen=100, group=1, shared=64)
    r2 = make_req(plen=100, group=2, shared=64)
    for r in (r1, r2):
        bm.charge_cache(cache.insert(r, now=0.0))
        cache.detach(r.rid)
    assert cache.cached_blocks == 8
    # evictions SPILL whole groups; beyond the 4-block host budget the
    # LRU spilled group (1) demotes to the cold tier
    assert cache.reclaim(8) == 8
    assert bm.cache_charge == 0
    assert set(cache.spilled) == {1, 2}
    assert cache.spilled[1].cold and not cache.spilled[2].cold
    assert cache.spilled_blocks == 8
    # a later match restores group 1 over the NARROW wire
    got = cache.match(make_req(plen=100, group=1, shared=64), now=10.0)
    assert got == 64
    assert cache.restored_blocks == 4
    assert bm.cache_charge == 4
    assert bm.h2d.busy_until == pytest.approx(
        10.0 + 4 * 1e-3 * COLD_WIRE_RATIO)
    # group 2 is still hot: full-width wire
    got2 = cache.match(make_req(plen=100, group=2, shared=64), now=20.0)
    assert got2 == 64
    assert bm.h2d.busy_until == pytest.approx(20.0 + 4 * 1e-3)
    assert not cache.spilled


def test_sim_cache_restore_pool_full_is_miss():
    bm = BlockManager(8, 16, 1e-3)
    cache = SimPrefixCache(16, 8, spill=True)
    cache.bm = bm
    bm.cache = cache
    r1 = make_req(plen=100, group=1, shared=64)
    bm.charge_cache(cache.insert(r1, now=0.0))
    cache.detach(r1.rid)
    assert cache.reclaim(4) == 4
    hog = make_req(plen=128)
    assert bm.grow(hog, 128, 0.0)       # 8 blocks: device full
    assert cache.match(make_req(plen=100, group=1, shared=64), now=1.0) == 0
    assert 1 in cache.spilled           # copy kept for later
    bm.release(hog)
    assert cache.match(make_req(plen=100, group=1, shared=64), now=2.0) == 64


def test_sim_cache_readopt_on_insert():
    bm = BlockManager(64, 16, 1e-3)
    cache = SimPrefixCache(16, 32, spill=True)
    cache.bm = bm
    bm.cache = cache
    r1 = make_req(plen=100, group=1, shared=64)
    bm.charge_cache(cache.insert(r1, now=0.0))
    cache.detach(r1.rid)
    assert cache.reclaim(4) == 4
    # a request that recomputed the prefix re-inserts: spilled copy is
    # superseded without an H2D restore
    r2 = make_req(plen=100, group=1, shared=64)
    adopted = cache.insert(r2, now=5.0)
    assert adopted == 4
    assert 1 not in cache.spilled
    assert bm.h2d.busy_until == 0.0


# --------------------------------------------------------------------------
# Engine end-to-end: cache on/off x tier on/off matrix (exact mode)
# --------------------------------------------------------------------------

def _matrix_engine(smoke_cfg, params, *, prefix_cache, host_tier_bytes,
                   cold_quantize):
    from repro.serving import Engine
    return Engine(smoke_cfg, params, EngineConfig(eta=1.0, w_p=4.0, tau=1e9),
                  make_policy("slidebatching"), num_blocks=7,
                  block_size=16, max_ctx=256, prefix_cache=prefix_cache,
                  host_tier_bytes=host_tier_bytes,
                  cold_quantize=cold_quantize)


def _matrix_run(smoke_cfg, params, prompts, *, prefix_cache, host_tier_bytes,
                cold_quantize=False):
    eng = _matrix_engine(smoke_cfg, params, prefix_cache=prefix_cache,
                         host_tier_bytes=host_tier_bytes,
                         cold_quantize=cold_quantize)
    reqs = []
    # staged admission: the first request seeds the radix cache before the
    # rest arrive and share its prefix blocks
    for wave in (prompts[:1], prompts[1:]):
        for p in wave:
            r = make_req(plen=len(p))
            r.output_len = 5
            eng.add_request(r, p)
            reqs.append(r)
        eng.run_until_drained(max_iters=400)
    return eng, [eng.outputs[r.rid] for r in reqs]


@pytest.fixture(scope="module")
def smoke_params(smoke_cfg):
    import jax
    from repro.models import init_params
    return init_params(smoke_cfg, jax.random.PRNGKey(0))


def test_engine_tier_matrix_exact_mode_bitwise(smoke_cfg, smoke_params):
    """Exact mode (fp32 cold tier): every cache x tier combination must
    emit the uninterrupted greedy reference token-for-token.  The tiny
    pool forces evictions, so tiered runs exercise spill + demote +
    reload on the live token path."""
    from repro.models import forward

    rng = np.random.default_rng(31)
    shared = rng.integers(1, smoke_cfg.vocab, 32).astype(np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(1, smoke_cfg.vocab, 8 + 4 * i)
                               .astype(np.int32)]) for i in range(4)]

    def ref(prompt, n=5):
        import jax.numpy as jnp
        cur = jnp.asarray(prompt)[None, :]
        out = []
        for _ in range(n):
            logits, _ = forward(smoke_cfg, smoke_params, cur)
            nxt = int(jnp.argmax(logits[0, -1]))
            out.append(nxt)
            cur = jnp.concatenate([cur, jnp.asarray([[nxt]])], axis=1)
        return out

    refs = [ref(p) for p in prompts]
    probe = _matrix_engine(smoke_cfg, smoke_params, prefix_cache=False,
                           host_tier_bytes=1 << 30, cold_quantize=False)
    bb = probe.pool.tier.block_bytes
    tier_demoted = 0
    for cache_on in (False, True):
        for tier_bytes in (None, 2 * bb):
            eng, outs = _matrix_run(smoke_cfg, smoke_params, prompts,
                                    prefix_cache=cache_on,
                                    host_tier_bytes=tier_bytes)
            assert outs == refs, (
                f"diverged: cache={cache_on} tier={tier_bytes}")
            if tier_bytes is not None:
                assert eng.stats.evictions > 0
                tier_demoted += eng.pool.tier.demoted_blocks
    # at least one tiered run must have pushed past the 2-block host
    # budget into the (exact fp32) cold tier
    assert tier_demoted > 0


def test_engine_tier_int8_cold_completes_under_pressure(smoke_cfg,
                                                        smoke_params):
    """Quantized cold tier: the engine must complete every request through
    int8 demote/reload cycles (no bitwise claim — int8 is lossy)."""
    rng = np.random.default_rng(32)
    shared = rng.integers(1, smoke_cfg.vocab, 32).astype(np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(1, smoke_cfg.vocab, 8 + 4 * i)
                               .astype(np.int32)]) for i in range(4)]
    probe = _matrix_engine(smoke_cfg, smoke_params, prefix_cache=False,
                           host_tier_bytes=1 << 30, cold_quantize=True)
    bb = probe.pool.tier.block_bytes
    eng, outs = _matrix_run(smoke_cfg, smoke_params, prompts,
                            prefix_cache=True, host_tier_bytes=2 * bb,
                            cold_quantize=True)
    assert all(len(o) == 5 for o in outs)
    assert eng.stats.spill_blocks > 0
    # demote-bound traffic lands cold either by direct int8 offload
    # (prefer_cold) or by later LRU demotion
    assert eng.stats.cold_blocks + eng.pool.tier.demoted_blocks > 0
    assert eng.stats.host_bytes <= 2 * bb
