"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (block_gather, chunked_prefill_attention,
                           kv_block_dequantize, kv_block_quantize,
                           paged_decode_attention)
from repro.kernels.ref import (block_gather_ref,
                               chunked_prefill_attention_ref,
                               kv_block_dequantize_ref,
                               kv_block_quantize_ref,
                               paged_decode_attention_ref)

pytestmark = pytest.mark.kernel

KEY = jax.random.PRNGKey(7)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hkv,hd,page,maxp", [
    (2, 4, 4, 32, 16, 4),      # MHA (G=1)
    (3, 8, 2, 64, 16, 5),      # GQA G=4
    (1, 16, 2, 16, 8, 8),      # G=8, small pages
    (4, 6, 6, 128, 32, 2),     # head_dim 128 (MXU-aligned)
])
def test_paged_decode_attention_sweep(dtype, b, h, hkv, hd, page, maxp):
    ks = jax.random.split(KEY, 4)
    P = maxp * b + 3
    q = jax.random.normal(ks[0], (b, h, hd), dtype)
    kp = jax.random.normal(ks[1], (P, page, hkv, hd), dtype)
    vp = jax.random.normal(ks[2], (P, page, hkv, hd), dtype)
    bt = jax.random.randint(ks[3], (b, maxp), 0, P)
    lens = jnp.asarray(
        np.random.default_rng(0).integers(1, maxp * page + 1, b), jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, lens)
    ref = paged_decode_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


def test_paged_decode_length_edge_cases():
    """len == 1 and len == full capacity."""
    b, h, hkv, hd, page, maxp = 2, 4, 2, 16, 8, 3
    ks = jax.random.split(KEY, 4)
    P = 8
    q = jax.random.normal(ks[0], (b, h, hd))
    kp = jax.random.normal(ks[1], (P, page, hkv, hd))
    vp = jax.random.normal(ks[2], (P, page, hkv, hd))
    bt = jax.random.randint(ks[3], (b, maxp), 0, P)
    lens = jnp.asarray([1, maxp * page], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, lens)
    ref = paged_decode_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(out, ref, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,smax,h,hkv,hd,kvb", [
    (2, 8, 64, 4, 2, 32, 16),
    (1, 16, 128, 8, 8, 16, 32),
    (3, 4, 40, 6, 2, 64, 16),   # smax not a multiple of kvb
])
def test_chunked_prefill_sweep(dtype, b, sq, smax, h, hkv, hd, kvb):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd), dtype)
    kc = jax.random.normal(ks[1], (b, smax, hkv, hd), dtype)
    vc = jax.random.normal(ks[2], (b, smax, hkv, hd), dtype)
    rng = np.random.default_rng(1)
    lens = jnp.asarray(rng.integers(sq, smax + 1, b), jnp.int32)
    out = chunked_prefill_attention(q, kc, vc, lens, kv_block=kvb)
    ref = chunked_prefill_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


def test_chunked_prefill_fresh_prompt():
    """cache_len == Sq: pure prefill with no prefix (causal within chunk)."""
    b, sq, h, hkv, hd = 2, 12, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd))
    kc = jax.random.normal(ks[1], (b, sq, hkv, hd))
    vc = jax.random.normal(ks[2], (b, sq, hkv, hd))
    lens = jnp.full((b,), sq, jnp.int32)
    out = chunked_prefill_attention(q, kc, vc, lens, kv_block=8)
    ref = chunked_prefill_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(out, ref, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_gather(dtype):
    pool = jax.random.normal(KEY, (32, 16, 2, 8), dtype)
    idx = jnp.asarray([3, 31, 0, 3, 17], jnp.int32)
    out = block_gather(pool, idx)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(block_gather_ref(pool, idx)))


@pytest.mark.parametrize("n,layers,bs,hkv,hd,amp", [
    (3, 2, 8, 2, 16, 3.0),
    (1, 4, 16, 2, 64, 0.02),    # tiny magnitudes
    (7, 2, 4, 1, 8, 50.0),      # large magnitudes
])
def test_kv_quant_bitwise_vs_ref(n, layers, bs, hkv, hd, amp):
    """Quantize AND dequantize kernels are bitwise-equal to the oracles
    (elementwise ops + exact reductions only)."""
    x = jax.random.normal(KEY, (n, layers, 2, bs, hkv, hd)) * amp
    x = x.at[0, 0].set(jnp.zeros_like(x[0, 0]))   # all-zero plane: scale 0
    vals, scales = kv_block_quantize(x)
    vr, sr = kv_block_quantize_ref(x)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(scales), np.asarray(sr))
    assert vals.dtype == jnp.int8 and scales.shape == (n, layers, 2)
    deq = kv_block_dequantize(vals, scales)
    np.testing.assert_array_equal(np.asarray(deq),
                                  np.asarray(kv_block_dequantize_ref(vr,
                                                                     sr)))


def test_kv_quant_roundtrip_error_bound():
    """The documented int8 bound: per element |x - deq(quant(x))| <=
    scale/2 of its (block, layer, k|v) plane, zero planes exact."""
    x = jax.random.normal(KEY, (4, 3, 2, 8, 2, 16)) * 7.0
    x = x.at[1].set(jnp.zeros_like(x[1]))
    vals, scales = kv_block_quantize(x)
    deq = np.asarray(kv_block_dequantize(vals, scales))
    err = np.abs(deq - np.asarray(x))
    bound = np.asarray(scales)[..., None, None, None] / 2.0
    assert (err <= bound).all()
    np.testing.assert_array_equal(deq[1], np.zeros_like(deq[1]))
    # extrema survive the roundtrip at full scale: absmax maps to +-127
    flat = np.abs(np.asarray(x)).reshape(4 * 3 * 2, -1)
    amax_q = np.abs(np.asarray(vals)).reshape(4 * 3 * 2, -1).max(axis=1)
    assert (amax_q[flat.max(axis=1) > 0] == 127).all()


def test_kernel_consistency_with_model_decode():
    """Paged kernel result == model's dense decode_attention on the same
    logical KV (the engine relies on this)."""
    from repro.models.layers import decode_attention as model_decode
    b, h, hkv, hd, page, maxp = 2, 4, 2, 16, 8, 4
    ks = jax.random.split(KEY, 4)
    P = b * maxp + 1
    q = jax.random.normal(ks[0], (b, h, hd))
    kp = jax.random.normal(ks[1], (P, page, hkv, hd))
    vp = jax.random.normal(ks[2], (P, page, hkv, hd))
    bt = jnp.arange(1, 1 + b * maxp, dtype=jnp.int32).reshape(b, maxp)
    lens = jnp.asarray([13, 29], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, lens)
    # build the contiguous equivalent
    k_lin = kp[bt].reshape(b, maxp * page, hkv, hd)
    v_lin = vp[bt].reshape(b, maxp * page, hkv, hd)
    ref = model_decode(q[:, None], k_lin, v_lin, lens)[:, 0]
    np.testing.assert_allclose(out, ref, atol=2e-5)
