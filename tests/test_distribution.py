"""Distribution layer: mesh construction, sharding rules, a REAL mini
dry-run (8 fake devices in a subprocess so the main process keeps 1
device), and the trip-count HLO cost analyzer."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get, get_smoke
from repro.launch.hlo_cost import analyze, xla_cost_analysis


def run_sub(code: str) -> str:
    """Run code in a subprocess with 8 fake XLA host devices."""
    import os
    # Force the CPU backend in the hermetic env: without JAX_PLATFORMS,
    # a jax install that bundles libtpu probes TPU metadata endpoints
    # (minutes of retries on non-TPU hosts) before falling back.
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"})
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_mesh_shapes_in_subprocess():
    out = run_sub("""
        from repro.launch.mesh import make_production_mesh, make_debug_mesh
        m = make_debug_mesh((4, 2), ("data", "model"))
        print(dict(m.shape))
        print(m.axis_names)
    """)
    assert "'data': 4" in out and "'model': 2" in out


def test_param_specs_divisibility_guards():
    """whisper vocab 51865 and mamba vocab 50280 must NOT be sharded on a
    16-way axis; qwen vocab 151936 must be."""
    import numpy as np
    from repro.distributed.sharding import ShardingPolicy, param_specs

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    pol = ShardingPolicy.__new__(ShardingPolicy)
    object.__setattr__(pol, "mesh", FakeMesh())
    object.__setattr__(pol, "mode", "serve")
    object.__setattr__(pol, "sp", True)
    object.__setattr__(pol, "fsdp", True)
    object.__setattr__(pol, "seq_sharded_kv", True)

    for arch, expect_sharded in [("whisper_small", False),
                                 ("mamba2_1_3b", False),
                                 ("qwen1_5_0_5b", True),
                                 ("hymba_1_5b", False)]:
        cfg = get(arch)
        fake = {"embed": np.zeros((cfg.vocab, 8)),
                "lm_head": np.zeros((cfg.vocab, 8))}
        specs = param_specs(cfg, pol, fake)
        sharded = specs["embed"][0] == "model"
        assert sharded == expect_sharded, arch


def test_mini_dryrun_lowers_and_compiles():
    """End-to-end dry-run machinery on a (4,2) debug mesh with a smoke
    config: lower + compile + memory/cost analysis must all work."""
    out = run_sub("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_smoke
        from repro.distributed.sharding import ShardingPolicy
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import build_train_step, build_serve_step
        from repro.launch import hlo_cost

        mesh = make_debug_mesh((4, 2), ("data", "model"))
        cfg = dataclasses.replace(get_smoke("qwen1_5_0_5b"),
                                  d_model=64, vocab=256)
        pol = ShardingPolicy(mesh=mesh, mode="train")
        with mesh:
            jitted, structs, meta = build_train_step(cfg, pol, microbatches=1)
            # shrink the inputs for an 8-device debug run
            import jax
            small = dict(tokens=jax.ShapeDtypeStruct((8, 64), jnp.int32),
                         labels=jax.ShapeDtypeStruct((8, 64), jnp.int32))
            lowered = jitted.lower(structs[0], structs[1], small)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            acc = hlo_cost.analyze(compiled.as_text())
            assert acc["flops"] > 0
            print("train ok", int(acc["flops"]))
        pol_s = ShardingPolicy(mesh=mesh, mode="serve")
        with mesh:
            jitted, structs, _ = build_serve_step(cfg, pol_s, "decode_32k")
            # full decode_32k struct is huge; just lower a small custom one
            from repro.launch.steps import cache_struct
            cs = cache_struct(cfg, 8, 128)
            import jax
            toks = jax.ShapeDtypeStruct((8,), jnp.int32)
            print("serve struct ok", len(jax.tree.leaves(cs)))
        print("DONE")
    """)
    assert "train ok" in out and "DONE" in out


def test_hlo_cost_trip_count_weighting():
    def body(x, _):
        return x @ x, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=6)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(x).compile()
    acc = analyze(compiled.as_text())
    expected = 6 * 2 * 128 ** 3
    assert acc["flops"] == pytest.approx(expected, rel=1e-6)
    # XLA's own analysis counts the body once — ours must not
    assert xla_cost_analysis(compiled)["flops"] == pytest.approx(
        expected / 6, rel=1e-6)


def test_hlo_cost_loop_free_exact():
    def g(a, b):
        return a @ b

    A = jax.ShapeDtypeStruct((64, 96), jnp.float32)
    B = jax.ShapeDtypeStruct((96, 32), jnp.float32)
    compiled = jax.jit(g).lower(A, B).compile()
    acc = analyze(compiled.as_text())
    assert acc["flops"] == 2 * 64 * 96 * 32
    assert acc["bytes"] == xla_cost_analysis(compiled)["bytes accessed"]


def test_nested_scan_multipliers():
    def inner(x, _):
        return x @ x, None

    def outer(x, _):
        y, _ = jax.lax.scan(inner, x, None, length=3)
        return y, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    acc = analyze(jax.jit(f).lower(x).compile().as_text())
    assert acc["flops"] == pytest.approx(12 * 2 * 64 ** 3, rel=1e-6)
