"""Windowed ClusterSim equivalence contract (docs/ARCHITECTURE.md):
the cross-replica event-batched outer loop must reproduce the reference
simulator EXACTLY — per-request token timestamps, finish times and
preemption counts — on seeded coloc traces across load regimes,
speculative decoding, streaming mode and time-bounded runs; non-coloc
traces must transparently fall back to the reference loop."""
import pytest

from repro.core import EngineConfig, GoRouting, RouterConfig
from repro.core.slidebatching import SlideBatching
from repro.sim import (AnalyticalExecutor, ClusterConfig, ClusterSim,
                       InstanceHardware, QWEN2_7B, WindowedClusterSim,
                       iter_scale_trace, spec_counters)


@pytest.fixture(scope="module")
def exec_est():
    ex = AnalyticalExecutor(QWEN2_7B, InstanceHardware(chips=4))
    est, _ = ex.fit_estimator(n=200)
    return ex, est


def make_cluster(ex, est, cls, *, pd_mode="coloc", n_prefill=2,
                 n_decode=0, spec_k=0):
    return cls(lambda: SlideBatching(),
               GoRouting(est, RouterConfig(pd_mode=pd_mode)),
               ex, est, EngineConfig(w_p=4.0, spec_k=spec_k),
               ClusterConfig(pd_mode=pd_mode, n_prefill=n_prefill,
                             n_decode=n_decode))


def trace(n, rate, seed=7):
    reqs = list(iter_scale_trace(n, rate=rate, seed=seed))
    # pin rids: the spec acceptance draw is keyed on (rid, step) and the
    # process-global rid counter depends on what ran earlier
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


def signature(reqs):
    return [(r.rid, tuple(r.out_times), r.finish_time, r.preemptions)
            for r in reqs]


def run_pair(ex, est, n, rate, *, spec_k=0, until=None, kills=None,
             **kw):
    out = {}
    for cls in (ClusterSim, WindowedClusterSim):
        cs = make_cluster(ex, est, cls, spec_k=spec_k, **kw)
        reqs = trace(n, rate)
        cs.run(reqs, until=until, kills=kills)
        out[cls] = (signature(reqs),
                    spec_counters(cs) if spec_k else None)
    return out[ClusterSim], out[WindowedClusterSim]


@pytest.mark.parametrize("n,rate", [(400, 600.0), (300, 2000.0)])
def test_equivalence_load_matrix(exec_est, n, rate):
    """Normal contention and deep overload (rejections exercised)."""
    ex, est = exec_est
    ref, win = run_pair(ex, est, n, rate)
    assert ref == win


def test_equivalence_spec(exec_est):
    """Speculative decoding: depth assignment, the (rid, step)-keyed
    acceptance draw, and the aggregated counters must all agree."""
    ex, est = exec_est
    ref, win = run_pair(ex, est, 300, 600.0, spec_k=2)
    assert ref == win
    assert win[1]["spec_proposed"] > 0


def test_equivalence_until(exec_est):
    """Time-bounded runs cut off at the same event horizon."""
    ex, est = exec_est
    ref, win = run_pair(ex, est, 400, 600.0, until=2.0)
    assert ref == win


def test_run_stream_matches_run(exec_est):
    """Streaming mode: same per-request physics, every completion
    delivered exactly once.  Callback ORDER within a heartbeat window is
    replica-grouped rather than globally time-interleaved (the one
    documented non-contract difference), so completions are compared as
    a set keyed by rid."""
    ex, est = exec_est
    cs_ref = make_cluster(ex, est, ClusterSim)
    reqs = trace(400, 600.0)
    cs_ref.run(reqs)

    cs_win = make_cluster(ex, est, WindowedClusterSim)
    done = []
    n = cs_win.run_stream(iter(trace(400, 600.0)),
                          on_finished=done.append)
    assert n == 400
    want = {r.rid: (tuple(r.out_times), r.finish_time, r.preemptions)
            for r in reqs if r.finish_time is not None}
    got = {r.rid: (tuple(r.out_times), r.finish_time, r.preemptions)
           for r in done}
    assert got == want


def test_disagg_falls_back(exec_est):
    """Non-coloc traces route through the reference loop (HANDOFF
    tie-breaking needs the global heap), so results stay identical."""
    ex, est = exec_est
    ref, win = run_pair(ex, est, 200, 400.0, pd_mode="disagg",
                        n_prefill=1, n_decode=1)
    assert ref == win


def test_kills_fall_back(exec_est):
    """Kill schedules force the reference loop; results stay identical."""
    ex, est = exec_est
    ref, win = run_pair(ex, est, 300, 600.0, kills=[(0.5, 0)])
    assert ref == win
