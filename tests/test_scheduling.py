"""SlideBatching (Alg. 1) + baseline policies against the shared engine view."""
import pytest

from repro.core import (BatchLatencyEstimator, BlockManager, EngineConfig,
                        Request, SLO, SchedView, SlideBatching, make_policy)

EST = BatchLatencyEstimator(a_p=1e-9, b_p=1e-9, c_p=2e-6, a_d=2e-8,
                            b_d=1e-4, t_c=2e-3)


def view(reqs, now=0.0, cfg=None, blocks=4096):
    bm = BlockManager(blocks, 16, 1e-4)
    return SchedView(list(reqs), bm, EST, cfg or EngineConfig(w_p=4.0), now)


def req(plen=500, out=50, prio=2, arrival=0.0, ttft=1.0, tpot=0.1, w=None):
    return Request(prompt_len=plen, output_len=out, arrival=arrival,
                   slo=SLO(ttft, tpot), priority=prio,
                   weight=w if w is not None else (2.0 if prio == 1 else 1.0))


def test_slidebatching_budget_lower_bound():
    # all requests already late: no deadline constrains the batch — the
    # budget rises to the top of its natural range [eta, max TPOT_SLO]
    v = view([req(arrival=-10.0, tpot=0.08)])
    plan = SlideBatching().form_batch(v)
    assert plan.t_budget == pytest.approx(max(v.cfg.eta, 0.08))
    # one request still savable with tiny remain: budget floors at eta
    v2 = view([req(arrival=-0.999, ttft=1.0)])   # remain = 1ms
    plan2 = SlideBatching().form_batch(v2)
    assert plan2.t_budget == pytest.approx(v2.cfg.eta)
    # savable request with comfortable remain sets the budget directly
    v3 = view([req(arrival=0.0, ttft=0.5)])
    plan3 = SlideBatching().form_batch(v3)
    assert plan3.t_budget == pytest.approx(0.5)


def test_slidebatching_time_budget_respected():
    reqs = [req(plen=5000, ttft=0.5) for _ in range(8)]
    v = view(reqs)
    plan = SlideBatching().form_batch(v)
    assert plan.entries
    # estimated batch time stays within budget + one-entry tolerance
    assert plan.est_time <= plan.t_budget * 1.5 + EST.t_c


def test_urgency_boundary_slides_with_load():
    """More load => more requests classified urgent (density-first)."""
    sb = SlideBatching()
    light = view([req(arrival=0.0) for _ in range(2)])
    heavy = view([req(arrival=0.0) for _ in range(80)])
    sb.form_batch(light)
    light_order = list(light.queue)
    sb.form_batch(heavy)
    # under heavy load the head of the queue must be density-sorted:
    # high-priority (weight 2) requests with equal exec come first
    heavy_reqs = [req(prio=1), req(prio=2)] * 10
    v = view(heavy_reqs + [req(plen=8000) for _ in range(50)])
    sb.form_batch(v)
    head = v.queue[:10]
    prio1 = sum(1 for r in head if r.priority == 1)
    assert prio1 >= 5  # density-first pushes high-weight requests forward


def test_density_ordering_in_urgent_group():
    cfg = EngineConfig(w_p=4.0, gamma=1e9)   # force everyone urgent
    short_high = req(plen=100, prio=1)
    long_low = req(plen=8000, prio=2)
    v = view([long_low, short_high], cfg=cfg)
    SlideBatching().form_batch(v)
    assert v.queue[0] is short_high          # max density first


def test_normal_group_is_edf():
    cfg = EngineConfig(w_p=4.0, gamma=0.0)   # force everyone normal
    early = req(arrival=0.0, ttft=0.5)
    late = req(arrival=0.0, ttft=5.0)
    v = view([late, early], cfg=cfg)
    SlideBatching().form_batch(v)
    assert v.queue[0] is early               # earliest deadline first


def test_starvation_promotion():
    cfg = EngineConfig(w_p=4.0, tau=5.0)
    starved = req(arrival=0.0, prio=3, plen=4000, ttft=0.5, w=0.1)
    fresh = [req(arrival=9.9, prio=1, plen=100) for _ in range(5)]
    v = view([starved] + fresh, now=10.0, cfg=cfg)
    SlideBatching().form_batch(v)
    assert starved.starving
    assert v.queue[0] is starved


def test_chunked_admission_under_memory_pressure():
    """With a tiny pool the batch former must evict or shrink, never
    overcommit blocks."""
    reqs = [req(plen=600) for _ in range(16)]
    v = view(reqs, blocks=64)   # only 1024 tokens of KV
    plan = SlideBatching().form_batch(v)
    assert v.bm.used_blocks <= 64
    assert plan.entries


# --- baselines ----------------------------------------------------------------

@pytest.mark.parametrize("name", ["vllm_fcfs", "sarathi_fcfs",
                                  "sarathi_priority", "fair_batching",
                                  "weighted_vtc", "edf", "sjf",
                                  "priority_first"])
def test_baseline_forms_valid_batch(name):
    pol = make_policy(name)
    reqs = [req(plen=100 + 50 * i, prio=1 + i % 2, arrival=0.01 * i)
            for i in range(10)]
    v = view(reqs)
    plan = pol.form_batch(v)
    assert plan.entries
    total = sum(e.n_tokens for e in plan.entries)
    assert total <= v.cfg.token_budget + max(r.prompt_len for r in reqs)
    for e in plan.entries:
        assert e.n_tokens >= 1


def test_sarathi_decode_priority():
    """Sarathi admits running decodes before any waiting prefill."""
    pol = make_policy("sarathi_fcfs")
    dec = req(plen=50, out=10)
    v = view([dec])
    # simulate: prefill done + one token out
    v.bm.grow(dec, 50, 0.0)
    dec.emit_token(0.5)
    wait = req(plen=3000, arrival=0.4)
    v.queue.append(wait)
    plan = pol.form_batch(v)
    assert plan.entries[0].req is dec and not plan.entries[0].is_prefill


def test_weighted_vtc_token_ratio():
    """Under symmetric saturation, processed tokens track weights ~2:1."""
    pol = make_policy("weighted_vtc")
    cfg = EngineConfig(token_budget=256, chunk_size=64)
    served = {1: 0, 2: 0}
    reqs = []
    for i in range(40):
        r = req(plen=10000, prio=1 + i % 2)
        r.client = r.priority
        reqs.append(r)
    bm = BlockManager(100000, 16, 1e-4)
    for _ in range(60):
        v = SchedView(reqs, bm, EST, cfg, 0.0)
        plan = pol.form_batch(v)
        for e in plan.entries:
            served[e.req.priority] += e.n_tokens
    ratio = served[1] / max(served[2], 1)
    assert 1.5 < ratio < 2.8      # weight ratio is 2:1


def test_vllm_overlong_prompt_runs_alone():
    pol = make_policy("vllm_fcfs")
    big = req(plen=10000)
    v = view([big, req(plen=100, arrival=0.1)])
    plan = pol.form_batch(v)
    assert len(plan.entries) == 1 and plan.entries[0].req is big
    assert plan.entries[0].n_tokens == 10000


# --------------------------------------------------------------------------
# columnar fast path (>= _MIN_COLS rows) is bitwise-identical to scalar
# --------------------------------------------------------------------------

def _mixed_world(now=12.0, n=48, blocks=4096):
    """Deterministic queue mixing fresh prefills, active decodes and
    evicted (host-resident) requests across priorities/SLOs/clients."""
    import random

    from repro.core.blocks import blocks_for
    rng = random.Random(7)
    bm = BlockManager(blocks, 16, 1e-4)
    reqs = []
    for i in range(n):
        prio = rng.choice([1, 2, 3])
        r = Request(prompt_len=rng.randrange(64, 2048), output_len=64,
                    arrival=rng.uniform(0.0, 10.0),
                    slo=SLO(rng.choice([0.5, 1.0, 2.0]),
                            rng.choice([0.05, 0.1])),
                    priority=prio, weight={1: 2.0, 2: 1.0, 3: 0.5}[prio],
                    client=rng.randrange(4))
        s = bm.state(r)
        kind = rng.random()
        if kind < 0.4:       # active decode: context fully resident
            for k in range(rng.randrange(1, 8)):
                r.out_times.append(now - 1.0 + 0.01 * k)
            s.dev_tokens = r.prompt_len + max(0, r.generated - 1)
            bm.used_blocks += blocks_for(s.dev_tokens, 16)
        elif kind < 0.6:     # evicted mid-decode: host-resident span
            for k in range(rng.randrange(1, 4)):
                r.out_times.append(now - 1.0 + 0.01 * k)
            s.host_tokens = r.prompt_len
        reqs.append(r)
    return reqs, bm


def _plan_snapshot(reqs, v, plan):
    pos = {r.rid: i for i, r in enumerate(reqs)}
    return {
        "entries": [(pos[e.req.rid], e.n_tokens, e.l_kv, e.is_prefill)
                    for e in plan.entries],
        "evictions": [pos[r.rid] for r in plan.evictions],
        "est_time": plan.est_time,
        "copy_blocks": plan.copy_blocks,
        "used": v.bm.used_blocks,
        "residency": [(v.bm.state(r).dev_tokens, v.bm.state(r).host_tokens)
                      for r in reqs],
        "h2d": v.bm.h2d.busy_until,
    }


@pytest.mark.parametrize("name", ["vllm_fcfs", "sarathi_fcfs",
                                  "sarathi_priority", "fair_batching",
                                  "weighted_vtc", "edf", "sjf",
                                  "priority_first"])
def test_columnar_baseline_bitwise_equivalent(name, monkeypatch):
    from repro.core import schedulers as S

    def run(min_cols):
        monkeypatch.setattr(S, "_MIN_COLS", min_cols)
        reqs, bm = _mixed_world()
        v = SchedView(list(reqs), bm, EST, EngineConfig(), 12.0)
        plan = make_policy(name).form_batch(v)
        return _plan_snapshot(reqs, v, plan)

    scalar = run(10 ** 9)     # force the reference loops
    fast = run(4)             # force the columnar path
    assert fast == scalar     # exact: ints and bitwise-equal floats
