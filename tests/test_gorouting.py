"""GoRouting (Alg. 2): the Fig.-10 over-balancing toy + mechanics."""
import pytest

from repro.core import (BatchLatencyEstimator, GoRouting, InstanceState,
                        MinLoad, QueuedStub, Request, RouterConfig, SLO)

EST = BatchLatencyEstimator(a_p=0.0, b_p=0.0, c_p=1e-3, a_d=0.0,
                            b_d=0.0, t_c=0.0)  # 1 ms per prefill token


def inst(iid, queued_exec=0.0, now=0.0, prompt=1000, ttft_deadline=10.0,
         b_f=1000):
    st = InstanceState(iid=iid, b_f=b_f, total_blocks=1000)
    if queued_exec > 0:
        st.on_dispatch(QueuedStub(rid=1000 + iid, arrival=now, priority=2,
                                  weight=1.0, prompt_len=prompt,
                                  ttft_deadline=ttft_deadline,
                                  exec=queued_exec), now)
    return st


def req(plen, ttft=1.0, prio=2, arrival=0.0):
    return Request(prompt_len=plen, output_len=10, arrival=arrival,
                   slo=SLO(ttft, 0.1), priority=prio,
                   weight=2.0 if prio == 1 else 1.0)


def test_fig10_overbalancing_scenario():
    """R1 (short) then R2 (long).  Min-Load balances R1 onto the
    less-loaded instance B, leaving no instance able to serve R2 in time.
    GoRouting parks R1 on the relatively heavier A (still meets R1's SLO)
    and preserves B's slack, so BOTH meet their deadlines — Fig. 10.
    (Both instances are moderately loaded: were B truly light, Alg. 2
    line 11 would rightly pick it to avoid under-utilization.)"""
    cfg = RouterConfig(alpha=0.5, mu=0.05, lam=0.9, pd_mode="disagg")
    r1 = req(plen=200, ttft=1.0)      # 0.2s of work, 1s deadline
    r2 = req(plen=700, ttft=0.85)     # 0.7s of work, tight deadline

    def fresh_pools():
        a = inst(0, queued_exec=0.3, ttft_deadline=10.0)   # heavier
        b = inst(1, queued_exec=0.1, ttft_deadline=10.0)   # lighter (not idle)
        return [a, b]

    # --- Min-Load ---
    pools = fresh_pools()
    ml = MinLoad(EST)
    pick1, _ = ml.select(r1, pools, None, now=0.0)
    assert pick1 == 1                  # balances instantly onto B
    pools[pick1].on_dispatch(QueuedStub(r1.rid, 0.0, 2, 1.0, 200, 1.0, 0.2),
                             0.0)
    pick2, _ = ml.select(r2, pools, None, now=0.0)
    # wherever R2 goes it misses: B 0.1+0.2+0.7 = 1.0 > 0.85;
    # A 0.3+0.7 = 1.0 > 0.85.
    wait = 0.3 if pick2 == 1 else 0.3
    assert wait + 0.7 > r2.slo.ttft

    # --- GoRouting ---
    pools = fresh_pools()
    gr = GoRouting(EST, cfg)
    pick1, _ = gr.select(r1, pools, None, now=0.0)
    assert pick1 == 0                  # heaviest non-heavy: reserve B
    pools[pick1].on_dispatch(QueuedStub(r1.rid, 0.0, 2, 1.0, 200, 1.0, 0.2),
                             0.0)
    pick2, _ = gr.select(r2, pools, None, now=0.0)
    assert pick2 == 1                  # B's slack was preserved
    # R1 on A: 0.3+0.2 = 0.5 < 1.0 ok; R2 on B: 0.1+0.7 = 0.8 < 0.85 ok.


def test_fallback_to_minload_when_no_gain():
    """If no instance can meet the SLO (Δmax == 0), Alg. 2 line 18 falls
    back to least-loaded dispatch."""
    cfg = RouterConfig(pd_mode="disagg")
    gr = GoRouting(EST, cfg)
    busy_a = inst(0, queued_exec=5.0)
    busy_b = inst(1, queued_exec=3.0)
    r = req(plen=2000, ttft=0.1)       # hopeless deadline
    pick, _ = gr.select(r, [busy_a, busy_b], None, now=0.0)
    assert pick == 1


def test_decode_instance_max_free_blocks():
    cfg = RouterConfig(pd_mode="disagg")
    gr = GoRouting(EST, cfg)
    d0 = inst(10, b_f=100)
    d1 = inst(11, b_f=900)
    _, d = gr.select(req(100), [inst(0)], [d0, d1], now=0.0)
    assert d == 11


def test_staleness_compensation():
    """Elapsed time since the queue timestamp reduces estimated load."""
    st = inst(0, queued_exec=2.0, now=0.0)
    assert st.queue_exec_total(now=1.5) == pytest.approx(0.5)
    assert st.queue_exec_total(now=10.0) == 0.0


def test_dead_instances_excluded():
    gr = GoRouting(EST, RouterConfig(pd_mode="disagg"))
    a, b = inst(0), inst(1)
    a.alive = False
    pick, _ = gr.select(req(100), [a, b], None, now=0.0)
    assert pick == 1


def test_straggler_speed_downweights():
    gr = GoRouting(EST, RouterConfig(pd_mode="disagg", alpha=0.0))
    slow = inst(0, queued_exec=0.2)
    slow.speed = 0.25                   # straggling: 4x slower
    fast = inst(1, queued_exec=0.4)
    r = req(plen=100, ttft=60.0)
    pick, _ = gr.select(r, [slow, fast], None, now=0.0)
    assert pick == 1                    # effective load on slow is 0.8


def test_decode_reservation_basics():
    """Role-aware routing reserves handoff blocks on the decode target at
    admission; effective_free is what the router sees."""
    st = InstanceState(iid=0, b_f=100, total_blocks=100, role="decode")
    st.reserve(30)
    assert st.reserved_blocks == 30 and st.effective_free == 70
    st.unreserve(10)
    assert st.reserved_blocks == 20
    st.unreserve(50)                       # clamped, never negative
    assert st.reserved_blocks == 0


def test_reserved_blocks_steer_decode_pick():
    from repro.core.gorouting import pick_decode_target
    d0 = InstanceState(iid=10, b_f=500, total_blocks=500, role="decode")
    d1 = InstanceState(iid=11, b_f=400, total_blocks=400, role="decode")
    r = req(plen=100)
    assert pick_decode_target([d0, d1], r, 16) == 10
    d0.reserve(450)                        # d0 is now nearly spoken for
    assert pick_decode_target([d0, d1], r, 16) == 11


def test_reservation_lifecycle_property():
    """Hypothesis: under any interleaving of admissions and settlements
    (exact adoption, adoption elsewhere, finish, explicit release, decode
    replica death), decode reservations NEVER oversubscribe a replica's
    block budget and are always fully released once every request has
    settled."""
    hyp = pytest.importorskip("hypothesis")
    hst = pytest.importorskip("hypothesis.strategies")
    from repro.core.gorouting import decode_need_blocks
    from repro.serving import RouterBook

    settle_modes = ("adopt", "adopt_elsewhere", "finish", "release",
                    "target_dies")

    @hyp.settings(max_examples=60, deadline=None)
    @hyp.given(
        decode_blocks=hst.integers(min_value=4, max_value=48),
        n_decode=hst.integers(min_value=1, max_value=3),
        ops=hst.lists(hst.tuples(hst.integers(min_value=8, max_value=600),
                                 hst.sampled_from(settle_modes)),
                      min_size=1, max_size=30))
    def run(decode_blocks, n_decode, ops):
        book = RouterBook(GoRouting(EST, RouterConfig(pd_mode="disagg")),
                          EST, prefix_affinity=False)
        book.add_instance(0, 10_000, 10_000, role="prefill")
        d_iids = []
        for k in range(n_decode):
            book.add_instance(100 + k, decode_blocks, decode_blocks,
                              role="decode")
            d_iids.append(100 + k)
        dead: set[int] = set()

        def check_budgets():
            for st in book.states.values():
                assert 0 <= st.reserved_blocks <= st.total_blocks, \
                    f"iid {st.iid}: {st.reserved_blocks} blocks reserved " \
                    f"of {st.total_blocks}"

        for plen, mode in ops:
            r = req(plen)
            book.log_request(r, None)
            iid = book.route(r, now=0.0)
            check_budgets()
            if iid is None:
                continue
            d = book.decode_target(r.rid)
            nb = decode_need_blocks(r, book.block_size)
            if mode == "adopt" and d is not None:
                book.on_handoff_delivered(r.rid, d, nb, 0, 0.0)
            elif mode == "adopt_elsewhere" and d is not None:
                other = next((x for x in d_iids
                              if x != d and x not in dead), d)
                book.on_handoff_delivered(r.rid, other, nb, 0, 0.0)
            elif mode == "finish":
                book.on_finished(iid, r.rid)
            elif mode == "target_dies" and d is not None:
                if len([x for x in d_iids if x not in dead]) > 1:
                    dead.add(d)
                    book.drop_instance(d)   # voids its reservations
                else:
                    book.release_reservation(r.rid)
            else:
                book.release_reservation(r.rid)
            check_budgets()

        # every request settled -> nothing is still spoken for
        for rid in [r for r in list(book.reservations)]:
            pass
        assert all(d in dead or st.reserved_blocks == 0
                   for d, st in ((s.iid, s)
                                 for s in book.states.values()))
        assert not [rid for rid, (d, _) in book.reservations.items()
                    if d not in dead]

    run()


def test_finished_without_prefill_done_cleans_stub():
    """A failover-resumed request can finish on an instance without ever
    reporting prefill-done there; its stub must not leak (it would inflate
    queue_exec_total and repel the router from the survivor forever)."""
    st = InstanceState(iid=0, b_f=10, total_blocks=10)
    st.on_dispatch(QueuedStub(7, 0.0, 1, 1.0, 100, 5.0, 0.1), 0.0)
    assert st.prefill_len_total == 100
    st.on_finished(7)
    assert st.pre_queue == {}
    assert st.prefill_len_total == 0
    assert st.n_d == 0                       # was never incremented

    # normal lifecycle still balances: dispatch -> prefill done -> finished
    st.on_dispatch(QueuedStub(8, 0.0, 1, 1.0, 50, 5.0, 0.1), 0.0)
    st.on_prefill_done(8, 1.0)
    assert st.n_d == 1
    st.on_finished(8)
    assert st.n_d == 0 and st.pre_queue == {}
