"""GoRouting (Alg. 2): the Fig.-10 over-balancing toy + mechanics."""
import pytest

from repro.core import (BatchLatencyEstimator, GoRouting, InstanceState,
                        MinLoad, QueuedStub, Request, RouterConfig, SLO)

EST = BatchLatencyEstimator(a_p=0.0, b_p=0.0, c_p=1e-3, a_d=0.0,
                            b_d=0.0, t_c=0.0)  # 1 ms per prefill token


def inst(iid, queued_exec=0.0, now=0.0, prompt=1000, ttft_deadline=10.0,
         b_f=1000):
    st = InstanceState(iid=iid, b_f=b_f, total_blocks=1000)
    if queued_exec > 0:
        st.on_dispatch(QueuedStub(rid=1000 + iid, arrival=now, priority=2,
                                  weight=1.0, prompt_len=prompt,
                                  ttft_deadline=ttft_deadline,
                                  exec=queued_exec), now)
    return st


def req(plen, ttft=1.0, prio=2, arrival=0.0):
    return Request(prompt_len=plen, output_len=10, arrival=arrival,
                   slo=SLO(ttft, 0.1), priority=prio,
                   weight=2.0 if prio == 1 else 1.0)


def test_fig10_overbalancing_scenario():
    """R1 (short) then R2 (long).  Min-Load balances R1 onto the
    less-loaded instance B, leaving no instance able to serve R2 in time.
    GoRouting parks R1 on the relatively heavier A (still meets R1's SLO)
    and preserves B's slack, so BOTH meet their deadlines — Fig. 10.
    (Both instances are moderately loaded: were B truly light, Alg. 2
    line 11 would rightly pick it to avoid under-utilization.)"""
    cfg = RouterConfig(alpha=0.5, mu=0.05, lam=0.9, pd_mode="disagg")
    r1 = req(plen=200, ttft=1.0)      # 0.2s of work, 1s deadline
    r2 = req(plen=700, ttft=0.85)     # 0.7s of work, tight deadline

    def fresh_pools():
        a = inst(0, queued_exec=0.3, ttft_deadline=10.0)   # heavier
        b = inst(1, queued_exec=0.1, ttft_deadline=10.0)   # lighter (not idle)
        return [a, b]

    # --- Min-Load ---
    pools = fresh_pools()
    ml = MinLoad(EST)
    pick1, _ = ml.select(r1, pools, None, now=0.0)
    assert pick1 == 1                  # balances instantly onto B
    pools[pick1].on_dispatch(QueuedStub(r1.rid, 0.0, 2, 1.0, 200, 1.0, 0.2),
                             0.0)
    pick2, _ = ml.select(r2, pools, None, now=0.0)
    # wherever R2 goes it misses: B 0.1+0.2+0.7 = 1.0 > 0.85;
    # A 0.3+0.7 = 1.0 > 0.85.
    wait = 0.3 if pick2 == 1 else 0.3
    assert wait + 0.7 > r2.slo.ttft

    # --- GoRouting ---
    pools = fresh_pools()
    gr = GoRouting(EST, cfg)
    pick1, _ = gr.select(r1, pools, None, now=0.0)
    assert pick1 == 0                  # heaviest non-heavy: reserve B
    pools[pick1].on_dispatch(QueuedStub(r1.rid, 0.0, 2, 1.0, 200, 1.0, 0.2),
                             0.0)
    pick2, _ = gr.select(r2, pools, None, now=0.0)
    assert pick2 == 1                  # B's slack was preserved
    # R1 on A: 0.3+0.2 = 0.5 < 1.0 ok; R2 on B: 0.1+0.7 = 0.8 < 0.85 ok.


def test_fallback_to_minload_when_no_gain():
    """If no instance can meet the SLO (Δmax == 0), Alg. 2 line 18 falls
    back to least-loaded dispatch."""
    cfg = RouterConfig(pd_mode="disagg")
    gr = GoRouting(EST, cfg)
    busy_a = inst(0, queued_exec=5.0)
    busy_b = inst(1, queued_exec=3.0)
    r = req(plen=2000, ttft=0.1)       # hopeless deadline
    pick, _ = gr.select(r, [busy_a, busy_b], None, now=0.0)
    assert pick == 1


def test_decode_instance_max_free_blocks():
    cfg = RouterConfig(pd_mode="disagg")
    gr = GoRouting(EST, cfg)
    d0 = inst(10, b_f=100)
    d1 = inst(11, b_f=900)
    _, d = gr.select(req(100), [inst(0)], [d0, d1], now=0.0)
    assert d == 11


def test_staleness_compensation():
    """Elapsed time since the queue timestamp reduces estimated load."""
    st = inst(0, queued_exec=2.0, now=0.0)
    assert st.queue_exec_total(now=1.5) == pytest.approx(0.5)
    assert st.queue_exec_total(now=10.0) == 0.0


def test_dead_instances_excluded():
    gr = GoRouting(EST, RouterConfig(pd_mode="disagg"))
    a, b = inst(0), inst(1)
    a.alive = False
    pick, _ = gr.select(req(100), [a, b], None, now=0.0)
    assert pick == 1


def test_straggler_speed_downweights():
    gr = GoRouting(EST, RouterConfig(pd_mode="disagg", alpha=0.0))
    slow = inst(0, queued_exec=0.2)
    slow.speed = 0.25                   # straggling: 4x slower
    fast = inst(1, queued_exec=0.4)
    r = req(plen=100, ttft=60.0)
    pick, _ = gr.select(r, [slow, fast], None, now=0.0)
    assert pick == 1                    # effective load on slow is 0.8


def test_finished_without_prefill_done_cleans_stub():
    """A failover-resumed request can finish on an instance without ever
    reporting prefill-done there; its stub must not leak (it would inflate
    queue_exec_total and repel the router from the survivor forever)."""
    st = InstanceState(iid=0, b_f=10, total_blocks=10)
    st.on_dispatch(QueuedStub(7, 0.0, 1, 1.0, 100, 5.0, 0.1), 0.0)
    assert st.prefill_len_total == 100
    st.on_finished(7)
    assert st.pre_queue == {}
    assert st.prefill_len_total == 0
    assert st.n_d == 0                       # was never incremented

    # normal lifecycle still balances: dispatch -> prefill done -> finished
    st.on_dispatch(QueuedStub(8, 0.0, 1, 1.0, 50, 5.0, 0.1), 0.0)
    st.on_prefill_done(8, 1.0)
    assert st.n_d == 1
    st.on_finished(8)
    assert st.n_d == 0 and st.pre_queue == {}
