"""End-to-end system behaviour: the paper's full pipeline in miniature —
multi-priority requests through GoRouting + SlideBatching + block
management on the cluster simulator, validating the paper's headline
ordering (ProServe >= baselines on TDG at high load)."""
import pytest

from repro.core import (EngineConfig, GoRouting, MinLoad, RouterConfig,
                        make_policy)
from repro.sim import (AnalyticalExecutor, ClusterConfig, ClusterSim,
                       InstanceHardware, QWEN2_7B, summarize)
from repro.sim.workloads import industrial

# real-model end-to-end matrix: runs in the CI slow shard
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def setup():
    ex = AnalyticalExecutor(QWEN2_7B, InstanceHardware(chips=4))
    est, mape = ex.fit_estimator(n=200)
    return ex, est


def run(setup, policy, router_name, rate=90, dur=10, seed=11):
    ex, est = setup
    reqs = industrial(rate=rate, duration=dur, seed=seed)
    router = (GoRouting(est, RouterConfig(pd_mode="coloc"))
              if router_name == "gorouting" else MinLoad(est))
    cs = ClusterSim(lambda: make_policy(policy), router, ex, est,
                    EngineConfig(w_p=4.0), ClusterConfig(n_prefill=2))
    cs.run(reqs)
    return summarize(reqs, w_p=4.0)


def test_proserve_beats_fcfs_baselines_under_load(setup):
    ours = run(setup, "slidebatching", "gorouting")
    vllm = run(setup, "vllm_fcfs", "min_load")
    sarathi = run(setup, "sarathi_fcfs", "min_load")
    assert ours.tdg_ratio >= vllm.tdg_ratio - 0.02
    assert ours.tdg_ratio >= sarathi.tdg_ratio - 0.02


def test_priority_ordering_preserved(setup):
    """ProServe must give high priority at least as much TDG as low."""
    s = run(setup, "slidebatching", "gorouting", rate=110)
    if 1 in s.per_priority and 3 in s.per_priority:
        assert s.per_priority[1]["tdg_ratio"] >= \
            s.per_priority[3]["tdg_ratio"] - 0.05
