"""Overlapped execution engine: packed multi-request prefill must be
bitwise-equivalent to the per-request path, the async transfer lanes must
preserve exactness through evict→reload→continue, and the adaptive copy
budget must respond to measured transfer throughput."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import EngineConfig, Request, SLO, make_policy
from repro.core.blocks import BlockManager
from repro.core.estimator import BatchLatencyEstimator
from repro.kernels import chunked_prefill_attention, packed_prefill_attention
from repro.models import forward, init_params
from repro.serving import Engine
from repro.serving.kv_pool import PagedKVPool
from repro.serving.transfer import TransferWorker

# real-model end-to-end matrix: runs in the CI slow shard
pytestmark = pytest.mark.slow

CFG = get_smoke("qwen1_5_0_5b")
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
RNG = np.random.default_rng(7)


def greedy_reference(prompt, n):
    cur = jnp.asarray(prompt)[None, :]
    out = []
    for _ in range(n):
        logits, _ = forward(CFG, PARAMS, cur)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        cur = jnp.concatenate([cur, jnp.asarray([[nxt]])], axis=1)
    return out


def make_engine(num_blocks=128, *, packed=True, overlap=True, **bm_kwargs):
    return Engine(CFG, PARAMS, EngineConfig(eta=1.0, w_p=4.0, tau=1e9),
                  make_policy("slidebatching"), num_blocks=num_blocks,
                  block_size=16, max_ctx=256, bm_kwargs=bm_kwargs,
                  packed_prefill=packed, overlap_transfers=overlap)


def submit(eng, plen, out_len, prio=2, prompt=None):
    r = Request(prompt_len=plen, output_len=out_len, arrival=0.0,
                slo=SLO(3600.0, 3600.0), priority=prio)
    if prompt is None:
        prompt = RNG.integers(1, CFG.vocab, plen).astype(np.int32)
    eng.add_request(r, prompt)
    return r, prompt


# ---------------------------------------------------------------------------
# packed prefill
# ---------------------------------------------------------------------------

def test_packed_kernel_bitwise_matches_per_segment():
    """packed_prefill_attention == S independent chunked_prefill calls with
    cache_lens = ctx + sq, bit for bit (same staging, same kv_block)."""
    rng = np.random.default_rng(0)
    s, sq, h, hkv, hd, smax = 3, 8, 4, 2, 16, 64
    q = rng.standard_normal((s, sq, h, hd)).astype(np.float32)
    k = rng.standard_normal((s, smax, hkv, hd)).astype(np.float32)
    v = rng.standard_normal((s, smax, hkv, hd)).astype(np.float32)
    ctx = np.array([0, 16, 40], np.int32)
    packed = np.asarray(packed_prefill_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(ctx),
        kv_block=32))
    for i in range(s):
        ref = np.asarray(chunked_prefill_attention(
            jnp.asarray(q[i:i + 1]), jnp.asarray(k[i:i + 1]),
            jnp.asarray(v[i:i + 1]), jnp.asarray(ctx[i:i + 1] + sq),
            kv_block=32))
        assert np.array_equal(packed[i], ref[0]), f"segment {i} diverged"


def test_packed_prefill_tokens_match_per_request_and_reference():
    lens = (24, 40, 17)
    prompts = [RNG.integers(1, CFG.vocab, n).astype(np.int32) for n in lens]
    refs = [greedy_reference(p, 4) for p in prompts]
    outs = {}
    for packed in (True, False):
        eng = make_engine(packed=packed, overlap=False)
        reqs = [submit(eng, n, 4, prompt=p)[0]
                for n, p in zip(lens, prompts)]
        eng.run_until_drained()
        outs[packed] = [eng.outputs[r.rid] for r in reqs]
        assert (eng.stats.packed_prefill_calls > 0) == packed
        for r, ref in zip(reqs, refs):
            assert eng.outputs[r.rid] == ref
    assert outs[True] == outs[False]


def test_packed_prefill_exact_through_preemption():
    """Tiny pool: packed path + eviction/reload/recompute still matches the
    uninterrupted reference token-for-token."""
    eng = make_engine(num_blocks=10, packed=True, overlap=False)
    reqs = [submit(eng, 40, 6) for _ in range(4)]
    refs = {r.rid: greedy_reference(p, 6) for r, p in reqs}
    eng.run_until_drained(max_iters=400)
    assert eng.stats.evictions > 0
    for r, _ in reqs:
        assert eng.outputs[r.rid] == refs[r.rid]


# ---------------------------------------------------------------------------
# async transfer lanes
# ---------------------------------------------------------------------------

def test_overlap_on_off_identical_streams_under_preemption():
    """evict→(async offload)→reload→continue must yield the same tokens
    with the background lanes on and off."""
    prompts = [RNG.integers(1, CFG.vocab, 40).astype(np.int32)
               for _ in range(4)]
    refs = [greedy_reference(p, 6) for p in prompts]
    streams = {}
    for overlap in (True, False):
        eng = make_engine(num_blocks=10, packed=True, overlap=overlap)
        # priority 3 mirrors most eagerly (n_off=2) -> real D2H traffic
        reqs = [submit(eng, 40, 6, prio=3, prompt=p)[0] for p in prompts]
        eng.run_until_drained(max_iters=400)
        assert eng.stats.evictions > 0
        for r, ref in zip(reqs, refs):
            assert eng.outputs[r.rid] == ref
        streams[overlap] = [eng.outputs[r.rid] for r in reqs]
        eng.kill()
    assert streams[True] == streams[False]


def test_async_offload_lands_and_feeds_accounting():
    eng = make_engine(num_blocks=24, packed=True, overlap=True)
    # enough full blocks per request (prio 3: mirror every 2 full blocks)
    reqs = [submit(eng, 48, 3, prio=3) for _ in range(3)]
    eng.run_until_drained(max_iters=400)
    assert eng.flush_transfers()
    for r, _ in reqs:
        assert r.phase.name == "FINISHED"
    assert eng.stats.offload_blocks > 0, "no async D2H transfer completed"
    assert eng.stats.t_block_measured > 0, "measured t_block never fed back"
    eng.kill()


def test_pool_offload_drop_reload_roundtrip_batched():
    """The batched one-fetch offload + staged reload restore identical
    device block contents."""
    pool = PagedKVPool(CFG, num_blocks=8, block_size=4)
    pool.alloc(1, 3)
    rng = np.random.default_rng(1)
    vals = rng.standard_normal(
        (CFG.n_layers, 2, 3, 4, CFG.n_kv_heads, CFG.hd)).astype(np.float32)
    phys = list(pool.tables[1])
    pool.kv = pool.kv.at[:, :, jnp.asarray(phys)].set(jnp.asarray(vals))
    pool.offload_blocks(1, [0, 1, 2])                    # one device fetch
    assert sorted(pool.host[1]) == [0, 1, 2]
    pool.drop_device_blocks(1)
    # stage via the worker lane, then consume
    w = TransferWorker()
    assert w.prefetch(1, 0, [pool.host[1][i] for i in range(3)])
    assert w.flush()
    staged = w.take_staged(1, 0)
    assert staged is not None and staged[0] == 3
    assert pool.reload_from_device(1, staged[1], 3) == 12
    new_phys = list(pool.tables[1])
    got = np.asarray(pool.kv[:, :, jnp.asarray(new_phys)])
    assert np.array_equal(got, np.moveaxis(
        np.stack([pool.host[1][i] for i in range(3)]), 0, 2))
    w.stop()


def test_stale_epoch_staging_discarded():
    w = TransferWorker()
    blk = np.zeros((2, 2, 4, 2, 8), np.float32)
    assert w.prefetch(5, 0, [blk])
    assert w.flush()
    assert w.take_staged(5, 1) is None      # epoch bumped -> stale
    w.stop()


def test_stale_staging_slot_released_without_consumer():
    """A staging job that completes after invalidate() must not pin one of
    the double-buffer slots forever (rid never reloads again)."""
    w = TransferWorker(max_staged=1)
    blk = np.zeros((2, 2, 4, 2, 8), np.float32)
    assert w.prefetch(5, 0, [blk])
    assert w.flush()
    w.discard_stale(5, current_epoch=1)     # what _drain_transfers does
    assert w.take_staged(5, 1) is None
    assert w.prefetch(6, 0, [blk])          # slot is free again
    assert w.flush()
    # a current-epoch buffer is NOT discarded
    w.discard_stale(6, current_epoch=0)
    assert w.take_staged(6, 0) is not None
    w.stop()


def test_failed_transfer_reported_and_pending_released():
    """A raising copy job must surface as a failed completion (engine
    counts it and releases the BlockManager pending-offload claim)."""
    w = TransferWorker()
    assert w.prefetch(7, 0, [np.zeros(3), np.zeros(2)])  # np.stack raises
    assert w.flush()
    done = w.drain()
    assert len(done) == 1 and not done[0].ok and done[0].n_blocks == 2
    w.stop()
    bm = BlockManager(64, 16, 1e-3)
    bm.external_lanes = True
    bm.offload_sink = lambda *a: None
    r = Request(prompt_len=64, output_len=4, arrival=0.0,
                slo=SLO(10.0, 1.0), priority=3)
    assert bm.grow(r, 64, now=0.0)
    s = bm.state(r)
    assert s.pending_offload == 4
    bm.note_offload_failed(r.rid, 4)
    assert s.pending_offload == 0 and s.mirrored_blocks == 0


def test_staged_reload_hit_end_to_end():
    """The double-buffered reload lane must actually fire: evict a request
    whose blocks were async-mirrored, let the worker pre-stage them, and
    the next reload must consume the staged buffer (a staged HIT) while
    the tokens stay exact."""
    from repro.core.batching import BatchPlan

    eng = make_engine(num_blocks=64, packed=True, overlap=True)
    a, pa = submit(eng, 64, 4, prio=3)      # 4 full blocks, n_off(3)=2
    ref = greedy_reference(pa, 4)
    while a.generated < 1:                  # prefill + first token
        assert eng.step() is not None
        eng.flush_transfers()               # async mirror lands, drained
    assert eng.bm.state(a).mirrored_blocks >= 4
    # preempt A through the real eviction path
    eng.bm.evict(a, eng.now)
    eng._sync_pool_with_bm(BatchPlan(evictions=[a]))
    assert eng.bm.state(a).host_tokens >= 64
    eng._prefetch_reloads()                 # hint the staging lane
    assert eng.flush_transfers()            # staging buffer lands
    eng.run_until_drained(max_iters=100)
    assert eng.stats.staged_hits >= 1, "pre-staged reload never consumed"
    assert eng.outputs[a.rid] == ref
    eng.kill()


# ---------------------------------------------------------------------------
# adaptive copy budget, closed loop
# ---------------------------------------------------------------------------

def test_copy_budget_monotone_in_measured_t_block():
    """Case 2(ii): as the measured per-block copy time grows, the budget
    the engine may spend on reloads must not grow."""
    budgets = []
    for t_block in (1e-4, 5e-4, 2e-3, 8e-3):
        bm = BlockManager(64, 16, t_block)
        budgets.append(bm.copy_budget(t_fwd_min=0.01, t_trans_max=0.08,
                                      t_budget=0.1, b_missing=100))
    assert budgets == sorted(budgets, reverse=True)
    assert budgets[0] > budgets[-1]


def test_observe_transfer_ewma_moves_toward_sample():
    bm = BlockManager(64, 16, 1e-3, t_block_alpha=0.5)
    bm.observe_transfer(4, 4 * 5e-3)        # measured: 5 ms/block
    assert 1e-3 < bm.t_block < 5e-3
    before = bm.t_block
    bm.observe_transfer(4, 4 * 5e-3)
    assert before < bm.t_block < 5e-3       # keeps converging
    assert bm.d2h.t_block == bm.t_block == bm.h2d.t_block
    bm.observe_transfer(0, 1.0)             # degenerate samples ignored
    bm.observe_transfer(4, 0.0)
    assert bm.d2h.t_block == bm.t_block


def test_external_lanes_bypass_virtual_clock():
    bm = BlockManager(64, 16, 1e-3)
    bm.external_lanes = True
    sink_calls = []
    bm.offload_sink = lambda rid, start, n: sink_calls.append(
        (rid, start, n))
    r = Request(prompt_len=64, output_len=4, arrival=0.0,
                slo=SLO(10.0, 1.0), priority=3)
    assert bm.grow(r, 64, now=0.0)          # 4 full blocks, n_off(3)=2
    assert sink_calls == [(r.rid, 0, 4)]
    s = bm.state(r)
    assert s.pending_offload == 4 and s.mirrored_blocks == 0
    bm.complete_offloads(now=1e9)           # virtual clock must NOT fire
    assert s.pending_offload == 4 and s.mirrored_blocks == 0
    bm.note_offload_complete(r.rid, 4)      # the real completion does
    assert s.pending_offload == 0 and s.mirrored_blocks == 4
    bm.note_offload_complete(r.rid, 99)     # over-completion is clamped
    assert s.mirrored_blocks == 4


# ---------------------------------------------------------------------------
# satellites
# ---------------------------------------------------------------------------

def test_refit_failures_logged_and_counted(monkeypatch):
    eng = make_engine(overlap=False)
    eng.refit_every = 2

    def boom(*a, **k):
        raise RuntimeError("synthetic fit failure")

    monkeypatch.setattr(BatchLatencyEstimator, "fit", boom)
    before = eng.est
    for _ in range(6):
        submit(eng, 16, 2)
    eng.run_until_drained()
    assert eng.stats.refit_failures > 0
    assert eng.est is before                # previous fit kept


def test_batch_latencies_bounded():
    eng = make_engine(overlap=False)
    assert eng.stats.batch_latencies.maxlen == 512
    for _ in range(600):
        eng.stats.batch_latencies.append(0.01)
    assert len(eng.stats.batch_latencies) == 512


def test_seq_cache_tracks_prompt_and_outputs():
    eng = make_engine(overlap=False)
    r, prompt = submit(eng, 20, 3)
    eng.run_until_drained()
    # finished requests are cleaned up
    assert r.rid not in eng._seqs
    # resumed request (failover): prior outputs preload the cache
    eng2 = make_engine(overlap=False)
    r2 = Request(prompt_len=20, output_len=5, arrival=0.0,
                 slo=SLO(3600.0, 3600.0))
    eng2.add_request(r2, prompt, prior_outputs=[3, 4])
    seq = eng2._seq_view(r2)
    assert np.array_equal(seq[:20], prompt) and list(seq[20:]) == [3, 4]


if __name__ == "__main__":
    pytest.main([__file__, "-x", "-q"])
