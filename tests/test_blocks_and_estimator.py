"""Block-management (§4.3) accounting invariants + latency estimator (§4.1)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import BlockManager, Request, SLO, blocks_for
from repro.core.estimator import BatchLatencyEstimator


def make_req(prio=1):
    return Request(prompt_len=100, output_len=10, arrival=0.0,
                   slo=SLO(1.0, 0.1), priority=prio)


# --- estimator ---------------------------------------------------------------

def test_estimator_fit_recovers_coefficients():
    true = BatchLatencyEstimator(a_p=2e-9, b_p=1e-9, c_p=3e-6, a_d=2e-8,
                                 b_d=1e-4, t_c=3e-3)
    rng = np.random.default_rng(0)
    batches, ys = [], []
    for _ in range(300):
        items = [(int(rng.integers(1, 2000)), int(rng.integers(0, 8000)),
                  bool(rng.random() < 0.5)) for _ in range(rng.integers(1, 12))]
        batches.append(items)
        ys.append(true.batch_time(items))
    fit = BatchLatencyEstimator.fit(batches, ys)
    assert fit.mape(batches, ys) < 0.01
    assert abs(fit.a_p - true.a_p) / true.a_p < 0.1


def test_estimator_mape_under_noise():
    true = BatchLatencyEstimator(a_p=1e-9, b_p=5e-10, c_p=2e-6, a_d=3e-8,
                                 b_d=1e-4, t_c=2e-3)
    rng = np.random.default_rng(1)
    batches, ys = [], []
    for _ in range(400):
        items = [(int(rng.integers(1, 4000)), int(rng.integers(0, 16000)),
                  bool(rng.random() < 0.5)) for _ in range(rng.integers(1, 16))]
        batches.append(items)
        ys.append(true.batch_time(items) * (1 + 0.045 * rng.standard_normal()))
    fit = BatchLatencyEstimator.fit(batches, ys)
    assert fit.mape(batches, ys) < 0.08   # ~paper's 4.5% regime


def test_chunked_prefill_decomposition():
    """Eq. 5 is chunking-consistent exactly when b_p = 2*a_p (causal
    attention: n^2 = a^2 + c^2 + 2ac): prefilling [0,a) then [a,n) with
    l_kv=a then equals a single [0,n) pass — the property that makes the
    estimator 'directly compatible with chunked prefill' (§4.1)."""
    e = BatchLatencyEstimator(a_p=1e-9, b_p=2e-9, c_p=1e-6)
    whole = e.prefill_time(1000, 0)
    split = e.prefill_time(400, 0) + e.prefill_time(600, 400)
    assert split == pytest.approx(whole, rel=1e-9)
    # three-way split too
    split3 = (e.prefill_time(250, 0) + e.prefill_time(250, 250)
              + e.prefill_time(500, 500))
    assert split3 == pytest.approx(whole, rel=1e-9)


# --- block manager -----------------------------------------------------------

def test_grow_evict_reload_roundtrip():
    bm = BlockManager(num_device_blocks=64, block_size=16, t_block=1e-3)
    r = make_req()
    assert bm.grow(r, 100, now=0.0)
    assert bm.dev_blocks(r) == blocks_for(100, 16) == 7
    assert bm.free_blocks == 64 - 7
    bm.complete_offloads(1.0)           # async mirrors become durable
    s = bm.state(r)
    mirrored = s.mirrored_blocks
    bm.evict(r, now=1.0)
    assert bm.free_blocks == 64
    assert s.dev_tokens == 0
    assert s.host_tokens == mirrored * 16      # only mirrored survives
    plan = bm.plan_reload(r, budget_blocks=100, chunk_cap_tokens=100,
                          remaining_tokens=10)
    assert plan.restore_blocks == blocks_for(s.host_tokens, 16)
    bm.apply_reload(r, plan, now=2.0)
    assert s.host_tokens == 0
    assert s.dev_tokens == mirrored * 16


def test_recompute_ablation_drops_everything():
    bm = BlockManager(16, 16, 1e-3, recompute_only=True)
    r = make_req()
    bm.grow(r, 64, 0.0)
    bm.evict(r, 1.0)
    s = bm.state(r)
    assert s.host_tokens == 0 and s.dev_tokens == 0


def test_priority_aware_offload_thresholds():
    """Lower priority => smaller n_off => more mirrored at eviction time."""
    out = {}
    for prio in (1, 3):
        bm = BlockManager(64, 16, 1e-3,
                          n_off_by_priority={1: 8, 2: 4, 3: 1})
        r = make_req(prio)
        for _ in range(5):
            bm.grow(r, 16, 0.0)
        bm.complete_offloads(10.0)
        out[prio] = bm.state(r).mirrored_blocks
    assert out[3] >= out[1]


def test_copy_budget_cases():
    bm = BlockManager(64, 16, t_block=1e-3)
    # case 1: forward pinned at budget -> hide copies under t_budget
    assert bm.copy_budget(t_fwd_min=0.2, t_trans_max=0.5, t_budget=0.1,
                          b_missing=1000) == 100
    # case 2i: compute dominates -> copy everything
    assert bm.copy_budget(t_fwd_min=0.05, t_trans_max=0.01, t_budget=0.1,
                          b_missing=10) == 10
    # case 2ii: binary search -> transfer time <= modeled batch latency
    b = bm.copy_budget(t_fwd_min=0.01, t_trans_max=0.08, t_budget=0.1,
                       b_missing=80)
    assert 0 <= b <= 80
    trans = b * bm.t_block
    fwd = 0.01 + (80 - b) * bm.t_block
    assert trans <= fwd
    # and b is maximal: b+1 would violate
    if b < 80:
        assert (b + 1) * bm.t_block > 0.01 + (80 - b - 1) * bm.t_block


def test_partial_copy_beta_rule():
    bm = BlockManager(64, 16, 1e-3, beta=1.5)
    r = make_req()
    bm.grow(r, 160, 0.0)
    bm.complete_offloads(1.0)
    bm.evict(r, 1.0)
    s = bm.state(r)
    assert s.host_tokens > 32
    # nearly-finished request (1 token left) with a big dropped span and a
    # large chunk cap: ratio = (dropped+1)/dropped < beta => SKIP this round
    plan = bm.plan_reload(r, budget_blocks=1, chunk_cap_tokens=10000,
                          remaining_tokens=1)
    assert not plan.admitted
    # plenty of remaining work amortizes the recompute => partial copy ok
    plan2 = bm.plan_reload(r, budget_blocks=1, chunk_cap_tokens=10000,
                           remaining_tokens=500)
    assert plan2.admitted and plan2.restore_blocks == 1
    # chunk-limited round: partial copy cannot reduce progress => admit
    plan3 = bm.plan_reload(r, budget_blocks=1, chunk_cap_tokens=8,
                           remaining_tokens=1)
    assert plan3.admitted


@given(st.lists(st.integers(1, 200), min_size=1, max_size=20))
@settings(max_examples=50, deadline=None)
def test_pool_conservation(growths):
    """used + free == capacity at every point; no negative pools."""
    bm = BlockManager(4096, 16, 1e-3)
    reqs = []
    for i, g in enumerate(growths):
        r = make_req(1 + i % 3)
        if bm.grow(r, g, float(i)):
            reqs.append(r)
        assert 0 <= bm.used_blocks <= 4096
        assert bm.free_blocks + bm.used_blocks == 4096
        if i % 3 == 0 and reqs:
            bm.evict(reqs[len(reqs) // 2], float(i))
            assert bm.free_blocks + bm.used_blocks == 4096
    for r in reqs:
        bm.release(r)
    assert bm.used_blocks == 0
