"""Vectorized ClusterSim equivalence contract (docs/ARCHITECTURE.md):
the columnar SlideBatching fast path and the streamed event loop must
reproduce the reference simulator EXACTLY — per-request token
timestamps, finish times, preemption counts, and all derived metrics —
on seeded traces across priority mixes, overload, PD modes, prefix
caching, kills, and ablation flags."""
import numpy as np
import pytest

from repro.core import (EngineConfig, GoRouting, Request, RouterConfig,
                        SLO)
from repro.core.slidebatching import SlideBatching
from repro.sim import (AnalyticalExecutor, ClusterConfig, ClusterSim,
                       InstanceHardware, QWEN2_7B, StreamingSummary,
                       VectorClusterSim, VectorSlideBatching,
                       iter_scale_trace, replay_sim, replay_sim_stream,
                       summarize, vectorize_policy)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def exec_est():
    ex = AnalyticalExecutor(QWEN2_7B, InstanceHardware(chips=4))
    est, _ = ex.fit_estimator(n=200)
    return ex, est


def make_cluster(ex, est, vector, *, pd_mode="coloc", n_prefill=2,
                 n_decode=0, prefix_cache=True, policy_kw=None):
    cls = VectorClusterSim if vector else ClusterSim
    return cls(lambda: SlideBatching(**(policy_kw or {})),
               GoRouting(est, RouterConfig(pd_mode=pd_mode)),
               ex, est, EngineConfig(w_p=4.0),
               ClusterConfig(pd_mode=pd_mode, n_prefill=n_prefill,
                             n_decode=n_decode, prefix_cache=prefix_cache))


def signature(reqs):
    return [(tuple(r.out_times), r.finish_time, r.preemptions)
            for r in reqs]


def run_pair(ex, est, trace_fn, *, kills=None, **kw):
    """The same seeded trace through reference and vectorized sims;
    returns (sig_ref, sig_vec, row_ref, row_vec)."""
    out = {}
    for vector in (False, True):
        cs = make_cluster(ex, est, vector, **kw)
        reqs = trace_fn()
        if kills:
            cs.run(reqs, kills=kills)
            row = summarize(reqs, w_p=4.0).row()
        else:
            row = {k: v for k, v in
                   replay_sim(cs, reqs, w_p=4.0).row().items()
                   if k not in ("wall_s", "speed")}
        out[vector] = (signature(reqs), row)
    return out[False] + out[True]


# ---------------------------------------------------------------------------
# exact equivalence across configurations
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pd_mode,prefix_cache", [
    ("coloc", True), ("coloc", False), ("disagg", True)])
def test_equivalence_matrix(exec_est, pd_mode, prefix_cache):
    ex, est = exec_est
    kw = {}
    if pd_mode == "disagg":
        kw = {"n_prefill": 1, "n_decode": 1}
    sig_ref, row_ref, sig_vec, row_vec = run_pair(
        ex, est, lambda: list(iter_scale_trace(400, rate=600.0, seed=7)),
        pd_mode=pd_mode, prefix_cache=prefix_cache, **kw)
    assert sig_ref == sig_vec
    assert row_ref == row_vec


def test_equivalence_overload(exec_est):
    """Heavy overload on one replica: rejections, preemptions and
    starvation promotion all fire, and every per-request outcome still
    matches the reference loop exactly."""
    ex, est = exec_est
    sig_ref, row_ref, sig_vec, row_vec = run_pair(
        ex, est, lambda: list(iter_scale_trace(300, rate=1200.0, seed=3)),
        n_prefill=1)
    assert sig_ref == sig_vec
    assert row_ref == row_vec
    assert row_ref["slo"] < 1.0      # genuinely contended, not a no-op run


@pytest.mark.parametrize("policy_kw", [
    {"use_density": False}, {"use_deadline": False},
    {"latency_aware_budget": False}])
def test_equivalence_ablations(exec_est, policy_kw):
    ex, est = exec_est
    sig_ref, row_ref, sig_vec, row_vec = run_pair(
        ex, est, lambda: list(iter_scale_trace(250, rate=700.0, seed=11)),
        policy_kw=policy_kw)
    assert sig_ref == sig_vec
    assert row_ref == row_vec


def test_equivalence_with_kills(exec_est):
    """Instance failure mid-run (requeue + rerouting) through both loops."""
    ex, est = exec_est
    sig_ref, row_ref, sig_vec, row_vec = run_pair(
        ex, est, lambda: list(iter_scale_trace(200, rate=500.0, seed=5)),
        kills=[(0.4, 0)], n_prefill=3)
    assert sig_ref == sig_vec
    assert row_ref == row_vec


# ---------------------------------------------------------------------------
# streamed loop + streamed metrics
# ---------------------------------------------------------------------------

def test_run_stream_matches_run(exec_est):
    """``run_stream`` (lazy arrivals, completion callback, no finished
    list) must schedule identically to ``run`` on the same trace, and
    ``StreamingSummary`` must reproduce ``summarize`` on the same
    request set."""
    ex, est = exec_est
    trace = lambda: list(iter_scale_trace(300, rate=600.0, seed=9))  # noqa: E731

    cs = make_cluster(ex, est, True)
    reqs = trace()
    cs.run(reqs)
    ref_sig = signature(reqs)
    ref_sum = summarize(reqs, w_p=4.0)

    cs2 = make_cluster(ex, est, True)
    got = []
    n = cs2.run_stream(iter(trace()), on_finished=got.append)
    got.sort(key=lambda r: r.rid)
    assert n == len(reqs)
    # dropped (rejected) requests are folded after the run, like
    # replay_sim_stream does
    done = {r.rid for r in got}
    got += [r for r in cs2.dropped if r.rid not in done]
    got.sort(key=lambda r: r.rid)
    assert signature(got) == ref_sig

    agg = StreamingSummary(w_p=4.0)
    for r in got:
        agg.add(r)
    assert agg.summary() == ref_sum


def test_replay_sim_stream_report(exec_est):
    """End-to-end streamed replay: report equals the list-mode replay's,
    and with ``release=True`` no token-timestamp lists stay resident."""
    ex, est = exec_est
    trace = lambda: iter_scale_trace(300, rate=600.0, seed=13)  # noqa: E731

    ref = replay_sim(make_cluster(ex, est, True), list(trace()), w_p=4.0)
    cs = make_cluster(ex, est, True)
    rep = replay_sim_stream(cs, trace(), w_p=4.0)
    strip = ("wall_s", "speed")
    assert ({k: v for k, v in rep.row().items() if k not in strip} ==
            {k: v for k, v in ref.row().items() if k not in strip})


# ---------------------------------------------------------------------------
# tie-breaking order
# ---------------------------------------------------------------------------

def _tie_trace(seed: int) -> list[Request]:
    """Many requests with identical lengths/weights and coinciding
    arrivals: φ densities and deadlines tie constantly, so ordering is
    decided purely by the sort's tie-breaking (stability + arrival key) —
    exactly what the vectorized lexsort must replicate."""
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for _ in range(50):
        if rng.random() < 0.4:
            t += float(rng.choice([0.02, 0.05]))
        prio = int(rng.choice([1, 2, 3]))
        reqs.append(Request(
            prompt_len=int(rng.choice([64, 64, 128])),
            output_len=int(rng.choice([8, 8, 16])),
            arrival=t, slo=SLO(ttft=1.0, tpot=0.1), priority=prio,
            weight={1: 4.0, 2: 2.0, 3: 1.0}[prio]))
    return reqs


def _check_tie_breaking(exec_est, seed):
    ex, est = exec_est
    sigs = {}
    for vector in (False, True):
        cs = make_cluster(ex, est, vector, n_prefill=1)
        reqs = _tie_trace(seed)
        cs.run(reqs)
        sigs[vector] = signature(reqs)
    assert sigs[False] == sigs[True]


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_tie_breaking_order(exec_est, seed):
        _check_tie_breaking(exec_est, seed)
else:                                                  # pragma: no cover
    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
    def test_tie_breaking_order(exec_est, seed):
        _check_tie_breaking(exec_est, seed)


# ---------------------------------------------------------------------------
# policy swap plumbing
# ---------------------------------------------------------------------------

def test_vectorize_policy_swaps_only_plain_slidebatching():
    plain = SlideBatching()
    vec = vectorize_policy(plain)
    assert type(vec) is VectorSlideBatching
    assert (vec.use_density, vec.use_deadline, vec.latency_aware_budget) \
        == (plain.use_density, plain.use_deadline,
            plain.latency_aware_budget)

    custom = SlideBatching(use_density=False)
    assert vectorize_policy(custom).use_density is False

    class Sub(SlideBatching):
        pass
    sub = Sub()
    assert vectorize_policy(sub) is sub        # subclasses pass through
