"""Disaggregated prefill/decode replicas with live KV handoff.

The headline contract: decode token streams produced by a disagg fleet
(prefill-role replica -> KV handoff over the transfer lanes -> decode-role
replica) are BITWISE identical to a coloc replica across the full
{prefix cache on/off} x {overlap on/off} x {int8 handoff on/off} matrix,
the admission-time decode reservations settle exactly (reserved ==
adopted, every handoff a hit), nothing leaks (tier groups, export state,
reserved blocks), and replica death at any handoff phase fails over to a
re-prefill with zero lost or duplicated tokens.

int8 wire note: the int8 handoff is lossy-but-deterministic (the cold
tier's quantize kernel, |x - deq| <= scale/2 per plane), so the bitwise
cells pin prompt/output lengths and seeds for which the greedy stream
provably survives the roundtrip — determinism is asserted separately.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import (EngineConfig, GoRouting, Request, RouterConfig,
                        SLO, make_policy)
from repro.core.estimator import BatchLatencyEstimator
from repro.models import forward, init_params
from repro.serving import Engine, ServiceController

# real-model end-to-end matrix: runs in the CI slow shard
pytestmark = pytest.mark.slow

CFG = get_smoke("qwen1_5_0_5b")
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
SLO_LOOSE = SLO(3600.0, 3600.0)

# int8-survival-verified fixtures: greedy streams at this shape survive
# the int8 KV roundtrip for these seeds (scanned offline; e.g. seeds 12
# and 14 do NOT and are deliberately absent)
PLEN, OLEN = 24, 8
SEEDS = (0, 1, 2, 3)


def make_engine(role="coloc", *, prefix_cache=True, overlap=True,
                handoff_quantize=False, num_blocks=128):
    return Engine(CFG, PARAMS, EngineConfig(eta=1.0, w_p=4.0, tau=1e9),
                  make_policy("slidebatching"), num_blocks=num_blocks,
                  block_size=16, max_ctx=256, role=role,
                  prefix_cache=prefix_cache, overlap_transfers=overlap,
                  packed_prefill=overlap,
                  handoff_quantize=handoff_quantize)


def make_controller():
    est = BatchLatencyEstimator(a_p=1e-8, b_p=1e-8, c_p=1e-4, a_d=1e-8,
                                b_d=1e-3, t_c=1e-2)
    return ServiceController(GoRouting(est, RouterConfig(pd_mode="disagg")),
                             est)


def fixture_prompts():
    return [np.random.default_rng(s).integers(1, CFG.vocab, PLEN)
            .astype(np.int32) for s in SEEDS]


def greedy_reference(prompt, n):
    cur = jnp.asarray(prompt)[None, :]
    out = []
    for _ in range(n):
        logits, _ = forward(CFG, PARAMS, cur)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        cur = jnp.concatenate([cur, jnp.asarray([[nxt]])], axis=1)
    return out


@pytest.fixture(scope="module")
def refs():
    return [greedy_reference(p, OLEN) for p in fixture_prompts()]


def run_disagg(*, prefix_cache, overlap, int8, prompts, olen=OLEN,
               n_decode=1):
    """One disagg fleet pass; returns (streams in submission order,
    controller, prefill engine, decode engines)."""
    svc = make_controller()
    pe = make_engine("prefill", prefix_cache=prefix_cache, overlap=overlap,
                     handoff_quantize=int8)
    des = [make_engine("decode", prefix_cache=prefix_cache,
                       overlap=overlap) for _ in range(n_decode)]
    svc.add_instance(pe)
    for de in des:
        svc.add_instance(de)
    reqs = []
    for p in prompts:
        r = Request(prompt_len=len(p), output_len=olen, arrival=0.0,
                    slo=SLO_LOOSE, priority=1)
        svc.submit(r, p)
        reqs.append(r)
    svc.serve_until_drained()
    streams = []
    for r in reqs:
        for de in des:
            if r.rid in de.outputs:
                streams.append(de.outputs[r.rid])
                break
        else:
            streams.append(None)
    return streams, svc, pe, des


MATRIX = list(itertools.product((True, False), (True, False),
                                (True, False)))


@pytest.mark.parametrize("prefix_cache,overlap,int8", MATRIX)
def test_disagg_streams_bitwise_identical_to_coloc(prefix_cache, overlap,
                                                   int8, refs):
    """The 8-cell matrix: every disagg configuration reproduces the coloc
    (== uninterrupted greedy) streams token for token."""
    streams, svc, pe, (de,) = run_disagg(
        prefix_cache=prefix_cache, overlap=overlap, int8=int8,
        prompts=fixture_prompts())
    assert len(svc.finished) == len(SEEDS)
    for got, want, seed in zip(streams, refs, SEEDS):
        assert got == want, (
            f"disagg stream diverged from coloc (cache={prefix_cache}, "
            f"overlap={overlap}, int8={int8}, seed={seed})")
    # every request travelled the two-leg path
    assert pe.stats.handoffs_out == len(SEEDS)
    assert de.stats.handoffs_in == len(SEEDS)
    if int8:
        # the int8 wire is actually narrower than fp32 would be
        assert (pe.stats.handoff_bytes_out
                < pe.stats.handoff_blocks_out * pe.pool.tier.block_bytes)
    else:
        assert (pe.stats.handoff_bytes_out
                == pe.stats.handoff_blocks_out * pe.pool.tier.block_bytes)


def test_disagg_int8_wire_deterministic():
    """Quantization is lossy but deterministic: two identical disagg-int8
    replays produce identical streams and identical wire accounting."""
    runs = []
    for _ in range(2):
        streams, svc, pe, _ = run_disagg(prefix_cache=False, overlap=True,
                                         int8=True,
                                         prompts=fixture_prompts())
        runs.append((streams, pe.stats.handoff_bytes_out,
                     svc.book.handoff_blocks))
    assert runs[0] == runs[1]


def test_disagg_handoff_accounting_invariants(refs):
    """Reserved decode blocks == adopted blocks, every reservation settles
    as a hit, engine-level counters mirror the book, and nothing leaks:
    no host-tier group for a real rid, no pending/ready export state, no
    standing reservation, zero reserved blocks on every instance."""
    streams, svc, pe, (de,) = run_disagg(prefix_cache=False, overlap=True,
                                         int8=False,
                                         prompts=fixture_prompts())
    assert streams == refs
    book = svc.book
    n = len(SEEDS)
    assert book.handoffs == n
    assert book.reservation_hits == n
    assert book.reservation_misses == 0
    assert book.reserved_blocks_total == book.adopted_blocks_total > 0
    assert book.reservations == {}
    # the engines' own counters agree with the router book's
    assert (pe.stats.handoffs_out, pe.stats.handoff_blocks_out,
            pe.stats.handoff_bytes_out) == \
        (book.handoffs, book.handoff_blocks, book.handoff_bytes)
    assert (de.stats.handoffs_in, de.stats.handoff_blocks_in,
            de.stats.handoff_bytes_in) == \
        (book.handoffs, book.handoff_blocks, book.handoff_bytes)
    for st in book.states.values():
        assert st.reserved_blocks == 0
    for eng in (pe, de):
        assert eng._handoff_wait == {} and eng._handoff_ready == []
        assert eng.queue == []
        assert eng.bm.used_blocks == 0
        # host-tier groups for real rids must be gone (negative keys are
        # prefix-cache pseudo-rids, legitimately persistent)
        for tier_dict in (eng.pool.tier.hot, eng.pool.tier.cold):
            assert not [rid for rid in tier_dict if rid >= 0]


def test_disagg_reservations_spread_decode_replicas(refs):
    """With two decode replicas, admission-time reservations steer the
    router: all requests still finish bitwise-exact, reservations all
    settle, and adopted == reserved even across multiple targets."""
    streams, svc, pe, des = run_disagg(prefix_cache=False, overlap=True,
                                       int8=False,
                                       prompts=fixture_prompts(),
                                       n_decode=2)
    assert streams == refs
    book = svc.book
    assert book.reservation_hits == len(SEEDS)
    assert book.reserved_blocks_total == book.adopted_blocks_total
    assert sum(d.stats.handoffs_in for d in des) == len(SEEDS)


# ---------------------------------------------------------------------------
# churn: kill replicas at every phase of the two-leg lifecycle
# ---------------------------------------------------------------------------

def churn_fleet():
    """prefill + decode + coloc: the failover target must exist."""
    svc = make_controller()
    pe = make_engine("prefill", prefix_cache=False)
    de = make_engine("decode", prefix_cache=False)
    ce = make_engine("coloc", prefix_cache=False)
    iids = [svc.add_instance(e) for e in (pe, de, ce)]
    return svc, (pe, de, ce), iids


def submit_cases(svc, n=3, olen=6):
    cases = []
    for s in SEEDS[:n]:
        p = np.random.default_rng(s).integers(1, CFG.vocab, PLEN) \
            .astype(np.int32)
        r = Request(prompt_len=PLEN, output_len=olen, arrival=0.0,
                    slo=SLO_LOOSE, priority=1)
        svc.submit(r, p)
        cases.append((r, greedy_reference(p, olen)))
    return cases


def assert_exact_streams(svc, cases):
    assert len(svc.finished) == len(cases)
    by_rid = {}
    for e in svc.engines.values():
        by_rid.update(e.outputs)
    for r, want in cases:
        got = by_rid.get(r.rid)
        assert got == want, f"rid {r.rid}: {got} != {want}"


def test_churn_decode_dies_before_any_handoff():
    """Decode replica dies while every request is still prefilling: the
    exported payloads find no decode capacity and fail over to a full
    re-prefill on the coloc replica — exact streams, nothing lost."""
    svc, (pe, de, ce), (ip, idd, ic) = churn_fleet()
    cases = submit_cases(svc)
    svc.kill_instance(idd)          # dies before any prefill completes
    svc.serve_until_drained()
    assert_exact_streams(svc, cases)
    assert svc.book.reservations == {}
    # the prefill replica's exports were all redirected, none adopted
    assert svc.book.handoffs == 0
    assert all(r.rid in ce.outputs for r, _ in cases)


def test_churn_decode_dies_mid_handoff():
    """Decode replica dies in the export window (D2H copy in flight /
    payload undelivered): failover re-prefills on the coloc replica with
    the already-streamed first token as the durable prefix — no token is
    lost or duplicated."""
    svc, (pe, de, ce), (ip, idd, ic) = churn_fleet()
    cases = submit_cases(svc)
    for _ in range(500):
        svc.step_all()
        if pe.stats.handoffs_out or pe._handoff_wait:
            break
    else:
        pytest.fail("prefill never reached the export window")
    svc.kill_instance(idd)
    svc.serve_until_drained()
    assert_exact_streams(svc, cases)
    assert svc.book.reservations == {}
    for st in svc.book.states.values():
        assert st.reserved_blocks == 0


def test_churn_decode_dies_after_adoption():
    """Decode replica dies mid-decode (payload adopted, tokens flowing):
    orphans resume from the durable log on the coloc replica, continuing
    exactly where the dead replica stopped."""
    svc, (pe, de, ce), (ip, idd, ic) = churn_fleet()
    cases = submit_cases(svc, olen=8)
    for _ in range(500):
        svc.step_all()
        if any(len(de.outputs.get(r.rid, [])) >= 2 for r, _ in cases):
            break
    else:
        pytest.fail("decode replica never got past token 2")
    assert svc.book.handoffs > 0     # the handoff leg actually ran
    svc.kill_instance(idd)
    svc.serve_until_drained()
    assert_exact_streams(svc, cases)


def test_churn_prefill_dies_mid_chunk():
    """Prefill replica dies with prompts partially prefilled: requests
    re-dispatch (KV lost, recomputed) and finish bitwise-exact wherever
    they land."""
    svc, (pe, de, ce), (ip, idd, ic) = churn_fleet()
    cases = submit_cases(svc)
    svc.step_all()                   # some prefill progress, no handoff
    svc.kill_instance(ip)
    svc.serve_until_drained()
    assert_exact_streams(svc, cases)
    for st in svc.book.states.values():
        assert st.reserved_blocks == 0


def test_churn_both_legs_die():
    """Prefill AND decode replicas die at different phases; the coloc
    survivor finishes everything exactly."""
    svc, (pe, de, ce), (ip, idd, ic) = churn_fleet()
    cases = submit_cases(svc)
    svc.step_all()
    svc.kill_instance(ip)            # prefill leg lost mid-chunk
    svc.step_all()
    svc.kill_instance(idd)           # then the decode tier vanishes
    svc.serve_until_drained()
    assert_exact_streams(svc, cases)
    assert all(r.rid in ce.outputs for r, _ in cases)
