"""Async service tier: concurrent streaming over real engine replicas,
admission control, failover, and replay-vs-simulator determinism."""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import (EngineConfig, GoRouting, Request, RouterConfig, SLO,
                        make_policy)
from repro.core.estimator import BatchLatencyEstimator
from repro.models import forward, init_params
from repro.serving import (AdmissionError, Engine, FrontendConfig,
                           ServiceFrontend)
from repro.sim import (AnalyticalExecutor, ClusterConfig, ClusterSim,
                       InstanceHardware, QWEN2_7B, clip_lengths, replay_sim)
from repro.sim.workloads import sharegpt

# real-model end-to-end matrix: runs in the CI slow shard
pytestmark = pytest.mark.slow

CFG = get_smoke("qwen1_5_0_5b")
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
RNG = np.random.default_rng(0)
SLO_LOOSE = SLO(3600.0, 3600.0)


def make_engine(num_blocks=160):
    return Engine(CFG, PARAMS, EngineConfig(eta=1.0, w_p=4.0, tau=1e9),
                  make_policy("slidebatching"), num_blocks=num_blocks,
                  block_size=16, max_ctx=256)


def make_frontend(n_replicas=2, **cfg_kwargs):
    est = BatchLatencyEstimator(a_p=1e-8, b_p=1e-8, c_p=1e-4, a_d=1e-8,
                                b_d=1e-3, t_c=1e-2)
    fe = ServiceFrontend(GoRouting(est, RouterConfig(pd_mode="coloc")), est,
                         FrontendConfig(**cfg_kwargs))
    for _ in range(n_replicas):
        fe.add_instance(make_engine())
    return fe


def greedy_reference(prompt, n):
    cur = jnp.asarray(prompt)[None, :]
    out = []
    for _ in range(n):
        logits, _ = forward(CFG, PARAMS, cur)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        cur = jnp.concatenate([cur, jnp.asarray([[nxt]])], axis=1)
    return out


def test_concurrent_streams_across_two_replicas():
    """The acceptance demo: 64 concurrent streaming requests of 2+
    priorities through 2 real engine replicas, measured at the client."""
    async def run():
        fe = make_frontend(n_replicas=2, max_inflight=128)
        await fe.start()
        streams = []
        for k in range(64):
            plen = int(RNG.integers(8, 32))
            r = Request(prompt_len=plen, output_len=3, arrival=0.0,
                        slo=SLO_LOOSE, priority=1 + k % 2,
                        weight=2.0 if k % 2 == 0 else 1.0)
            prompt = RNG.integers(1, CFG.vocab, plen).astype(np.int32)
            streams.append(await fe.submit(r, prompt))
        await asyncio.gather(*[s.collect() for s in streams])
        await fe.stop()
        return fe, streams

    fe, streams = asyncio.run(run())
    assert len(fe.finished) == 64
    for s in streams:
        assert s.done and len(s.tokens) == 3
        assert s.ttft is not None and s.ttft > 0
        assert s.tpot is not None and s.tpot > 0
    # both replicas actually served work
    per_engine = [e.stats.tokens_out for e in fe.engines.values()]
    assert len(per_engine) == 2 and all(t > 0 for t in per_engine)
    assert sum(per_engine) == 64 * 3
    # client-edge per-priority summary is well formed
    from repro.sim import summarize
    summ = summarize(fe.client_edge_requests(), w_p=4.0)
    assert set(summ.per_priority) == {1, 2}
    assert summ.n == 64


def test_stream_ordering_and_event_flags():
    async def run():
        fe = make_frontend(n_replicas=2)
        await fe.start()
        events = {}
        streams = {}
        for k in range(8):
            r = Request(prompt_len=12, output_len=4, arrival=0.0,
                        slo=SLO_LOOSE, priority=1 + k % 2)
            prompt = RNG.integers(1, CFG.vocab, 12).astype(np.int32)
            s = await fe.submit(r, prompt)
            streams[r.rid] = s
            events[r.rid] = []

        async def consume(rid, s):
            async for ev in s:
                assert ev.rid == rid
                events[rid].append(ev)

        await asyncio.gather(*[consume(rid, s)
                               for rid, s in streams.items()])
        await fe.stop()
        return events, streams

    events, streams = asyncio.run(run())
    for rid, evs in events.items():
        # per-stream ordering: 1-based indices strictly increasing
        assert [e.index for e in evs] == list(range(1, 5))
        assert evs[0].first and not any(e.first for e in evs[1:])
        assert evs[-1].last and not any(e.last for e in evs[:-1])
        wall = [e.t_wall for e in evs]
        assert wall == sorted(wall)
        # stream recorded exactly the event tokens
        assert streams[rid].tokens == [e.token for e in evs]


def test_admission_rejection_and_backpressure():
    async def run():
        fe = make_frontend(n_replicas=1,
                           max_inflight=4, priority_quota={1: 1, 2: 2})
        await fe.start()

        def req(prio, out=2):
            return Request(prompt_len=8, output_len=out, arrival=0.0,
                           slo=SLO_LOOSE, priority=prio)

        p8 = RNG.integers(1, CFG.vocab, 8).astype(np.int32)
        s1 = await fe.submit(req(1), p8)
        # priority-1 quota (1) exhausted -> fast rejection...
        with pytest.raises(AdmissionError) as ei:
            await fe.submit(req(1), p8)
        assert ei.value.priority == 1 and ei.value.limit == 1
        # ...but priority 2 has its own quota (isolation)
        s2 = await fe.submit(req(2), p8)
        assert fe.rejected == 1

        # backpressure path: wait=True suspends until the p1 slot frees
        waiter = asyncio.ensure_future(
            fe.submit(req(1), p8, wait=True))
        await asyncio.sleep(0.05)
        assert not waiter.done()          # still blocked on the quota
        await asyncio.gather(s1.collect(), s2.collect())
        s3 = await asyncio.wait_for(waiter, timeout=60.0)
        await s3.collect()
        await fe.drain()
        await fe.stop()
        return fe, s3

    fe, s3 = asyncio.run(run())
    assert len(s3.tokens) == 2
    assert len(fe.finished) == 3


def test_frontend_failover_resumes_streams_exactly():
    """Kill a replica mid-generation: orphans re-dispatch with their
    streamed prefix and every client stream still gets the exact greedy
    reference continuation."""
    async def run():
        fe = make_frontend(n_replicas=2)
        await fe.start()
        cases = []
        for _ in range(6):
            plen = int(RNG.integers(8, 24))
            prompt = RNG.integers(1, CFG.vocab, plen).astype(np.int32)
            r = Request(prompt_len=plen, output_len=8, arrival=0.0,
                        slo=SLO_LOOSE, priority=1)
            s = await fe.submit(r, prompt)
            cases.append((r, prompt, s))
        tasks = [asyncio.ensure_future(s.collect()) for _, _, s in cases]
        # wait until every stream saw its first token, then kill replica 0
        deadline = asyncio.get_running_loop().time() + 120.0
        while any(not s.recv_times for _, _, s in cases):
            assert asyncio.get_running_loop().time() < deadline
            await asyncio.sleep(0.01)
        fe.kill_instance(0)
        await asyncio.gather(*tasks)
        await fe.stop()
        return cases

    cases = asyncio.run(run())
    for r, prompt, s in cases:
        assert len(s.tokens) == 8
        assert s.tokens == greedy_reference(prompt, 8), \
            f"rid {r.rid} diverged across failover"


def make_disagg_frontend(*, coloc=True, **cfg_kwargs):
    """prefill + decode replicas (+ a coloc failover target) behind the
    async frontend, with the role-aware disagg router."""
    est = BatchLatencyEstimator(a_p=1e-8, b_p=1e-8, c_p=1e-4, a_d=1e-8,
                                b_d=1e-3, t_c=1e-2)
    fe = ServiceFrontend(GoRouting(est, RouterConfig(pd_mode="disagg")),
                         est, FrontendConfig(**cfg_kwargs))

    def eng(role):
        return Engine(CFG, PARAMS, EngineConfig(eta=1.0, w_p=4.0, tau=1e9),
                      make_policy("slidebatching"), num_blocks=160,
                      block_size=16, max_ctx=256, role=role,
                      prefix_cache=False)

    roles = ["prefill", "decode"] + (["coloc"] if coloc else [])
    iids = {role: fe.add_instance(eng(role)) for role in roles}
    return fe, iids


def _disagg_cases(fe, n=4, olen=8):
    async def submit():
        cases = []
        for _ in range(n):
            plen = int(RNG.integers(12, 28))
            prompt = RNG.integers(1, CFG.vocab, plen).astype(np.int32)
            r = Request(prompt_len=plen, output_len=olen, arrival=0.0,
                        slo=SLO_LOOSE, priority=1)
            s = await fe.submit(r, prompt)
            cases.append((r, prompt, s))
        return cases
    return submit


def test_frontend_disagg_two_leg_streams_exact():
    """Happy path through the async frontend: prefill replica -> KV
    handoff -> decode replica, streams measured at the client edge are
    the exact greedy references and the two-leg accounting settles."""
    async def run():
        fe, iids = make_disagg_frontend(coloc=False)
        await fe.start()
        cases = await _disagg_cases(fe)()
        await asyncio.gather(*[s.collect() for _, _, s in cases])
        await fe.stop()
        return fe, iids, cases

    fe, iids, cases = asyncio.run(run())
    for r, prompt, s in cases:
        assert s.tokens == greedy_reference(prompt, 8), \
            f"rid {r.rid} diverged across the handoff"
    book = fe.book
    assert book.handoffs == len(cases)
    assert book.reservation_misses == 0
    assert book.reserved_blocks_total == book.adopted_blocks_total
    assert book.reservations == {}
    for st in book.states.values():
        assert st.reserved_blocks == 0


def test_frontend_churn_decode_replica_dies_mid_handoff():
    """Kill the decode replica once every stream has its first token
    (handoffs in flight or freshly adopted): each request fails over to
    a re-prefill on the coloc replica and the client still receives the
    exact greedy stream — no token lost, none duplicated."""
    async def run():
        fe, iids = make_disagg_frontend()
        await fe.start()
        cases = await _disagg_cases(fe)()
        tasks = [asyncio.ensure_future(s.collect()) for _, _, s in cases]
        deadline = asyncio.get_running_loop().time() + 120.0
        while any(not s.recv_times for _, _, s in cases):
            assert asyncio.get_running_loop().time() < deadline
            await asyncio.sleep(0.005)
        fe.kill_instance(iids["decode"])
        await asyncio.gather(*tasks)
        await fe.stop()
        return fe, cases

    fe, cases = asyncio.run(run())
    assert len(fe.finished) == len(cases)
    for r, prompt, s in cases:
        assert len(s.tokens) == 8          # nothing lost, nothing doubled
        assert s.tokens == greedy_reference(prompt, 8), \
            f"rid {r.rid} diverged across decode-replica death"
    assert fe.book.reservations == {}
    for st in fe.book.states.values():
        assert st.reserved_blocks == 0


def test_frontend_churn_prefill_replica_dies_mid_chunk():
    """Kill the prefill replica right after admission (prompts mid-
    prefill, KV lost): requests re-dispatch to the coloc replica, which
    recomputes and streams the exact references."""
    async def run():
        fe, iids = make_disagg_frontend()
        await fe.start()
        cases = await _disagg_cases(fe)()
        tasks = [asyncio.ensure_future(s.collect()) for _, _, s in cases]
        await asyncio.sleep(0.01)          # let prefill chunks start
        fe.kill_instance(iids["prefill"])
        await asyncio.gather(*tasks)
        await fe.stop()
        return fe, cases

    fe, cases = asyncio.run(run())
    assert len(fe.finished) == len(cases)
    for r, prompt, s in cases:
        assert len(s.tokens) == 8
        assert s.tokens == greedy_reference(prompt, 8), \
            f"rid {r.rid} diverged across prefill-replica death"


def test_replay_sim_deterministic_and_per_priority():
    """The same trace through the cluster simulator is bit-deterministic
    and reports the per-priority gain/SLO split."""
    ex = AnalyticalExecutor(QWEN2_7B, InstanceHardware(chips=4))
    est, mape = ex.fit_estimator(n=200)
    assert mape < 0.15

    def run_once():
        reqs = clip_lengths(sharegpt(rate=30, duration=4, seed=3),
                            max_in=512, max_out=64)
        cs = ClusterSim(lambda: make_policy("slidebatching"),
                        GoRouting(est, RouterConfig(pd_mode="coloc")),
                        ex, est, EngineConfig(w_p=4.0),
                        ClusterConfig(pd_mode="coloc", n_prefill=2))
        return replay_sim(cs, reqs, w_p=4.0)

    a, b = run_once(), run_once()
    row_a = {k: v for k, v in a.row().items() if k != "wall_s"}
    row_b = {k: v for k, v in b.row().items() if k != "wall_s"}
    assert row_a == row_b
    assert a.n_completed == a.n_submitted
    assert set(a.per_priority) == {1, 2}
    for m in a.per_priority.values():
        assert 0.0 <= m["slo"] <= 1.0 and m["tdg_ratio"] >= 0.0
