"""Training substrate: loss descent, checkpoint fault tolerance, gradient
compression, deterministic data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import init_params
from repro.training import (CheckpointManager, TokenPipeline, init_adamw,
                            make_train_step)
from repro.training.optimizer import compress_decompress, quantize_int8

CFG = get_smoke("qwen1_5_0_5b")


def test_loss_decreases():
    p = init_params(CFG, jax.random.PRNGKey(0))
    opt = init_adamw(p)
    step = jax.jit(make_train_step(CFG, remat=False, lr=3e-3))
    pipe = TokenPipeline(CFG.vocab, batch=4, seq=32, seed=0)
    losses = []
    for i in range(12):
        b = pipe.batch_at(i % 3)   # small cycling set => memorizable
        p, opt, m = step(p, opt, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_grad_accum_equivalence():
    """microbatches=2 must match microbatches=1 on the same global batch."""
    p = init_params(CFG, jax.random.PRNGKey(1))
    pipe = TokenPipeline(CFG.vocab, batch=4, seq=16, seed=1)
    b = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    outs = {}
    for mb in (1, 2):
        step = make_train_step(CFG, remat=False, lr=1e-3, microbatches=mb)
        p2, _, m = step(p, init_adamw(p), b)
        outs[mb] = (float(m["loss"]), p2)
    assert outs[1][0] == pytest.approx(outs[2][0], rel=1e-5)
    d = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(outs[1][1]), jax.tree.leaves(outs[2][1])))
    assert d < 1e-4


def test_compressed_grads_close_to_exact():
    g = jax.random.normal(jax.random.PRNGKey(2), (256, 64)) * 0.01
    q, s = quantize_int8(g)
    g2 = q.astype(jnp.float32) * s
    rel = float(jnp.abs(g - g2).max() / jnp.abs(g).max())
    assert rel < 0.02
    # error feedback keeps the accumulated bias bounded
    err = jnp.zeros_like(g)
    acc_true, acc_hat = jnp.zeros_like(g), jnp.zeros_like(g)
    for _ in range(20):
        ghat, err = compress_decompress(g, err)
        acc_true += g
        acc_hat += ghat
    drift = float(jnp.abs(acc_true - acc_hat).max() / jnp.abs(acc_true).max())
    assert drift < 0.01


def test_train_step_with_compression_converges():
    p = init_params(CFG, jax.random.PRNGKey(3))
    opt = init_adamw(p, compress=True)
    step = jax.jit(make_train_step(CFG, remat=False, lr=3e-3,
                                   compress_grads=True))
    pipe = TokenPipeline(CFG.vocab, batch=4, seq=32, seed=3)
    losses = []
    for i in range(10):
        b = pipe.batch_at(i % 2)
        p, opt, m = step(p, opt, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_data_pipeline_deterministic_replay():
    a = TokenPipeline(1000, 4, 32, seed=7).batch_at(42)
    b = TokenPipeline(1000, 4, 32, seed=7).batch_at(42)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = TokenPipeline(1000, 4, 32, seed=8).batch_at(42)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_checkpoint_roundtrip_and_restart(tmp_path):
    p = init_params(CFG, jax.random.PRNGKey(4))
    opt = init_adamw(p)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(10, {"params": p, "opt": opt, "step": 10})
    mgr.save(20, {"params": p, "opt": opt, "step": 20})
    mgr.save(30, {"params": p, "opt": opt, "step": 30})
    assert mgr.latest_step() == 30
    # retention: only 2 newest kept
    assert not os.path.exists(os.path.join(str(tmp_path), "step_10"))
    restored, step = mgr.restore({"params": p, "opt": opt, "step": 0})
    assert step == 30
    for a, b in zip(jax.tree.leaves(restored["params"]), jax.tree.leaves(p)):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_corruption_detected(tmp_path):
    p = {"w": jnp.ones((8, 8))}
    mgr = CheckpointManager(str(tmp_path))
    d = mgr.save(1, p)
    # corrupt the shard
    path = os.path.join(d, "shard_0.npz")
    data = dict(np.load(path))
    data["a0"] = data["a0"] + 1.0
    np.savez(path, **data)
    with pytest.raises(IOError, match="checksum"):
        mgr.restore(p)


def test_checkpoint_async_save(tmp_path):
    p = {"w": jnp.arange(64.0).reshape(8, 8)}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(5, p)
    mgr.wait()
    restored, step = mgr.restore(p)
    assert step == 5
    np.testing.assert_array_equal(restored["w"], p["w"])


def test_checkpoint_training_restart_equivalence(tmp_path):
    """Train 4 steps; or train 2, checkpoint, restart, train 2 more — the
    final params must be identical (deterministic pipeline + state)."""
    def fresh():
        p = init_params(CFG, jax.random.PRNGKey(5))
        return p, init_adamw(p)

    step = jax.jit(make_train_step(CFG, remat=False, lr=1e-3))
    pipe = TokenPipeline(CFG.vocab, 2, 16, seed=9)

    p, opt = fresh()
    for i in range(4):
        b = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        p, opt, _ = step(p, opt, b)

    p2, opt2 = fresh()
    mgr = CheckpointManager(str(tmp_path))
    for i in range(2):
        b = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        p2, opt2, _ = step(p2, opt2, b)
    mgr.save(2, {"p": p2, "o": opt2})
    restored, s = mgr.restore({"p": p2, "o": opt2})
    p3, opt3 = restored["p"], restored["o"]
    for i in range(2, 4):
        b = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        p3, opt3, _ = step(p3, opt3, b)
    for a, b_ in zip(jax.tree.leaves(p), jax.tree.leaves(p3)):
        np.testing.assert_allclose(a, b_, atol=1e-6)
