"""Real-engine integration: the paged serving path must be byte-exact with
teacher forcing, including through preemption / offload / reload."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import EngineConfig, Request, SLO, make_policy
from repro.models import forward, init_params
from repro.serving import Engine, ServiceController
from repro.core.gorouting import GoRouting, RouterConfig
from repro.core.estimator import BatchLatencyEstimator

# real-model end-to-end matrix: runs in the CI slow shard
pytestmark = pytest.mark.slow

CFG = get_smoke("qwen1_5_0_5b")
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
RNG = np.random.default_rng(0)


def greedy_reference(prompt, n):
    cur = jnp.asarray(prompt)[None, :]
    out = []
    for _ in range(n):
        logits, _ = forward(CFG, PARAMS, cur)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        cur = jnp.concatenate([cur, jnp.asarray([[nxt]])], axis=1)
    return out


def make_engine(policy="slidebatching", num_blocks=128, **bm_kwargs):
    return Engine(CFG, PARAMS, EngineConfig(eta=1.0, w_p=4.0, tau=1e9),
                  make_policy(policy), num_blocks=num_blocks,
                  block_size=16, max_ctx=256, bm_kwargs=bm_kwargs)


def submit(eng, plen, out_len, prio=1, arrival=0.0):
    r = Request(prompt_len=plen, output_len=out_len, arrival=arrival,
                slo=SLO(3600.0, 3600.0), priority=prio)
    prompt = RNG.integers(1, CFG.vocab, plen).astype(np.int32)
    eng.add_request(r, prompt)
    return r, prompt


def test_engine_matches_greedy_reference():
    eng = make_engine()
    reqs = [submit(eng, int(RNG.integers(8, 40)), 5) for _ in range(3)]
    refs = {r.rid: greedy_reference(p, 5) for r, p in reqs}
    eng.run_until_drained()
    for r, _ in reqs:
        assert eng.outputs[r.rid] == refs[r.rid]


def test_engine_preemption_roundtrip_exact():
    """A tiny pool forces evictions (offload->reload / recompute); outputs
    must STILL match the uninterrupted reference token-for-token."""
    eng = make_engine(num_blocks=10)     # 144 usable tokens < 4*(40+6)
    reqs = [submit(eng, 40, 6) for _ in range(4)]
    refs = {r.rid: greedy_reference(p, 6) for r, p in reqs}
    eng.run_until_drained(max_iters=400)
    assert eng.stats.evictions > 0, "test needs actual preemption pressure"
    for r, _ in reqs:
        assert eng.outputs[r.rid] == refs[r.rid], \
            f"rid {r.rid} diverged after preemption"


def test_engine_sync_vs_async_offload_equivalent_outputs():
    for kwargs in [dict(async_offload=False), dict(recompute_only=True)]:
        eng = make_engine(num_blocks=10, **kwargs)
        reqs = [submit(eng, 40, 4) for _ in range(4)]
        refs = {r.rid: greedy_reference(p, 4) for r, p in reqs}
        eng.run_until_drained(max_iters=400)
        for r, _ in reqs:
            assert eng.outputs[r.rid] == refs[r.rid]


def test_engine_estimator_refit_from_measurements():
    eng = make_engine()
    eng.refit_every = 5
    for _ in range(8):
        submit(eng, 24, 3)
    eng.run_until_drained()
    # after refit, the estimator should predict CPU-scale latencies
    t = eng.est.batch_time([(24, 0, True)])
    assert 1e-4 < t < 60.0


def test_service_failover_completes_all():
    est = BatchLatencyEstimator(a_p=1e-8, b_p=1e-8, c_p=1e-4, a_d=1e-8,
                                b_d=1e-3, t_c=1e-2)
    svc = ServiceController(GoRouting(est, RouterConfig(pd_mode="coloc")),
                            est)
    e0, e1 = make_engine(), make_engine()
    i0 = svc.add_instance(e0)
    i1 = svc.add_instance(e1)
    reqs = []
    for k in range(6):
        r = Request(prompt_len=20, output_len=3, arrival=0.0,
                    slo=SLO(3600.0, 3600.0), priority=1 + k % 2)
        prompt = RNG.integers(1, CFG.vocab, 20).astype(np.int32)
        refs = greedy_reference(prompt, 3)
        svc.submit(r, prompt)
        reqs.append((r, refs))
    svc.step_all()                       # let some work start
    svc.kill_instance(i0)                # hard failure
    svc.serve_until_drained()
    assert len(svc.finished) == 6
    # outputs still correct wherever each request ended up
    eng_by_rid = {}
    for e in svc.engines.values():
        eng_by_rid.update(e.outputs)
    for r, refs in reqs:
        got = eng_by_rid.get(r.rid) or e0.outputs.get(r.rid)
        assert got == refs


def test_service_elastic_add_and_graceful_remove():
    est = BatchLatencyEstimator(c_p=1e-4, b_d=1e-3, t_c=1e-2)
    svc = ServiceController(GoRouting(est, RouterConfig(pd_mode="coloc")),
                            est)
    i0 = svc.add_instance(make_engine())
    for _ in range(4):
        r = Request(prompt_len=16, output_len=2, arrival=0.0,
                    slo=SLO(3600.0, 3600.0))
        svc.submit(r, RNG.integers(1, CFG.vocab, 16).astype(np.int32))
    i1 = svc.add_instance(make_engine())          # scale up
    for _ in range(2):
        r = Request(prompt_len=16, output_len=2, arrival=0.0,
                    slo=SLO(3600.0, 3600.0))
        svc.submit(r, RNG.integers(1, CFG.vocab, 16).astype(np.int32))
    svc.remove_instance(i0, drain=True)           # graceful scale down
    svc.serve_until_drained()
    assert len(svc.finished) == 6
