"""Per-arch smoke tests (deliverable f) + model-level equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get, get_smoke
from repro.models import (chunked_attention, decode_step, dense_attention,
                          forward, init_params, prefill)
from repro.models.moe import init_moe, moe_forward, moe_ref
from repro.models.ssm import (init_ssm, init_state, spec_for, ssd_chunked,
                              ssd_decode_step)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", ARCH_IDS)
def test_arch_smoke_forward_and_train_shapes(name):
    """Reduced same-family config: one forward pass, shapes + no NaNs."""
    cfg = get_smoke(name)
    p = init_params(cfg, KEY)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_inputs"] = jax.random.normal(
            KEY, (B, cfg.enc_frames, cfg.d_model)) * 0.02
    logits, _ = forward(cfg, p, toks, **kw)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", ARCH_IDS)
def test_arch_smoke_train_step(name):
    """One training step on the reduced config: loss finite, params move."""
    from repro.training import init_adamw, make_train_step
    cfg = get_smoke(name)
    p = init_params(cfg, KEY)
    opt = init_adamw(p)
    step = make_train_step(cfg, remat=False, lr=1e-3)
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["enc_inputs"] = jax.random.normal(
            KEY, (B, cfg.enc_frames, cfg.d_model)) * 0.02
    p2, opt2, metrics = step(p, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    moved = jnp.abs(p2["embed"] - p["embed"]).max()
    assert float(moved) > 0


@pytest.mark.parametrize("name", ARCH_IDS)
def test_decode_matches_teacher_forcing(name):
    """prefill+decode_step must reproduce the full-forward logits — the
    cache/rope/ring/state bookkeeping correctness contract."""
    cfg = get_smoke(name)
    p = init_params(cfg, KEY)
    B, S, extra = 2, 12, 3
    toks = jax.random.randint(KEY, (B, S + extra), 0, cfg.vocab)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_inputs"] = jax.random.normal(
            KEY, (B, cfg.enc_frames, cfg.d_model)) * 0.02
    full, _ = forward(cfg, p, toks, **kw)
    lg, cache = prefill(cfg, p, toks[:, :S], max_seq=S + extra + 2, **kw)
    np.testing.assert_allclose(lg, full[:, S - 1], atol=2e-4)
    for i in range(extra):
        lg, cache = decode_step(cfg, p, cache, toks[:, S + i])
        np.testing.assert_allclose(lg, full[:, S + i], atol=2e-4)


def test_full_configs_match_assignment():
    """The exact assigned hyperparameters (spot checks)."""
    c = get("qwen2_moe_a2_7b")
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k, c.n_shared) == \
        (24, 2048, 60, 4, 4) and c.vocab == 151936
    c = get("deepseek_coder_33b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (62, 7168, 56, 8, 19200, 32256)
    c = get("chatglm3_6b")
    assert c.n_kv_heads == 2 and c.rope_fraction == 0.5
    c = get("mamba2_1_3b")
    assert c.ssm_state == 128 and c.family == "ssm"
    c = get("hymba_1_5b")
    assert (c.n_heads, c.n_kv_heads, c.ssm_state) == (25, 5, 16)
    c = get("whisper_small")
    assert c.n_enc_layers == 12 and c.vocab == 51865
    c = get("phi4_mini_3_8b")
    assert c.vocab == 200064
    c = get("olmoe_1b_7b")
    assert (c.n_experts, c.top_k) == (64, 8)
    c = get("chameleon_34b")
    assert (c.d_model, c.vocab) == (8192, 65536)
    c = get("qwen1_5_0_5b")
    assert c.qkv_bias and c.vocab == 151936


# --- component equivalences ---------------------------------------------------

@pytest.mark.parametrize("sq,skv,h,hkv,chunk", [
    (8, 32, 4, 2, 8), (16, 16, 4, 4, 16), (5, 40, 6, 2, 7),
])
def test_chunked_attention_matches_dense(sq, skv, h, hkv, chunk):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, sq, h, 16))
    k = jax.random.normal(ks[1], (2, skv, hkv, 16))
    v = jax.random.normal(ks[2], (2, skv, hkv, 16))
    off = skv - sq
    d = dense_attention(q, k, v, causal=True, q_offset=jnp.asarray(off))
    c = chunked_attention(q, k, v, causal=True, q_offset=off,
                          kv_chunk=chunk)
    np.testing.assert_allclose(d, c, atol=2e-5)


def test_chunked_attention_window():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 24, 2, 8))
    k = jax.random.normal(ks[1], (1, 24, 2, 8))
    v = jax.random.normal(ks[2], (1, 24, 2, 8))
    d = dense_attention(q, k, v, causal=True, window=6)
    c = chunked_attention(q, k, v, causal=True, window=6, kv_chunk=8)
    np.testing.assert_allclose(d, c, atol=2e-5)


def test_ssd_chunked_equals_stepwise():
    """SSD chunked scan must equal token-by-token recurrence — the
    state-space duality itself."""
    spec = spec_for(d_model=32, d_state=16, head_dim=8, chunk=8)
    p = init_ssm(KEY, spec)
    x = jax.random.normal(KEY, (2, 20, 32)) * 0.5
    y_chunk, final = ssd_chunked(p, spec, x)
    st = init_state(spec, 2)
    ys = []
    for t in range(20):
        y_t, st = ssd_decode_step(p, spec, x[:, t:t + 1], st)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_step, atol=3e-4)
    np.testing.assert_allclose(final.ssm, st.ssm, atol=3e-4)


def test_ssd_prefix_continuation():
    """Chunked prefix + stepwise continuation == full stepwise run."""
    spec = spec_for(d_model=16, d_state=8, head_dim=8, chunk=4)
    p = init_ssm(KEY, spec)
    x = jax.random.normal(KEY, (1, 12, 16)) * 0.5
    _, mid = ssd_chunked(p, spec, x[:, :8])
    y_a, _ = ssd_decode_step(p, spec, x[:, 8:9], mid)
    st = init_state(spec, 1)
    for t in range(9):
        y_b, st = ssd_decode_step(p, spec, x[:, t:t + 1], st)
    np.testing.assert_allclose(y_a, y_b, atol=3e-4)


def test_moe_dispatch_matches_dropless_ref():
    p = init_moe(KEY, 32, 16, 8, 1)
    x = jax.random.normal(KEY, (3, 10, 32)) * 0.5
    y = moe_forward(x, p, top_k=2, capacity_factor=4.0)  # cap == T: dropless
    np.testing.assert_allclose(y, moe_ref(x, p, top_k=2), atol=2e-5)


def test_moe_capacity_drops_are_bounded():
    """With cf < E/k some tokens drop — output stays finite and close on
    most tokens."""
    p = init_moe(KEY, 32, 16, 8, 0)
    x = jax.random.normal(KEY, (2, 64, 32)) * 0.5
    y = moe_forward(x, p, top_k=2, capacity_factor=1.0)
    assert bool(jnp.isfinite(y).all())
