"""Cluster simulator invariants + real service layer fault tolerance."""
import pytest

from repro.core import (EngineConfig, GoRouting, MinLoad, Request,
                        RouterConfig, make_policy)
from repro.sim import (AnalyticalExecutor, ClusterConfig, ClusterSim,
                       EngineSim, InstanceHardware, QWEN2_7B, summarize)
from repro.sim.workloads import WORKLOADS, sharegpt


@pytest.fixture(scope="module")
def exec_est():
    ex = AnalyticalExecutor(QWEN2_7B, InstanceHardware(chips=4))
    est, mape = ex.fit_estimator(n=200)
    assert mape < 0.15
    return ex, est


def drive_single(engine, reqs):
    pending = sorted(reqs, key=lambda r: r.arrival)
    now, i, guard = 0.0, 0, 0
    while (i < len(pending) or engine.has_work()) and guard < 100000:
        guard += 1
        while i < len(pending) and pending[i].arrival <= now:
            engine.add_request(pending[i], now)
            i += 1
        res = engine.step(now)
        if res is None:
            if i < len(pending):
                now = pending[i].arrival
            else:
                break
        else:
            now = res.end
    return reqs


@pytest.mark.parametrize("policy", ["slidebatching", "sarathi_fcfs",
                                    "vllm_fcfs", "fair_batching"])
def test_sim_conservation(exec_est, policy):
    """Every request terminates; token times strictly ordered; no request
    served beyond its output length."""
    ex, est = exec_est
    reqs = sharegpt(rate=20, duration=5, seed=2)
    eng = EngineSim(0, make_policy(policy), ex, est, EngineConfig(w_p=4.0))
    drive_single(eng, reqs)
    for r in reqs:
        assert r.finish_time is not None, f"{r} never finished"
        assert len(r.out_times) == r.output_len
        assert all(b >= a for a, b in zip(r.out_times, r.out_times[1:]))
        assert r.out_times[0] > r.arrival


def test_slidebatching_beats_strict_priority_on_gain(exec_est):
    """§3.1: strict priority-first starves low priority; SlideBatching
    keeps overall gain higher under load."""
    ex, est = exec_est
    out = {}
    for pol in ["slidebatching", "sarathi_priority"]:
        reqs = sharegpt(rate=70, duration=12, seed=5)
        eng = EngineSim(0, make_policy(pol), ex, est,
                        EngineConfig(w_p=4.0))
        drive_single(eng, reqs)
        out[pol] = summarize(reqs, w_p=4.0)
    assert out["slidebatching"].tdg_ratio >= out["sarathi_priority"].tdg_ratio
    lo_sb = out["slidebatching"].per_priority[2]["slo"]
    lo_sp = out["sarathi_priority"].per_priority[2]["slo"]
    assert lo_sb >= lo_sp   # low-priority not starved


def test_cluster_coloc_and_disagg_complete(exec_est):
    ex, est = exec_est
    for mode, n_dec in [("coloc", 0), ("disagg", 2)]:
        reqs = sharegpt(rate=30, duration=4, seed=3)
        cs = ClusterSim(lambda: make_policy("slidebatching"),
                        GoRouting(est, RouterConfig(pd_mode=mode)),
                        ex, est, EngineConfig(w_p=4.0),
                        ClusterConfig(pd_mode=mode, n_prefill=2,
                                      n_decode=n_dec))
        cs.run(reqs)
        done = sum(r.finish_time is not None for r in reqs)
        assert done == len(reqs), f"{mode}: {done}/{len(reqs)}"


def test_cluster_heterogeneous_disagg_tiers(exec_est):
    """prefill_blocks/decode_blocks size the tiers asymmetrically; the
    admission-time decode reservation keeps the fleet consistent and the
    disagg counters settle (reserved == adopted, everything completes)."""
    ex, est = exec_est
    reqs = sharegpt(rate=30, duration=4, seed=9)
    cs = ClusterSim(lambda: make_policy("slidebatching"),
                    GoRouting(est, RouterConfig(pd_mode="disagg")),
                    ex, est, EngineConfig(w_p=4.0),
                    ClusterConfig(pd_mode="disagg", n_prefill=2,
                                  n_decode=2, prefill_blocks=2048,
                                  decode_blocks=16384,
                                  handoff_block_bytes=4096))
    assert all(st.total_blocks == 2048 for st in cs.states.values())
    assert all(st.total_blocks == 16384
               for st in cs.decode_states.values())
    cs.run(reqs)
    assert all(r.finish_time is not None for r in reqs)
    assert cs.handoffs > 0
    assert cs.reservation_hits + cs.reservation_misses == cs.handoffs
    assert cs.reserved_blocks_total == cs.adopted_blocks_total
    assert cs.handoff_bytes == cs.handoff_blocks * 4096
    assert cs.reservations == {}
    for st in list(cs.states.values()) + list(cs.decode_states.values()):
        assert st.reserved_blocks == 0


def test_cluster_failure_recovery(exec_est):
    """Killing an instance mid-run re-dispatches its requests; everything
    still completes (at degraded latency)."""
    ex, est = exec_est
    reqs = sharegpt(rate=30, duration=4, seed=4)
    cs = ClusterSim(lambda: make_policy("slidebatching"),
                    MinLoad(est), ex, est, EngineConfig(w_p=4.0),
                    ClusterConfig(pd_mode="coloc", n_prefill=3))
    cs.run(reqs, kills=[(1.0, 0)])
    assert all(r.finish_time is not None for r in reqs)
    assert any(r.preemptions > 0 or r.instance != 0 for r in reqs)


def test_cluster_elastic_scale_up(exec_est):
    ex, est = exec_est
    reqs = sharegpt(rate=60, duration=4, seed=6)
    base = ClusterSim(lambda: make_policy("slidebatching"), MinLoad(est),
                      ex, est, EngineConfig(w_p=4.0),
                      ClusterConfig(pd_mode="coloc", n_prefill=1))
    base.run([Request(r.prompt_len, r.output_len, r.arrival, r.slo,
                      r.priority, r.weight) for r in reqs])
    scaled = ClusterSim(lambda: make_policy("slidebatching"), MinLoad(est),
                        ex, est, EngineConfig(w_p=4.0),
                        ClusterConfig(pd_mode="coloc", n_prefill=1))
    scaled.run(reqs, scale_ups=[0.5, 0.5, 0.5])
    assert len(scaled.engines) == 4
    s = summarize(reqs, w_p=4.0)
    assert s.tdg_ratio > 0.3   # scaled cluster actually served load


@pytest.mark.parametrize("name", sorted(WORKLOADS))
@pytest.mark.parametrize("seed", [0, 3, 5])
def test_workload_generators_wellformed(name, seed):
    reqs = WORKLOADS[name](rate=20, duration=3, seed=seed)
    assert all(r.prompt_len >= 1 and r.output_len >= 1 for r in reqs)
    arr = [r.arrival for r in reqs]
    assert arr == sorted(arr)
    assert all(0 <= r.arrival < 3 for r in reqs)
    assert all(r.weight > 0 for r in reqs)
