"""Roofline table (deliverable g): aggregate the dry-run JSONs into the
per-(arch x shape x mesh) roofline table for EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os

HEADERS = ["arch", "shape", "mesh", "GiB/dev", "fits",
           "compute_s", "memory_s", "coll_s", "dominant",
           "useful_ratio", "roofline_frac"]


def load_cells(dryrun_dir: str = "experiments/dryrun",
               include_variants: bool = False):
    cells = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        d = json.load(open(f))
        if d.get("variant") and not include_variants:
            continue          # hillclimb variants live in §Perf, not here
        if d.get("skipped") or not d.get("ok"):
            cells.append(d)
            continue
        r = d["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        d["roofline_frac"] = r["compute_s"] / bound if bound > 0 else 0.0
        cells.append(d)
    return cells


def to_rows(cells, mesh="single"):
    rows = []
    for d in cells:
        if d.get("mesh") != mesh:
            continue
        if d.get("skipped"):
            rows.append([d["arch"], d["shape"], mesh, "-", "skip",
                         "-", "-", "-", "-", "-", "-"])
            continue
        if not d.get("ok"):
            rows.append([d["arch"], d["shape"], mesh] + ["FAIL"] * 8)
            continue
        r = d["roofline"]
        m = d["memory"]
        rows.append([
            d["arch"], d["shape"], mesh,
            f"{m['per_device_tpu_estimate']/2**30:.2f}",
            "y" if m["fits_16GiB"] else "NO",
            f"{r['compute_s']:.3f}", f"{r['memory_s']:.3f}",
            f"{r['collective_s']:.3f}", r["dominant"],
            f"{d['useful_flops_ratio']:.2f}",
            f"{d['roofline_frac']:.3f}"])
    return rows


def markdown_table(mesh="single", dryrun_dir="experiments/dryrun",
                   include_variants=False) -> str:
    cells = load_cells(dryrun_dir, include_variants)
    rows = to_rows(cells, mesh)
    out = ["| " + " | ".join(HEADERS) + " |",
           "|" + "|".join(["---"] * len(HEADERS)) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def summary(dryrun_dir="experiments/dryrun") -> dict:
    cells = [c for c in load_cells(dryrun_dir) if c.get("ok")
             and not c.get("skipped")]
    doms = {}
    for c in cells:
        doms[c["roofline"]["dominant"]] = \
            doms.get(c["roofline"]["dominant"], 0) + 1
    worst = sorted((c for c in cells if c["mesh"] == "single"),
                   key=lambda c: c["roofline_frac"])[:5]
    most_coll = sorted((c for c in cells if c["mesh"] == "single"),
                       key=lambda c: -c["roofline"]["collective_s"])[:5]
    return {
        "n_cells": len(cells),
        "dominant_counts": doms,
        "worst_roofline_frac": [(c["arch"], c["shape"],
                                 round(c["roofline_frac"], 4))
                                for c in worst],
        "most_collective_bound": [(c["arch"], c["shape"],
                                   round(c["roofline"]["collective_s"], 2))
                                  for c in most_coll],
    }


def main():
    print(markdown_table("single"))
    print()
    print(json.dumps(summary(), indent=1))


if __name__ == "__main__":
    main()
