"""Benchmark suite entry: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # fast subset
    PYTHONPATH=src python -m benchmarks.run --full     # full sweeps
    PYTHONPATH=src python -m benchmarks.run --only fig12_single_node

Prints ``name,identifier,...,derived`` CSV per row (harness contract) and
writes full JSON per benchmark to experiments/results/.
"""
from __future__ import annotations

import argparse
import time
import traceback

from . import paper_figures, replay_bench, roofline
from .common import emit_csv, save

BENCHES = [
    ("replay_router_sweep", replay_bench.replay_router_sweep),
    ("replay_shared_prefix", replay_bench.replay_shared_prefix),
    ("replay_overlap", replay_bench.replay_overlap),
    # trajectory benches: also write BENCH_replay_scale.json /
    # BENCH_engine_step.json at the repo root (docs/BENCHMARKS.md)
    ("replay_scale", replay_bench.replay_scale),
    ("engine_step", replay_bench.engine_step),
    ("fig2_partition_vs_colocation", paper_figures.fig2_partition_vs_colocation),
    ("fig3_priority_first_vs_fcfs", paper_figures.fig3_priority_first_vs_fcfs),
    ("fig4to8_policy_load_sweeps", paper_figures.fig4to8_policy_load_sweeps),
    ("fig12_single_node", paper_figures.fig12_single_node),
    ("fig13_14_multi_node", paper_figures.fig13_14_multi_node),
    ("fig15_16_priorities", paper_figures.fig15_16_priorities),
    ("fig17_ablations", paper_figures.fig17_ablations),
    ("fig18_weight_scaling", paper_figures.fig18_weight_scaling),
    ("fig19_large_scale", paper_figures.fig19_large_scale),
    ("fig20_gamma_sensitivity", paper_figures.fig20_gamma_sensitivity),
    ("fig21_22_timelines", paper_figures.fig21_22_timelines),
    ("table_estimator_mape", paper_figures.table_estimator_mape),
    ("table_scheduler_overhead", paper_figures.table_scheduler_overhead),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    fast = not args.full

    t_all = time.time()
    failures = []
    for name, fn in BENCHES:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            rows = fn(fast=fast)
            save(name, rows)
            emit_csv(name, rows if isinstance(rows, list) else [rows])
            print(f"# {name}: {len(rows)} rows in {time.time()-t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            traceback.print_exc()
    if args.only in (None, "roofline"):
        try:
            s = roofline.summary()
            print(f"# roofline: {s['n_cells']} dry-run cells, "
                  f"dominant={s['dominant_counts']}")
        except Exception as e:  # noqa: BLE001
            failures.append(("roofline", repr(e)))
    print(f"# total {time.time()-t_all:.1f}s; failures: {failures or 'none'}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
