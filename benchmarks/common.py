"""Shared benchmark machinery: one simulator run = one (dataset, rate,
scheduler, router, mode) cell; results as dict rows, JSON-dumped to
experiments/results/ and summarized as CSV on stdout."""
from __future__ import annotations

import json
import os
import time

from repro.core import (EngineConfig, GoRouting, MinLoad, RoundRobin,
                        RouterConfig, make_policy)
from repro.core.slidebatching import SlideBatching
from repro.sim import (AnalyticalExecutor, ClusterConfig, ClusterSim,
                       EngineSim, InstanceHardware, QWEN2_7B, QWEN3_32B,
                       summarize)
from repro.sim.workloads import WORKLOADS

RESULTS_DIR = "experiments/results"

_EXEC_CACHE = {}


def get_exec(model_name: str = "qwen2-7b", chips: int = 4):
    key = (model_name, chips)
    if key not in _EXEC_CACHE:
        model = QWEN2_7B if model_name == "qwen2-7b" else QWEN3_32B
        ex = AnalyticalExecutor(model, InstanceHardware(chips=chips))
        est, mape = ex.fit_estimator(n=300)
        _EXEC_CACHE[key] = (ex, est, mape)
    return _EXEC_CACHE[key]


def make_sched(name: str, **kw):
    if name.startswith("slide"):
        parts = dict()
        if "only_deadline" in name:
            parts = dict(use_density=False)
        elif "only_density" in name:
            parts = dict(use_deadline=False)
        elif "no_latency" in name:
            parts = dict(latency_aware_budget=False)
        return SlideBatching(**parts)
    return make_policy(name)


def run_single_node(dataset: str, rate: float, sched: str, *,
                    model: str = "qwen2-7b", duration: float = 20.0,
                    seed: int = 0, w_p: float = 4.0, chips: int = 4,
                    eng_cfg: EngineConfig | None = None,
                    bm_kwargs: dict | None = None, spec=None,
                    num_blocks: int | None = None,
                    t_block_scale: float = 1.0):
    ex, est, _ = get_exec(model, chips)
    reqs = WORKLOADS[dataset](rate=rate, duration=duration, seed=seed,
                              **({"spec": spec} if spec else {}))
    cfg = eng_cfg or EngineConfig(w_p=w_p)
    from repro.core.blocks import BlockManager
    bm = BlockManager(num_blocks or ex.num_blocks, ex.block_size,
                      ex.t_block * t_block_scale, beta=cfg.beta,
                      **(bm_kwargs or {}))
    eng = EngineSim(0, make_sched(sched), ex, est, cfg, bm)
    pending = sorted(reqs, key=lambda r: r.arrival)
    now, i, guard = 0.0, 0, 0
    t0 = time.time()
    while (i < len(pending) or eng.has_work()) and guard < 500000:
        guard += 1
        while i < len(pending) and pending[i].arrival <= now:
            eng.add_request(pending[i], now)
            i += 1
        res = eng.step(now)
        if res is None:
            if i < len(pending):
                now = pending[i].arrival
            else:
                break
        else:
            now = res.end
    s = summarize(reqs, w_p=w_p)
    row = {"dataset": dataset, "rate": rate, "sched": sched,
           "model": model, **s.row(),
           "sched_overhead_frac": _sched_overhead(eng),
           "wall_s": round(time.time() - t0, 2)}
    return row, reqs, eng


def _sched_overhead(eng) -> float:
    # iteration count * O(n log n) python scheduling vs simulated exec time
    sim_time = sum(l for _, _, l in eng.batch_log)
    return round(1e-4 * eng.iterations / max(sim_time, 1e-9), 6)


def run_multi_node(dataset: str, rate: float, sched: str, router: str, *,
                   pd_mode: str = "coloc", n_prefill: int = 4,
                   n_decode: int = 0, model: str = "qwen2-7b",
                   duration: float = 20.0, seed: int = 0, w_p: float = 4.0,
                   chips: int = 4, kills=None, router_cfg=None):
    ex, est, _ = get_exec(model, chips)
    reqs = WORKLOADS[dataset](rate=rate, duration=duration, seed=seed)
    if router == "gorouting":
        rt = GoRouting(est, router_cfg or RouterConfig(pd_mode=pd_mode))
    elif router == "round_robin":
        rt = RoundRobin(est)
    else:
        rt = MinLoad(est)
    cs = ClusterSim(lambda: make_sched(sched), rt, ex, est,
                    EngineConfig(w_p=w_p),
                    ClusterConfig(pd_mode=pd_mode, n_prefill=n_prefill,
                                  n_decode=n_decode))
    cs.run(reqs, kills=kills)
    s = summarize(reqs, w_p=w_p)
    return {"dataset": dataset, "rate": rate, "sched": sched,
            "router": router, "pd": pd_mode,
            "n_inst": n_prefill + n_decode, **s.row()}, reqs


def save(name: str, rows) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


def emit_csv(name: str, rows, keys=("tdg_ratio", "slo")) -> None:
    for r in rows:
        ident = ",".join(str(r.get(k, "")) for k in
                         ("dataset", "rate", "sched", "router", "pd")
                         if r.get(k) is not None)
        derived = ";".join(f"{k}={r[k]}" for k in keys if k in r)
        print(f"{name},{ident},{derived}")
