"""One benchmark function per paper table/figure (§3 motivation + §5 eval).

Every function returns a list of result rows; ``--fast`` shrinks durations
and sweeps so the whole suite runs on 1 CPU core in minutes.
"""
from __future__ import annotations

from repro.core import EngineConfig
from repro.sim import gain_timeline, summarize, urgent_timeout_timeline
from repro.sim.workloads import WorkloadSpec

from .common import get_exec, run_multi_node, run_single_node

MAIN_SCHEDS = ["slidebatching", "vllm_fcfs", "weighted_vtc", "sarathi_fcfs",
               "sarathi_priority", "fair_batching"]


def fig2_partition_vs_colocation(fast=True):
    """Static per-priority partition vs ProServe co-location (industrial)."""
    dur = 15 if fast else 60
    rate = 90 if fast else 120
    rows = []
    # co-location: one 4-chip instance serves all priorities
    row, _, _ = run_single_node("industrial", rate, "slidebatching",
                                duration=dur, chips=4)
    row["setting"] = "colocated"
    rows.append(row)
    # partition: 3 instances sized by AVERAGE class load (chips 1/1/2 of 4)
    from repro.sim.workloads import industrial
    reqs = industrial(rate=rate, duration=dur, seed=0)
    by_p = {p: [r for r in reqs if r.priority == p] for p in (1, 2, 3)}
    chips_of = {1: 1, 2: 1, 3: 2}
    from repro.core import make_policy
    from repro.sim import EngineSim
    all_rs = []
    for p, rs in by_p.items():
        ex, est, _ = get_exec("qwen2-7b", chips_of[p])
        eng = EngineSim(p, make_policy("slidebatching"), ex, est,
                        EngineConfig(w_p=4.0))
        pend, now, i = sorted(rs, key=lambda r: r.arrival), 0.0, 0
        while i < len(pend) or eng.has_work():
            while i < len(pend) and pend[i].arrival <= now:
                eng.add_request(pend[i], now)
                i += 1
            res = eng.step(now)
            if res is None:
                if i < len(pend):
                    now = pend[i].arrival
                else:
                    break
            else:
                now = res.end
        all_rs += rs
    s = summarize(all_rs, w_p=4.0)
    rows.append({"setting": "partitioned", "dataset": "industrial",
                 "rate": rate, "sched": "slidebatching", **s.row()})
    return rows


def fig3_priority_first_vs_fcfs(fast=True):
    dur = 15 if fast else 40
    rows = []
    for sched in ("priority_first", "sarathi_fcfs", "slidebatching"):
        row, _, _ = run_single_node("sharegpt", 70, sched, duration=dur)
        rows.append(row)
    return rows


def fig4to8_policy_load_sweeps(fast=True):
    """EDF vs SJF vs FCFS across loads and token budgets, heterogeneous
    SLOs (the §3.2 adaptive-deficit study)."""
    dur = 12 if fast else 30
    spec = WorkloadSpec("sharegpt", mean_in=280, mean_out=230,
                        slo_classes=((0.6, 0.05), (2.0, 0.1), (6.0, 0.2)),
                        slo_probs=(0.3, 0.5, 0.2))
    rows = []
    rates = [40, 70, 100] if fast else [30, 50, 70, 90, 110]
    for rate in rates:
        for sched in ("edf", "sjf", "sarathi_fcfs", "slidebatching"):
            row, _, _ = run_single_node("sharegpt", rate, sched,
                                        duration=dur, spec=spec, seed=2)
            rows.append(row)
    # budget sweep (fig 8): token budget sensitivity under medium load
    for budget in ([1024, 4096] if fast else [512, 1024, 2048, 4096, 8192]):
        for sched in ("edf", "sjf", "sarathi_fcfs"):
            row, _, _ = run_single_node(
                "sharegpt", 70, sched, duration=dur, spec=spec, seed=2,
                eng_cfg=EngineConfig(w_p=4.0, token_budget=budget))
            row["token_budget"] = budget
            rows.append(row)
    return rows


def fig12_single_node(fast=True):
    """Main single-node comparison: datasets x rates x schedulers."""
    dur = 12 if fast else 30
    datasets = ["sharegpt", "azure", "burstgpt", "qwentrace"]
    rates = {"sharegpt": [50, 80, 110], "azure": [30, 50, 70],
             "burstgpt": [40, 70, 100], "qwentrace": [20, 35, 50]}
    if not fast:
        for k in rates:
            lo, mid, hi = rates[k]
            rates[k] = [lo * 0.6, lo, mid, hi, hi * 1.3]
    rows = []
    for ds in datasets:
        for rate in rates[ds]:
            for sched in MAIN_SCHEDS:
                row, _, _ = run_single_node(ds, rate, sched, duration=dur)
                rows.append(row)
    return rows


def fig13_14_multi_node(fast=True):
    dur = 12 if fast else 30
    rows = []
    datasets = ["sharegpt", "qwentrace"] if fast else \
        ["sharegpt", "azure", "burstgpt", "qwentrace"]
    for pd_mode, n_p, n_d in (("disagg", 3, 1), ("coloc", 4, 0)):
        for ds in datasets:
            rate = 120 if ds != "qwentrace" else 45
            for sched in ("slidebatching", "sarathi_fcfs"):
                for router in ("gorouting", "min_load"):
                    row, _ = run_multi_node(ds, rate, sched, router,
                                            pd_mode=pd_mode, n_prefill=n_p,
                                            n_decode=n_d, duration=dur)
                    rows.append(row)
    return rows


def fig15_16_priorities(fast=True):
    dur = 15 if fast else 40
    rows = []
    for sched in ("slidebatching", "sarathi_fcfs", "sarathi_priority"):
        row, reqs, _ = run_single_node("sharegpt", 90, sched, duration=dur,
                                       model="qwen3-32b", chips=8)
        import numpy as np
        for p in (1, 2):
            sub = [r for r in reqs if r.priority == p]
            ttfts = [r.ttft for r in sub if r.ttft is not None]
            tpots = [r.tpot for r in sub if r.tpot is not None]
            row[f"ttft_p50_prio{p}"] = round(float(np.median(ttfts)), 4) \
                if ttfts else None
            row[f"tpot_p50_prio{p}"] = round(float(np.median(tpots)), 4) \
                if tpots else None
        rows.append(row)
    return rows


def fig17_ablations(fast=True):
    dur = 12 if fast else 30
    rows = []
    # SlideBatching component ablations at two loads
    for rate in (60, 100):
        for sched in ("slidebatching", "slide_only_deadline",
                      "slide_only_density", "slide_no_latency"):
            row, _, _ = run_single_node("sharegpt", rate, sched,
                                        duration=dur)
            rows.append(row)
    # block-management ablations under a LOW memory-utilization threshold
    # (paper: SMALL pool => memory pressure with RECOVERABLE compute —
    # bursts evict, lulls reload; under pure compute overload the evicted
    # tail is never readmitted and all modes coincide)
    for name, bmk in [("full", {}), ("w/o async", {"async_offload": False}),
                      ("w/o dynamic", {"adaptive_copy": False}),
                      ("recompute", {"recompute_only": True})]:
        row, _, eng = run_single_node(
            "burstgpt", 35, "slidebatching", duration=dur,
            bm_kwargs=bmk, num_blocks=2600)   # ~10% of the full pool
        row["block_mgmt"] = name
        row["reload_blocks"] = eng.bm.h2d.total_blocks
        row["offload_blocks"] = eng.bm.d2h.total_blocks
        rows.append(row)
    # same ablation on a CONTENDED host link (40x slower per block):
    # this is where the adaptive copy budget and async offload earn their
    # keep — the paper's NPU host link is far slower than v5e PCIe
    for name, bmk in [("full/slow", {}),
                      ("w/o async/slow", {"async_offload": False}),
                      ("w/o dynamic/slow", {"adaptive_copy": False}),
                      ("recompute/slow", {"recompute_only": True})]:
        row, _, eng = run_single_node(
            "burstgpt", 35, "slidebatching", duration=dur,
            bm_kwargs=bmk, num_blocks=2600, t_block_scale=40.0)
        row["block_mgmt"] = name
        row["reload_blocks"] = eng.bm.h2d.total_blocks
        rows.append(row)
    return rows


def fig18_weight_scaling(fast=True):
    dur = 12 if fast else 30
    rows = []
    for w_hi in (1.0, 2.0, 4.0, 8.0):
        for rate in ((70, 110) if fast else (50, 80, 110, 140)):
            spec = WorkloadSpec("sharegpt", 280, 230,
                                weights=(w_hi, 1.0))
            for sched in ("slidebatching", "sarathi_priority"):
                row, _, _ = run_single_node("sharegpt", rate, sched,
                                            duration=dur, spec=spec)
                row["w_hi"] = w_hi
                rows.append(row)
    return rows


def fig19_large_scale(fast=True):
    """32 instances of qwen3-32b on the industrial workload."""
    dur = 10 if fast else 30
    n_inst = 8 if fast else 32
    rate = 150 if fast else 600
    rows = []
    for sched, router in (("slidebatching", "gorouting"),
                          ("sarathi_fcfs", "round_robin"),
                          ("vllm_fcfs", "round_robin"),
                          ("weighted_vtc", "round_robin")):
        row, _ = run_multi_node("industrial", rate, sched, router,
                                n_prefill=n_inst, duration=dur,
                                model="qwen3-32b", chips=8)
        rows.append(row)
    return rows


def fig20_gamma_sensitivity(fast=True):
    dur = 12 if fast else 30
    rows = []
    for gamma in (0.01, 0.2, 0.5, 0.8, 1.0, 1.5):
        for rate in ((70, 110) if fast else (50, 80, 110)):
            row, _, _ = run_single_node(
                "sharegpt", rate, "slidebatching", duration=dur,
                eng_cfg=EngineConfig(w_p=4.0, gamma=gamma))
            row["gamma"] = gamma
            rows.append(row)
    return rows


def fig21_22_timelines(fast=True):
    dur = 15 if fast else 60
    out = []
    for sched in ("slidebatching", "sarathi_fcfs"):
        row, reqs, _ = run_single_node("azure", 60, sched, duration=dur)
        tl = gain_timeline(reqs, bucket=1.0, w_p=4.0)
        ut = urgent_timeout_timeline(reqs, horizon=dur * 2)
        out.append({"sched": sched, "tdg_per_s": tl,
                    "urgent_timeout": {k: v for k, v in ut.items()
                                       if k != "bucket"}, **row})
    return out


def table_estimator_mape(fast=True):
    """§4.1: MAPE of the fitted batch-latency estimator."""
    rows = []
    for model, chips in (("qwen2-7b", 4), ("qwen3-32b", 8)):
        _, _, mape = get_exec(model, chips)
        rows.append({"model": model, "chips": chips,
                     "mape": round(mape, 4), "paper_mape": 0.045})
    return rows


def table_scheduler_overhead(fast=True):
    """App. D.3: scheduling cost as a fraction of batch execution."""
    row, _, eng = run_single_node("sharegpt", 60, "slidebatching",
                                  duration=10)
    row_f, _, eng_f = run_single_node("sharegpt", 60, "sarathi_fcfs",
                                      duration=10)
    return [{"sched": "slidebatching",
             "overhead_frac": row["sched_overhead_frac"]},
            {"sched": "sarathi_fcfs",
             "overhead_frac": row_f["sched_overhead_frac"]}]
