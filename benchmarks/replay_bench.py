"""Service-tier replay benchmarks.

* ``replay_router_sweep`` — the identical request trace replayed through
  the cluster simulator under each global router, reporting the
  per-priority gain / SLO-attainment rows the async frontend reports live.
  This is the offline counterpart of ``examples/serve_cluster.py``.
* ``replay_shared_prefix`` — the shared-system-prompt trace replayed with
  the radix prefix cache ON vs OFF, in BOTH simulated time (ClusterSim +
  SimPrefixCache) and wall-clock mode (ServiceFrontend + real engines +
  RadixPrefixCache), reporting prefill tokens actually computed, cache
  hits and TTFT.  The offline counterpart of ``examples/shared_prefix.py``.
* ``replay_overlap`` — the overlapped execution engine (packed prefill +
  async transfer lanes) ON vs OFF in wall-clock mode: a prefill-heavy
  trace measures prefill throughput, a decode trace guards TPOT, and the
  token streams are asserted identical.  The offline counterpart of
  ``tools/perf_smoke.py``.
"""
from __future__ import annotations

from repro.core import (EngineConfig, GoRouting, MinLoad, RoundRobin,
                        RouterConfig, make_policy)
from repro.sim import ClusterConfig, ClusterSim, replay_sim
from repro.sim.workloads import WORKLOADS

from .common import get_exec


def replay_router_sweep(fast: bool = True) -> list[dict]:
    ex, est, _ = get_exec()
    datasets = ["sharegpt"] if fast else ["sharegpt", "azure", "industrial"]
    rates = [40] if fast else [30, 60, 90]
    routers = [
        ("gorouting", lambda: GoRouting(est, RouterConfig(pd_mode="coloc"))),
        ("min_load", lambda: MinLoad(est)),
        ("round_robin", lambda: RoundRobin()),
    ]
    rows = []
    for ds in datasets:
        for rate in rates:
            for rname, mk in routers:
                reqs = WORKLOADS[ds](rate=rate, duration=6, seed=7)
                cs = ClusterSim(lambda: make_policy("slidebatching"), mk(),
                                ex, est, EngineConfig(w_p=4.0),
                                ClusterConfig(pd_mode="coloc", n_prefill=4))
                rep = replay_sim(cs, reqs, w_p=4.0)
                rows.append({"name": "replay_router_sweep", "dataset": ds,
                             "rate": rate, "router": rname, **rep.row()})
    return rows


def _shared_prefix_sim(fast: bool) -> list[dict]:
    ex, est, _ = get_exec()
    rate, duration = (40, 6) if fast else (80, 20)
    rows = []
    for cache_on in (True, False):
        reqs = WORKLOADS["shared_prefix"](rate=rate, duration=duration,
                                          seed=11, n_groups=4,
                                          prefix_len=1024, p_shared=0.8)
        cs = ClusterSim(lambda: make_policy("slidebatching"),
                        GoRouting(est, RouterConfig(pd_mode="coloc")),
                        ex, est, EngineConfig(w_p=4.0),
                        ClusterConfig(pd_mode="coloc", n_prefill=2,
                                      prefix_cache=cache_on))
        rep = replay_sim(cs, reqs, w_p=4.0)
        engines = list(cs.engines.values())
        rows.append({
            "name": "replay_shared_prefix",
            "dataset": f"shared_prefix/sim/cache-{'on' if cache_on else 'off'}",
            "mode": "sim", "prefix_cache": cache_on,
            "prefill_tokens": sum(e.prefill_tokens for e in engines),
            "cache_hit_tokens": sum(e.prefix_cache.hit_tokens
                                    for e in engines if e.prefix_cache),
            **rep.row()})
    return rows


def _shared_prefix_frontend(fast: bool) -> list[dict]:
    """Wall-clock mode: real engines + radix cache behind the async
    frontend (the shared smoke stack from ``repro.sim.replay``).  Each
    configuration is replayed twice and the warm pass is reported, so
    one-off JIT compilation doesn't pollute the comparison."""
    import asyncio

    from repro.sim import replay_frontend
    from repro.sim.replay import smoke_frontend, smoke_shared_prefix_trace

    # enough concurrent streams that prefill queueing dominates TTFT —
    # at smoke scale fewer requests make the on/off TTFT delta pure noise
    n = 48 if fast else 64

    async def run(cache_on: bool) -> dict:
        fe, cfg = smoke_frontend(2, prefix_cache=cache_on, w_p=4.0)
        await fe.start()
        trace = smoke_shared_prefix_trace(n, max_out=2)
        rep = await replay_frontend(fe, trace, cfg.vocab, speed=200.0,
                                    w_p=4.0)
        engines = list(fe.engines.values())
        row = {"name": "replay_shared_prefix",
               "dataset": "shared_prefix/frontend/"
                          f"cache-{'on' if cache_on else 'off'}",
               "mode": "frontend", "prefix_cache": cache_on,
               "prefill_tokens": sum(e.stats.prefill_tokens
                                     for e in engines),
               "cache_hit_tokens": sum(e.stats.cache_hit_tokens
                                       for e in engines),
               **rep.row()}
        await fe.stop()
        return row

    rows = []
    for cache_on in (True, False):
        asyncio.run(run(cache_on))             # warm pass: JIT compilation
        rows.append(asyncio.run(run(cache_on)))
    return rows


def replay_shared_prefix(fast: bool = True) -> list[dict]:
    return _shared_prefix_sim(fast) + _shared_prefix_frontend(fast)


def replay_overlap(fast: bool = True) -> list[dict]:
    """Overlapped execution (packed prefill + async transfer lanes) on vs
    off, wall-clock, direct engine drive (no asyncio noise)."""
    from tools.perf_smoke import make_trace, run_once

    import jax

    from repro.configs import get_smoke
    from repro.models import init_params

    cfg = get_smoke("qwen1_5_0_5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_req = 24 if fast else 48
    rows = []
    streams: dict = {}
    # the speedup is dominated by packed prefill; the transfer lanes keep
    # the streams identical and remove copy stalls under preemption (their
    # liveness is asserted by tests/test_overlap_exec.py staged-hit test)
    for label, out_len in (("prefill_heavy", 1), ("decode", 8)):
        for packed, overlap in ((False, False), (True, True)):
            for _warm in (True, False):
                trace = make_trace(cfg, n_req, 160, out_len, seed=5)
                row, outs = run_once(cfg, params, trace, packed=packed,
                                     overlap=overlap)
            streams[(label, packed)] = outs
            mode = "overlapped" if packed else "baseline"
            rows.append({"name": "replay_overlap",
                         "dataset": f"{label}/{mode}", **row})
        assert streams[(label, True)] == streams[(label, False)], \
            f"token streams diverged on the {label} trace"
    base = next(r for r in rows if r["dataset"] == "prefill_heavy/baseline")
    fastr = next(r for r in rows
                 if r["dataset"] == "prefill_heavy/overlapped")
    for r in rows:
        r["prefill_speedup"] = round(
            fastr["prefill_tok_per_s"] / base["prefill_tok_per_s"], 2)
    return rows
