"""Service-tier replay benchmarks.

* ``replay_router_sweep`` — the identical request trace replayed through
  the cluster simulator under each global router, reporting the
  per-priority gain / SLO-attainment rows the async frontend reports live.
  This is the offline counterpart of ``examples/serve_cluster.py``.
* ``replay_shared_prefix`` — the shared-system-prompt trace replayed with
  the radix prefix cache ON vs OFF, in BOTH simulated time (ClusterSim +
  SimPrefixCache) and wall-clock mode (ServiceFrontend + real engines +
  RadixPrefixCache), reporting prefill tokens actually computed, cache
  hits and TTFT.  The offline counterpart of ``examples/shared_prefix.py``.
* ``replay_overlap`` — the overlapped execution engine (packed prefill +
  async transfer lanes) ON vs OFF in wall-clock mode: a prefill-heavy
  trace measures prefill throughput, a decode trace guards TPOT, and the
  token streams are asserted identical.  The offline counterpart of
  ``tools/perf_smoke.py``.
* ``replay_scale`` — the windowed cluster simulator on the 10⁴/10⁵/10⁶
  scale presets (streamed trace, streamed metrics; ``--workers N``
  shards replicas over forked processes), plus per-request equivalence
  cross-checks against the reference event loop.  Results are
  written to ``BENCH_replay_scale.json`` at the repo root; CI's
  ``sim-scale`` job replays the ``ci`` preset under a wall budget and
  compares the deterministic metrics against the checked-in file
  (docs/BENCHMARKS.md).  Also runnable directly:

      PYTHONPATH=src python -m benchmarks.replay_bench --preset ci \\
          --budget 300 --check BENCH_replay_scale.json
"""
from __future__ import annotations

import json
import os
import time

from repro.core import (EngineConfig, GoRouting, MinLoad, RoundRobin,
                        RouterConfig, make_policy)
from repro.sim import ClusterConfig, ClusterSim, replay_sim
from repro.sim.workloads import WORKLOADS

from .common import get_exec

# deterministic fields of a replay row (everything except wall time /
# replay speed) — what the CI scale gate compares bit-for-bit.  The spec
# depth histogram is written as trajectory data but exempt from the
# cross-build compare: BLAS-dependent estimator fits can flip near-tie
# scheduling decisions, shuffling a few entries between depth buckets
# (the scalar counters get the usual 2% float tolerance instead).
NONDETERMINISTIC_KEYS = ("wall_s", "speed", "spec_depth_hist")

SCALE_PRESETS = {
    # contended: ~0.62 SLO attainment at rate 600 — scheduling decisions
    # actually matter; finishes in well under the CI wall budget
    "ci": {"n_requests": 10_000, "rate": 600.0, "seed": 7, "replicas": 8},
    # the 10⁵ preset: 3 priorities, < 2 min single-core; CI's
    # sim-scale-mp job replays it sharded over 4 workers
    "full": {"n_requests": 100_000, "rate": 450.0, "seed": 7,
             "replicas": 8, "workers": 4, "window": 0.5},
    # the million-request preset (weekly CI, 4-core bar: < 5 min
    # sharded over 4 workers — docs/BENCHMARKS.md)
    "mega": {"n_requests": 1_000_000, "rate": 450.0, "seed": 7,
             "replicas": 8, "workers": 4, "window": 0.5},
}

# thrash-regime preset for the tiered KV cache (run_tiered_preset): the
# shared-prefix working set (n_groups * prefix_len) is 2x the device-side
# prefix cache, and the request rate is low enough that groups go
# unpinned between uses — so the cache continually evicts live prefixes.
# HBM-only destroys them (recompute); the tiered cache spills them to the
# host tier and restores over the H2D lane (int8-cold past the host
# budget), which must win on both TTFT p50 and recomputed prefill tokens.
TIERED_PRESET = {
    "rate": 8.0, "duration": 30.0, "seed": 13, "replicas": 1,
    "n_groups": 8, "prefix_len": 1024, "p_shared": 0.9,
}

# coloc-vs-disagg smoke (run_disagg_preset): the same sharegpt trace
# through a 5-replica coloc fleet and a 3 prefill + 2 decode disagg
# fleet — the CI gate is on the handoff-accounting invariants, the
# TTFT/TPOT rows are trajectory data.
DISAGG_PRESET = {
    "rate": 40.0, "duration": 6.0, "seed": 7,
    "n_prefill": 3, "n_decode": 2,
}

# speculative-decoding smoke (run_spec_preset): the identical sharegpt
# trace with speculation off and on (k=2, the deterministic per-(rid,
# step) acceptance oracle from core/spec.py).  The CI gates are the
# accounting invariants plus a decode tokens/s (1/TPOT) improvement for
# the HIGH-priority tier: priority 1 keeps full draft depth while lower
# tiers are penalized, so accepted draft tokens compress its decode
# steps the most.
SPEC_PRESET = {
    "rate": 40.0, "duration": 6.0, "seed": 7, "replicas": 4, "spec_k": 2,
}


def replay_router_sweep(fast: bool = True) -> list[dict]:
    ex, est, _ = get_exec()
    datasets = ["sharegpt"] if fast else ["sharegpt", "azure", "industrial"]
    rates = [40] if fast else [30, 60, 90]
    routers = [
        ("gorouting", lambda: GoRouting(est, RouterConfig(pd_mode="coloc"))),
        ("min_load", lambda: MinLoad(est)),
        ("round_robin", lambda: RoundRobin()),
    ]
    rows = []
    for ds in datasets:
        for rate in rates:
            for rname, mk in routers:
                reqs = WORKLOADS[ds](rate=rate, duration=6, seed=7)
                cs = ClusterSim(lambda: make_policy("slidebatching"), mk(),
                                ex, est, EngineConfig(w_p=4.0),
                                ClusterConfig(pd_mode="coloc", n_prefill=4))
                rep = replay_sim(cs, reqs, w_p=4.0)
                rows.append({"name": "replay_router_sweep", "dataset": ds,
                             "rate": rate, "router": rname, **rep.row()})
    return rows


def _shared_prefix_sim(fast: bool) -> list[dict]:
    ex, est, _ = get_exec()
    rate, duration = (40, 6) if fast else (80, 20)
    rows = []
    for cache_on in (True, False):
        reqs = WORKLOADS["shared_prefix"](rate=rate, duration=duration,
                                          seed=11, n_groups=4,
                                          prefix_len=1024, p_shared=0.8)
        cs = ClusterSim(lambda: make_policy("slidebatching"),
                        GoRouting(est, RouterConfig(pd_mode="coloc")),
                        ex, est, EngineConfig(w_p=4.0),
                        ClusterConfig(pd_mode="coloc", n_prefill=2,
                                      prefix_cache=cache_on))
        rep = replay_sim(cs, reqs, w_p=4.0)
        engines = list(cs.engines.values())
        rows.append({
            "name": "replay_shared_prefix",
            "dataset": f"shared_prefix/sim/cache-{'on' if cache_on else 'off'}",
            "mode": "sim", "prefix_cache": cache_on,
            "prefill_tokens": sum(e.prefill_tokens for e in engines),
            "cache_hit_tokens": sum(e.prefix_cache.hit_tokens
                                    for e in engines if e.prefix_cache),
            **rep.row()})
    return rows


def _shared_prefix_frontend(fast: bool) -> list[dict]:
    """Wall-clock mode: real engines + radix cache behind the async
    frontend (the shared smoke stack from ``repro.sim.replay``).  Each
    configuration is replayed twice and the warm pass is reported, so
    one-off JIT compilation doesn't pollute the comparison."""
    import asyncio

    from repro.sim import replay_frontend
    from repro.sim.replay import smoke_frontend, smoke_shared_prefix_trace

    # enough concurrent streams that prefill queueing dominates TTFT —
    # at smoke scale fewer requests make the on/off TTFT delta pure noise
    n = 48 if fast else 64

    async def run(cache_on: bool) -> dict:
        fe, cfg = smoke_frontend(2, prefix_cache=cache_on, w_p=4.0)
        await fe.start()
        trace = smoke_shared_prefix_trace(n, max_out=2)
        rep = await replay_frontend(fe, trace, cfg.vocab, speed=200.0,
                                    w_p=4.0)
        engines = list(fe.engines.values())
        row = {"name": "replay_shared_prefix",
               "dataset": "shared_prefix/frontend/"
                          f"cache-{'on' if cache_on else 'off'}",
               "mode": "frontend", "prefix_cache": cache_on,
               "prefill_tokens": sum(e.stats.prefill_tokens
                                     for e in engines),
               "cache_hit_tokens": sum(e.stats.cache_hit_tokens
                                       for e in engines),
               **rep.row()}
        await fe.stop()
        return row

    rows = []
    for cache_on in (True, False):
        asyncio.run(run(cache_on))             # warm pass: JIT compilation
        rows.append(asyncio.run(run(cache_on)))
    return rows


def replay_shared_prefix(fast: bool = True) -> list[dict]:
    return _shared_prefix_sim(fast) + _shared_prefix_frontend(fast)


def replay_overlap(fast: bool = True) -> list[dict]:
    """Overlapped execution (packed prefill + async transfer lanes) on vs
    off, wall-clock, direct engine drive (no asyncio noise)."""
    from tools.perf_smoke import make_trace, run_once

    import jax

    from repro.configs import get_smoke
    from repro.models import init_params

    cfg = get_smoke("qwen1_5_0_5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_req = 24 if fast else 48
    rows = []
    streams: dict = {}
    # the speedup is dominated by packed prefill; the transfer lanes keep
    # the streams identical and remove copy stalls under preemption (their
    # liveness is asserted by tests/test_overlap_exec.py staged-hit test)
    for label, out_len in (("prefill_heavy", 1), ("decode", 8)):
        for packed, overlap in ((False, False), (True, True)):
            for _warm in (True, False):
                trace = make_trace(cfg, n_req, 160, out_len, seed=5)
                row, outs = run_once(cfg, params, trace, packed=packed,
                                     overlap=overlap)
            streams[(label, packed)] = outs
            mode = "overlapped" if packed else "baseline"
            rows.append({"name": "replay_overlap",
                         "dataset": f"{label}/{mode}", **row})
        assert streams[(label, True)] == streams[(label, False)], \
            f"token streams diverged on the {label} trace"
    base = next(r for r in rows if r["dataset"] == "prefill_heavy/baseline")
    fastr = next(r for r in rows
                 if r["dataset"] == "prefill_heavy/overlapped")
    for r in rows:
        r["prefill_speedup"] = round(
            fastr["prefill_tok_per_s"] / base["prefill_tok_per_s"], 2)
    return rows


def engine_step(fast: bool = True) -> list[dict]:
    """Engine hot-loop trajectory: the full ``tools/perf_smoke.py``
    measurement (overlap + fused decode + host-sync accounting), written
    to ``BENCH_engine_step.json`` at the repo root."""
    import types

    from tools import perf_smoke

    args = types.SimpleNamespace(
        min_speedup=1.1, requests=24 if fast else 48, prompt_len=160,
        decode_len=8, max_tpot_ratio=1.3, max_fused_ratio=1.2, seed=0)
    payload, failures = perf_smoke.collect(args)
    assert not failures, f"perf gates failed: {failures}"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = os.path.join(root, "BENCH_engine_step.json")
    perf_smoke.merge_trajectory(payload, out)
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    rows = []
    for section, variants in (("prefill", ("baseline", "overlapped")),
                              ("decode", ("baseline", "overlapped")),
                              ("decode_fusion", ("logits", "fused"))):
        for variant in variants:
            rows.append({"name": "engine_step",
                         "dataset": f"{section}/{variant}",
                         **payload[section][variant]})
    rows.append({"name": "engine_step", "dataset": "gates",
                 "prefill_speedup": payload["prefill"]["speedup"],
                 "tpot_ratio": payload["decode"]["tpot_ratio"],
                 "fused_tpot_ratio":
                     payload["decode_fusion"]["fused_tpot_ratio"],
                 "streams_identical": payload["streams_identical"]})
    return rows


# --------------------------------------------------------------------------
# million-request scale replays (vectorized simulator)
# --------------------------------------------------------------------------

def _scale_cluster(n_prefill: int, loop: str = "windowed",
                   spec_k: int = 0):
    from repro.sim import VectorClusterSim, WindowedClusterSim
    ex, est, _ = get_exec()
    cls = {"reference": ClusterSim, "vector": VectorClusterSim,
           "windowed": WindowedClusterSim}[loop]
    return cls(lambda: make_policy("slidebatching"),
               GoRouting(est, RouterConfig(pd_mode="coloc")),
               ex, est, EngineConfig(w_p=4.0, spec_k=spec_k),
               ClusterConfig(pd_mode="coloc", n_prefill=n_prefill))


def _pinned_trace(n: int, rate: float, seed: int):
    """Scale trace with rids renumbered 0..n-1 so runs are independent
    of the process-global rid counter (and of each other)."""
    from repro.sim import iter_scale_trace
    for i, r in enumerate(iter_scale_trace(n, rate=rate, seed=seed)):
        r.rid = i
        yield r


def run_scale_preset(preset: str, loop: str = "windowed") -> dict:
    """One streamed scale replay: the trace is generated lazily
    (``iter_scale_trace``) and metrics fold per completion
    (``replay_sim_stream``), so peak memory is O(in-flight), not O(n).
    The windowed loop is the default — per-request results are bitwise
    identical to the vector/reference loops (``scale_equivalence_row``)
    at lower event-dispatch cost."""
    from repro.sim import iter_scale_trace, replay_sim_stream
    p = SCALE_PRESETS[preset]
    cs = _scale_cluster(p["replicas"], loop=loop)
    rep = replay_sim_stream(
        cs, iter_scale_trace(p["n_requests"], rate=p["rate"],
                             seed=p["seed"]), w_p=4.0,
        bounded=p["n_requests"] >= 1_000_000)
    return {"name": "replay_scale", "preset": preset, **p, **rep.row()}


def run_sharded_preset(preset: str, workers: int | None = None,
                       window: float | None = None) -> dict:
    """The scale preset replayed through the sharded stale-view loop:
    replicas partitioned over forked worker processes, the GoRouting
    frontend exchanging per-window dispatch/ack batches over pipes.
    Row key ``{preset}-mp{workers}``.  Metrics differ from the exact
    loop only through window-delayed routing (bounded, quantified by
    ``sharded_equivalence_row``); they are identical across worker
    counts and partitions, so the checked-in row gates determinism."""
    from repro.sim import iter_scale_trace, replay_sim_sharded
    p = SCALE_PRESETS[preset]
    workers = workers if workers is not None else p.get("workers", 4)
    window = window if window is not None else p.get("window", 0.5)
    rep, extras = replay_sim_sharded(
        lambda: _scale_cluster(p["replicas"]),
        iter_scale_trace(p["n_requests"], rate=p["rate"], seed=p["seed"]),
        workers=workers, window=window, w_p=4.0,
        bounded=p["n_requests"] >= 1_000_000)
    row = {"name": "replay_scale", "preset": f"{preset}-mp{workers}",
           **{k: v for k, v in p.items() if k not in ("workers", "window")},
           "workers": workers, "window": window,
           "windows": extras["windows"], **rep.row()}
    # floats, so check_scale_row applies its 2% tolerance (BLAS-build
    # estimator jitter can flip near-tie scheduling decisions)
    row["prefill_tokens"] = float(extras["counters"]["prefill_tokens"])
    row["iterations"] = float(extras["counters"]["iterations"])
    return row


def run_tiered_preset() -> dict:
    """Tiered-KV thrash replay: the identical shared-prefix trace through
    three cache configurations (no cache / HBM-only destroy-on-evict /
    host-tier spill with int8 cold demotion), reported as one flat row
    keyed ``tiered`` in BENCH_replay_scale.json.  Token counts are
    emitted as floats so the CI check compares them with the same 2%
    tolerance as the other scale metrics (BLAS-build estimator jitter can
    shift a few scheduling near-ties); the pass/fail gates are the
    booleans, recomputed on every run."""
    ex, est, _ = get_exec()
    p = TIERED_PRESET
    working = p["n_groups"] * (p["prefix_len"] // ex.block_size)
    cache_frac = (working / 2) / ex.num_blocks     # HBM ~ 1/2 working set
    variants = {
        "cache_off": dict(prefix_cache=False),
        "hbm_only": dict(prefix_cache=True, cache_frac=cache_frac),
        "tiered": dict(prefix_cache=True, cache_frac=cache_frac,
                       host_tier_blocks=working),
    }
    row = {"name": "replay_scale", "preset": "tiered", **p,
           "hbm_cache_blocks": working // 2, "host_tier_blocks": working}
    for label, kw in variants.items():
        reqs = WORKLOADS["shared_prefix"](
            rate=p["rate"], duration=p["duration"], seed=p["seed"],
            n_groups=p["n_groups"], prefix_len=p["prefix_len"],
            p_shared=p["p_shared"])
        row.setdefault("n_requests", len(reqs))
        cs = ClusterSim(lambda: make_policy("slidebatching"),
                        GoRouting(est, RouterConfig(pd_mode="coloc")),
                        ex, est, EngineConfig(w_p=4.0),
                        ClusterConfig(pd_mode="coloc",
                                      n_prefill=p["replicas"], **kw))
        rep = replay_sim(cs, reqs, w_p=4.0)
        engines = list(cs.engines.values())
        r = rep.row()
        row[f"ttft_p50_{label}"] = r["ttft_p50"]
        row[f"slo_{label}"] = r["slo"]
        row[f"prefill_tokens_{label}"] = float(
            sum(e.prefill_tokens for e in engines))
        caches = [e.prefix_cache for e in engines if e.prefix_cache]
        row[f"spilled_blocks_{label}"] = float(
            sum(c.spilled_blocks for c in caches))
        row[f"restored_blocks_{label}"] = float(
            sum(c.restored_blocks for c in caches))
    row["tiered_beats_hbm_ttft"] = (
        row["ttft_p50_tiered"] < row["ttft_p50_hbm_only"])
    row["tiered_beats_hbm_prefill"] = (
        row["prefill_tokens_tiered"] < row["prefill_tokens_hbm_only"])
    return row


def run_disagg_preset() -> dict:
    """Disaggregated prefill/decode vs coloc on the identical trace: one
    flat row keyed ``disagg`` in BENCH_replay_scale.json with both modes'
    TTFT/TPOT plus the handoff/reservation counters (priced at the
    analytical executor's physical per-block KV bytes, the same constant
    the live pool uses — see tools/perf_smoke.py's parity gate).  The
    pass/fail gates are the invariant booleans, recomputed every run."""
    ex, est, _ = get_exec()
    p = DISAGG_PRESET
    block_bytes = int(ex.model.kv_bytes_per_token * ex.block_size)
    row = {"name": "replay_scale", "preset": "disagg", **p,
           "block_bytes": block_bytes}
    counters = {}
    for mode in ("coloc", "disagg"):
        reqs = WORKLOADS["sharegpt"](rate=p["rate"],
                                     duration=p["duration"],
                                     seed=p["seed"])
        row.setdefault("n_requests", len(reqs))
        ccfg = (ClusterConfig(pd_mode="coloc",
                              n_prefill=p["n_prefill"] + p["n_decode"])
                if mode == "coloc" else
                ClusterConfig(pd_mode="disagg", n_prefill=p["n_prefill"],
                              n_decode=p["n_decode"],
                              handoff_block_bytes=block_bytes))
        cs = ClusterSim(lambda: make_policy("slidebatching"),
                        GoRouting(est, RouterConfig(pd_mode=mode)),
                        ex, est, EngineConfig(w_p=4.0), ccfg)
        rep = replay_sim(cs, reqs, w_p=4.0)
        r = rep.row()
        for k in ("ttft_p50", "ttft_p99", "tpot_p50", "tpot_p99", "slo",
                  "tdg_ratio"):
            row[f"{k}_{mode}"] = r[k]
        if mode == "disagg":
            from repro.sim import disagg_counters
            counters = disagg_counters(cs)
            row["dropped_disagg"] = len(cs.dropped)
            for k, v in counters.items():
                row[f"disagg_{k}"] = float(v) if k == "handoff_bytes" \
                    else v
    row["reservations_settled"] = (
        counters["reservation_hits"] + counters["reservation_misses"]
        == counters["handoffs"])
    row["reserved_matches_adopted"] = (
        counters["reserved_blocks_total"]
        == counters["adopted_blocks_total"])
    row["handoff_bytes_consistent"] = (
        counters["handoff_bytes"]
        == counters["handoff_blocks"] * block_bytes)
    return row


def run_spec_preset() -> dict:
    """Speculative-decoding replay: one flat row keyed ``spec`` in
    BENCH_replay_scale.json.  Depth is priced per decode entry by the
    shared SlideBatching policy (load/priority policy, block-room cap,
    estimator tokens/s pricing); acceptance is the deterministic
    splitmix draw, so the row is bit-reproducible.  The pass/fail gates
    are the invariant booleans (conservation, bounded depth, and the
    high-priority decode speedup), recomputed on every run."""
    import numpy as np

    from repro.sim import spec_counters

    ex, est, _ = get_exec()
    p = SPEC_PRESET
    row = {"name": "replay_scale", "preset": "spec", **p}
    counters: dict = {}
    for label, k in (("off", 0), ("on", p["spec_k"])):
        reqs = WORKLOADS["sharegpt"](rate=p["rate"], duration=p["duration"],
                                     seed=p["seed"])
        # the acceptance oracle is keyed on (rid, step) and rids come from
        # a process-global counter — renumber so the draws (and therefore
        # the committed counters) don't depend on what ran earlier
        for i, q in enumerate(reqs):
            q.rid = i
        row.setdefault("n_requests", len(reqs))
        cs = ClusterSim(lambda: make_policy("slidebatching"),
                        GoRouting(est, RouterConfig(pd_mode="coloc")),
                        ex, est, EngineConfig(w_p=4.0, spec_k=k),
                        ClusterConfig(pd_mode="coloc",
                                      n_prefill=p["replicas"]))
        rep = replay_sim(cs, reqs, w_p=4.0)
        r = rep.row()
        for key in ("ttft_p50", "tpot_p50", "tpot_p99", "slo",
                    "tdg_ratio"):
            row[f"{key}_{label}"] = r[key]
        pmin = min(q.priority for q in reqs)
        hi = [q.tpot for q in reqs
              if q.priority == pmin and q.tpot is not None]
        row.setdefault("hi_priority", pmin)
        hi_tpot = float(np.percentile(hi, 50))
        row[f"hi_tpot_p50_{label}"] = round(hi_tpot, 6)
        row[f"hi_decode_tok_per_s_{label}"] = round(
            1.0 / max(hi_tpot, 1e-12), 2)
        if k:
            counters = spec_counters(cs)
            # floats, so the CI check applies its 2% tolerance (see
            # NONDETERMINISTIC_KEYS note on BLAS-build jitter)
            row["spec_proposed"] = float(counters["spec_proposed"])
            row["spec_accepted"] = float(counters["spec_accepted"])
            row["spec_rejected"] = float(counters["spec_rejected"])
            row["spec_depth_hist"] = {str(d): n for d, n in
                                      counters["spec_depth_hist"].items()}
    row["spec_conserved"] = (
        counters["spec_proposed"]
        == counters["spec_accepted"] + counters["spec_rejected"])
    row["spec_depth_bounded"] = all(
        0 <= int(d) <= p["spec_k"] for d in row["spec_depth_hist"])
    row["hi_decode_speedup"] = round(
        row["hi_tpot_p50_off"] / max(row["hi_tpot_p50_on"], 1e-12), 4)
    row["hi_priority_decode_improves"] = (
        row["hi_tpot_p50_on"] < row["hi_tpot_p50_off"])
    return row


def spec_gate_failures(row: dict) -> list[str]:
    out = []
    if not row["spec_proposed"] > 0:
        out.append("spec replay proposed no draft tokens — speculation "
                   "never engaged")
    if not row["spec_accepted"] > 0:
        out.append("spec replay accepted no draft tokens")
    if not row["spec_conserved"]:
        out.append("spec accounting broke: proposed %d != accepted %d + "
                   "rejected %d" % (row["spec_proposed"],
                                    row["spec_accepted"],
                                    row["spec_rejected"]))
    if not row["spec_depth_bounded"]:
        out.append("spec depth histogram %r escapes [0, %d]"
                   % (row["spec_depth_hist"], row["spec_k"]))
    if not row["hi_priority_decode_improves"]:
        out.append("high-priority decode tokens/s did not improve with "
                   "speculation on (%.2f vs %.2f tok/s)"
                   % (row["hi_decode_tok_per_s_on"],
                      row["hi_decode_tok_per_s_off"]))
    return out


def disagg_gate_failures(row: dict) -> list[str]:
    out = []
    if not row["disagg_handoffs"] > 0:
        out.append("disagg replay performed no handoffs — the trace "
                   "never exercised the prefill->decode path")
    if row["dropped_disagg"]:
        out.append("disagg replay dropped %d requests" %
                   row["dropped_disagg"])
    if not row["reservations_settled"]:
        out.append("disagg reservations did not all settle: %d hits + %d "
                   "misses != %d handoffs"
                   % (row["disagg_reservation_hits"],
                      row["disagg_reservation_misses"],
                      row["disagg_handoffs"]))
    if not row["reserved_matches_adopted"]:
        out.append("disagg reserved blocks %d != adopted blocks %d"
                   % (row["disagg_reserved_blocks_total"],
                      row["disagg_adopted_blocks_total"]))
    if not row["handoff_bytes_consistent"]:
        out.append("disagg handoff bytes %.0f != blocks %d x %d bytes"
                   % (row["disagg_handoff_bytes"],
                      row["disagg_handoff_blocks"], row["block_bytes"]))
    return out


def tiered_gate_failures(row: dict) -> list[str]:
    out = []
    if not row["tiered_beats_hbm_ttft"]:
        out.append("tiered TTFT p50 %.4fs did not beat HBM-only %.4fs"
                   % (row["ttft_p50_tiered"], row["ttft_p50_hbm_only"]))
    if not row["tiered_beats_hbm_prefill"]:
        out.append("tiered prefill tokens %d did not beat HBM-only %d"
                   % (row["prefill_tokens_tiered"],
                      row["prefill_tokens_hbm_only"]))
    if not row["restored_blocks_tiered"] > 0:
        out.append("tiered replay restored no spilled blocks — the trace "
                   "is not in the thrash regime")
    return out


def scale_equivalence_row(n: int = 2000, spec_k: int = 0,
                          loop: str = "vector") -> dict:
    """Reference vs batched event loop on the same seeded trace slice:
    per-request output timestamps, finish times and preemption counts
    must be IDENTICAL (the tentpole's equivalence contract; the full
    matrices live in tests/test_vector_sim.py and
    tests/test_windowed_sim.py).  ``loop`` picks the candidate —
    ``vector`` (policy vectorization) or ``windowed`` (cross-replica
    event batching).  With ``spec_k`` the same contract covers
    speculative decoding — depth assignment, the acceptance draw and
    bonus-token emission must agree between the two loops, including the
    aggregated speculation counters."""
    from repro.sim import spec_counters
    results = {}
    for lp in ("reference", loop):
        cs = _scale_cluster(4, loop=lp, spec_k=spec_k)
        # pin rids: the spec acceptance draw is keyed on (rid, step), and
        # the process-global rid counter would otherwise hand the two
        # loops different draw sequences
        reqs = list(_pinned_trace(n, 600.0, 7))
        rep = replay_sim(cs, reqs, w_p=4.0)
        per_req = [(tuple(r.out_times), r.finish_time, r.preemptions)
                   for r in reqs]
        row = {k: v for k, v in rep.row().items()
               if k not in NONDETERMINISTIC_KEYS}
        if spec_k:
            row.update(spec_counters(cs))
            row["spec_depth_hist"] = {
                str(d): v for d, v in row["spec_depth_hist"].items()}
        results[lp] = (per_req, row)
    identical = results["reference"] == results[loop]
    assert identical, f"{loop} sim diverged from the reference loop" \
        + (" (spec on)" if spec_k else "")
    prefix = "" if loop == "vector" else f"{loop}-"
    name = (f"{prefix}equivalence-n{n}"
            + (f"-spec{spec_k}" if spec_k else ""))
    return {"name": "replay_scale", "preset": name,
            "n_requests": n, "identical_per_request": identical,
            **results[loop][1]}


def sharded_equivalence_row(n: int = 3000, workers: int = 2,
                            window: float = 0.5) -> dict:
    """Two gates on the sharded stale-view loop, one row.

    1. Partition-independence (exact): ``workers=0`` (in-process twin of
       the worker protocol) and ``workers=N`` (forked processes) must
       produce IDENTICAL per-request results, merged summaries and
       engine counters — routing sees boundary-frozen views either way,
       so process placement cannot leak into the physics.
    2. Stale-view divergence (quantified, not hidden): the same trace
       through the exact windowed loop, with the deltas recorded as
       ``stale_delta_*`` fields so the checked-in row documents how far
       window-delayed routing drifts from per-event routing."""
    from repro.sim import replay_sim_sharded, replay_sim_stream
    results = {}
    for w in (0, workers):
        rep, extras = replay_sim_sharded(
            lambda: _scale_cluster(4), _pinned_trace(n, 200.0, 7),
            workers=w, window=window, w_p=4.0, collect=True)
        per_req = sorted(
            (r.rid, tuple(r.out_times), r.finish_time, r.preemptions)
            for r in extras["finished"])
        row = {k: v for k, v in rep.row().items()
               if k not in NONDETERMINISTIC_KEYS}
        results[w] = (per_req, row, extras["counters"])
    identical = results[0] == results[workers]
    assert identical, (f"sharded replay diverged between workers=0 and "
                       f"workers={workers}")
    cs = _scale_cluster(4)
    exact = replay_sim_stream(cs, _pinned_trace(n, 200.0, 7), w_p=4.0)
    er = exact.row()
    row = results[workers][1]
    out = {"name": "replay_scale", "preset": f"sharded-equivalence-n{n}",
           "n_requests": n, "workers": workers, "window": window,
           "identical_across_workers": identical, **row}
    for k in ("ttft_p50", "ttft_p99", "tpot_p50", "slo", "tdg_ratio"):
        out[f"{k}_exact"] = er[k]
        out[f"stale_delta_{k}"] = round(row[k] - er[k], 6)
    return out


def replay_scale(fast: bool = True) -> list[dict]:
    tiered = run_tiered_preset()
    assert not tiered_gate_failures(tiered), tiered_gate_failures(tiered)
    disagg = run_disagg_preset()
    assert not disagg_gate_failures(disagg), disagg_gate_failures(disagg)
    spec = run_spec_preset()
    assert not spec_gate_failures(spec), spec_gate_failures(spec)
    rows = [scale_equivalence_row(),
            scale_equivalence_row(spec_k=SPEC_PRESET["spec_k"]),
            scale_equivalence_row(loop="windowed"),
            sharded_equivalence_row(),
            run_scale_preset("ci"), tiered, disagg, spec]
    if not fast:
        rows.append(run_scale_preset("full"))
        rows.append(run_sharded_preset("full"))
    write_scale_bench(rows)
    return rows


def _git_commit() -> str:
    import subprocess
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10,
                             cwd=root).stdout.strip()
        return out or "unknown"
    except OSError:
        return "unknown"


def write_scale_bench(rows: list[dict],
                      path: str = "BENCH_replay_scale.json") -> str:
    """Merge scale rows into the repo-root trajectory file.

    ``presets`` holds the latest full row per preset (a fast run updates
    ``ci`` without dropping ``full``).  ``trajectory`` is append-only
    perf history: one commit-keyed, timestamp-free entry per run
    recording each preset's wall time and replay speed, replacing only a
    prior entry for the SAME commit — so the file accumulates a
    commit-over-commit speed trace without churning on reruns."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, path)
    payload = {"schema": 1,
               "generated_by": "benchmarks/run.py --only replay_scale",
               "presets": {}, "trajectory": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
            if old.get("schema") == 1:
                payload["presets"].update(old.get("presets", {}))
                payload["trajectory"] = list(old.get("trajectory", []))
        except (OSError, ValueError):
            pass
    for r in rows:
        payload["presets"][r["preset"]] = {k: v for k, v in r.items()
                                           if k not in ("name", "preset")}
    entry = {"commit": _git_commit(),
             "rows": {r["preset"]: {
                 "wall_s": r["wall_s"],
                 "req_per_s": round(r["submitted"] / max(r["wall_s"],
                                                         1e-9), 1)}
                      for r in rows if "wall_s" in r}}
    if entry["rows"]:
        # same-commit rerun: merge row-by-row (a partial run must not
        # drop presets benched earlier at this commit)
        prev = next((e for e in payload["trajectory"]
                     if e.get("commit") == entry["commit"]), None)
        if prev is not None:
            entry["rows"] = {**prev.get("rows", {}), **entry["rows"]}
            payload["trajectory"].remove(prev)
        payload["trajectory"].append(entry)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    return path


def check_scale_row(row: dict, ref_path: str) -> list[str]:
    """Compare a fresh preset run against the checked-in trajectory file.

    Trace generation and the event loop are bit-deterministic, but the
    estimator fit goes through LAPACK least squares, whose last-ulp
    results vary across BLAS builds and can flip near-tie scheduling
    decisions — so metric comparison is tight-tolerance, not bitwise:
    counts (submitted/n) exact, ratio metrics within 0.02, completion
    counts within 0.5%.  Same-machine reruns match exactly; the bitwise
    per-request equivalence contract is enforced by
    ``scale_equivalence_row`` / tests/test_vector_sim.py."""
    try:
        with open(ref_path) as f:
            ref = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{ref_path}: unreadable ({e})"]
    want = ref.get("presets", {}).get(row["preset"])
    if want is None:
        return [f"{ref_path}: no entry for preset {row['preset']!r}"]
    errors = []
    for k, v in row.items():
        if k in NONDETERMINISTIC_KEYS or k in ("name", "preset"):
            continue
        w = want.get(k)
        if k in ("submitted", "n", "n_requests", "rate", "seed",
                 "replicas"):
            ok = w == v
        elif k in ("completed", "rejected"):
            ok = w is not None and abs(w - v) <= max(5, 0.005 * row["n"])
        elif isinstance(v, float) and isinstance(w, (int, float)):
            ok = abs(w - v) <= 0.02 * max(1.0, abs(v))
        else:
            ok = w == v
        if not ok:
            errors.append(f"{row['preset']}.{k}: measured {v!r} vs "
                          f"checked-in {w!r} (outside tolerance)")
    return errors


def main(argv=None) -> int:
    import argparse
    import sys
    ap = argparse.ArgumentParser(
        description="scale replay presets (vectorized ClusterSim)")
    ap.add_argument("--preset", choices=sorted(SCALE_PRESETS),
                    default="ci")
    ap.add_argument("--budget", type=float, default=None,
                    help="fail if the replay exceeds this wall-clock "
                         "budget in seconds (CI sim-scale gate)")
    ap.add_argument("--check", default=None,
                    help="BENCH_replay_scale.json to compare the "
                         "deterministic metrics against")
    ap.add_argument("--loop", choices=("reference", "vector", "windowed"),
                    default="windowed",
                    help="event loop for the single-process preset run")
    ap.add_argument("--workers", type=int, default=0,
                    help="replay the preset through the sharded "
                         "multiprocess loop with this many worker "
                         "processes (0 = single-process --loop run)")
    ap.add_argument("--window", type=float, default=None,
                    help="heartbeat window for --workers (default: the "
                         "preset's, else the cluster heartbeat interval)")
    ap.add_argument("--equivalence", action="store_true",
                    help="also run the reference-vs-vectorized and "
                         "reference-vs-windowed per-request equivalence "
                         "cross-checks")
    ap.add_argument("--sharded-equivalence", action="store_true",
                    help="also gate workers=0 vs forked-worker identity "
                         "and record stale-view deltas vs the exact loop")
    ap.add_argument("--bench-out", default=None,
                    help="merge this run's rows (including a commit-"
                         "keyed trajectory entry) into the given "
                         "BENCH_replay_scale.json")
    ap.add_argument("--tiered", action="store_true",
                    help="also run the tiered-KV thrash replay and gate "
                         "tiered > HBM-only on TTFT p50 + prefill tokens")
    ap.add_argument("--disagg", action="store_true",
                    help="also run the coloc-vs-disagg smoke and gate "
                         "the handoff-accounting invariants (reserved == "
                         "adopted, every reservation settled)")
    ap.add_argument("--spec", action="store_true",
                    help="also run the speculative-decoding replay and "
                         "gate accounting conservation, bounded depth, "
                         "reference-vs-vectorized equivalence with spec "
                         "on, and high-priority decode tokens/s "
                         "improvement")
    args = ap.parse_args(argv)

    failures = []
    bench_rows = []
    if args.equivalence:
        for loop in ("vector", "windowed"):
            row = scale_equivalence_row(loop=loop)
            print(json.dumps(row, indent=1))
            bench_rows.append(row)
    if args.sharded_equivalence:
        srow = sharded_equivalence_row()
        print(json.dumps(srow, indent=1))
        bench_rows.append(srow)
        if args.check:
            failures += check_scale_row(srow, args.check)
    if args.spec:
        erow = scale_equivalence_row(spec_k=SPEC_PRESET["spec_k"])
        print(json.dumps(erow, indent=1))
        bench_rows.append(erow)
        specrow = run_spec_preset()
        print(json.dumps(specrow, indent=1))
        bench_rows.append(specrow)
        failures += spec_gate_failures(specrow)
        if args.check:
            failures += check_scale_row(specrow, args.check)
    if args.tiered:
        trow = run_tiered_preset()
        print(json.dumps(trow, indent=1))
        bench_rows.append(trow)
        failures += tiered_gate_failures(trow)
        if args.check:
            failures += check_scale_row(trow, args.check)
    if args.disagg:
        drow = run_disagg_preset()
        print(json.dumps(drow, indent=1))
        bench_rows.append(drow)
        failures += disagg_gate_failures(drow)
        if args.check:
            failures += check_scale_row(drow, args.check)
    if args.workers:
        row = run_sharded_preset(args.preset, args.workers, args.window)
    else:
        row = run_scale_preset(args.preset, loop=args.loop)
    print(json.dumps(row, indent=1))
    bench_rows.append(row)
    if args.budget is not None and row["wall_s"] > args.budget:
        failures.append(f"wall {row['wall_s']}s > budget {args.budget}s")
    if args.check:
        failures += check_scale_row(row, args.check)
    if args.bench_out and not failures:
        print(f"wrote {write_scale_bench(bench_rows, args.bench_out)}")
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    if failures:
        return 1
    print(f"OK: preset {args.preset} in {row['wall_s']}s"
          + (f" (budget {args.budget}s)" if args.budget else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
