"""Service-tier replay benchmark: the identical request trace replayed
through the cluster simulator under each global router, reporting the
per-priority gain / SLO-attainment rows the async frontend reports live.
This is the offline counterpart of ``examples/serve_cluster.py``."""
from __future__ import annotations

from repro.core import (EngineConfig, GoRouting, MinLoad, RoundRobin,
                        RouterConfig, make_policy)
from repro.sim import ClusterConfig, ClusterSim, replay_sim
from repro.sim.workloads import WORKLOADS

from .common import get_exec


def replay_router_sweep(fast: bool = True) -> list[dict]:
    ex, est, _ = get_exec()
    datasets = ["sharegpt"] if fast else ["sharegpt", "azure", "industrial"]
    rates = [40] if fast else [30, 60, 90]
    routers = [
        ("gorouting", lambda: GoRouting(est, RouterConfig(pd_mode="coloc"))),
        ("min_load", lambda: MinLoad(est)),
        ("round_robin", lambda: RoundRobin()),
    ]
    rows = []
    for ds in datasets:
        for rate in rates:
            for rname, mk in routers:
                reqs = WORKLOADS[ds](rate=rate, duration=6, seed=7)
                cs = ClusterSim(lambda: make_policy("slidebatching"), mk(),
                                ex, est, EngineConfig(w_p=4.0),
                                ClusterConfig(pd_mode="coloc", n_prefill=4))
                rep = replay_sim(cs, reqs, w_p=4.0)
                rows.append({"name": "replay_router_sweep", "dataset": ds,
                             "rate": rate, "router": rname, **rep.row()})
    return rows
